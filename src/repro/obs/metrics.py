"""Named-metric registry and wall-clock self-profiler.

A deliberately small, stdlib-only metrics facility.  Components
(:class:`~repro.core.des.TieredMemorySim`, the serving
``TransferQueue``/``ServingEngine``, ``ControlLoop``, the sweep pool)
register named counters/gauges/histograms against the *process-default*
registry; ``run_scenario(..., profile=True)`` snapshots it into
``ResultTable.meta["metrics"]``.  Registries are per-process: sweep
shards running in a process pool each accumulate their own registry,
so pool-run metrics reflect only the parent process (documented in
``docs/observability.md``).

:class:`PhaseProfiler` is the one place in the repo allowed to touch
``time.perf_counter`` for simulation work — sim packages are screened
for wall-clock calls by the repo lint pass, so the DES and planner call
``profiler.clock()`` / ``profiler.add()`` instead and stay deterministic
when no profiler is attached.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterator

from repro.obs.histogram import LatencyHistogram

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "PhaseProfiler",
    "default_registry",
]


class Counter:
    """Monotonic named counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value:g})"


class Gauge:
    """Last-write-wins named gauge."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value:g})"


class MetricsRegistry:
    """Accessor-on-first-use registry of named metrics."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, LatencyHistogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> LatencyHistogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = LatencyHistogram()
        return h

    def snapshot(self) -> dict:
        """JSON-able view: counters/gauges verbatim, histograms summarized."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: {
                    "n": h.n,
                    "mean": h.mean(),
                    "p50": h.percentile(0.5),
                    "p95": h.percentile(0.95),
                    "p99": h.percentile(0.99),
                }
                for k, h in sorted(self._histograms.items())
            },
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry components register against."""
    return _DEFAULT


class PhaseProfiler:
    """Wall-clock phase accounting for sim self-profiling.

    Phases are additive: ``add("window_pass", dt)`` accumulates across
    windows; ``window_pass`` time is a subset of ``event_loop`` time.
    The profiler is attached explicitly (``SimJob.profile=True``) so an
    unprofiled simulation performs no clock reads at all.
    """

    __slots__ = ("seconds", "calls", "clock")

    def __init__(self) -> None:
        self.seconds: Dict[str, float] = {}
        self.calls: Dict[str, int] = {}
        self.clock = time.perf_counter

    def add(self, phase: str, dt: float) -> None:
        self.seconds[phase] = self.seconds.get(phase, 0.0) + dt
        self.calls[phase] = self.calls.get(phase, 0) + 1

    @contextmanager
    def phase(self, name: str) -> Iterator[None]:
        t0 = self.clock()
        try:
            yield
        finally:
            self.add(name, self.clock() - t0)

    def snapshot(self) -> dict:
        return {
            "phases": {
                k: {"seconds": round(v, 6), "calls": self.calls.get(k, 0)}
                for k, v in sorted(self.seconds.items())
            }
        }

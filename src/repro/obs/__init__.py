"""repro.obs — the observability layer: tracing, histograms, metrics.

Three cooperating pieces, all opt-in and all zero-cost when disabled
(the hot paths pay one integer/pointer compare per request transition,
and the tracing-off DES stays bit-identical to every pinned golden):

* :mod:`repro.obs.trace` — sampled request-lifecycle tracing: a 1-in-N
  deterministic sampler (keyed on ToR insert order, no RNG draws) records
  each traced request's span chain — issue → ToR entry → per-hop port
  queue/service → device queue/service → return flight — from the DES
  and the serving :class:`~repro.core.offload.TransferQueue`, exportable
  as Chrome trace-event JSON (``benchmarks/run.py --perfetto NAME``).
* :mod:`repro.obs.histogram` — mergeable log-bucketed latency histograms
  (HDR-style: 16 sub-buckets per power-of-two octave, globally fixed
  boundaries) as a first-class metric type alongside the bounded
  reservoir: per workload, per tier, per window, with *exact* merge
  across windows, cells, and process-pool shards.
* :mod:`repro.obs.metrics` — a small named-metric registry (counters /
  gauges / histograms registered by the DES, TransferQueue, serving
  engine, ControlLoop, and sweep pool) plus a wall-clock
  :class:`~repro.obs.metrics.PhaseProfiler` for sim setup / event-loop /
  window-pass self-profiling.

See ``docs/observability.md`` for the span schema, bucket layout, merge
semantics, and CLI surface.
"""

from repro.obs.histogram import LatencyHistogram
from repro.obs.metrics import MetricsRegistry, PhaseProfiler, default_registry
from repro.obs.trace import (
    RequestTracer,
    TraceConfig,
    TransferTracer,
    to_chrome,
)

__all__ = [
    "LatencyHistogram",
    "MetricsRegistry",
    "PhaseProfiler",
    "RequestTracer",
    "TraceConfig",
    "TransferTracer",
    "default_registry",
    "to_chrome",
]

"""Sampled request-lifecycle tracing with Chrome trace-event export.

The DES samples every Nth ToR admission **deterministically** — the
sampler is keyed on the running ``tor_inserts`` counter, so it draws no
random numbers and the tracing-off simulation stays bit-identical to
every pinned golden.  A traced request accumulates raw transition
events (station enter / service done / backpressure stall) while live;
at retire the chain is *finalized* into a span list that contiguously
partitions ``[t_tor, t_retire]``:

``irq_wait`` ``[t_issue, t_tor]`` (IRQ staging, outside the ToR), then
``<station>:queue`` / ``<station>:service`` pairs per hop port and for
the final device (or LLC), with ``<station>:stall`` spans wherever the
request was held by a full downstream port, and a closing
``flight:<tier>`` span for the pipelined return flight.  Because the
spans partition the interval, queue wait + service + stalls + flight
exactly equals the ToR residency — the conservation law the property
tests pin.

:func:`to_chrome` converts finalized records into Chrome trace-event
JSON (``"X"`` complete events, microsecond timestamps) loadable in
Perfetto / ``chrome://tracing``: one *process* per workload, one
*thread* per traced request, so unfair queuing and backpressure
cascades are directly visible as widened queue/stall spans.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

__all__ = ["TraceConfig", "RequestTracer", "TransferTracer", "to_chrome"]

# Raw event kinds accumulated while a request is live.
_ENTER = 0  # entered a station (hop port or device/LLC): service may queue
_DONE = 1  # station service completed (carries the service time)
_STALL = 2  # held by a full downstream port (ends at the next _ENTER)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Sampling policy for the request tracer.

    ``sample_every``: trace the 1st, (N+1)th, (2N+1)th ... ToR admission.
    ``limit``: hard cap on traced requests per sim (bounds memory and
    export size); admissions past the cap are counted as dropped.
    """

    sample_every: int = 64
    limit: int = 512

    def __post_init__(self) -> None:
        if self.sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        if self.limit < 1:
            raise ValueError("limit must be >= 1")


class RequestTracer:
    """Span-chain recorder for sampled DES requests.

    The DES owns the sampling decision (it has ``tor_inserts`` in a
    local); this class only stores events for rids it was told to admit.
    ``live`` maps rid -> mutable record while the request is in flight;
    ``done`` holds finalized span records.  Rid recycling is safe: a rid
    is only in ``live`` between admit and retire, and the DES re-checks
    membership before every hook call.
    """

    __slots__ = ("config", "live", "done", "dropped", "_wl_names", "_st_names", "_tier_names")

    def __init__(
        self,
        config: TraceConfig,
        workload_names: Sequence[str],
        station_names: Sequence[str],
        tier_names: Sequence[str],
    ) -> None:
        self.config = config
        self.live: Dict[int, list] = {}
        self.done: List[dict] = []
        self.dropped = 0
        self._wl_names = list(workload_names)
        self._st_names = list(station_names)
        self._tier_names = list(tier_names)

    # -- hooks (called by the DES, guarded by membership in ``live``) --
    def admit(self, rid: int, wi: int, tier: int, t_issue: float, now: float) -> bool:
        """Start tracing ``rid``; False when the limit already dropped it."""
        if len(self.done) + len(self.live) >= self.config.limit:
            self.dropped += 1
            return False
        # [wi, tier, t_issue, t_tor, events]
        self.live[rid] = [wi, tier, t_issue, now, []]
        return True

    def station_enter(self, rid: int, station: int, now: float) -> None:
        rec = self.live.get(rid)
        if rec is not None:
            rec[4].append((_ENTER, station, now, 0.0))

    def service_done(self, rid: int, station: int, now: float, service: float) -> None:
        rec = self.live.get(rid)
        if rec is not None:
            rec[4].append((_DONE, station, now, service))

    def stall(self, rid: int, station: int, now: float) -> None:
        rec = self.live.get(rid)
        if rec is not None:
            rec[4].append((_STALL, station, now, 0.0))

    def retire(self, rid: int, now: float) -> None:
        rec = self.live.pop(rid, None)
        if rec is not None:
            self.done.append(self._finalize(rec, now))

    # -- finalization --------------------------------------------------
    def _finalize(self, rec: list, t_retire: float) -> dict:
        wi, tier, t_issue, t_tor, events = rec
        names = self._st_names
        spans: List[dict] = []
        if t_tor > t_issue:
            spans.append(
                {
                    "name": "irq_wait",
                    "station": "irq",
                    "kind": "irq",
                    "t0": t_issue,
                    "t1": t_tor,
                }
            )
        enter_t = t_tor
        stall_of: Optional[int] = None
        last_done = t_tor
        for kind, st, t, svc in events:
            stname = names[st] if 0 <= st < len(names) else f"st{st}"
            if kind == _ENTER:
                if stall_of is not None:
                    # stall span runs from the upstream done (== stall
                    # event time) to this enter.
                    sname = names[stall_of] if 0 <= stall_of < len(names) else f"st{stall_of}"
                    if t > enter_t:
                        spans.append(
                            {
                                "name": f"{sname}:stall",
                                "station": sname,
                                "kind": "stall",
                                "t0": enter_t,
                                "t1": t,
                            }
                        )
                    stall_of = None
                enter_t = t
            elif kind == _DONE:
                start = t - svc
                if start < enter_t:
                    start = enter_t  # float slack: (enter + svc) - svc != enter
                if start > enter_t:
                    spans.append(
                        {
                            "name": f"{stname}:queue",
                            "station": stname,
                            "kind": "queue",
                            "t0": enter_t,
                            "t1": start,
                        }
                    )
                spans.append(
                    {
                        "name": f"{stname}:service",
                        "station": stname,
                        "kind": "service",
                        "t0": start,
                        "t1": t,
                    }
                )
                enter_t = t
                last_done = t
            else:  # _STALL — span materialises at the next _ENTER
                stall_of = st
                enter_t = t
        tname = (
            self._tier_names[tier] if 0 <= tier < len(self._tier_names) else f"t{tier}"
        )
        if t_retire > last_done:
            spans.append(
                {
                    "name": f"flight:{tname}",
                    "station": tname,
                    "kind": "flight",
                    "t0": last_done,
                    "t1": t_retire,
                }
            )
        wl = self._wl_names[wi] if 0 <= wi < len(self._wl_names) else f"w{wi}"
        return {
            "workload": wl,
            "tier": tname,
            "t_issue": t_issue,
            "t_tor": t_tor,
            "t_retire": t_retire,
            "spans": spans,
        }

    # -- export --------------------------------------------------------
    def run_payload(self) -> dict:
        """The ``SimResult.trace`` payload (in-flight traces are dropped)."""
        return {
            "sample_every": self.config.sample_every,
            "limit": self.config.limit,
            "n_traced": len(self.done),
            "n_in_flight": len(self.live),
            "n_dropped": self.dropped,
            "requests": list(self.done),
        }


class TransferTracer:
    """Chunk-level span sampler for the serving ``TransferQueue``.

    Each sampled migration chunk yields a record shaped like a DES
    request record (so :func:`to_chrome` renders both): queue span
    ``[enqueue, service_start]`` and service span
    ``[service_start, done]`` on the ``offload:<tier>`` track.
    """

    __slots__ = ("every", "limit", "count", "records")

    def __init__(self, sample_every: int = 64, limit: int = 512) -> None:
        if sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        self.every = sample_every
        self.limit = limit
        self.count = 0
        self.records: List[dict] = []

    def on_chunk(self, tier: str, enq: float, done: float, service: float) -> None:
        self.count += 1
        if (self.count - 1) % self.every != 0 or len(self.records) >= self.limit:
            return
        start = done - service
        if start < enq:
            start = enq
        spans = []
        if start > enq:
            spans.append(
                {
                    "name": f"offload:{tier}:queue",
                    "station": tier,
                    "kind": "queue",
                    "t0": enq,
                    "t1": start,
                }
            )
        spans.append(
            {
                "name": f"offload:{tier}:service",
                "station": tier,
                "kind": "service",
                "t0": start,
                "t1": done,
            }
        )
        self.records.append(
            {
                "workload": f"offload:{tier}",
                "tier": tier,
                "t_issue": enq,
                "t_tor": enq,
                "t_retire": done,
                "spans": spans,
            }
        )


def to_chrome(records: Sequence[dict]) -> dict:
    """Finalized span records -> Chrome trace-event JSON.

    One trace *process* per workload (named via ``process_name``
    metadata), one *thread* per traced request.  Timestamps are emitted
    in microseconds (trace-event convention); ``displayTimeUnit: ns``
    keeps Perfetto's cursor readout in nanoseconds.
    """
    pids: Dict[str, int] = {}
    events: List[dict] = []
    for i, rec in enumerate(records):
        wl = rec["workload"]
        pid = pids.setdefault(wl, len(pids) + 1)
        tid = i + 1
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": f"req{i} [{rec['tier']}]"},
            }
        )
        for sp in rec["spans"]:
            events.append(
                {
                    "name": sp["name"],
                    "cat": sp["kind"],
                    "ph": "X",
                    "ts": sp["t0"] / 1000.0,
                    "dur": (sp["t1"] - sp["t0"]) / 1000.0,
                    "pid": pid,
                    "tid": tid,
                    "args": {"station": sp["station"], "tier": rec["tier"]},
                }
            )
    meta = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "tid": 0,
            "args": {"name": wl},
        }
        for wl, pid in sorted(pids.items(), key=lambda kv: kv[1])
    ]
    return {"traceEvents": meta + events, "displayTimeUnit": "ns"}

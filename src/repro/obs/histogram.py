"""Mergeable log-bucketed latency histograms (HDR-style).

The bucket layout is *globally fixed* — it does not depend on the data —
which is what makes the merge exact: two histograms recorded anywhere
(different windows, different grid cells, different process-pool shards)
always share bucket boundaries, so merging is per-index count addition
and ``merge(h(a), h(b)) == h(a + b)`` bucket-for-bucket.

Layout: each power-of-two octave ``[2^(e-1), 2^e)`` is split into 16
linear sub-buckets.  For a value ``v > 0`` with ``m, e = math.frexp(v)``
(``m in [0.5, 1)``), the sub-bucket is ``int((m - 0.5) * 32)`` (0..15)
and the global index is ``e * 16 + sub``.  Bucket ``idx`` therefore
covers ``[ldexp(1 + s/16, e-1), ldexp(1 + (s+1)/16, e-1))`` with
``e, s = divmod(idx, 16)``.  The relative width of a bucket is
``1/(16 + s) <= 1/16``, so any percentile read back from the histogram
is within **6.25 %** of the true order statistic (the documented
tolerance vs. the reservoir is 7 % to absorb interpolation slack).

Counts may be floats: the batched fluid lane synthesizes analytic
histograms from per-window station waits via :meth:`record_weighted`
with fractional request counts.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Optional, Tuple

__all__ = ["LatencyHistogram", "bucket_bounds", "bucket_index"]

_SUBBUCKETS = 16


def bucket_index(v: float) -> int:
    """Global bucket index for a positive value (see module docstring)."""
    m, e = math.frexp(v)
    return e * _SUBBUCKETS + int((m - 0.5) * 32)


def bucket_bounds(idx: int) -> Tuple[float, float]:
    """``[lo, hi)`` covered by global bucket ``idx``."""
    e, s = divmod(idx, _SUBBUCKETS)
    lo = math.ldexp(1.0 + s / 16.0, e - 1)
    hi = math.ldexp(1.0 + (s + 1) / 16.0, e - 1)
    return lo, hi


class LatencyHistogram:
    """Sparse log-bucketed histogram with exact merge.

    Equality compares the exactly-mergeable state — ``n``, ``zero``, the
    bucket counts, and the min/max water marks.  The running ``total``
    is a float accumulation whose value depends on summation order, so
    it is deliberately excluded from ``__eq__`` (it still merges
    additively and is what :meth:`mean` reads).
    """

    __slots__ = ("counts", "n", "zero", "total", "vmin", "vmax")

    def __init__(self) -> None:
        self.counts: Dict[int, float] = {}
        self.n: float = 0.0
        self.zero: float = 0.0  # values <= 0 (defensive; latencies are > 0)
        self.total: float = 0.0
        self.vmin: float = math.inf
        self.vmax: float = -math.inf

    # -- recording ----------------------------------------------------
    def record(self, v: float) -> None:
        self.record_weighted(v, 1.0)

    def record_weighted(self, v: float, count: float) -> None:
        """Record ``count`` observations of value ``v`` (count may be float)."""
        if count <= 0.0:
            return
        self.n += count
        self.total += v * count
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            self.zero += count
            return
        idx = bucket_index(v)
        self.counts[idx] = self.counts.get(idx, 0.0) + count

    @classmethod
    def from_samples(cls, values: Iterable[float]) -> "LatencyHistogram":
        """Histogram of a sample vector (numpy fast path for long inputs)."""
        h = cls()
        vals = values if isinstance(values, list) else list(values)
        if len(vals) >= 512:
            try:
                import numpy as np
            except ImportError:  # pragma: no cover - numpy is a core dep
                np = None
            if np is not None:
                arr = np.asarray(vals, dtype=float)
                pos = arr[arr > 0.0]
                nz = arr.size - pos.size
                m, e = np.frexp(pos)
                idx = e.astype(np.int64) * _SUBBUCKETS + ((m - 0.5) * 32).astype(
                    np.int64
                )
                uniq, cnt = np.unique(idx, return_counts=True)
                h.counts = {int(i): float(c) for i, c in zip(uniq, cnt)}
                h.n = float(arr.size)
                h.zero = float(nz)
                h.total = float(math.fsum(vals))
                h.vmin = float(arr.min())
                h.vmax = float(arr.max())
                return h
        for v in vals:
            h.record(float(v))
        return h

    # -- merge --------------------------------------------------------
    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Exact merge: a new histogram with per-bucket counts added."""
        out = LatencyHistogram()
        out.counts = dict(self.counts)
        for idx, c in other.counts.items():
            out.counts[idx] = out.counts.get(idx, 0.0) + c
        out.n = self.n + other.n
        out.zero = self.zero + other.zero
        out.total = self.total + other.total
        out.vmin = min(self.vmin, other.vmin)
        out.vmax = max(self.vmax, other.vmax)
        return out

    # -- reading ------------------------------------------------------
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Approximate order statistic (rank ``q * (n - 1)``).

        Walks the sorted buckets to the one containing the rank and
        interpolates linearly inside it; the result is clamped to the
        observed ``[vmin, vmax]``.  Max relative error is the bucket
        relative width, <= 1/16.

        An empty histogram returns NaN: a zero-completion window (open-
        loop overload can starve one entirely) has no order statistics,
        and 0.0 would read as an impossibly good latency in an SLO sweep
        — NaN propagates honestly and never passes a budget comparison.
        """
        if not self.n:
            return float("nan")
        r = min(max(q, 0.0), 1.0) * (self.n - 1.0)
        if r < self.zero:
            return min(0.0, self.vmin)
        cum = self.zero
        for idx in sorted(self.counts):
            c = self.counts[idx]
            if r < cum + c:
                lo, hi = bucket_bounds(idx)
                pos = (r - cum + 0.5) / c
                v = lo + pos * (hi - lo)
                return min(max(v, self.vmin), self.vmax)
            cum += c
        return self.vmax

    # -- (de)serialisation --------------------------------------------
    def to_jsonable(self) -> dict:
        return {
            "scheme": "log16",
            "n": self.n,
            "zero": self.zero,
            "total": self.total,
            "min": self.vmin if self.n else None,
            "max": self.vmax if self.n else None,
            "counts": {str(idx): c for idx, c in sorted(self.counts.items())},
        }

    @classmethod
    def from_jsonable(cls, blob: Optional[dict]) -> "LatencyHistogram":
        h = cls()
        if not blob:
            return h
        h.counts = {int(k): float(v) for k, v in blob.get("counts", {}).items()}
        h.n = float(blob.get("n", 0.0))
        h.zero = float(blob.get("zero", 0.0))
        h.total = float(blob.get("total", 0.0))
        h.vmin = blob["min"] if blob.get("min") is not None else math.inf
        h.vmax = blob["max"] if blob.get("max") is not None else -math.inf
        return h

    # -- comparison / repr --------------------------------------------
    def __eq__(self, other: object) -> bool:
        if not isinstance(other, LatencyHistogram):
            return NotImplemented
        return (
            self.n == other.n
            and self.zero == other.zero
            and self.counts == other.counts
            and (self.vmin == other.vmin or (not self.n and not other.n))
            and (self.vmax == other.vmax or (not self.n and not other.n))
        )

    def __hash__(self) -> int:  # pragma: no cover - dict use only
        return id(self)

    def __repr__(self) -> str:
        if not self.n:
            return "LatencyHistogram(empty)"
        return (
            f"LatencyHistogram(n={self.n:g}, mean={self.mean():.1f}, "
            f"p50={self.percentile(0.5):.1f}, p99={self.percentile(0.99):.1f})"
        )


def merge_all(hists: Iterable[LatencyHistogram]) -> LatencyHistogram:
    """Fold :meth:`LatencyHistogram.merge` over an iterable."""
    out = LatencyHistogram()
    for h in hists:
        out = out.merge(h)
    return out

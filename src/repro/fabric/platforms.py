"""Fabric-carrying platform factories (registered into ``PLATFORMS``).

These wrap the canonical Platform A with a routed topology: the degenerate
direct-attach fabric (a pure-refactoring sanity platform — bit-identical
simulation to plain ``A``), a single-switch port in front of the CXL tier,
and the two-host spine-leaf fabric where cross-host congestion lives.
Importing :mod:`repro.fabric` registers ``"A-direct"`` and ``"A-spine"``
into :data:`repro.core.device_model.PLATFORMS` so the benchmark CLI can
name them.
"""

from __future__ import annotations

import dataclasses

from repro.core.device_model import PLATFORMS, PlatformModel, platform_a
from repro.fabric.topology import direct, single_switch, spine_leaf

__all__ = [
    "direct_platform",
    "single_switch_platform",
    "spine_leaf_platform",
]


def direct_platform(base: str = "A") -> PlatformModel:
    """``PLATFORMS[base]`` carrying the degenerate direct-attach fabric:
    zero hop stations, so it simulates bit-identically to ``base`` — the
    refactoring-sanity platform the one-hop identity tests pin."""
    pm = PLATFORMS[base]
    return dataclasses.replace(
        pm,
        name=f"{pm.name}-direct",
        fabric=direct(pm.tier_names),
    )


def single_switch_platform(
    *,
    port_slots: int = 8,
    port_service_ns: float = 36.0,
    port_queue: int = 1024,
) -> PlatformModel:
    """Platform A with its CXL tier behind one port-bearing switch link
    (``sw0-cxl``): the minimal real fabric, used by the port-queue-vs-ToR
    crossover scenario.  ``port_queue`` is the port's entry limit in
    cachelines (compare against ``tor_entries=2048``)."""
    base = platform_a()
    topo = single_switch(
        base.tier_names, routed=("cxl",),
        port_slots=port_slots, service_ns=port_service_ns,
        queue_entries=port_queue,
    )
    return dataclasses.replace(
        base,
        name=f"{base.name}-sw{port_slots}p{port_queue}q",
        fabric=topo,
    )


def spine_leaf_platform(
    *,
    n_hosts: int = 2,
    uplink_slots=16,
    uplink_service_ns=18.0,
    uplink_queue=1024,
    spine_slots: int = 8,
    spine_service_ns: float = 36.0,
    spine_queue: int = 1024,
) -> PlatformModel:
    """Platform A behind a two-host spine-leaf fabric: each host's CXL
    requests traverse ``uplink{i}`` then the *shared* ``spine-cxl``
    downlink, while DDR stays direct-attached per host.  Uplink parameters
    accept a scalar or a per-host sequence (asymmetric uplinks for the
    per-edge MIKU fairness scenario).  Queue limits are in cachelines."""
    base = platform_a()
    topo = spine_leaf(
        base.tier_names, routed=("cxl",), n_hosts=n_hosts,
        uplink_slots=uplink_slots, uplink_service_ns=uplink_service_ns,
        uplink_queue=uplink_queue,
        spine_slots=spine_slots, spine_service_ns=spine_service_ns,
        spine_queue=spine_queue,
    )
    return dataclasses.replace(
        base,
        name=f"{base.name}-spine{spine_slots}p{spine_queue}q",
        fabric=topo,
    )


PLATFORMS.setdefault("A-direct", direct_platform())
PLATFORMS.setdefault("A-spine", spine_leaf_platform())

"""Fabric topology graphs: hosts, switches, devices, directed port links.

A :class:`FabricTopology` is a small DAG describing how memory tiers hang
off hosts.  Nodes are **hosts** (where workload cores issue from),
**switches** (interior fan-in/fan-out points), and **devices** (one per
memory-tier name).  Directed :class:`Link` edges connect them; a link is
either *transparent* (pure attachment — wires with no modelled port) or
*port-bearing*, in which case it carries its own service rate, server
count, and a ToR-style queue-entry limit, and the DES materializes it as a
hop station on every route that crosses it.

The canonical flat platforms are the degenerate case: :func:`direct`
builds an all-transparent topology, every route has zero hop stations,
and the simulator's fabric machinery stays fully dormant — simulation
event chains are bit-identical to a fabric-less platform by construction.

Validation happens eagerly at construction: unknown endpoints, cycles,
tiers unreachable from a host, and zero-capacity ports (a link that names
a port but gives it no slots/queue/service) all raise
:class:`TopologyError` with the offending names in the message.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from repro.core.device_model import UnknownTierError

__all__ = [
    "Link",
    "FabricTopology",
    "TopologyError",
    "direct",
    "single_switch",
    "spine_leaf",
]


class TopologyError(ValueError):
    """A fabric topology failed structural validation (cycle, unreachable
    tier, dangling endpoint, duplicate name, or zero-capacity port)."""


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed edge ``src -> dst`` of the fabric graph.

    ``port_slots == 0`` (the default) declares a *transparent* link: pure
    attachment, no modelled port, no hop station.  A *port-bearing* link
    sets all three of ``port_slots`` (parallel servers at the port),
    ``service_ns`` (per-cacheline service time — peak port bandwidth is
    ``port_slots * 64 / service_ns`` GB/s), and ``queue_entries`` (the
    port's ToR-style entry limit in cachelines; a full port exerts
    backpressure on upstream hops).  Mixing — some of the three set, some
    zero — is a :class:`TopologyError` (a "zero-capacity port").
    """

    name: str
    src: str
    dst: str
    port_slots: int = 0
    service_ns: float = 0.0
    queue_entries: int = 0

    @property
    def is_transparent(self) -> bool:
        """True when this link is pure attachment (no hop station)."""
        return (
            self.port_slots == 0
            and self.queue_entries == 0
            and self.service_ns == 0.0
        )


@dataclasses.dataclass(frozen=True)
class FabricTopology:
    """A validated routed fabric: hosts, switches, devices, directed links.

    ``devices`` are memory-tier names (they must cover every tier of the
    platform the topology is attached to).  Construction validates the
    graph (see module docstring) and eagerly resolves one :class:`Route`
    per ``(host, device)`` pair — shortest path, ties broken by link
    declaration order — so :meth:`route` is a dict lookup at sim-build
    time.
    """

    hosts: Tuple[str, ...]
    devices: Tuple[str, ...]
    switches: Tuple[str, ...] = ()
    links: Tuple[Link, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "hosts", tuple(self.hosts))
        object.__setattr__(self, "devices", tuple(self.devices))
        object.__setattr__(self, "switches", tuple(self.switches))
        object.__setattr__(self, "links", tuple(self.links))
        self._validate()
        # Frozen dataclass: cache derived tables via object.__setattr__
        # (eq/hash/pickle see only the declared fields, like PlatformModel).
        from repro.fabric.routing import resolve_routes

        object.__setattr__(
            self,
            "_station_links",
            tuple(l for l in self.links if not l.is_transparent),
        )
        object.__setattr__(self, "_routes", resolve_routes(self))

    # -- validation -----------------------------------------------------------

    def _validate(self) -> None:
        nodes = self.hosts + self.switches + self.devices
        if len(set(nodes)) != len(nodes):
            raise TopologyError(f"duplicate node names in fabric: {nodes}")
        if not self.hosts:
            raise TopologyError("fabric topology declares no hosts")
        if not self.devices:
            raise TopologyError("fabric topology declares no devices")
        node_set = set(nodes)
        seen_links = set()
        for l in self.links:
            if l.name in seen_links:
                raise TopologyError(f"duplicate link name {l.name!r}")
            seen_links.add(l.name)
            for end in (l.src, l.dst):
                if end not in node_set:
                    raise TopologyError(
                        f"link {l.name!r} references unknown node {end!r}"
                    )
            if l.src in self.devices:
                raise TopologyError(
                    f"link {l.name!r} leaves device node {l.src!r}; "
                    "devices are sinks"
                )
            if l.dst in self.hosts:
                raise TopologyError(
                    f"link {l.name!r} enters host node {l.dst!r}; "
                    "hosts are sources"
                )
            if not l.is_transparent and (
                l.port_slots <= 0 or l.queue_entries <= 0
                or l.service_ns <= 0.0
            ):
                raise TopologyError(
                    f"link {l.name!r} declares a zero-capacity port "
                    f"(port_slots={l.port_slots}, "
                    f"queue_entries={l.queue_entries}, "
                    f"service_ns={l.service_ns}); a port-bearing link "
                    "needs all three positive, a transparent link all "
                    "three zero"
                )
        self._check_acyclic()
        self._check_reachable()

    def _adjacency(self) -> Dict[str, list]:
        adj: Dict[str, list] = {}
        for l in self.links:  # declaration order == tie-break order
            adj.setdefault(l.src, []).append(l)
        return adj

    def _check_acyclic(self) -> None:
        # Iterative DFS three-coloring over the directed graph; any back
        # edge is a cycle (backpressure chains must terminate at devices).
        adj = self._adjacency()
        color: Dict[str, int] = {}  # 1 = on stack, 2 = done
        for root in self.hosts + self.switches:
            if color.get(root):
                continue
            stack = [(root, iter(adj.get(root, ())))]
            color[root] = 1
            while stack:
                node, it = stack[-1]
                for link in it:
                    c = color.get(link.dst)
                    if c == 1:
                        raise TopologyError(
                            f"fabric topology has a cycle through link "
                            f"{link.name!r} ({link.src!r} -> {link.dst!r})"
                        )
                    if c is None:
                        color[link.dst] = 1
                        stack.append(
                            (link.dst, iter(adj.get(link.dst, ())))
                        )
                        break
                else:
                    color[node] = 2
                    stack.pop()

    def _check_reachable(self) -> None:
        adj = self._adjacency()
        for host in self.hosts:
            seen = {host}
            frontier = [host]
            while frontier:
                node = frontier.pop()
                for link in adj.get(node, ()):
                    if link.dst not in seen:
                        seen.add(link.dst)
                        frontier.append(link.dst)
            for dev in self.devices:
                if dev not in seen:
                    raise TopologyError(
                        f"tier {dev!r} is unreachable from host {host!r}"
                    )

    # -- queries --------------------------------------------------------------

    @property
    def station_links(self) -> Tuple[Link, ...]:
        """Port-bearing links in declaration order — the hop stations the
        DES materializes and the link control edges of per-edge MIKU."""
        return self._station_links

    @property
    def has_hops(self) -> bool:
        """True when any route can cross a port (non-degenerate fabric)."""
        return bool(self._station_links)

    def route(self, host: str, tier: str):
        """The resolved :class:`~repro.fabric.routing.Route` for requests a
        ``host`` workload issues to ``tier`` (raises
        :class:`~repro.core.device_model.UnknownTierError` on unknown
        names)."""
        if host not in self.hosts:
            raise UnknownTierError(
                host, self.hosts, kind="fabric host",
                known_desc="topology hosts",
            )
        if tier not in self.devices:
            raise UnknownTierError(
                tier, self.devices, kind="fabric device",
                known_desc="topology devices",
            )
        return self._routes[(host, tier)]


# -- named constructors -------------------------------------------------------


def direct(tiers: Sequence[str], host: str = "host0") -> FabricTopology:
    """The degenerate direct-attach topology: every tier hangs off ``host``
    over a transparent link.  Zero hop stations — a platform carrying this
    fabric simulates bit-identically to one carrying no fabric at all."""
    return FabricTopology(
        hosts=(host,),
        devices=tuple(tiers),
        links=tuple(
            Link(name=f"{host}-{t}", src=host, dst=t) for t in tiers
        ),
    )


def single_switch(
    tiers: Sequence[str],
    routed: Sequence[str],
    *,
    port_slots: int,
    service_ns: float,
    queue_entries: int,
    host: str = "host0",
    switch: str = "sw0",
) -> FabricTopology:
    """One host, one switch: each tier in ``routed`` sits behind its own
    port-bearing switch link (``{switch}-{tier}``); the rest attach
    transparently.  The minimal topology for port-queue-vs-ToR studies."""
    routed = tuple(routed)
    for t in routed:
        if t not in tiers:
            raise TopologyError(f"routed tier {t!r} not in tiers {tiers}")
    links = [Link(name=f"{host}-{switch}", src=host, dst=switch)]
    for t in tiers:
        if t in routed:
            links.append(Link(
                name=f"{switch}-{t}", src=switch, dst=t,
                port_slots=port_slots, service_ns=service_ns,
                queue_entries=queue_entries,
            ))
        else:
            links.append(Link(name=f"{host}-{t}", src=host, dst=t))
    return FabricTopology(
        hosts=(host,), devices=tuple(tiers), switches=(switch,),
        links=tuple(links),
    )


def _per_host(value, n: int, what: str) -> Tuple:
    """Broadcast a scalar (or validate a length-``n`` sequence) per host."""
    if isinstance(value, (list, tuple)):
        if len(value) != n:
            raise TopologyError(
                f"{what} has {len(value)} entries for {n} hosts"
            )
        return tuple(value)
    return (value,) * n


def spine_leaf(
    tiers: Sequence[str],
    routed: Sequence[str],
    *,
    n_hosts: int = 2,
    uplink_slots=16,
    uplink_service_ns=18.0,
    uplink_queue=1024,
    spine_slots: int = 8,
    spine_service_ns: float = 36.0,
    spine_queue: int = 1024,
) -> FabricTopology:
    """A two-level fabric: ``host{i} -> leaf{i} -> spine -> tier`` for each
    tier in ``routed``, the rest attached transparently per host.

    Each host's leaf uplink (``uplink{i}``) and the shared per-tier spine
    downlink (``spine-{tier}``) are port-bearing; uplink parameters accept
    a scalar (broadcast) or a per-host sequence, so asymmetric fabrics — a
    narrow uplink on one host — are one argument away.  The shared spine
    downlink is where cross-host congestion lives.
    """
    routed = tuple(routed)
    for t in routed:
        if t not in tiers:
            raise TopologyError(f"routed tier {t!r} not in tiers {tiers}")
    slots = _per_host(uplink_slots, n_hosts, "uplink_slots")
    svc = _per_host(uplink_service_ns, n_hosts, "uplink_service_ns")
    queue = _per_host(uplink_queue, n_hosts, "uplink_queue")
    hosts = tuple(f"host{i}" for i in range(n_hosts))
    leaves = tuple(f"leaf{i}" for i in range(n_hosts))
    links = []
    for i, (h, leaf) in enumerate(zip(hosts, leaves)):
        links.append(Link(name=f"{h}-{leaf}", src=h, dst=leaf))
        links.append(Link(
            name=f"uplink{i}", src=leaf, dst="spine",
            port_slots=slots[i], service_ns=svc[i],
            queue_entries=queue[i],
        ))
    for t in tiers:
        if t in routed:
            links.append(Link(
                name=f"spine-{t}", src="spine", dst=t,
                port_slots=spine_slots, service_ns=spine_service_ns,
                queue_entries=spine_queue,
            ))
        else:
            for h in hosts:
                links.append(Link(name=f"{h}-{t}", src=h, dst=t))
    return FabricTopology(
        hosts=hosts, devices=tuple(tiers),
        switches=leaves + ("spine",), links=tuple(links),
    )

"""Route resolution: one ordered station path per ``(host, tier)`` pair.

A :class:`Route` is the full link path a request follows from its
workload's host to its target tier's device.  Only the *port-bearing*
links on the path become hop stations in the DES (:attr:`Route.hops`);
transparent links are pure attachment.  Routes are resolved eagerly at
:class:`~repro.fabric.topology.FabricTopology` construction — BFS
shortest path, ties broken by link declaration order, so resolution is
deterministic for a given topology literal.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = ["Route", "resolve_routes"]


@dataclasses.dataclass(frozen=True)
class Route:
    """The resolved path for ``host``-issued requests targeting ``tier``."""

    host: str
    tier: str
    #: Every link on the path, in traversal order (transparent included).
    links: Tuple = ()

    @property
    def hops(self) -> Tuple:
        """The port-bearing links only — the hop stations a request
        queues through (in order) before entering the tier's device."""
        return tuple(l for l in self.links if not l.is_transparent)

    @property
    def nodes(self) -> Tuple[str, ...]:
        """Node names along the path, ``host`` first, device last."""
        if not self.links:
            return (self.host, self.tier)
        return (self.links[0].src,) + tuple(l.dst for l in self.links)


def resolve_routes(topology) -> Dict[Tuple[str, str], Route]:
    """BFS-resolve a :class:`Route` for every ``(host, device)`` pair.

    Shortest path by link count; among equal-length paths the one using
    earlier-declared links wins (BFS expands links in declaration order).
    The topology validated reachability already, so every pair resolves.
    """
    adj: Dict[str, list] = {}
    for link in topology.links:
        adj.setdefault(link.src, []).append(link)
    routes: Dict[Tuple[str, str], Route] = {}
    for host in topology.hosts:
        # parent[node] = link used to first reach node
        parent: Dict[str, object] = {host: None}
        frontier = [host]
        while frontier:
            nxt = []
            for node in frontier:
                for link in adj.get(node, ()):
                    if link.dst not in parent:
                        parent[link.dst] = link
                        nxt.append(link.dst)
            frontier = nxt
        for dev in topology.devices:
            path = []
            node = dev
            while node != host:
                link = parent[node]
                path.append(link)
                node = link.src
            routes[(host, dev)] = Route(
                host=host, tier=dev, links=tuple(reversed(path))
            )
    return routes

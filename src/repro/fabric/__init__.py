"""repro.fabric: routed CXL switch-fabric topologies for the tiered DES.

The flat station layer models every tier as one queue directly off the
host.  Real disaggregated memory traverses switch fabrics, and per-hop
port queuing is where latency and unfairness actually live.  This package
adds the routed layer:

* :mod:`~repro.fabric.topology` — :class:`FabricTopology` graphs (hosts,
  switches, device nodes, directed :class:`Link` edges with per-port
  queue capacity and service rates) and the named constructors
  :func:`direct`, :func:`single_switch`, :func:`spine_leaf`.
* :mod:`~repro.fabric.routing` — a resolved :class:`Route` (ordered
  station path) per ``(host, tier)``, validated against the topology.
* :mod:`~repro.fabric.control` — :func:`peredge_miku`, the MIKU ladder
  ensemble generalized from per-slow-tier to per-control-edge (device
  edges + port-bearing link edges; per-tier is the zero-link special
  case), and the :func:`edge_names` schedule it shares with
  ``TieredMemorySim(control_scope="edge")``.
* :mod:`~repro.fabric.platforms` — Platform-A variants carrying a fabric
  (``A-direct``, ``A-spine`` are registered into ``PLATFORMS`` on
  import).

Attach a topology via ``PlatformModel.fabric``; the DES materializes each
port-bearing link as a hop station with its own entry limit and
head-of-line backpressure, and a platform whose links are all transparent
simulates bit-identically to a fabric-less one.
"""

from repro.fabric.control import edge_names, peredge_miku
from repro.fabric.platforms import (
    direct_platform,
    single_switch_platform,
    spine_leaf_platform,
)
from repro.fabric.routing import Route, resolve_routes
from repro.fabric.topology import (
    FabricTopology,
    Link,
    TopologyError,
    direct,
    single_switch,
    spine_leaf,
)

__all__ = [
    "FabricTopology",
    "Link",
    "Route",
    "TopologyError",
    "direct",
    "direct_platform",
    "edge_names",
    "peredge_miku",
    "resolve_routes",
    "single_switch",
    "single_switch_platform",
    "spine_leaf",
    "spine_leaf_platform",
]

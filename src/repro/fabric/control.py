"""Per-edge MIKU: the ladder ensemble generalized from tiers to fabric edges.

A *control edge* is anything the simulator meters residency through and the
controller can independently throttle: every slow tier's **device edge**
(named by the tier) plus every port-bearing fabric link's **link edge**
(named by the link).  The schedule is fixed — slow tiers in platform
order, then station links in topology declaration order — and shared by
:func:`edge_names`, ``TieredMemorySim(control_scope="edge")``'s window
reports, and the controllers built here, so decision vectors line up by
construction.

The per-slow-tier ensemble is the zero-link special case: on a platform
whose fabric is absent (or all-transparent), :func:`peredge_miku` builds
the exact controller :func:`~repro.memsim.calibration.default_miku`
builds, and edge windows equal tier windows, so decisions are
bit-identical to the ``pertier`` law.
"""

from __future__ import annotations

from typing import Tuple

from repro.core.controller import MikuConfig, MikuController
from repro.core.device_model import DeviceModel, PlatformModel
from repro.memsim.calibration import calibrate_estimator, tier_class_caps

__all__ = ["edge_names", "peredge_miku"]


def edge_names(platform: PlatformModel) -> Tuple[str, ...]:
    """The platform's control-edge schedule: one device edge per slow tier
    (platform order, named by the tier), then one link edge per
    port-bearing fabric link (declaration order, named by the link)."""
    fabric = getattr(platform, "fabric", None)
    links = fabric.station_links if fabric is not None else ()
    return tuple(platform.tier_names[1:]) + tuple(l.name for l in links)


def _link_device(link, reference: DeviceModel) -> DeviceModel:
    """View a port-bearing link as a DeviceModel so the standard tier
    calibration helpers apply to it unchanged: the port's servers are the
    parallelism, its per-cacheline service time covers reads and writes
    alike (the port transports both), and there is no pipeline — a link's
    entry-holding cost is pure service + queueing."""
    return DeviceModel(
        name=f"link:{link.name}",
        tier=link.name,
        parallelism=link.port_slots,
        read_service_ns=link.service_ns,
        write_service_ns=link.service_ns,
        pipeline_ns=0.0,
        access_bytes=reference.access_bytes,
    )


def peredge_miku(
    platform: PlatformModel,
    granularity: int = 4,
    **est_overrides,
) -> MikuController:
    """A per-edge MIKU ensemble calibrated for ``platform``.

    Device edges get ladders identical to
    :func:`~repro.memsim.calibration.default_miku`'s per-slow-tier units
    (same rungs, same entry-holding-scaled caps, same ToR-share-split
    thresholds), so a fabric-less platform yields the per-tier ensemble
    exactly.  Each link edge gets its own ladder calibrated from the
    port's DeviceModel view (:func:`_link_device`): threshold from the
    port service time with the standard queue markup, caps scaled by the
    port's entry-holding time — a narrow port gets a low ceiling.  Pair
    with ``TieredMemorySim(..., control_scope="edge")`` (or
    ``SimJob(miku=True, miku_law="peredge")``)."""
    slow_devs = platform.tiers[1:]
    n_slow = len(slow_devs)
    reference = slow_devs[0]
    cfgs = [
        MikuConfig(
            levels=(1, 2, 4, 8, 16),
            class_caps=tier_class_caps(dev, reference, granularity),
        )
        for dev in slow_devs
    ]
    ests = [
        calibrate_estimator(
            platform, granularity, slow_device=dev,
            shared_slow_tiers=n_slow, **est_overrides
        )
        for dev in slow_devs
    ]
    fabric = getattr(platform, "fabric", None)
    links = fabric.station_links if fabric is not None else ()
    for link in links:
        dev = _link_device(link, reference)
        cfgs.append(MikuConfig(
            levels=(1, 2, 4, 8, 16),
            class_caps=tier_class_caps(dev, reference, granularity),
        ))
        ests.append(calibrate_estimator(
            platform, granularity, slow_device=dev,
            shared_slow_tiers=1, **est_overrides
        ))
    return MikuController(cfgs, ests)

"""Open-loop workload generation (arrival processes + trace replay).

The DES's native workloads are closed loops: each core re-issues as soon
as a request retires, so the offered load self-throttles to whatever the
memory system sustains.  The serving regime the ROADMAP targets — and the
regime where the paper's unfair-queuing/DDR-collapse mechanisms bite
hardest — is *open-loop*: requests arrive at an offered rate the system
cannot refuse, and queues grow when it falls behind.

:class:`~repro.workload.arrivals.ArrivalSpec` describes one arrival
process (Poisson, Zipfian-keyed, bursty/periodic, diurnal, flash-crowd, or
trace-file replay); attached to a :class:`~repro.core.des.WorkloadSpec`
via ``arrival=`` it turns that workload open-loop.  Generators are
deterministic given their seeds, draw from dedicated RNG streams (never
the simulation's), and use no wall-clock — see docs/workloads.md.
"""

from repro.workload.arrivals import (
    ArrivalSpec,
    arrival_iter,
    arrival_times,
)

__all__ = ["ArrivalSpec", "arrival_iter", "arrival_times"]

"""Deterministic arrival-process generators for open-loop workloads.

An :class:`ArrivalSpec` names one process and its parameters; `
:func:`arrival_iter` turns it into an iterator of ``(t_ns, key)`` pairs
with strictly non-decreasing times.  ``key`` is the request's *key
quantile* in ``[0, 1)`` for keyed processes (0.0 is the hottest key —
rank mass under a Zipf(s) law), or ``-1.0`` for unkeyed ones; the DES
routes keyed requests by quantile against the workload's placement
vector (hot keys land on the fast tier) instead of drawing from the
simulation RNG.

Determinism contract (enforced by the property tests and the repo lint
pass): every generator draws only from a :class:`random.Random` seeded
from ``(stream_seed, spec.seed, kind)`` — no wall-clock, no module-level
``random``, no numpy global state — so the same spec and seeds always
produce the identical arrival stream, and enabling an arrival process
can never perturb the simulation's own random stream.

Process catalog (rates are mean offered rates in requests per ns; one
request is one simulated macro-request):

``poisson``
    Homogeneous Poisson: i.i.d. exponential gaps at ``rate``.
``zipf``
    Poisson times; each arrival carries a key drawn Zipf(``s``) over
    ``n_keys`` ranks, encoded as the rank quantile ``rank / n_keys``.
``bursty``
    On/off periodic: all arrivals land in the first ``duty`` fraction of
    each ``period_ns`` window, as a Poisson stream at ``rate / duty``
    during the burst — the time average is exactly ``rate``.
``diurnal``
    Non-homogeneous Poisson, rate ``rate * (1 + amplitude *
    sin(2*pi*t/period_ns))`` via thinning (exact).
``flash_crowd``
    Piecewise-constant rate: ``rate`` until ``t_step_ns``, ``rate *
    surge`` for ``surge_ns`` (forever when 0), then ``rate`` again;
    exponential gaps restarted at each boundary (exact by
    memorylessness).
``trace``
    Bit-faithful replay of a trace file: one arrival per line,
    ``t_ns[,key]``, ``#`` comments and blank lines skipped; times must
    be non-decreasing.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Iterator, List, Optional, Tuple

__all__ = ["ArrivalSpec", "arrival_iter", "arrival_times", "KINDS"]

KINDS = ("poisson", "zipf", "bursty", "diurnal", "flash_crowd", "trace")

#: Per-kind salt folded into the generator seed so two processes of
#: different kinds never share a stream even with equal seeds.
_KIND_SALT = {k: i * 0x9E3779B1 for i, k in enumerate(KINDS)}


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """One open-loop arrival process (picklable, validated at creation)."""

    kind: str
    #: Mean offered rate in requests/ns (macro-requests; unused for trace).
    rate: float = 0.0
    #: Generator stream selector, composed with the simulation seed — two
    #: workloads with equal specs in one sim still get distinct streams.
    seed: int = 0
    # zipf
    s: float = 1.1
    n_keys: int = 1024
    # bursty / diurnal share the period
    period_ns: float = 20_000.0
    duty: float = 0.5
    # diurnal
    amplitude: float = 0.5
    # flash_crowd
    t_step_ns: float = 50_000.0
    surge: float = 4.0
    surge_ns: float = 0.0  # 0.0 = the surge never ends
    # trace replay
    path: Optional[str] = None
    #: Backlog bound: arrivals beyond this queue depth are shed (counted,
    #: never silently dropped).  None = unbounded queue growth.
    queue_limit: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; expected one of "
                f"{', '.join(KINDS)}"
            )
        if self.kind == "trace":
            if not self.path:
                raise ValueError("trace arrivals need path=")
        elif not self.rate > 0.0:
            raise ValueError(
                f"{self.kind} arrivals need rate > 0 (requests/ns), "
                f"got {self.rate}"
            )
        if self.kind == "zipf":
            if self.s <= 0.0:
                raise ValueError(f"zipf skew s must be > 0, got {self.s}")
            if self.n_keys < 1:
                raise ValueError(f"zipf needs n_keys >= 1, got {self.n_keys}")
        if self.kind in ("bursty", "diurnal") and self.period_ns <= 0.0:
            raise ValueError(f"period_ns must be > 0, got {self.period_ns}")
        if self.kind == "bursty" and not (0.0 < self.duty <= 1.0):
            raise ValueError(f"duty must be in (0, 1], got {self.duty}")
        if self.kind == "diurnal" and not (0.0 <= self.amplitude < 1.0):
            raise ValueError(
                f"amplitude must be in [0, 1), got {self.amplitude}"
            )
        if self.kind == "flash_crowd":
            if self.t_step_ns < 0.0:
                raise ValueError("t_step_ns must be >= 0")
            if self.surge <= 0.0:
                raise ValueError(f"surge must be > 0, got {self.surge}")
            if self.surge_ns < 0.0:
                raise ValueError("surge_ns must be >= 0")
        if self.queue_limit is not None and self.queue_limit < 1:
            raise ValueError(
                f"queue_limit must be >= 1 (or None), got {self.queue_limit}"
            )


def _rng(spec: ArrivalSpec, stream_seed: int) -> random.Random:
    """Dedicated per-(spec, stream) RNG — never the simulation's."""
    mixed = (
        (stream_seed & 0xFFFFFFFF) * 0x85EBCA77
        ^ (spec.seed & 0xFFFFFFFF) * 0xC2B2AE35
        ^ _KIND_SALT[spec.kind]
    ) & 0xFFFFFFFFFFFFFFFF
    return random.Random(mixed)


def _poisson(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    rng = _rng(spec, stream_seed)
    expo = rng.expovariate
    rate = spec.rate
    t = 0.0
    while True:
        t += expo(rate)
        yield (t, -1.0)


def _zipf_cum(s: float, n_keys: int) -> List[float]:
    """Cumulative normalized Zipf(s) rank weights (rank 0 hottest)."""
    acc = 0.0
    cum: List[float] = []
    for r in range(n_keys):
        acc += 1.0 / (r + 1) ** s
        cum.append(acc)
    return [c / acc for c in cum]


def _zipf(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    rng = _rng(spec, stream_seed)
    expo, unif = rng.expovariate, rng.random
    rate = spec.rate
    cum = _zipf_cum(spec.s, spec.n_keys)
    n_keys = spec.n_keys
    t = 0.0
    while True:
        t += expo(rate)
        rank = bisect.bisect_right(cum, unif())
        yield (t, min(rank, n_keys - 1) / n_keys)


def _bursty(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    # Homogeneous Poisson on the *active* timeline at rate/duty, mapped
    # onto the first duty*period of each period — duty-cycle conservation
    # by construction, time-average rate exactly spec.rate.
    rng = _rng(spec, stream_seed)
    expo = rng.expovariate
    burst_rate = spec.rate / spec.duty
    on_ns = spec.duty * spec.period_ns
    period = spec.period_ns
    a = 0.0  # active-time clock
    while True:
        a += expo(burst_rate)
        k, frac = divmod(a, on_ns)
        yield (k * period + frac, -1.0)


def _diurnal(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    # Thinning (Lewis-Shedler): candidates at the envelope rate
    # rate*(1+amplitude), accepted with probability rate(t)/envelope.
    rng = _rng(spec, stream_seed)
    expo, unif = rng.expovariate, rng.random
    rate, amp = spec.rate, spec.amplitude
    envelope = rate * (1.0 + amp)
    omega = 2.0 * math.pi / spec.period_ns
    t = 0.0
    while True:
        t += expo(envelope)
        lam = rate * (1.0 + amp * math.sin(omega * t))
        if unif() * envelope < lam:
            yield (t, -1.0)


def _flash_crowd(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    rng = _rng(spec, stream_seed)
    expo = rng.expovariate
    base = spec.rate
    hi = spec.rate * spec.surge
    t0 = spec.t_step_ns
    t1 = math.inf if spec.surge_ns == 0.0 else t0 + spec.surge_ns
    t = 0.0
    while True:
        # Piecewise-constant rate; restarting the exponential at each
        # boundary is exact (memorylessness).
        rate = hi if t0 <= t < t1 else base
        nxt = t + expo(rate)
        boundary = t0 if t < t0 else (t1 if t < t1 else math.inf)
        if nxt >= boundary:
            t = boundary
            continue
        t = nxt
        yield (t, -1.0)


def _trace(spec: ArrivalSpec, stream_seed: int) -> Iterator[
        Tuple[float, float]]:
    del stream_seed  # replay draws nothing
    prev = -math.inf
    with open(spec.path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            text = line.strip()
            if not text or text.startswith("#"):
                continue
            parts = text.split(",")
            try:
                t = float(parts[0])
                key = float(parts[1]) if len(parts) > 1 else -1.0
            except (ValueError, IndexError):
                raise ValueError(
                    f"{spec.path}:{lineno}: expected 't_ns[,key]', "
                    f"got {text!r}"
                ) from None
            if t < prev:
                raise ValueError(
                    f"{spec.path}:{lineno}: arrival times must be "
                    f"non-decreasing ({t} after {prev})"
                )
            prev = t
            yield (t, key)


_GENERATORS = {
    "poisson": _poisson,
    "zipf": _zipf,
    "bursty": _bursty,
    "diurnal": _diurnal,
    "flash_crowd": _flash_crowd,
    "trace": _trace,
}


def arrival_iter(
    spec: ArrivalSpec, stream_seed: int = 0
) -> Iterator[Tuple[float, float]]:
    """The (t_ns, key) arrival stream for ``spec``.

    ``stream_seed`` is the host's stream selector (the DES passes a value
    derived from the simulation seed and the workload index); the same
    ``(spec, stream_seed)`` always yields the identical stream.
    """
    return _GENERATORS[spec.kind](spec, stream_seed)


def arrival_times(
    spec: ArrivalSpec,
    *,
    stream_seed: int = 0,
    horizon_ns: Optional[float] = None,
    limit: Optional[int] = None,
) -> List[Tuple[float, float]]:
    """Materialize the stream up to a horizon and/or a count (test aid)."""
    if horizon_ns is None and limit is None:
        raise ValueError("arrival_times needs horizon_ns and/or limit")
    out: List[Tuple[float, float]] = []
    for t, key in arrival_iter(spec, stream_seed):
        if horizon_ns is not None and t > horizon_ns:
            break
        out.append((t, key))
        if limit is not None and len(out) >= limit:
            break
    return out

"""Fault-tolerant checkpointing: atomic, async, elastic.

Format: one ``.npz`` of flattened leaves (keyed by pytree path) + a JSON
manifest (step, leaf paths/shapes/dtypes, data-loader state, mesh note).
Writes go to ``<dir>/tmp.<step>`` and are atomically renamed to
``<dir>/step_<step>`` — a crash mid-write can never corrupt the latest
checkpoint.  ``CheckpointManager`` runs saves on a background thread (the
training loop donates a host copy and keeps going) and keeps the newest K.

**Elastic restore**: leaves are stored unsharded (gathered); ``restore``
re-``device_put``s every leaf with the shardings derived from the *current*
mesh — so a checkpoint written on a 16x16 mesh restores cleanly onto 8x8 or
2x16x16 (tested on small meshes in tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    *,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(state)
    arrays = {}
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i:05d}"
        stored_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in stored_dtype:
            # npz cannot round-trip ml_dtypes (bfloat16 etc.): store the
            # f32 upcast; the manifest remembers the true dtype.
            arr = arr.astype(np.float32)
        arrays[name] = arr
        manifest["leaves"].append(
            {"key": key, "name": name, "shape": list(arr.shape),
             "dtype": stored_dtype}
        )
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"step_(\d+)$", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    state_template: Any,
    *,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the template's treedef; reshard onto ``shardings`` (a
    matching pytree of Shardings, or None for host arrays)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["leaves"]}

    template_leaves = _flatten_with_paths(state_template)
    treedef = jax.tree_util.tree_structure(state_template)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(template_leaves)
    )
    restored = []
    for (key, tmpl), sh in zip(template_leaves, shard_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if str(arr.dtype) != str(tmpl.dtype):
            # bf16 leaves were stored as f32; ml_dtypes registers the cast.
            arr = arr.astype(np.float32).astype(tmpl.dtype)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != "
                f"template {tmpl.shape}"
            )
        restored.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["extra"]


class CheckpointManager:
    """Async checkpointing with retention.  ``save`` snapshots to host
    memory synchronously (cheap) and writes on a worker thread; ``wait``
    fences (called before exit / preemption)."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: List[Future] = []
        self._lock = threading.Lock()

    def save(self, step: int, state: Any,
             extra: Optional[Dict[str, Any]] = None) -> Future:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            p = save_checkpoint(self.directory, step, host_state, extra=extra)
            self._gc()
            return p

        fut = self._pool.submit(work)
        with self._lock:
            self._pending.append(fut)
        return fut

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := re.match(r"step_(\d+)$", name))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for fut in pending:
            fut.result()

    def restore_latest(self, state_template: Any, *, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        state, extra = restore_checkpoint(
            self.directory, step, state_template, shardings=shardings
        )
        return step, state, extra

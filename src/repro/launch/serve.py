"""Serving driver: tiered co-located instances with MIKU request control.

  PYTHONPATH=src python -m repro.launch.serve --arch llama31-8b --smoke \\
      --requests 24 --mode miku

Modes: ``opt`` (each instance alone), ``racing`` (no control), ``miku``
(dynamic control).  Mirrors the paper's §6 LLM case study on the TPU tier
model (DESIGN.md §2).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.core.controller import MikuConfig, MikuController
from repro.core.littles_law import EstimatorConfig
from repro.models.transformer import TransformerLM
from repro.serving.engine import (
    EngineConfig,
    Request,
    ServingEngine,
    TieredServingCluster,
)


def build_cluster(arch_id: str, *, smoke: bool, n_requests: int, mode: str,
                  max_new: int = 24, stream_chunks: int = 64):
    spec = get_arch(arch_id)
    cfg = spec.smoke if smoke else spec.config
    model = TransformerLM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    def mk(name, placement, n):
        e = ServingEngine(
            EngineConfig(name=name, model=cfg, max_slots=4, max_len=96,
                         placement=placement, stream_chunks=stream_chunks),
            params,
        )
        for i in range(n):
            e.submit(Request(rid=i, prompt=list(range(1, 9)),
                             max_new_tokens=max_new))
        return e

    controller = None
    if mode == "miku":
        probe = mk("probe", "host", 0)
        chunk_service = probe.param_bytes / stream_chunks / 16.0
        controller = MikuController(
            MikuConfig(levels=(1, 2, 4, 8)),
            EstimatorConfig(t_fast=1.2e3,
                            slow_read_threshold=8 * chunk_service,
                            min_window_inserts=4, min_slow_inserts=1),
        )
    engines = [mk("hbm", "device", n_requests),
               mk("host", "host", max(n_requests // 3, 1))]
    return TieredServingCluster(engines, controller=controller,
                                window_ns=3e4)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--mode", choices=("opt", "racing", "miku"),
                    default="miku")
    args = ap.parse_args()
    if args.mode == "opt":
        for placement in ("device", "host"):
            cl = build_cluster(args.arch, smoke=args.smoke,
                               n_requests=args.requests, mode="racing")
            cl.engines = [e for e in cl.engines
                          if e.cfg.placement == placement]
            res = cl.run()
            for k, v in res.items():
                print(f"[serve/opt] {k}: {v['tokens_per_s']:.0f} tok/s "
                      f"({v['requests']:.0f} requests)")
        return
    cl = build_cluster(args.arch, smoke=args.smoke,
                       n_requests=args.requests, mode=args.mode)
    res = cl.run()
    for k, v in res.items():
        print(f"[serve/{args.mode}] {k}: {v['tokens_per_s']:.0f} tok/s "
              f"({v['requests']:.0f} requests)")


if __name__ == "__main__":
    main()

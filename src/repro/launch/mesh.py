"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
force-host-device-count trick in dryrun.py to work (device count locks on
first backend init).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The assignment's production mesh: one v5e pod = (data=16, model=16);
    two pods add a leading 'pod' axis = (2, 16, 16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(
    *, data: Optional[int] = None, model: int = 1
) -> jax.sharding.Mesh:
    """A small mesh over whatever devices exist (tests / smoke runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))

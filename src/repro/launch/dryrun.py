import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first backend init), which is why the module docstring
# lives in this comment block and `from __future__` is not used here.
#
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this produces, with ZERO device allocation (ShapeDtypeStruct
# inputs only):
#   * proof the sharding config is coherent (compile succeeds on the
#     single-pod 16x16 and multi-pod 2x16x16 meshes),
#   * compiled.memory_analysis()  -- per-device bytes (fits / doesn't),
#   * compiled.cost_analysis()    -- per-device HLO FLOPs & bytes,
#   * per-collective wire bytes parsed from the partitioned HLO text,
# which repro.roofline turns into the three roofline terms.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out dryrun.json

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCH_IDS, SHAPES, ArchSpec, Shape, get_arch
from repro.distributed.autosharding import logical_sharding_context
from repro.distributed.sharding import (
    partition_spec_for,
    rules_for_shape,
    tree_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import TransformerLM
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine
from repro.train.step import (
    make_train_step,
    train_state_axes,
    train_state_shapes,
)

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\("
)
_OP_LINE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

#: Ring-algorithm wire multipliers (bytes crossing links per chip, relative
#: to the per-chip buffer size in the partitioned HLO).
_WIRE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-chip result-buffer bytes of every collective in partitioned
    HLO, weighted by ring wire factors.  Shapes in post-SPMD HLO are already
    per-device."""
    out: Dict[str, float] = {k: 0.0 for k in _WIRE_FACTOR}
    for m in _OP_LINE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        size = 1
        if dims:
            for d in dims.split(","):
                if d:
                    size *= int(d)
        out[op] += size * nbytes * _WIRE_FACTOR[op]
    return out


def _replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def _sh(mesh, axes, shape, rules) -> NamedSharding:
    return NamedSharding(mesh, partition_spec_for(axes, shape, mesh, rules))


def _decode_state_shardings(model: TransformerLM, state_specs, mesh, rules):
    ax = model.decode_state_axes()
    return jax.tree.map(
        lambda spec, a: _sh(mesh, tuple(a), tuple(spec.shape), rules),
        state_specs,
        ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, str) for i in x
        ),
    )


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds_lower: float = 0.0
    seconds_compile: float = 0.0
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    bytes_min_per_device: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    memory: Dict[str, float] = dataclasses.field(default_factory=dict)
    error: str = ""
    notes: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _maybe_fe_spec(cfg, shape: Shape, b: int):
    if cfg.frontend == "vision":
        return jax.ShapeDtypeStruct((b, cfg.frontend_seq, cfg.d_model),
                                    jnp.float32)
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.float32)
    return None


def build_cell(
    spec: ArchSpec, shape: Shape, mesh, *, microbatches: int = 8,
    remat: str = "full",
):
    """Returns (jitted_fn, args_specs) ready to .lower(*args_specs)."""
    cfg = spec.config
    b, s = shape.global_batch, shape.seq_len
    rules = rules_for_shape(shape.kind, b)

    if shape.kind == "train":
        model = TransformerLM(cfg, remat=remat)
        # fp32 master weights unless the model is too large for the pod's
        # HBM at 12 bytes/param of optimizer+master state.
        n_dev = mesh.devices.size
        master = cfg.param_count() * 12 / n_dev < 6e9
        opt = AdamW(master=master)
        sched = lambda step: warmup_cosine(  # noqa: E731
            step, peak_lr=3e-4, warmup_steps=100, total_steps=10_000
        )
        mb = microbatches if b % microbatches == 0 else 1
        step_fn = make_train_step(model, opt, sched, microbatches=mb)
        ts_specs = train_state_shapes(model, opt)
        ts_axes = train_state_axes(model, opt)
        ts_sh = tree_shardings(mesh, ts_specs, ts_axes, rules)
        tok_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)
        lab_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)
        tok_sh = _sh(mesh, ("batch", "seq"), (b, s), rules)
        fe_spec = _maybe_fe_spec(cfg, shape, b)
        metrics_sh = {k: _replicated(mesh)
                      for k in ("loss", "aux_loss", "grad_norm", "lr")}
        in_sh = (ts_sh, tok_sh, tok_sh) + (
            (_sh(mesh, ("batch", "seq", "embed_act"), fe_spec.shape, rules),)
            if fe_spec is not None else ()
        )
        args = (ts_specs, tok_spec, lab_spec) + (
            (fe_spec,) if fe_spec is not None else ()
        )
        fn = jax.jit(
            step_fn,
            in_shardings=in_sh,
            out_shardings=(ts_sh, metrics_sh),
            donate_argnums=(0,),
        )
        return fn, args, f"master={master} microbatches={mb} remat={remat}"

    if shape.kind == "prefill":
        model = TransformerLM(cfg)
        p_specs = model.param_specs()
        p_axes = model.param_axes()
        p_sh = tree_shardings(mesh, p_specs, p_axes, rules)
        tok_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)
        tok_sh = _sh(mesh, ("batch", "seq"), (b, s), rules)
        fe_spec = _maybe_fe_spec(cfg, shape, b)

        state_specs = jax.eval_shape(lambda: model.init_decode_state(b, s))
        state_out_sh = _decode_state_shardings(model, state_specs, mesh, rules)
        logits_sh = _sh(mesh, ("batch", "vocab"), (b, cfg.vocab), rules)

        def step_fn(params, tokens, frontend_embeds=None):
            state0 = model.init_decode_state(b, s)
            return model.prefill(params, tokens, state0,
                                 frontend_embeds=frontend_embeds)

        in_sh = (p_sh, tok_sh) + (
            (_sh(mesh, ("batch", "seq", "embed_act"), fe_spec.shape, rules),)
            if fe_spec is not None else ()
        )
        args = (p_specs, tok_spec) + ((fe_spec,) if fe_spec is not None else ())
        fn = jax.jit(
            step_fn,
            in_shardings=in_sh,
            out_shardings=(logits_sh, state_out_sh),
        )
        return fn, args, ""

    if shape.kind == "decode":
        model = TransformerLM(cfg)
        p_specs = model.param_specs()
        p_axes = model.param_axes()
        p_sh = tree_shardings(mesh, p_specs, p_axes, rules)
        state_specs = jax.eval_shape(lambda: model.init_decode_state(b, s))
        state_sh = _decode_state_shardings(model, state_specs, mesh, rules)
        tok_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        tok_sh = _sh(mesh, ("batch",), (b,), rules)
        logits_sh = _sh(mesh, ("batch", "vocab"), (b, cfg.vocab), rules)

        fn = jax.jit(
            model.decode_step,
            in_shardings=(p_sh, state_sh, tok_sh),
            out_shardings=(logits_sh, state_sh),
            donate_argnums=(1,),
        )
        return fn, (p_specs, state_specs, tok_spec), ""

    raise ValueError(shape.kind)


def run_cell(
    arch_id: str,
    shape_name: str,
    mesh,
    mesh_name: str,
    *,
    verbose: bool = True,
    microbatches: int = 8,
    remat: str = "full",
    builder=build_cell,
) -> CellResult:
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    res = CellResult(arch=arch_id, shape=shape_name, mesh=mesh_name, ok=False)
    if not spec.shape_applicable(shape_name):
        res.error = "shape not applicable (see DESIGN.md §4)"
        res.notes = "skipped"
        return res
    try:
        rules = rules_for_shape(shape.kind, shape.global_batch)
        with mesh, logical_sharding_context(mesh, rules):
            fn, args, notes = builder(spec, shape, mesh,
                                      microbatches=microbatches, remat=remat)
            res.notes = notes
            t0 = time.time()
            lowered = fn.lower(*args)
            res.seconds_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            res.seconds_compile = time.time() - t0
            try:
                mem = compiled.memory_analysis()
                if mem is not None:
                    for attr in (
                        "temp_size_in_bytes",
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "alias_size_in_bytes",
                        "generated_code_size_in_bytes",
                    ):
                        v = getattr(mem, attr, None)
                        if v is not None:
                            res.memory[attr] = float(v)
            except Exception as ex:  # backend may not implement it
                res.memory["error"] = 0.0
                res.notes += f" mem_analysis_unavailable({type(ex).__name__})"
            try:
                cost = compiled.cost_analysis()
                if isinstance(cost, list):
                    cost = cost[0]
                if cost:
                    # Raw XLA numbers (while bodies counted once) — kept for
                    # reference; the roofline uses the trip-scaled parse.
                    res.memory["xla_cost_flops"] = float(cost.get("flops", 0.0))
                    res.memory["xla_cost_bytes"] = float(
                        cost.get("bytes accessed", 0.0)
                    )
            except Exception as ex:
                res.notes += f" cost_analysis_unavailable({type(ex).__name__})"
            from repro.roofline.hlo_costs import parse_hlo_costs

            hlo = parse_hlo_costs(compiled.as_text())
            res.flops_per_device = hlo.flops
            res.bytes_per_device = hlo.bytes
            res.bytes_min_per_device = hlo.bytes_min
            res.collective_bytes = hlo.collective_bytes
            if hlo.notes:
                res.notes += " " + "; ".join(hlo.notes[:3])
            res.ok = True
    except Exception as ex:
        res.error = f"{type(ex).__name__}: {str(ex)[:500]}"
        if verbose:
            traceback.print_exc()
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON results here")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--remat", default="full")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                r = run_cell(arch, shape_name, mesh, mesh_name,
                             microbatches=args.microbatches, remat=args.remat)
                results.append(r)
                status = "OK " if r.ok else ("SKIP" if r.notes == "skipped"
                                             else "FAIL")
                coll = sum(r.collective_bytes.values())
                print(
                    f"{status} {mesh_name} {arch:28s} {shape_name:12s} "
                    f"lower={r.seconds_lower:6.1f}s compile="
                    f"{r.seconds_compile:6.1f}s flops/dev={r.flops_per_device:.3e} "
                    f"bytes/dev={r.bytes_per_device:.3e} coll/dev={coll:.3e} "
                    f"{r.error[:120]}"
                )
                if r.ok and r.memory:
                    print(f"     memory_analysis: {r.memory}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.to_json() for r in results], f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""Training driver: checkpoint/restart, preemption handling, straggler
governor, elastic resume.

Single-host usage (CPU smoke / examples):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \\
      --steps 20 --ckpt-dir /tmp/ckpt --resume

At scale the same driver runs under ``jax.distributed`` with the production
mesh; the data loader shards by host, the checkpoint manager writes
per-step manifests asynchronously, SIGTERM (preemption notice) triggers a
final synchronous checkpoint, and ``--resume`` restores the latest manifest
onto *whatever mesh is alive* (elastic: leaves are stored unsharded and
re-device_put with current-mesh shardings).
"""

from __future__ import annotations

import argparse
import signal
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_arch
from repro.core.controller import StragglerGovernor
from repro.core.substrate import ControlLoop, StepTimingSubstrate
from repro.data.pipeline import HostDataLoader, SyntheticTokenDataset
from repro.distributed.autosharding import logical_sharding_context
from repro.distributed.sharding import TRAIN_RULES, tree_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import TransformerLM
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine
from repro.train.step import (
    TrainState,
    init_train_state,
    make_train_step,
    train_state_axes,
)


class Trainer:
    def __init__(
        self,
        arch_id: str,
        *,
        smoke: bool = False,
        global_batch: int = 8,
        seq_len: int = 128,
        microbatches: int = 1,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 10,
        grad_compression: bool = False,
        mesh=None,
        remat: str = "none",
        peak_lr: float = 3e-4,
        total_steps: int = 1000,
        config_override=None,
    ):
        spec = get_arch(arch_id)
        self.cfg = config_override or (spec.smoke if smoke else spec.config)
        self.model = TransformerLM(self.cfg, remat=remat)
        self.opt = AdamW()
        self.mesh = mesh or make_host_mesh()
        self.rules = TRAIN_RULES
        self.global_batch = global_batch
        self.seq_len = seq_len
        sched = lambda s: warmup_cosine(  # noqa: E731
            s, peak_lr=peak_lr, warmup_steps=min(100, total_steps // 10 + 1),
            total_steps=total_steps,
        )
        self.step_fn = jax.jit(
            make_train_step(self.model, self.opt, sched,
                            microbatches=microbatches,
                            grad_compression=grad_compression),
            donate_argnums=(0,),
        )
        self.loader = HostDataLoader(
            SyntheticTokenDataset(vocab=self.cfg.vocab),
            global_batch=global_batch,
            seq_len=seq_len,
        )
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        # Straggler control plane: per-host step times flow through the same
        # substrate/ControlLoop interface as the memory tiers (DESIGN.md §5).
        # The substrate returns a plain (step_times,) tuple — not a
        # TierWindow — so the loop splats it into the governor's
        # window(step_times) unchanged under the vector contract.  Single
        # host here; the same loop runs fleet-wide at scale.  One window per
        # step (the governor's native cadence).
        self.governor = StragglerGovernor(n_hosts=1)
        self.step_substrate = StepTimingSubstrate(n_hosts=1)
        self.straggler_loop = ControlLoop(
            self.step_substrate, self.governor, window_ns=1.0, record=False,
            max_history=64,
        )
        self.grad_compression = grad_compression
        self._preempted = False

    def _state_shardings(self, state: TrainState):
        axes = train_state_axes(self.model, self.opt,
                                grad_compression=self.grad_compression)
        return tree_shardings(self.mesh, state, axes, self.rules)

    def init_or_resume(self, resume: bool) -> TrainState:
        with self.mesh:
            state = init_train_state(self.model, self.opt,
                                     jax.random.PRNGKey(0),
                                     grad_compression=self.grad_compression)
        if resume and self.ckpt is not None:
            step, restored, extra = self.ckpt.restore_latest(
                state, shardings=self._state_shardings(state)
            )
            if step is not None:
                print(f"[train] resumed from step {step} "
                      f"(elastic onto {self.mesh.devices.shape})")
                if extra and "loader" in extra:
                    self.loader.load_state_dict(extra["loader"])
                return restored
        return state

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            del signum, frame
            print("[train] SIGTERM: checkpoint-and-exit requested")
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def train(self, steps: int, *, resume: bool = False,
              log_every: int = 1) -> TrainState:
        self.install_preemption_handler()
        state = self.init_or_resume(resume)
        start_step = int(jax.device_get(state.opt.step))
        with self.mesh, logical_sharding_context(self.mesh, self.rules):
            for step in range(start_step, steps):
                t0 = time.time()
                tokens, labels = next(self.loader)
                state, metrics = self.step_fn(
                    state, jnp.asarray(tokens), jnp.asarray(labels)
                )
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.time() - t0
                # Straggler governor window: record this host's step service
                # time, fire the control loop (estimate → HostHealth →
                # per-host dispatch rates applied back to the substrate).
                self.step_substrate.record_step(0, dt)
                self.straggler_loop.fire()
                if step % log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if np.isnan(loss):
                    raise FloatingPointError(f"NaN loss at step {step}")
                if self.ckpt and (
                    (step + 1) % self.ckpt_every == 0 or self._preempted
                ):
                    self.ckpt.save(
                        step + 1, state,
                        extra={"loader": self.loader.state_dict()},
                    )
                if self._preempted:
                    print("[train] preemption checkpoint written; exiting")
                    self.ckpt and self.ckpt.wait()
                    sys.exit(0)
        if self.ckpt:
            self.ckpt.save(steps, state,
                           extra={"loader": self.loader.state_dict()})
            self.ckpt.wait()
        return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()
    trainer = Trainer(
        args.arch, smoke=args.smoke, global_batch=args.global_batch,
        seq_len=args.seq_len, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        grad_compression=args.grad_compression, remat=args.remat,
        total_steps=args.steps,
    )
    trainer.train(args.steps, resume=args.resume)


if __name__ == "__main__":
    main()

"""Flash-decode GQA Pallas kernel (one new token vs a long KV cache).

Layout (kernel-native, what the serving engine stores):
  q:       [B, Hkv, G, Dh]   (G = Hq // Hkv query heads per KV head)
  k, v:    [B, Hkv, S, Dh]
  lengths: [B] int32         (#valid cache tokens; token at index
                              ``lengths-1`` is the newest)
  out:     [B, Hkv, G, Dh]

Grid: (B, Hkv, S // block_s) — the KV-block dimension is last (sequential on
TPU), so the online-softmax scratch (m, l, acc) carries across KV blocks of
one (batch, kv-head) before the grid moves on.  Each step streams one
[block_s, Dh] K tile and V tile HBM->VMEM and issues two MXU contractions:
[G, Dh] x [Dh, block_s] and [G, block_s] x [block_s, Dh].

VMEM working set per step: 2 x block_s x Dh (KV tiles) + G x (block_s + 2Dh)
scratch — e.g. block_s=512, Dh=128, G=8: ~300 KB, comfortably inside the
~16 MB VMEM with room for double-buffered prefetch of the next tile.
block_s and Dh are kept at multiples of 128 where the config allows (MXU
lane alignment); G is zero-padded to the sublane multiple by ops.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_attn_kernel(
    lengths_ref,  # scalar prefetch: [B] int32
    q_ref,  # [1, 1, G, Dh]
    k_ref,  # [1, 1, block_s, Dh]
    v_ref,  # [1, 1, block_s, Dh]
    o_ref,  # [1, 1, G, Dh]
    m_scr,  # [G, 1] f32
    l_scr,  # [G, 1] f32
    acc_scr,  # [G, Dh] f32
    *,
    block_s: int,
    scale: float,
    window: int,
    softcap: Optional[float],
):
    b = pl.program_id(0)
    sb = pl.program_id(2)
    n_sb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, Dh]
    k = k_ref[0, 0].astype(jnp.float32)  # [S_blk, Dh]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q * scale, k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [G, S_blk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    length = lengths_ref[b]
    pos = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = (pos < length) & (length - 1 - pos < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]  # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)  # [G, S_blk]
    alpha = jnp.exp(m_prev - m_new)  # [G, 1]
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(sb == n_sb - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_s", "window", "softcap", "scale", "interpret"),
)
def decode_attention_kernel(
    q: jax.Array,  # [B, Hkv, G, Dh]
    k: jax.Array,  # [B, Hkv, S, Dh]
    v: jax.Array,  # [B, Hkv, S, Dh]
    lengths: jax.Array,  # [B] int32
    *,
    block_s: int = 512,
    window: int = 1 << 30,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    assert s % block_s == 0, (s, block_s)
    if scale is None:
        scale = dh**-0.5

    kernel = functools.partial(
        _decode_attn_kernel,
        block_s=block_s,
        scale=scale,
        window=window,
        softcap=softcap,
    )
    grid = (b, hkv, s // block_s)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, dh), lambda b_, h_, s_, *_refs: (b_, h_, 0, 0)),
                pl.BlockSpec(
                    (1, 1, block_s, dh), lambda b_, h_, s_, *_refs: (b_, h_, s_, 0)
                ),
                pl.BlockSpec(
                    (1, 1, block_s, dh), lambda b_, h_, s_, *_refs: (b_, h_, s_, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, dh), lambda b_, h_, s_, *_refs: (b_, h_, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)

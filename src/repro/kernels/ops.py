"""Jit-ready wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python, validating the exact TPU code path; on TPU
they compile to Mosaic.  ``auto`` picks per-backend.

The wrappers also adapt model-layout tensors ([B, S, H, Dh] caches,
[B, S, H, P] SSD inputs) to the kernel-native layouts and pad GQA group
sizes up to the sublane multiple.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel


def _use_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def decode_attention(
    q: jax.Array,  # [B, Hq, Dh] (one new token per sequence)
    k: jax.Array,  # [B, S, Hkv, Dh] (model layout) — newest at lengths-1
    v: jax.Array,  # [B, S, Hkv, Dh]
    lengths: jax.Array,  # [B] int32 valid token counts
    *,
    window: int = 1 << 30,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash-decode GQA.  Returns [B, Hq, Dh]."""
    b, hq, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    assert g * hkv == hq
    qg = q.reshape(b, hkv, g, dh)
    kk = jnp.swapaxes(k, 1, 2)  # [B, Hkv, S, Dh]
    vv = jnp.swapaxes(v, 1, 2)
    # Pad G to the f32 sublane multiple (8) for MXU-aligned tiles.
    g_pad = -(-g // 8) * 8
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    bs = min(block_s, s)
    while s % bs != 0:
        bs //= 2
    out = decode_attention_kernel(
        qg, kk, vv, lengths.astype(jnp.int32),
        block_s=max(bs, 1), window=window, softcap=softcap, scale=scale,
        interpret=_use_interpret(interpret),
    )
    return out[:, :, :g, :].reshape(b, hq, dh)


def ssd_scan(
    x: jax.Array,  # [B, S, H, P] (model layout)
    dt: jax.Array,  # [B, S, H] f32 (post-softplus)
    bmat: jax.Array,  # [B, S, N] (G=1)
    cmat: jax.Array,  # [B, S, N]
    a: jax.Array,  # [H] f32 negative
    *,
    chunk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Chunked SSD scan.  Returns y [B, S, H, P]."""
    b, s, h, p = x.shape
    xk = jnp.moveaxis(x, 2, 1)  # [B, H, S, P]
    dtk = jnp.moveaxis(dt, 2, 1)  # [B, H, S]
    bc = jnp.stack([bmat, cmat], axis=2)  # [B, S, 2, N]
    ck = min(chunk, s)
    while s % ck != 0:
        ck //= 2
    y = ssd_scan_kernel(
        xk, dtk.astype(jnp.float32), bc, a.astype(jnp.float32),
        chunk=max(ck, 1), interpret=_use_interpret(interpret),
    )
    return jnp.moveaxis(y, 1, 2)  # [B, S, H, P]

"""Mamba2 SSD chunked-scan Pallas kernel.

Layout (kernel-native):
  x:   [B, H, S, P]    (P = SSM head dim)
  dt:  [B, H, S]       (post-softplus, f32)
  bc:  [B, S, 2, N]    (B-matrix at [:, :, 0], C-matrix at [:, :, 1]; G=1)
  a:   [1, H]          (negative decay rates, f32)
  out: [B, H, S, P]

Grid: (B, H, S // chunk) with the chunk dimension last (sequential): the
[P, N] recurrent state lives in VMEM scratch and carries across chunks of
one (batch, head) pair.  Per chunk the kernel runs the quadratic intra-chunk
contraction on the MXU ([Q, N] x [N, Q], [Q, Q] x [Q, P]) plus the state
in/out projections — identical math to the jnp reference
(:func:`repro.models.ssm.ssd_chunked`), which serves as its oracle.

VMEM working set per step (Q=128, P=64, N=128): x 32 KB + bc 128 KB +
decay [Q, Q] 64 KB + state 32 KB — well under VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # [1, 1, Q, P]
    dt_ref,  # [1, 1, Q]
    bc_ref,  # [1, Q, 2, N]
    a_ref,  # [1, 1]
    o_ref,  # [1, 1, Q, P]
    h_scr,  # [P, N] f32
    *,
    chunk: int,
):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [Q]
    bmat = bc_ref[0, :, 0, :].astype(jnp.float32)  # [Q, N]
    cmat = bc_ref[0, :, 1, :].astype(jnp.float32)  # [Q, N]
    a = a_ref[0, 0]  # scalar (negative)

    da = dt * a  # [Q]
    cum = jnp.cumsum(da)  # [Q]

    # Intra-chunk quadratic term.
    rel = cum[:, None] - cum[None, :]  # [Q, Q]
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(k_idx <= q_idx, jnp.exp(rel), 0.0)  # causal [Q, Q]
    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, Q]
    w = scores * decay
    dx = dt[:, None] * x  # [Q, P]
    y = jax.lax.dot_general(
        w, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]

    # Inter-chunk contribution from the carried state: exp(cum) * C @ h^T.
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, h_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, N] x [P, N]^T -> [Q, P]

    # State update: h = exp(sum(da)) * h + sum_q tail_q dt_q x_q B_q^T.
    tail = jnp.exp(cum[-1] - cum)  # [Q]
    wx = (tail * dt)[:, None] * x  # [Q, P]
    s_chunk = jax.lax.dot_general(
        wx, bmat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [P, N]
    h_scr[...] = jnp.exp(jnp.sum(da)) * h_scr[...] + s_chunk

    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(
    x: jax.Array,  # [B, H, S, P]
    dt: jax.Array,  # [B, H, S] f32
    bc: jax.Array,  # [B, S, 2, N]
    a: jax.Array,  # [H] f32 (negative)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, p = x.shape
    n = bc.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (b, h, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1, chunk, 2, n), lambda b_, h_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (0, h_)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)
        ),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        interpret=interpret,
    )(x, dt, bc, a.reshape(1, h))

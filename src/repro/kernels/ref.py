"""Pure-jnp oracles for the Pallas kernels.

These are deliberately *naive* implementations (full-materialization
attention; token-by-token SSD recurrence) — independent of both the kernels
and the blocked model code, so kernel bugs cannot hide behind shared logic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def decode_attention_ref(
    q: jax.Array,  # [B, Hkv, G, Dh]
    k: jax.Array,  # [B, Hkv, S, Dh]
    v: jax.Array,  # [B, Hkv, S, Dh]
    lengths: jax.Array,  # [B] int32
    *,
    window: int = 1 << 30,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    dh = q.shape[-1]
    s = k.shape[2]
    if scale is None:
        scale = dh**-0.5
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)[None, :]  # [1, S]
    length = lengths[:, None]  # [B, 1]
    valid = (pos < length) & (length - 1 - pos < window)  # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # [B, H, S, P]
    dt: jax.Array,  # [B, H, S] f32
    bc: jax.Array,  # [B, S, 2, N]
    a: jax.Array,  # [H] f32 (negative)
) -> jax.Array:
    """Token-by-token SSD recurrence (the ground-truth semantics):

        h_t = exp(a * dt_t) h_{t-1} + dt_t * B_t x_t^T
        y_t = C_t . h_t
    """
    b, h, s, p = x.shape
    n = bc.shape[-1]
    xf = x.astype(jnp.float32)
    bmat = bc[:, :, 0, :].astype(jnp.float32)  # [B, S, N]
    cmat = bc[:, :, 1, :].astype(jnp.float32)

    def step(hstate, t_inputs):
        xt, dtt, bt, ct = t_inputs  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * a[None, :])  # [B, H]
        upd = jnp.einsum("bhp,bn->bhpn", dtt[..., None] * xt, bt)
        hstate = hstate * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", hstate, ct)
        return hstate, yt

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 2, 0),  # [S, B, H, P]
        jnp.moveaxis(dt.astype(jnp.float32), 2, 0),  # [S, B, H]
        jnp.moveaxis(bmat, 1, 0),  # [S, B, N]
        jnp.moveaxis(cmat, 1, 0),  # [S, B, N]
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)  # [B, H, S, P]

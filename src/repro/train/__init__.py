from repro.train.step import (
    TrainState,
    chunked_cross_entropy,
    make_train_step,
    make_eval_step,
)

__all__ = ["TrainState", "chunked_cross_entropy", "make_train_step",
           "make_eval_step"]

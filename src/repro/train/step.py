"""Training step factory: chunked loss, microbatch accumulation, remat,
optional int8 error-feedback gradient compression for the cross-pod
all-reduce (DESIGN.md §5).

Everything here is ordinary pjit-able JAX: gradient reductions come from
GSPMD sharding propagation (batch sharded over (pod, data) ⇒ psum over those
axes inserted by XLA), so compute/comm overlap is handled by the latency-
hiding scheduler; microbatch accumulation keeps per-step activation memory
bounded and gives the scheduler independent chunks to overlap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.autosharding import constrain
from repro.models.transformer import TransformerLM
from repro.optim.adamw import AdamW, OptState, clip_by_global_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState
    #: int8 error-feedback residual (grad compression), or None
    ef_residual: Optional[Any]


def chunked_cross_entropy(
    model: TransformerLM,
    params: Any,
    hidden: jax.Array,  # [B, S, D]
    labels: jax.Array,  # [B, S]
    *,
    chunk: int = 512,
) -> jax.Array:
    """Token-mean cross entropy without materializing [B, S, V].

    The unembedding matmul + log-softmax run per sequence-chunk inside a
    lax.map, bounding live logits to [B, chunk, V_shard].
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    hidden_c = hidden.reshape(b, n, chunk, d).swapaxes(0, 1)  # [n, B, c, D]
    labels_c = labels.reshape(b, n, chunk).swapaxes(0, 1)  # [n, B, c]

    def one(args):
        h, y = args
        logits = model.logits(params, h).astype(jnp.float32)  # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    totals = jax.lax.map(one, (hidden_c, labels_c))
    return jnp.sum(totals) / (b * s)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (optional, cross-pod)
# ---------------------------------------------------------------------------


def _ef_compress(g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize (g + residual) to int8 with a per-tensor scale; return the
    dequantized gradient and the new residual.  The all-reduce over the
    dequantized value is what XLA sees; on real hardware the int8 payload is
    what crosses the DCN (pod) links."""
    acc = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(acc)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), acc - deq


def make_loss_fn(
    model: TransformerLM,
    *,
    aux_weight: float = 0.01,
    loss_chunk: int = 512,
) -> Callable:
    def loss_fn(params, tokens, labels, frontend_embeds=None):
        hidden, aux = model.forward(params, tokens,
                                    frontend_embeds=frontend_embeds)
        loss = chunked_cross_entropy(model, params, hidden, labels,
                                     chunk=loss_chunk)
        return loss + aux_weight * aux, (loss, aux)

    return loss_fn


def make_train_step(
    model: TransformerLM,
    optimizer: AdamW,
    lr_schedule: Callable,
    *,
    microbatches: int = 1,
    grad_clip: float = 1.0,
    aux_weight: float = 0.01,
    loss_chunk: int = 512,
    grad_compression: bool = False,
) -> Callable:
    """Returns train_step(state, tokens, labels[, frontend_embeds]) ->
    (state, metrics)."""
    loss_fn = make_loss_fn(model, aux_weight=aux_weight, loss_chunk=loss_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    param_axes = model.param_axes()

    def _constrain_grads(grads):
        """Pin gradients to the parameter sharding: the batch-axis psum
        becomes a reduce-scatter (ZeRO-2) instead of a full all-reduce."""
        return jax.tree.map(
            lambda g, ax: constrain(g, tuple(ax)),
            grads, param_axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, str) for a in x),
        )

    def compute_grads(params, tokens, labels, frontend_embeds):
        if microbatches <= 1:
            (tot, (loss, aux)), grads = grad_fn(params, tokens, labels,
                                                frontend_embeds)
            return _constrain_grads(grads), loss, aux
        b = tokens.shape[0]
        assert b % microbatches == 0
        mb = b // microbatches

        def resh(x):
            return x.reshape((microbatches, mb) + x.shape[1:])

        tk = resh(tokens)
        lb = resh(labels)
        fe = resh(frontend_embeds) if frontend_embeds is not None else None

        def body(carry, inp):
            g_acc, l_acc, a_acc = carry
            if fe is not None:
                t1, l1, f1 = inp
            else:
                t1, l1 = inp
                f1 = None
            (_, (loss, aux)), grads = grad_fn(params, t1, l1, f1)
            grads = _constrain_grads(grads)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                g_acc, grads,
            )
            return (g_acc, l_acc + loss / microbatches,
                    a_acc + aux / microbatches), None

        g0 = _constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        xs = (tk, lb, fe) if fe is not None else (tk, lb)
        (grads, loss, aux), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            xs,
        )
        return grads, loss, aux

    def train_step(state: TrainState, tokens, labels, frontend_embeds=None):
        grads, loss, aux = compute_grads(state.params, tokens, labels,
                                         frontend_embeds)
        new_resid = state.ef_residual
        if grad_compression and state.ef_residual is not None:
            pairs = jax.tree.map(_ef_compress, grads, state.ef_residual)
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_resid = jax.tree.map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(state.opt.step)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt,
                          ef_residual=new_resid), metrics

    return train_step


def make_eval_step(model: TransformerLM, *, loss_chunk: int = 512) -> Callable:
    def eval_step(params, tokens, labels, frontend_embeds=None):
        hidden, _ = model.forward(params, tokens,
                                  frontend_embeds=frontend_embeds)
        return chunked_cross_entropy(model, params, hidden, labels,
                                     chunk=loss_chunk)

    return eval_step


def init_train_state(
    model: TransformerLM,
    optimizer: AdamW,
    key,
    *,
    grad_compression: bool = False,
) -> TrainState:
    params, _ = model.init(key)
    opt = optimizer.init(params)
    resid = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if grad_compression
        else None
    )
    return TrainState(params=params, opt=opt, ef_residual=resid)


def train_state_shapes(
    model: TransformerLM, optimizer: AdamW, *, grad_compression: bool = False
) -> TrainState:
    specs = model.param_specs()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    return TrainState(
        params=specs,
        opt=optimizer.init_shapes(specs),
        ef_residual=jax.tree.map(f32, specs) if grad_compression else None,
    )


def train_state_axes(model: TransformerLM, optimizer: AdamW,
                     *, grad_compression: bool = False) -> TrainState:
    axes = model.param_axes()
    return TrainState(
        params=axes,
        opt=optimizer.state_axes(axes),
        ef_residual=axes if grad_compression else None,
    )

"""Logical-axis sharding rules (DP/FSDP/TP/EP/SP + the multi-pod axis).

Every parameter / activation tensor carries a tuple of *logical* axis names
(:mod:`repro.models.layers` init functions).  A :class:`ShardingRules` maps
each logical axis to an ordered list of *candidate* mesh-axis assignments;
``partition_spec_for`` resolves a tensor's tuple greedily:

  * a candidate is taken only if the dimension is divisible by the mesh-axis
    (product) size and none of its mesh axes is already used by this tensor;
  * otherwise the next candidate is tried; exhaustion => replicated dim.

The fallback chains encode real alternatives, not guesses — e.g. KV heads
shard over ``model`` when the head count divides (gemma2: 16), and fall back
to sharding ``head_dim`` (whisper: 20 heads on a 16-way axis; qwen2.5: 2 KV
heads) so tensor parallelism survives awkward head counts.  hymba's 25 query
heads resolve to head_dim sharding the same way.

Shape-kind differences:
  * train/prefill: batch over (pod, data); params FSDP over data x TP model.
  * decode:        batch over (pod, data); KV cache batch-sharded.
  * long-context decode (batch=1): KV *sequence* shards over data
    (context parallelism); batch replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    name: str
    rules: Dict[str, List[Candidate]]

    def candidates(self, logical: str) -> List[Candidate]:
        return self.rules.get(logical, [])


def _base_rules(extra: Dict[str, List[Candidate]]) -> Dict[str, List[Candidate]]:
    rules: Dict[str, List[Candidate]] = {
        # parameters
        "layers": [],
        "embed": ["data"],  # FSDP shard
        "ffn": ["model"],
        "vocab": ["model"],
        "q_heads": ["model"],
        "kv_heads": ["model"],
        "head_dim": ["model"],  # fallback TP when heads don't divide
        "experts": ["model"],  # expert parallelism
        "experts_r": [],
        "ssm_proj": ["model"],
        "ssm_inner": ["model"],
        "ssm_conv_dim": ["model"],
        "ssm_heads": ["model"],
        "ssm_head_dim": ["model"],
        "ssm_state": [],
        "conv": [],
        # activations
        "batch": [("pod", "data"), "data"],
        "seq": [],
        "kv_seq": [],
        #: KV-cache-specific axes (decoupled from the weight head axes so
        #: decode can choose a cache layout independently of weight TP)
        "cache_heads": ["model"],
        "cache_dim": ["model"],
        # residual-stream feature dim: replicated (TP acts on heads/ffn)
        "embed_act": [],
        #: MoE dispatch buffer capacity dim: sharded over the batch axes so
        #: the expert einsums are local (E over model x C over data) — the
        #: alternative (replicated C) makes GSPMD partial-sum the FSDP
        #: embed dim into a [E,C,F] all-reduce (tens of TB/step on dbrx).
        "moe_cap": [("pod", "data"), "data"],
        "gathered": [],  # explicit "replicate now" (forces a weight AG)
        "data_shards": [("pod", "data"), "data"],  # shard-major MoE dispatch
        "moe_tok": [],
        "moe_cap_l": [],
    }
    rules.update(extra)
    return rules


TRAIN_RULES = ShardingRules("train", _base_rules({}))
#: Decode: shard the KV cache along *sequence* over the model axis
#: (flash-decode partial-softmax combine: per-layer collectives shrink to
#: [B,H,1] stats + [B,1,H,Dh] partial outputs instead of cache-sized
#: all-gathers).  Cache head/dim axes replicate.
DECODE_RULES = ShardingRules(
    "decode",
    _base_rules({
        "kv_seq": ["model"],
        "cache_heads": [],
        "cache_dim": [],
        #: no FSDP dim on weights at decode time: an embed-sharded weight
        #: would be all-gathered every token (pure TP instead; params/16
        #: fit HBM comfortably next to the KV shard).
        "embed": [],
    }),
)
#: batch=1 long-context decode: context-parallel KV over (pod, data) AND
#: model — 500k tokens spread over every chip; batch replicated.
LONG_CONTEXT_RULES = ShardingRules(
    "long_context",
    _base_rules({
        "batch": [],
        "kv_seq": [("pod", "data", "model"), ("data", "model"), "data"],
        "cache_heads": [],
        "cache_dim": [],
        "embed": ["data"],  # batch=1: data axis is otherwise idle; FSDP free
    }),
)


def rules_for_shape(kind: str, global_batch: int) -> ShardingRules:
    if kind == "decode" and global_batch == 1:
        return LONG_CONTEXT_RULES
    if kind in ("decode",):
        return DECODE_RULES
    return TRAIN_RULES


def _axis_size(mesh: Mesh, cand: Candidate) -> Optional[int]:
    names = (cand,) if isinstance(cand, str) else cand
    size = 1
    for n in names:
        if n not in mesh.shape:
            return None
        size *= mesh.shape[n]
    return size


def partition_spec_for(
    logical_axes: Sequence[str],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules,
) -> PartitionSpec:
    used: set = set()
    out: List[Any] = []
    for dim, logical in zip(shape, logical_axes):
        assigned = None
        for cand in rules.candidates(logical):
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            size = _axis_size(mesh, cand)
            if size is None or size <= 1:
                continue
            if any(n in used for n in names):
                continue
            if dim % size != 0:
                continue
            assigned = names if len(names) > 1 else names[0]
            used.update(names)
            break
        out.append(assigned)
    # Trim trailing Nones (canonical PartitionSpec form).
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(
    mesh: Mesh,
    specs_tree: Any,  # tree of ShapeDtypeStruct (or arrays)
    axes_tree: Any,  # matching tree of logical-axis tuples
    rules: ShardingRules,
) -> Any:
    """NamedShardings for a pytree given its logical axes."""

    def one(spec, axes):
        if axes is None:
            return NamedSharding(mesh, PartitionSpec())
        pspec = partition_spec_for(tuple(axes), tuple(spec.shape), mesh, rules)
        return NamedSharding(mesh, pspec)

    return jax.tree.map(
        one, specs_tree, axes_tree,
        is_leaf=lambda x: x is None or (
            isinstance(x, tuple) and all(isinstance(a, str) for a in x)
        ),
    )


def input_sharding_axes(kind: str) -> Dict[str, Any]:
    """Logical axes for step-function inputs by shape kind."""
    if kind == "train":
        return {
            "tokens": ("batch", "seq"),
            "labels": ("batch", "seq"),
            "frontend_embeds": ("batch", "seq", "embed_act"),
        }
    if kind == "prefill":
        return {
            "tokens": ("batch", "seq"),
            "frontend_embeds": ("batch", "seq", "embed_act"),
        }
    if kind == "decode":
        return {"token": ("batch",)}
    raise ValueError(kind)


def bytes_per_device(tree: Any, shardings: Any) -> int:
    """Static parameter-byte footprint per device for a specs tree."""
    total = 0
    for spec, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(spec.shape)) * spec.dtype.itemsize
        total += n // sh.num_devices if sh.is_fully_addressable else n
    return total

"""Activation sharding constraints via a logical-axis context.

GSPMD propagation alone lets FSDP-sharded parameters leak their sharding
into activations (e.g. the embedding gather emits [B, S, D@data] with a
replicated batch — the involuntary-full-remat warnings).  Model code calls
``constrain(x, ("batch", "seq", "embed_act"))`` at block boundaries; when a
:func:`logical_sharding_context` is active this becomes a
``with_sharding_constraint`` resolved through the same divisibility-aware
rules as everything else, and is a no-op otherwise (tests, single device).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ShardingRules, partition_spec_for

_state = threading.local()


def _top() -> Optional[Tuple[Mesh, ShardingRules]]:
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def logical_sharding_context(mesh: Mesh, rules: ShardingRules):
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append((mesh, rules))
    try:
        yield
    finally:
        stack.pop()


def constrain(x: jax.Array, logical_axes: Sequence[str]) -> jax.Array:
    ctx = _top()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = partition_spec_for(tuple(logical_axes), tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

from repro.distributed.sharding import (
    ShardingRules,
    TRAIN_RULES,
    DECODE_RULES,
    LONG_CONTEXT_RULES,
    partition_spec_for,
    tree_shardings,
    rules_for_shape,
)

__all__ = [
    "ShardingRules",
    "TRAIN_RULES",
    "DECODE_RULES",
    "LONG_CONTEXT_RULES",
    "partition_spec_for",
    "tree_shardings",
    "rules_for_shape",
]

"""stablelm-12b — dense GQA with per-head QK norm (StableLM-2 family).

Assigned: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_q_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    block="dense",
    norm="layernorm",
    qk_norm=True,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        norm="layernorm",
        qk_norm=True,
    )


SPEC = ArchSpec(
    arch_id="stablelm-12b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # pure full attention: long_500k skipped (DESIGN §4)
    notes="layernorm + per-head qk-norm",
)

"""Architecture registry: one module per assigned arch (exact published
config), the four assigned input shapes, and ShapeDtypeStruct input specs for
the allocation-free dry-run.

Every arch exposes:
  * ``CONFIG``      — the full :class:`repro.models.transformer.ModelConfig`.
  * ``smoke_config()`` — a reduced same-family config for CPU smoke tests.
  * applicability flags (which shapes run; long_500k only for sub-quadratic
    families — see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import DecodeState, ModelConfig, TransformerLM


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "hymba-1.5b",
    "stablelm-12b",
    "qwen2.5-3b",
    "h2o-danube-1.8b",
    "gemma2-27b",
    "internvl2-2b",
    "whisper-large-v3",
    "dbrx-132b",
    "llama4-maverick-400b-a17b",
    "mamba2-2.7b",
    # the paper's own LLM-serving case-study model (§6, LLaMA 3.1 8B class):
    "llama31-8b",
]

_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "stablelm-12b": "stablelm_12b",
    "qwen2.5-3b": "qwen25_3b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "gemma2-27b": "gemma2_27b",
    "internvl2-2b": "internvl2_2b",
    "whisper-large-v3": "whisper_large_v3",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mamba2-2.7b": "mamba2_2p7b",
    "llama31-8b": "llama31_8b",
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    #: sub-quadratic decode state (SSM / SWA / local-global) => long_500k runs
    long_context: bool
    notes: str = ""

    def shapes(self) -> List[Shape]:
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.long_context:
            out.append(SHAPES["long_500k"])
        return out

    def shape_applicable(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            return self.long_context
        return shape_name in SHAPES


def get_arch(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SPEC


def all_archs() -> List[ArchSpec]:
    return [get_arch(a) for a in ARCH_IDS]


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input — weak-type
# correct, shardable, zero allocation (MULTI-POD DRY-RUN step 2).
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    spec: ArchSpec, shape: Shape, *, batch_override: Optional[int] = None
) -> Dict[str, Any]:
    """Returns kwargs-of-specs for the step function of ``shape.kind``.

    train:   {"tokens": [B,S] i32, "labels": [B,S] i32, (+"frontend_embeds")}
    prefill: {"tokens": [B,S] i32, (+"frontend_embeds")}
    decode:  {"token": [B] i32, "state": DecodeState specs}
    """
    cfg = spec.config
    b = batch_override or shape.global_batch
    s = shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = _sds((b, s), jnp.int32)
        if shape.kind == "train":
            out["labels"] = _sds((b, s), jnp.int32)
        if cfg.frontend == "vision":
            out["frontend_embeds"] = _sds(
                (b, cfg.frontend_seq, cfg.d_model), jnp.float32
            )
        elif cfg.frontend == "audio":
            out["frontend_embeds"] = _sds(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32
            )
    elif shape.kind == "decode":
        out["token"] = _sds((b,), jnp.int32)
        model = TransformerLM(cfg)
        out["state"] = jax.eval_shape(
            lambda: model.init_decode_state(b, s)
        )
    else:
        raise ValueError(shape.kind)
    return out

"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
(arXiv:2401.16818).

Assigned: 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_q_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    block="dense",
    window_pattern="swa",
    sliding_window=4096,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        window_pattern="swa",
        sliding_window=16,
    )


SPEC = ArchSpec(
    arch_id="h2o-danube-1.8b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=True,  # SWA: decode state bounded by the window
    notes="mistral-style SWA(4096)",
)

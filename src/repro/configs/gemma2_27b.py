"""gemma2-27b — local/global alternating attention with logit soft-capping
(arXiv:2408.00118).

Assigned: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
The most paper-representative arch for tiered KV: the SWA half keeps a
window-sized hot KV; the global half's long-tail KV is the cold tier.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    n_q_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    block="dense",
    window_pattern="gemma2",
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,  # query_pre_attn_scalar = d_model/n_heads
    activation="gelu",
    use_post_norms=True,
    tied_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        n_layers=4,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        window_pattern="gemma2",
        sliding_window=16,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="gelu",
        use_post_norms=True,
        tied_embeddings=True,
        embed_scale=True,
    )


SPEC = ArchSpec(
    arch_id="gemma2-27b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=True,  # half the layers are SWA; global-layer decode is O(S)
    notes="local/global alternating + softcaps + post-norms",
)

"""qwen2.5-3b — dense GQA with QKV bias (Qwen2.5 family).

Assigned: 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    n_layers=36,
    d_model=2048,
    n_q_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab=151936,
    block="dense",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tied_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        qkv_bias=True,
        tied_embeddings=True,
    )


SPEC = ArchSpec(
    arch_id="qwen2.5-3b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # pure full attention
    notes="QKV bias, 8:1 GQA ratio",
)

"""llama31-8b — the paper's own LLM-serving case-study model (§6 runs
LLaMA 3.1 8B on a single CXL module vs 70B-q4 on DDR; we use the 8B config
for the serving engine benchmarks and examples).
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b",
    n_layers=32,
    d_model=4096,
    n_q_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    block="dense",
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama31-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
    )


SPEC = ArchSpec(
    arch_id="llama31-8b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,
    notes="paper §6 case-study model (serving engine + fig11 bench)",
)

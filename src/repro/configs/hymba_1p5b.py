"""hymba-1.5b — hybrid parallel attention + Mamba heads (arXiv:2411.13676).

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Window pattern: full attention at layers {0, L/2, L-1}, SWA(1024) elsewhere
(the paper's meta-token + cross-layer-KV-sharing tricks are orthogonal to the
memory-tiering study and omitted; noted in DESIGN.md).
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_q_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    block="hybrid",
    window_pattern="hymba",
    sliding_window=1024,
    rope_theta=10_000.0,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    tied_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        n_layers=4,
        d_model=128,
        n_q_heads=5,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="hybrid",
        window_pattern="hymba",
        sliding_window=16,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=16,
        tied_embeddings=True,
    )


SPEC = ArchSpec(
    arch_id="hymba-1.5b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=True,  # hybrid: SSM state + SWA hot window
    notes="parallel attn+mamba heads, mean-fused; meta tokens omitted",
)

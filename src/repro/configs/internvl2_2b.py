"""internvl2-2b — VLM: stubbed InternViT patch embeddings + InternLM2-1.8B
backbone (arXiv:2404.16821).

Assigned: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The vision frontend is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings fused into the first positions (early fusion).
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24,
    d_model=2048,
    n_q_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    block="dense",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_seq=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        frontend="vision",
        frontend_seq=8,
    )


SPEC = ArchSpec(
    arch_id="internvl2-2b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # pure full attention backbone
    notes="vision frontend stubbed (precomputed patch embeddings)",
)

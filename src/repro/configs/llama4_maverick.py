"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1 + shared
expert, early-fusion multimodal (frontend out of scope for the LM shapes).

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 128e top-1.  Uniform per-layer MoE with these numbers gives ~780B total;
the published 400B-total/17B-active reconciles with *interleaved* dense/MoE
layers (24+24) and dense d_ff=16384 — which is what Maverick ships and what
we implement (pair-scanned; DESIGN.md §4).  Active params ≈ 17B either way.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_q_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    block="moe",
    n_experts=128,
    top_k=1,
    shared_expert_ff=8192,
    moe_every=2,
    d_ff_dense=16384,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke",
        n_layers=4,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab=512,
        block="moe",
        n_experts=4,
        top_k=1,
        shared_expert_ff=128,
        moe_every=2,
        d_ff_dense=256,
    )


SPEC = ArchSpec(
    arch_id="llama4-maverick-400b-a17b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # treated as full attention per assignment
    notes="interleaved dense/MoE pairs; 128e top-1 + shared expert",
)

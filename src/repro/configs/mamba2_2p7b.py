"""mamba2-2.7b — attention-free SSD state-space model (arXiv:2405.21060).

Assigned: 64L d_model=2560 (attn-free) d_ff=0 vocab=50280 ssm_state=128.
d_inner = 2*d = 5120, P = 64 => 80 SSM heads, 1 group.

Arch-applicability note (DESIGN.md §4): no KV cache exists, so the paper's
tiered-KV serving technique is inapplicable; MIKU still governs the
training-time optimizer-state offload stream for this arch.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_q_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    block="ssm",
    rope_theta=None,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    tied_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab=512,
        block="ssm",
        rope_theta=None,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=16,
        tied_embeddings=True,
    )


SPEC = ArchSpec(
    arch_id="mamba2-2.7b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=True,  # O(1) decode state
    notes="attention-free SSD; KV tiering inapplicable (no KV cache)",
)

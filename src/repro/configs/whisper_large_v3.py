"""whisper-large-v3 — encoder-decoder, conv frontend stubbed
(arXiv:2212.04356).

Assigned: 32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
The conv1d audio frontend is a STUB: input_specs() provides 1500 precomputed
frame embeddings (30 s at the post-conv 10 ms hop).  Adaptations noted in
DESIGN.md: gated MLP instead of plain GELU MLP; RoPE on decoder self-attn in
place of learned absolute positions (backbone-stress-equivalent).
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32,
    d_model=1280,
    n_q_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    block="dense",
    norm="layernorm",
    activation="gelu",
    rope_theta=10_000.0,
    n_encoder_layers=32,
    encoder_seq=1500,
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        block="dense",
        norm="layernorm",
        activation="gelu",
        n_encoder_layers=2,
        encoder_seq=32,
        frontend="audio",
    )


SPEC = ArchSpec(
    arch_id="whisper-large-v3",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # full attention enc-dec
    notes="enc-dec; conv frontend stubbed; MHA (kv=q=20)",
)

"""dbrx-132b — fine-grained MoE, 16 experts top-4 (databricks/dbrx-base).

Assigned: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16e top-4.  Analytic: ~132B total / ~36B active.
"""

from repro.configs import ArchSpec
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_q_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    block="moe",
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke",
        n_layers=2,
        d_model=128,
        n_q_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab=512,
        block="moe",
        n_experts=4,
        top_k=2,
    )


SPEC = ArchSpec(
    arch_id="dbrx-132b",
    config=CONFIG,
    smoke=smoke_config(),
    long_context=False,  # full attention
    notes="16 experts top-4 every layer; sort-based dispatch",
)

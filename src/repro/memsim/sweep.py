"""Parallel sweep runner: many independent DES configs, one process pool.

Every figure in the paper is a *matrix* of independent simulations (ops x
tiers x thread counts x platforms).  :class:`SimJob` is the picklable
description of one cell; :func:`run_sweep` executes a batch — serially in
process for small batches, or fanned out over a ``ProcessPoolExecutor`` for
figure matrices (``processes`` argument, or the ``REPRO_SWEEP_PROCS``
environment variable for the benchmark harness).  Results come back in job
order regardless of scheduling, and each job is deterministic given its
seed, so serial and parallel execution are bit-identical.

``run_sweep(jobs, lane="batched")`` (or ``REPRO_SWEEP_LANE=batched``)
routes the whole batch through the vectorized sweep-scale lane
(:mod:`repro.memsim.batched`) instead: the grid advances as one stacked
window-lockstep computation — tiering hooks and per-window telemetry
included — with automatic per-job fallback to the scalar DES only for the
rare job the lane genuinely cannot stack.

MIKU controllers are *constructed inside the worker* (``miku=True``) rather
than shipped across the pool: the controller is stateful, and a fresh,
platform-calibrated instance per job is exactly what the figure runners
want anyway.
"""

from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, List, Optional, Sequence

from repro.core.des import (
    SimResult,
    TieredMemorySim,
    WorkloadSpec,
    validate_workloads,
)
from repro.core.device_model import PlatformModel


@dataclasses.dataclass
class SimJob:
    """One independent simulation: everything a worker needs, picklable."""

    platform: PlatformModel
    workloads: List[WorkloadSpec]
    sim_ns: float
    seed: int = 0
    granularity: int = 4
    window_ns: float = 10_000.0
    #: Build a platform-calibrated MIKU controller in the worker.
    miku: bool = False
    miku_overrides: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: Which decision law ``miku=True`` builds: "pertier" (the per-slow-tier
    #: ensemble, default), "merged" (the explicit MergedSlowPolicy
    #: baseline — one CXL-calibrated ladder over the folded slow deltas),
    #: or "peredge" (the fabric generalization: one ladder per control
    #: edge — device edges plus port-bearing link edges — driving the sim
    #: with ``control_scope="edge"``; identical to "pertier" on
    #: fabric-less platforms).
    miku_law: str = "pertier"
    #: Record per-window control telemetry into SimResult.window_records
    #: (the ``benchmarks/run.py --trace`` payload).
    record_windows: bool = False
    #: Optional :class:`repro.tiering.TieringSpec` — the worker builds a
    #: fresh hook per sim (stateful, like MIKU controllers).
    tiering: Optional[object] = None
    #: Runtime sanitizer (:mod:`repro.analysis.sanitizer`): True/"raise"
    #: checks invariants every window and raises on violation, "record"
    #: accumulates into ``SimResult.sanitizer``; None (default) consults
    #: the ``REPRO_SANITIZE`` environment switch.  Sanitized jobs always
    #: run on the scalar DES (the batched lane cannot be instrumented).
    sanitize: Optional[object] = None
    #: Sampled request-lifecycle tracing (:mod:`repro.obs.trace`): trace
    #: every Nth ToR admission's span chain into ``SimResult.trace``
    #: (0 = off).  Traced jobs always run on the scalar DES — the span
    #: chain is an event-level lens the closed-form lanes cannot produce.
    trace: int = 0
    #: Collect mergeable log-bucketed latency histograms
    #: (:mod:`repro.obs.histogram`) per workload and per tier — and per
    #: window when combined with ``record_windows``.  Supported on every
    #: lane: the exact lane buckets its full latency vector, the fluid
    #: lane synthesizes analytic histograms from station waits.
    latency_hist: bool = False
    #: Record a wall-clock phase profile (setup / event loop / window
    #: passes) into ``SimResult.profile`` via
    #: :class:`repro.obs.metrics.PhaseProfiler` (scalar lane only).
    profile: bool = False

    def __post_init__(self):
        # Fail at job construction (with the platform's tier list) rather
        # than deep inside a pool worker: unknown tier names raise
        # UnknownTierError here.
        validate_workloads(self.platform, self.workloads)
        if self.miku_law not in ("pertier", "merged", "peredge"):
            raise ValueError(
                f"unknown miku_law {self.miku_law!r}; "
                "expected 'pertier', 'merged' or 'peredge'"
            )


def run_job(job: SimJob) -> SimResult:
    """Execute one job (the worker entry point; also the serial path)."""
    controller = None
    if job.miku:
        if job.miku_law == "peredge":
            from repro.fabric import peredge_miku

            controller = peredge_miku(
                job.platform, job.granularity, **job.miku_overrides
            )
        else:
            from repro.memsim.calibration import default_miku, merged_miku

            build = merged_miku if job.miku_law == "merged" else default_miku
            controller = build(
                job.platform, job.granularity, **job.miku_overrides
            )
    prof = None
    if job.profile:
        from repro.obs.metrics import PhaseProfiler

        prof = PhaseProfiler()
        _t0 = prof.clock()
    sim = TieredMemorySim(
        job.platform,
        job.workloads,
        seed=job.seed,
        granularity=job.granularity,
        controller=controller,
        window_ns=job.window_ns,
        record_windows=job.record_windows,
        tiering=job.tiering.build() if job.tiering is not None else None,
        control_scope="edge" if job.miku and job.miku_law == "peredge"
        else "tier",
        sanitize=job.sanitize,
        latency_hist=job.latency_hist,
        trace=job.trace,
        profiler=prof,
    )
    if prof is not None:
        prof.add("setup", prof.clock() - _t0)
    return sim.run(job.sim_ns)


def default_processes() -> int:
    """Worker count from ``REPRO_SWEEP_PROCS`` (0/1 = serial)."""
    try:
        return int(os.environ.get("REPRO_SWEEP_PROCS", "0"))
    except ValueError:
        return 0


def default_lane() -> str:
    """Execution lane from ``REPRO_SWEEP_LANE`` (scalar | batched)."""
    return os.environ.get("REPRO_SWEEP_LANE", "scalar").strip().lower() \
        or "scalar"


def run_sweep(
    jobs: Sequence[SimJob],
    processes: Optional[int] = None,
    lane: Optional[str] = None,
) -> List[SimResult]:
    """Run ``jobs``, returning results in job order.

    ``processes=None`` consults ``REPRO_SWEEP_PROCS``; <=1 runs serially in
    process (no pool overhead — the right default under pytest and for
    single-job calls).

    ``lane`` selects the execution engine (``REPRO_SWEEP_LANE`` when None):

    * ``"scalar"`` (default) — one event-driven DES per job, bit-identical
      to the pinned goldens, fanned over the process pool.
    * ``"batched"`` — the vectorized sweep-scale lane
      (:mod:`repro.memsim.batched`): the whole grid advances as one stacked
      window-lockstep computation, tiering hooks and ``record_windows``
      telemetry included; only jobs the lane genuinely cannot stack (e.g.
      an unregistered tiering policy) fall back to the scalar DES.
    """
    if lane is None:
        lane = default_lane()
    if lane not in ("scalar", "batched"):
        raise ValueError(
            f"unknown sweep lane {lane!r}; expected 'scalar' or 'batched'"
        )
    jobs = list(jobs)
    # Pool metrics on the parent-process registry (worker registries are
    # per-process and not folded back; see docs/observability.md).
    from repro.obs.metrics import default_registry

    reg = default_registry()
    reg.counter("sweep.jobs").inc(float(len(jobs)))
    reg.counter(f"sweep.lane.{lane}").inc(float(len(jobs)))
    if lane == "batched":
        from repro.memsim.batched import run_sweep_batched

        return run_sweep_batched(jobs, processes)
    if processes is None:
        processes = default_processes()
    if processes <= 1 or len(jobs) <= 1:
        return [run_job(j) for j in jobs]
    workers = min(processes, len(jobs), os.cpu_count() or 1)
    with ProcessPoolExecutor(max_workers=workers) as pool:
        return list(pool.map(run_job, jobs))

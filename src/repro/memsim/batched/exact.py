"""Closed-form exact path for single-workload cells (bw-test / lat-test).

A single-workload, single-tier, controller-free cell is a *deterministic*
closed network: every DES event time is a float-accumulated chain
(``t += service``, ``retire = t + pipeline``), and completions happen in
fixed-size cohorts.  Two regimes reproduce the scalar event loop's counts
and times exactly — including the binary-float accumulation, which this
module replays with the same operation order:

* **no-queue** (outstanding ≤ device slots): every request cycles
  issue → service → pipeline → reissue with period ``(t + S) + P``; all
  ``O`` requests share one chain.
* **saturated** (population ≥ slots × (2 + ceil(P/S))): the device never
  idles; completions are cohorts of ``c`` on the ``t += S`` chain, retires
  ``P`` later, and each retire admits exactly one queued request.

Everything in between (partially-filled devices) falls back to the fluid
engine.  Bandwidth, completed counts and timeline buckets are
**bit-identical** to the scalar DES here; occupancy/latency integrals are
reproduced to float-summation order (≤1e-9 relative; see
``tests/test_batched.py``).
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.invariants import require

from repro.core.des import LATENCY_RESERVOIR, SimResult, WorkloadStats
from repro.core.littles_law import OpClass, TierCounters
from repro.memsim.batched.stacking import CellPlan

_OPS = tuple(OpClass)


def _single_tier(export: dict) -> Optional[int]:
    """The one tier a single-workload cell routes to, or None."""
    frac = export["w_tier_frac"][0]
    hot = [t for t, f in enumerate(frac) if f > 0.0]
    if len(hot) != 1 or abs(frac[hot[0]] - 1.0) > 0.0:
        return None
    return hot[0]


def exact_regime(plan: CellPlan) -> Optional[str]:
    """"noqueue" / "saturated" when the closed form applies, else None."""
    e = plan.export
    if plan.units or len(e["w_names"]) != 1:
        return None
    if e["w_phit"][0] != -1.0 or e["w_phases"][0] is not None:
        return None
    tier = _single_tier(e)
    if tier is None:
        return None
    c = e["st_slots"][tier]
    if c < 1:
        return None
    svc = e["w_svc"][0][tier]
    pipe = e["pipe"][tier]
    O = e["w_cores"][0] * e["w_effmlp"][0]
    N = min(O, e["tor_capacity"])
    # The no-queue cycle needs every outstanding request admitted at once:
    # both the device slots AND the ToR pool must cover O (a tiny ToR
    # staggers admissions even with idle servers — that's fluid territory).
    if O <= c and O <= e["tor_capacity"]:
        return "noqueue"
    if N >= c * (2 + math.ceil(pipe / max(svc, 1e-12))):
        return "saturated"
    return None


def _chain(sim_ns: float, svc: float, pipe: float,
           per_cycle: bool) -> Tuple[List[float], List[float]]:
    """Replay the DES's float-accumulated event chain.

    ``per_cycle=True`` is the no-queue cycle (reissue at retire:
    ``t = (t + S) + P``); ``False`` is the saturated cohort chain
    (``t += S``, retire ``t + P``).  Returns (completion, retire) times
    with retire ≤ ``sim_ns`` — exactly the events the scalar loop
    processes."""
    comps: List[float] = []
    rets: List[float] = []
    t = 0.0
    while True:
        t = t + svc
        r = t + pipe if pipe > 0.0 else t
        if r > sim_ns:
            break
        comps.append(t)
        rets.append(r)
        if per_cycle:
            t = r
    return comps, rets


def _timeline(retires: np.ndarray, weights: np.ndarray, sim_ns: float,
              window_ns: float) -> List[Tuple[float, float]]:
    """Reproduce the DES's window-flushed bandwidth timeline buckets.

    A retire at exactly a window boundary lands in the *next* bucket (the
    window event was scheduled earlier, so it pops first on ties)."""
    # Replay the DES's accumulated window schedule (t += window_ns) so the
    # flush count matches its float arithmetic exactly.
    bounds: List[float] = []
    t = window_ns
    while t <= sim_ns:
        bounds.append(t)
        t += window_ns
    n_flush = len(bounds)
    out: List[Tuple[float, float]] = []
    if n_flush == 0:
        return out
    boundaries = np.asarray(bounds)
    idx = np.searchsorted(boundaries, retires, side="right")
    sums = np.zeros(n_flush)
    valid = idx < n_flush
    np.add.at(sums, idx[valid], weights[valid])
    for i, b in enumerate(boundaries):
        out.append((float(b), float(sums[i])))
    return out


def run_exact(plan: CellPlan) -> SimResult:
    """Execute one eligible cell in closed form; see the module docstring."""
    e = plan.export
    regime = exact_regime(plan)
    require(
        regime is not None,
        "exact-regime",
        "run_exact called on a cell outside both closed-form regimes; "
        "the lane must route such cells to the fluid engine",
    )
    tier = _single_tier(e)
    sim_ns = float(plan.job.sim_ns)
    window_ns = float(e["window_ns"])
    svc = e["w_svc"][0][tier]
    pipe = e["pipe"][tier]
    nbytes = e["w_bytes"][0][tier]
    c = e["st_slots"][tier]
    O = e["w_cores"][0] * e["w_effmlp"][0]
    N = min(O, e["tor_capacity"])
    op = _OPS[e["w_op"][0]]

    if regime == "noqueue":
        _, rets = _chain(sim_ns, svc, pipe, per_cycle=True)
        K = len(rets)
        completed = O * K
        r = np.asarray(rets)
        issue = np.concatenate(([0.0], r[:-1]))
        res = r - issue  # residency == latency (admission == issue)
        occ = float((O * res).sum())
        last = r[-1] if K else 0.0
        occ_total = occ + O * (sim_ns - last)
        lat_sum = occ
        latencies = np.repeat(res, O)
        tl_ret, tl_w = r, np.full(K, O * nbytes)
        tor_inserts = O + completed
        tor_peak = O
    else:  # saturated
        comps, rets = _chain(sim_ns, svc, pipe, per_cycle=False)
        K = len(rets)
        completed = c * K
        r = np.asarray(rets)
        # Admission order: the first N at t=0, then one per retire.
        n_adm = N + completed
        a = np.zeros(n_adm)
        if completed:
            a[N:] = np.repeat(r, c)[: n_adm - N]
        j = np.arange(n_adm)
        cohort = j // c  # service cohort (0-based); retires at r[cohort]
        retired = cohort < K
        res = r[cohort[retired]] - a[retired]
        occ = float(res.sum())
        occ_total = occ + float((sim_ns - a[~retired]).sum())
        # Issue (IRQ-entry) times: with O > N the IRQ stages L requests, so
        # admission j was issued when admission j-L freed its IRQ slot.
        L = min(O - N, e["irq_capacity"]) if O > N else 0
        tissue = np.zeros(n_adm)
        if L:
            tissue[N + L:] = a[N: n_adm - L]
        else:
            tissue[N:] = a[N:]
        lat = r[cohort[retired]] - tissue[retired]
        lat_sum = float(lat.sum())
        latencies = lat
        tl_ret, tl_w = r, np.full(K, c * nbytes)
        tor_inserts = N + completed
        tor_peak = N

    st = WorkloadStats()
    st.completed = completed
    st.bytes = float(completed) * nbytes
    st.latency_sum = lat_sum
    st.latency_count = completed
    if completed <= LATENCY_RESERVOIR:
        st.latency_samples = [float(x) for x in latencies]
    else:
        # The DES reservoir-samples uniformly on a private RNG stream; an
        # evenly-spaced subsample is the deterministic stand-in (documented
        # approximate — percentiles, not bandwidth, depend on it).
        pick = np.linspace(0, len(latencies) - 1, LATENCY_RESERVOIR)
        st.latency_samples = [float(latencies[int(i)]) for i in pick]
    st.timeline = _timeline(tl_ret, tl_w, sim_ns, window_ns)

    names = e["tier_names"]
    tier_hists = None
    if getattr(plan.job, "latency_hist", False):
        # Exact cells have the *full* latency vector — bucket it directly
        # (better than the scalar reservoir, same mergeable layout).
        from repro.obs.histogram import LatencyHistogram

        hist = LatencyHistogram.from_samples(latencies)
        st.latency_hist = hist
        tier_hists = {
            names[t]: (hist if t == tier else LatencyHistogram())
            for t in range(e["n_tiers"])
        }
    tcs = {}
    for t in range(e["n_tiers"]):
        tc = TierCounters()
        if t == tier:
            tc.inserts = completed
            tc.occupancy_time = occ
            tc.class_counts = {
                o: (completed if o is op else 0) for o in _OPS
            }
        tcs[names[t]] = tc
    return SimResult(
        sim_ns=sim_ns,
        stats={e["w_names"][0]: st},
        tier_counters=tcs,
        tor_peak=tor_peak,
        tor_occupancy_integral=occ_total,
        tor_inserts=tor_inserts,
        decisions=[],
        per_tier_occupancy_integral={
            names[t]: (occ_total if t == tier else 0.0)
            for t in range(e["n_tiers"])
        },
        window_records=[],
        tiering=None,
        tier_latency_hist=tier_hists,
    )

"""Per-window equilibrium solvers for the batched lane.

The fluid engine reduces each control window to two water-filling
questions, both answered by bisection over the common per-core admission
rate λ (the fluid image of the DES's round-robin core arbitration):

* :func:`station_lambdas` — per-station fair rates: the largest λ each
  station can serve among its users (``+inf`` where unconstrained).  A
  workload held below its fair inflow by a saturated station queues — up
  to its MLP population — instead of inserting faster.
* :func:`global_lambda` — one λ per cell under the shared-ToR *population*
  constraint: each workload's ToR holding is ``min(O, y·R_tor)``, jumping
  to its full MLP population ``O`` once a saturated station clamps it
  below its fair share (its queue then soaks up every permit it has).
  When the summed holdings exceed the ToR, λ shrinks until they fit —
  FIFO admission ties every hungry workload to the same per-core share,
  which is the paper's unfair-queuing collapse in fluid form.

``global_lambda`` has two backends: numpy (default) and a Pallas kernel
(``REPRO_BATCH_BACKEND=pallas``) that runs the whole bisection on-device
(``jax.lax.fori_loop`` inside one ``pl.pallas_call``; interpreted
automatically off-TPU).  Both produce the same fixed point to float
tolerance — ``tests/test_batched.py`` pins backend parity.
"""

from __future__ import annotations

import os
import warnings
from typing import Optional

import numpy as np

_BISECT_ITERS = 48
_EPS = 1e-9


def backend() -> str:
    """Solver backend from ``REPRO_BATCH_BACKEND`` (numpy | pallas)."""
    return os.environ.get("REPRO_BATCH_BACKEND", "numpy").strip().lower()


def station_lambdas(
    A: np.ndarray, cap: np.ndarray, route_svc: np.ndarray, slots: np.ndarray
) -> np.ndarray:
    """Per-(cell, station) fair per-core rate.

    ``A``/``cap``: ``(C, W)`` active cores and per-workload issue-rate caps;
    ``route_svc``: ``(C, W, S)`` expected service seconds each inserted
    request demands from station ``s``; ``slots``: ``(C, S)`` server counts
    (0 = padding).  Returns ``(C, S)`` λ, ``+inf`` where the station can
    serve every user at their cap."""
    C, W = A.shape
    S = slots.shape[1]
    hi0 = (cap / np.maximum(A, 1e-12)).max(axis=1) + 1e-6  # y saturates here
    hi = np.broadcast_to(hi0[:, None], (C, S)).copy()
    lo = np.zeros((C, S))

    def demand(lam):
        y = np.minimum(lam[:, None, :] * A[:, :, None], cap[:, :, None])
        return (y * route_svc).sum(axis=1)

    feasible_at_cap = demand(hi) <= slots + _EPS
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        ok = demand(mid) <= slots + _EPS
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid)
    return np.where(feasible_at_cap, np.inf, lo)


def _population(lam, A, cap, y_sta, o_eff, R_tor, irq_cap):
    """Per-workload ToR holdings at per-core rate ``lam`` (see module doc).

    A queue-builder's holdings are its MLP population minus its share of
    the (full, at the boundary) IRQ — staged requests count against MLP
    but hold no ToR entry."""
    y_free = np.minimum(lam[:, None] * A, cap)
    y = np.minimum(y_free, y_sta)
    clamped = y_sta < y_free * (1.0 - 1e-9)
    unclamped_pop = np.minimum(o_eff, y * R_tor)
    share = y / np.maximum(y.sum(axis=1, keepdims=True), 1e-12)
    qb_pop = np.maximum(o_eff - irq_cap[:, None] * share, unclamped_pop)
    return y, np.where(clamped, qb_pop, unclamped_pop)


def _global_lambda_numpy(A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap):
    C = A.shape[0]
    hi0 = (cap / np.maximum(A, 1e-12)).max(axis=1) + 1e-6
    lo = np.zeros(C)
    hi = hi0.copy()

    def feasible(lam):
        _, pop = _population(lam, A, cap, y_sta, o_eff, R_tor, irq_cap)
        return pop.sum(axis=1) <= tor_cap + _EPS

    feasible_at_cap = feasible(hi0)
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        ok = feasible(mid)
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid)
    return np.where(feasible_at_cap, np.inf, lo)


_pallas_solver = None
_pallas_failed = False


def _build_pallas_solver():
    """Compile the bisection as one Pallas kernel (interpreted off-TPU)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    interpret = jax.default_backend() != "tpu"

    def kernel(a_ref, cap_ref, ysta_ref, oeff_ref, rtor_ref, tor_ref,
               irq_ref, hi_ref, out_ref):
        A = a_ref[:]              # (C, W)
        cap = cap_ref[:]          # (C, W)
        y_sta = ysta_ref[:]       # (C, W)
        o_eff = oeff_ref[:]       # (C, W)
        r_tor = rtor_ref[:]       # (C, W)
        tor = tor_ref[:]          # (C, 1)
        irq = irq_ref[:]          # (C, 1)
        hi0 = hi_ref[:]           # (C, 1)

        def feasible(lam):        # lam (C, 1) -> (C, 1) bool
            y_free = jnp.minimum(lam * A, cap)
            y = jnp.minimum(y_free, y_sta)
            clamped = y_sta < y_free * (1.0 - 1e-9)
            unc = jnp.minimum(o_eff, y * r_tor)
            share = y / jnp.maximum(y.sum(axis=1, keepdims=True), 1e-12)
            pop = jnp.where(
                clamped, jnp.maximum(o_eff - irq * share, unc), unc
            )
            return pop.sum(axis=1, keepdims=True) <= tor + _EPS

        def body(_, lo_hi):
            lo, hi = lo_hi
            mid = 0.5 * (lo + hi)
            ok = feasible(mid)
            return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

        lo = jnp.zeros_like(hi0)
        lo, _ = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi0))
        out_ref[:] = jnp.where(feasible(hi0), jnp.inf, lo)

    @jax.jit
    def solve(A, cap, y_sta, o_eff, r_tor, tor, irq, hi0):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(hi0.shape, jnp.float32),
            interpret=interpret,
        )(A, cap, y_sta, o_eff, r_tor, tor, irq, hi0)

    return solve


def _global_lambda_pallas(A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap):
    global _pallas_solver
    import jax.numpy as jnp

    if _pallas_solver is None:
        _pallas_solver = _build_pallas_solver()
    big = 1e30  # f32-safe stand-in for +inf inputs
    f32 = lambda x: jnp.asarray(np.minimum(x, big), jnp.float32)  # noqa: E731
    hi0 = (np.minimum(cap, big) / np.maximum(A, 1e-12)).max(axis=1) + 1e-6
    lam = _pallas_solver(
        f32(A), f32(cap), f32(y_sta), f32(o_eff), f32(R_tor),
        f32(tor_cap[:, None]), f32(irq_cap[:, None]), f32(hi0[:, None]),
    )
    return np.asarray(lam, dtype=np.float64)[:, 0]


def global_lambda(
    A: np.ndarray,
    cap: np.ndarray,
    y_sta: np.ndarray,
    o_eff: np.ndarray,
    R_tor: np.ndarray,
    tor_cap: np.ndarray,
    irq_cap: np.ndarray,
    force_backend: Optional[str] = None,
) -> np.ndarray:
    """Max common per-core rate per cell under the ToR population bound.

    ``cap`` is the issue-side cap (token rate and MLP); ``y_sta`` the
    per-workload fair station-capacity share; ``o_eff`` the MLP population
    bound; ``R_tor`` the per-insert ToR residency; ``irq_cap`` the staging
    queue each queue-builder's MLP partly parks in.  Returns ``(C,)`` λ,
    ``+inf`` where the ToR never fills."""
    chosen = force_backend or backend()
    global _pallas_failed
    if chosen == "pallas" and not _pallas_failed:
        if force_backend:
            # Explicitly forced (tests, parity gates): a broken pallas
            # backend must FAIL, not silently compare numpy to numpy.
            return _global_lambda_pallas(
                A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
            )
        try:
            return _global_lambda_pallas(
                A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
            )
        except Exception as ex:  # missing/odd jax: fall back, once, loudly
            _pallas_failed = True
            warnings.warn(
                f"REPRO_BATCH_BACKEND=pallas unavailable ({ex!r}); "
                "falling back to the numpy solver",
                RuntimeWarning,
            )
    return _global_lambda_numpy(
        A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
    )

"""Per-window equilibrium solvers for the batched lane.

The fluid engine reduces each control window to two water-filling
questions, both answered by bisection over the common per-core admission
rate λ (the fluid image of the DES's round-robin core arbitration):

* :func:`station_lambdas` — per-station fair rates: the largest λ each
  station can serve among its users (``+inf`` where unconstrained).  A
  workload held below its fair inflow by a saturated station queues — up
  to its MLP population — instead of inserting faster.
* :func:`global_lambda` — one λ per cell under the shared-ToR *population*
  constraint: each workload's ToR holding is ``min(O, y·R_tor)``, jumping
  to its full MLP population ``O`` once a saturated station clamps it
  below its fair share (its queue then soaks up every permit it has).
  When the summed holdings exceed the ToR, λ shrinks until they fit —
  FIFO admission ties every hungry workload to the same per-core share,
  which is the paper's unfair-queuing collapse in fluid form.

``global_lambda`` has two backends: numpy (default) and a Pallas kernel
(``REPRO_BATCH_BACKEND=pallas``) that runs the whole bisection on-device
(``jax.lax.fori_loop`` inside one ``pl.pallas_call``; interpreted
automatically off-TPU).  Both produce the same fixed point to float
tolerance — ``tests/test_batched.py`` pins backend parity.

:func:`fused_window_solve` goes further: under the pallas backend the
fluid engine hands the *entire* per-window wait-relaxation loop (station
scaling, global-λ Pallas bisection, queue-builder population
accounting, Little's-law wait update — everything between routing setup
and the control-window fire) to one jit-compiled function, so a window
costs one device dispatch instead of ``n_outer`` python iterations of
einsums.  That is what scales 1k+-cell grids: the python overhead per
window becomes O(1) in cell count.
"""

from __future__ import annotations

import os
import warnings
from typing import Optional

import numpy as np

_BISECT_ITERS = 48
_EPS = 1e-9


def backend() -> str:
    """Solver backend from ``REPRO_BATCH_BACKEND`` (numpy | pallas)."""
    return os.environ.get("REPRO_BATCH_BACKEND", "numpy").strip().lower()


def station_lambdas(
    A: np.ndarray, cap: np.ndarray, route_svc: np.ndarray, slots: np.ndarray
) -> np.ndarray:
    """Per-(cell, station) fair per-core rate.

    ``A``/``cap``: ``(C, W)`` active cores and per-workload issue-rate caps;
    ``route_svc``: ``(C, W, S)`` expected service seconds each inserted
    request demands from station ``s``; ``slots``: ``(C, S)`` server counts
    (0 = padding).  Returns ``(C, S)`` λ, ``+inf`` where the station can
    serve every user at their cap."""
    C, W = A.shape
    S = slots.shape[1]
    hi0 = (cap / np.maximum(A, 1e-12)).max(axis=1) + 1e-6  # y saturates here
    hi = np.broadcast_to(hi0[:, None], (C, S)).copy()
    lo = np.zeros((C, S))

    def demand(lam):
        y = np.minimum(lam[:, None, :] * A[:, :, None], cap[:, :, None])
        return (y * route_svc).sum(axis=1)

    feasible_at_cap = demand(hi) <= slots + _EPS
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        ok = demand(mid) <= slots + _EPS
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid)
    return np.where(feasible_at_cap, np.inf, lo)


def _population(lam, A, cap, y_sta, o_eff, R_tor, irq_cap):
    """Per-workload ToR holdings at per-core rate ``lam`` (see module doc).

    A queue-builder's holdings are its MLP population minus its share of
    the (full, at the boundary) IRQ — staged requests count against MLP
    but hold no ToR entry."""
    y_free = np.minimum(lam[:, None] * A, cap)
    y = np.minimum(y_free, y_sta)
    clamped = y_sta < y_free * (1.0 - 1e-9)
    unclamped_pop = np.minimum(o_eff, y * R_tor)
    share = y / np.maximum(y.sum(axis=1, keepdims=True), 1e-12)
    qb_pop = np.maximum(o_eff - irq_cap[:, None] * share, unclamped_pop)
    return y, np.where(clamped, qb_pop, unclamped_pop)


def _global_lambda_numpy(A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap):
    C = A.shape[0]
    hi0 = (cap / np.maximum(A, 1e-12)).max(axis=1) + 1e-6
    lo = np.zeros(C)
    hi = hi0.copy()

    def feasible(lam):
        _, pop = _population(lam, A, cap, y_sta, o_eff, R_tor, irq_cap)
        return pop.sum(axis=1) <= tor_cap + _EPS

    feasible_at_cap = feasible(hi0)
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        ok = feasible(mid)
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid)
    return np.where(feasible_at_cap, np.inf, lo)


_pallas_solver = None
_pallas_failed = False


def _glam_kernel(jax, jnp):
    """The global-λ bisection as a Pallas kernel body (shared by the
    standalone :func:`global_lambda` backend and the fused window solver)."""

    def kernel(a_ref, cap_ref, ysta_ref, oeff_ref, rtor_ref, tor_ref,
               irq_ref, hi_ref, out_ref):
        A = a_ref[:]              # (C, W)
        cap = cap_ref[:]          # (C, W)
        y_sta = ysta_ref[:]       # (C, W)
        o_eff = oeff_ref[:]       # (C, W)
        r_tor = rtor_ref[:]       # (C, W)
        tor = tor_ref[:]          # (C, 1)
        irq = irq_ref[:]          # (C, 1)
        hi0 = hi_ref[:]           # (C, 1)

        def feasible(lam):        # lam (C, 1) -> (C, 1) bool
            y_free = jnp.minimum(lam * A, cap)
            y = jnp.minimum(y_free, y_sta)
            clamped = y_sta < y_free * (1.0 - 1e-9)
            unc = jnp.minimum(o_eff, y * r_tor)
            share = y / jnp.maximum(y.sum(axis=1, keepdims=True), 1e-12)
            pop = jnp.where(
                clamped, jnp.maximum(o_eff - irq * share, unc), unc
            )
            return pop.sum(axis=1, keepdims=True) <= tor + _EPS

        def body(_, lo_hi):
            lo, hi = lo_hi
            mid = 0.5 * (lo + hi)
            ok = feasible(mid)
            return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

        lo = jnp.zeros_like(hi0)
        lo, _ = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi0))
        out_ref[:] = jnp.where(feasible(hi0), jnp.inf, lo)

    return kernel


def _build_pallas_solver():
    """Compile the bisection as one Pallas kernel (interpreted off-TPU)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    interpret = jax.default_backend() != "tpu"
    kernel = _glam_kernel(jax, jnp)

    @jax.jit
    def solve(A, cap, y_sta, o_eff, r_tor, tor, irq, hi0):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(hi0.shape, jnp.float32),
            interpret=interpret,
        )(A, cap, y_sta, o_eff, r_tor, tor, irq, hi0)

    return solve


def _global_lambda_pallas(A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap):
    global _pallas_solver
    import jax.numpy as jnp

    if _pallas_solver is None:
        _pallas_solver = _build_pallas_solver()
    big = 1e30  # f32-safe stand-in for +inf inputs
    f32 = lambda x: jnp.asarray(np.minimum(x, big), jnp.float32)  # noqa: E731
    hi0 = (np.minimum(cap, big) / np.maximum(A, 1e-12)).max(axis=1) + 1e-6
    lam = _pallas_solver(
        f32(A), f32(cap), f32(y_sta), f32(o_eff), f32(R_tor),
        f32(tor_cap[:, None]), f32(irq_cap[:, None]), f32(hi0[:, None]),
    )
    return np.asarray(lam, dtype=np.float64)[:, 0]


def global_lambda(
    A: np.ndarray,
    cap: np.ndarray,
    y_sta: np.ndarray,
    o_eff: np.ndarray,
    R_tor: np.ndarray,
    tor_cap: np.ndarray,
    irq_cap: np.ndarray,
    force_backend: Optional[str] = None,
) -> np.ndarray:
    """Max common per-core rate per cell under the ToR population bound.

    ``cap`` is the issue-side cap (token rate and MLP); ``y_sta`` the
    per-workload fair station-capacity share; ``o_eff`` the MLP population
    bound; ``R_tor`` the per-insert ToR residency; ``irq_cap`` the staging
    queue each queue-builder's MLP partly parks in.  Returns ``(C,)`` λ,
    ``+inf`` where the ToR never fills."""
    chosen = force_backend or backend()
    global _pallas_failed
    if chosen == "pallas" and not _pallas_failed:
        if force_backend:
            # Explicitly forced (tests, parity gates): a broken pallas
            # backend must FAIL, not silently compare numpy to numpy.
            return _global_lambda_pallas(
                A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
            )
        try:
            return _global_lambda_pallas(
                A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
            )
        except Exception as ex:  # missing/odd jax: fall back, once, loudly
            _pallas_failed = True
            warnings.warn(
                f"REPRO_BATCH_BACKEND=pallas unavailable ({ex!r}); "
                "falling back to the numpy solver",
                RuntimeWarning,
            )
    return _global_lambda_numpy(
        A, cap, y_sta, o_eff, R_tor, tor_cap, irq_cap
    )


_fused_solvers: dict = {}


def _build_fused_solver(n_outer: int, damp: float):
    """Compile the whole wait-relaxation loop as one jit function.

    The outer loop (``n_outer`` damped iterations), the station bisection,
    and the global-λ Pallas bisection all run inside a single ``jax.jit``
    trace, so the fluid engine pays one dispatch per window regardless of
    cell count.  f32 throughout with ``1e30`` standing in for ``+inf``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    interpret = jax.default_backend() != "tpu"
    glam_kernel = _glam_kernel(jax, jnp)
    big = 1e30

    def glam(A, cap, y_sta, o_eff, r_tor, tor, irq):
        hi0 = (jnp.minimum(cap, big)
               / jnp.maximum(A, 1e-12)).max(axis=1, keepdims=True) + 1e-6
        return pl.pallas_call(
            glam_kernel,
            out_shape=jax.ShapeDtypeStruct(hi0.shape, jnp.float32),
            interpret=interpret,
        )(A, cap, y_sta, o_eff, r_tor, tor, irq, hi0)

    def station_lams(A, cap, route_svc, slots):
        hi0 = (cap / jnp.maximum(A, 1e-12)).max(axis=1) + 1e-6  # (C,)
        hi = jnp.broadcast_to(hi0[:, None], slots.shape)
        lo = jnp.zeros_like(hi)

        def demand(lam):
            y = jnp.minimum(lam[:, None, :] * A[:, :, None], cap[:, :, None])
            return (y * route_svc).sum(axis=1)

        feasible_at_cap = demand(hi) <= slots + _EPS

        def body(_, lo_hi):
            lo, hi = lo_hi
            mid = 0.5 * (lo + hi)
            ok = demand(mid) <= slots + _EPS
            return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

        lo, _ = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
        return jnp.where(feasible_at_cap, big, lo)

    @jax.jit
    def solve(A, y_rate, o_eff, route, route_svc, svc_pipe, slots, tor,
              irq, Wq0):
        # Mirrors the numpy relaxation in fluid.run_fluid line for line;
        # tor/irq arrive as (C, 1) columns for in-kernel broadcasting.
        R_base = (route * svc_pipe).sum(axis=2)
        used = route_svc > 1e-12

        def outer(_, state):
            y, Wq, lam = state
            r_sta = Wq[:, None, :] + svc_pipe
            R_tor = (route * r_sta).sum(axis=2)
            cap = jnp.minimum(y_rate, o_eff / jnp.maximum(R_tor, 1e-9))
            cap = jnp.where(A > 0, cap, 0.0)
            lam_s = station_lams(A, cap, route_svc, slots)
            lam_min = jnp.where(used, lam_s[:, None, :], big).min(axis=2)
            y_sta = jnp.minimum(lam_min, big) * jnp.maximum(A, 0.0)
            lam = glam(A, cap, y_sta, o_eff, R_tor, tor, irq)  # (C, 1)
            lam_b = jnp.minimum(lam, big)
            y_free = jnp.minimum(lam_b * A, cap)
            y = jnp.minimum(y_free, y_sta)
            qb = (y_sta <= lam_b * A * (1.0 + 1e-9)) & (
                y_sta < cap * (1.0 - 1e-9)
            )
            unc_pop = jnp.minimum(o_eff, y * R_tor)
            share = y / jnp.maximum(y.sum(axis=1, keepdims=True), 1e-12)
            pop_w = jnp.where(
                qb, jnp.maximum(o_eff - irq * share, unc_pop), unc_pop
            )
            d_s = jnp.einsum("cw,cws->cs", y, route_svc)
            inflow_s = jnp.einsum("cw,cws->cs", y, route)
            util = d_s / jnp.maximum(slots, 1e-9)
            sat = (util >= 0.98) & (slots > 0)
            n_pop = jnp.minimum(pop_w.sum(axis=1), tor[:, 0])
            base_pop = (y * R_base).sum(axis=1)
            q_total = jnp.maximum(n_pop - base_pop, 0.0)
            q_max = jnp.where(qb, jnp.maximum(pop_w - y * R_base, 0.0), 0.0)
            q_sum = q_max.sum(axis=1)
            scale = jnp.where(
                q_sum > 1e-12,
                jnp.minimum(1.0, q_total / jnp.maximum(q_sum, 1e-12)), 0.0,
            )
            q_w = q_max * scale[:, None]
            w_st = jnp.where(sat[:, None, :], route_svc, 0.0)
            w_norm = w_st.sum(axis=2, keepdims=True)
            w_st = jnp.where(
                w_norm > 1e-12, w_st / jnp.maximum(w_norm, 1e-12), 0.0
            )
            q_s = jnp.einsum("cw,cws->cs", q_w, w_st)
            mean_svc = d_s / jnp.maximum(inflow_s, 1e-12)
            w_new = q_s * mean_svc / jnp.maximum(slots, 1e-9)
            w_new = jnp.where(sat, w_new, 0.0)
            Wq = damp * Wq + (1.0 - damp) * w_new
            return y, Wq, lam

        y0 = jnp.zeros_like(A)
        lam0 = jnp.full((A.shape[0], 1), jnp.inf, jnp.float32)
        y, Wq, lam = jax.lax.fori_loop(
            0, n_outer, outer, (y0, Wq0, lam0)
        )
        return y, Wq, lam[:, 0]

    return solve


def fused_window_solve(
    A: np.ndarray,
    y_rate: np.ndarray,
    o_eff: np.ndarray,
    route: np.ndarray,
    route_svc: np.ndarray,
    svc_pipe: np.ndarray,
    slots: np.ndarray,
    tor_cap: np.ndarray,
    irq_cap: np.ndarray,
    Wq: np.ndarray,
    n_outer: int,
    damp: float,
) -> tuple:
    """One jit dispatch for a window's full wait-relaxation loop.

    Numpy in / numpy out: arrays go to f32 on device (``1e30`` standing in
    for ``+inf`` rate caps) and come back float64.  Returns ``(y, Wq, lam)``
    with ``lam`` the last iteration's global λ — ``+inf`` where the ToR
    never fills, so ``np.isfinite(lam)`` stays the coupling test.  Raises
    on any jax failure; the fluid engine catches once, warns, and reruns
    the numpy loop.
    """
    import jax.numpy as jnp

    key = (int(n_outer), float(damp))
    solver = _fused_solvers.get(key)
    if solver is None:
        solver = _fused_solvers[key] = _build_fused_solver(*key)
    big = 1e30
    f32 = lambda x: jnp.asarray(np.minimum(x, big), jnp.float32)  # noqa: E731
    y, wq, lam = solver(
        f32(A), f32(y_rate), f32(o_eff), f32(route), f32(route_svc),
        f32(svc_pipe), f32(slots), f32(tor_cap[:, None]),
        f32(irq_cap[:, None]), f32(Wq),
    )
    return (
        np.asarray(y, dtype=np.float64),
        np.asarray(wq, dtype=np.float64),
        np.asarray(lam, dtype=np.float64),
    )

"""The window-lockstep fluid engine: one numpy step advances every cell.

Each control window is modeled as a closed-network equilibrium of the same
structures the DES simulates event-by-event (§4.2): cores with bounded MLP
issuing round-robin, the FIFO IRQ/ToR admission path, per-tier device
stations, the optional LLC station, and the shared ToR population bound.
Two regimes per cell per window, matching the scalar dynamics:

* **uncoupled** — the ToR has room: each workload runs at its own issue
  cap (MLP / token rate), clamped to the fair share of any saturated
  station it routes traffic through.
* **coupled** — the combined queue appetite exceeds the ToR: every
  admission is a fair per-core share (FIFO arbitration), so one λ governs
  all workloads and a saturated slow station collapses the fast tier's
  inserts — the paper's unfair-queuing mechanism in fluid form.

Station waits relax to put the queued population where the saturated
stations are (Little's law both ways), the per-tier window counters feed
the vectorized MIKU ladders (:class:`repro.core.controller.
VectorMikuLadder`), and the resulting tier-addressed caps/rates throttle
the next window — the same sample → estimate → decide → apply loop as
:class:`repro.core.substrate.ControlLoop`, evaluated across all cells at
once.
"""

from __future__ import annotations

import math
import warnings
from typing import List, Optional

import numpy as np

from repro.core.controller import (
    Decision,
    Phase,
    TierDecisions,
    VectorMikuLadder,
)
from repro.core.des import SimResult, WorkloadStats
from repro.core.littles_law import OpClass, TierCounters, TierEstimate
from repro.core.substrate import _decision_jsonable
from repro.memsim.batched import kernel
from repro.memsim.batched.stacking import BatchGroup
from repro.memsim.batched.tiering import VectorTiering, build_tiering

_OPS = tuple(OpClass)
_N_OUTER = 30  # wait-relaxation iterations per window
_DAMP = 0.5


def build_ladder(group: BatchGroup) -> Optional[VectorMikuLadder]:
    """The group's stacked vector ladder (None when no cell has MIKU).

    Raises ``ValueError`` for unstackable ladder configurations — the lane
    catches that during planning and falls the group back to the scalar
    DES."""
    grid = [
        p.units if p.units else []
        for p in group.plans
    ]
    if not any(grid):
        return None
    return VectorMikuLadder.from_units(grid)


def run_fluid(
    group: BatchGroup,
    ladder: Optional[VectorMikuLadder] = None,
    tiering: Optional[VectorTiering] = None,
) -> List[SimResult]:
    """Run one stacked cell group to its horizons; SimResults in group order.

    ``ladder``/``tiering`` are the group's pre-built :func:`build_ladder` /
    :func:`~repro.memsim.batched.tiering.build_tiering` results (built here
    when omitted)."""
    C, W, S, T = (len(group.plans), group.n_wl, group.n_st, group.n_tiers)
    llc = group.llc
    win = group.window_ns
    n_ops = len(_OPS)
    merged = np.array([p.merged for p in group.plans])
    has_ctl = np.array([bool(p.units) for p in group.plans])
    n_slow_cell = group.n_tiers_cell - 1
    U = max(1, T - 1)

    if ladder is None:
        ladder = build_ladder(group)
    if tiering is None:
        tiering = build_tiering(group)
    vt = tiering
    record_mask = np.array(
        [bool(p.job.record_windows) for p in group.plans]
    )
    # Analytic latency histograms (repro.obs): fluid cells have no per-
    # request events, so each window contributes one weighted entry — the
    # window's mean station latency at the window's completion count —
    # per workload (and per tier from the device-station split).  Same
    # mergeable bucket layout as the scalar lane; parity is toleranced,
    # not exact (documented in docs/observability.md).
    hist_mask = np.array(
        [bool(getattr(p.job, "latency_hist", False)) for p in group.plans]
    )
    hist_on = bool(hist_mask.any())
    LatencyHistogram = None
    hist_w: Optional[list] = None
    hist_t: Optional[list] = None
    if hist_on:
        from repro.obs.histogram import LatencyHistogram

        hist_w = [
            [LatencyHistogram() for _ in range(W)] if hist_mask[ci] else None
            for ci in range(C)
        ]
        hist_t = [
            [LatencyHistogram() for _ in range(T)] if hist_mask[ci] else None
            for ci in range(C)
        ]

    # Station-shaped constants: device service per (c, w, s) with the LLC
    # column; pipeline per station (LLC has none).
    pipe_st = np.zeros((C, W, S))
    pipe_st[:, :, :T] = group.pipe[:, None, :T]
    svc = group.svc  # (C, W, S): tiers then llc
    svc_pipe = svc + pipe_st  # per-insert station residency sans queueing
    op_onehot = np.zeros((C, W, n_ops))
    for o in range(n_ops):
        op_onehot[:, :, o] = group.op == o
    has_phases = any(
        seq is not None for row in group.phases for seq in row
    )

    # Throttle state written by the ladder (tier-addressed, like apply()).
    tier_cap = np.full((C, max(1, T - 1)), np.inf)
    tier_rate = np.ones((C, max(1, T - 1)))
    Wq = np.zeros((C, S))  # station waits, warm-started across windows
    # Live issue tables written by the tiering twin: routing vectors
    # (placement re-resolution) and effective MLP (migration issue gating),
    # the fluid image of the scalar hook's ``_apply_placements`` /
    # ``_w_effmlp`` writes.  Without tiering they never change.
    tier_frac_live = group.tier_frac.copy()
    effmlp_live = group.effmlp.copy()
    # Under the pallas backend the whole relaxation loop runs as one jit
    # dispatch per window (kernel.fused_window_solve); a failing jax stack
    # falls back — once, loudly — to the numpy loop below.
    use_fused = kernel.backend() == "pallas"

    # Accumulators.
    bytes_w = np.zeros((C, W))
    completed_w = np.zeros((C, W))
    latsum_w = np.zeros((C, W))
    ins_t = np.zeros((C, T))
    occ_t = np.zeros((C, T))
    cls_t = np.zeros((C, T, n_ops))
    occ_int_t = np.zeros((C, T))
    tor_inserts = np.zeros(C)
    tor_occ = np.zeros(C)
    tor_peak = np.zeros(C)
    decisions: List[list] = [[] for _ in range(C)]
    timelines: List[List[np.ndarray]] = [[] for _ in range(C)]
    records: List[List[dict]] = [[] for _ in range(C)]
    fired_count = np.zeros(C, np.int64)

    n_seg = int(np.max(np.ceil(group.sim_ns / win - 1e-9))) if C else 0
    for k in range(n_seg):
        t0 = np.full(C, k * win)
        t1 = np.minimum(t0 + win, group.sim_ns)
        seg_len = np.maximum(t1 - t0, 0.0)
        active = seg_len > 1e-12
        if not active.any():
            break
        fire = active & (t1 >= t0 + win - 1e-9)

        # -- routing & throttles for this window --------------------------
        frac = (
            group.window_fracs(t0, t1, base=tier_frac_live)
            if has_phases else tier_frac_live
        )  # (C, W, T)
        p = group.p_llc
        route = np.zeros((C, W, S))
        lottery = (p >= 0.0) & (p <= 1.0)
        p_llc = np.where(p == 2.0, 1.0, np.where(lottery, p, 0.0))
        route[:, :, :T] = frac * (1.0 - p_llc)[:, :, None]
        route[:, :, llc] = p_llc
        touched = group.managed[:, :, None] & (frac[:, :, 1:] > 1e-12)
        cap_full = np.where(touched, tier_cap[:, None, :T - 1], np.inf)
        w_cap = cap_full.min(axis=2) if T > 1 else np.full((C, W), np.inf)
        rate_full = np.where(touched, tier_rate[:, None, :T - 1], 1.0)
        w_rate = rate_full.min(axis=2) if T > 1 else np.ones((C, W))
        A = np.minimum(group.cores, w_cap)
        A = np.where(group.active_w, np.maximum(A, 0.0), 0.0)
        e_cost = (frac * svc[:, :, :T]).sum(axis=2)
        y_rate = np.where(
            w_rate >= 1.0 - 1e-12, np.inf,
            w_rate / np.maximum(e_cost, 1e-9),
        )
        o_eff = A * effmlp_live
        route_svc = route * svc

        # -- equilibrium solve (wait relaxation + water-filling) ----------
        y = None
        if use_fused:
            try:
                y, Wq, lam = kernel.fused_window_solve(
                    A, y_rate, o_eff, route, route_svc, svc_pipe,
                    group.slots, group.tor_cap, group.irq_cap, Wq,
                    _N_OUTER, _DAMP,
                )
                coupled = np.isfinite(lam)
            except Exception as ex:
                use_fused = False
                y = None
                warnings.warn(
                    f"fused pallas window solver unavailable ({ex!r}); "
                    "falling back to the numpy relaxation loop",
                    RuntimeWarning,
                )
        if y is None:
            y = np.zeros((C, W))
            coupled = np.zeros(C, bool)
            R_tor = np.zeros((C, W))
            used = route_svc > 1e-12
            for _ in range(_N_OUTER):
                r_sta = Wq[:, None, :] + svc_pipe
                R_tor = (route * r_sta).sum(axis=2)
                R_base = (route * svc_pipe).sum(axis=2)
                # Issue-side caps: token-bucket rate and the MLP population
                # (waits included — a backlogged tier slows its own
                # issuers).
                cap = np.minimum(y_rate, o_eff / np.maximum(R_tor, 1e-9))
                cap = np.where(A > 0, cap, 0.0)
                lam_s = kernel.station_lambdas(
                    A, cap, route_svc, group.slots
                )
                lam_min = np.where(
                    used, lam_s[:, None, :], np.inf
                ).min(axis=2)
                # Inactive (padded) workload slots have no used station:
                # their lam_min is +inf and A is 0 — clamp before
                # multiplying so the product is 0, not NaN.
                y_sta = np.where(np.isfinite(lam_min), lam_min, 1e30) \
                    * np.maximum(A, 0.0)
                lam = kernel.global_lambda(
                    A, cap, y_sta, o_eff, R_tor, group.tor_cap,
                    group.irq_cap,
                )
                coupled = np.isfinite(lam)
                lam_b = np.where(np.isfinite(lam), lam, 1e30)[:, None]
                y_free = np.minimum(lam_b * A, cap)
                y = np.minimum(y_free, y_sta)
                # Queue-builders: held at their station share while their
                # admission allowance (λ·A) and issue caps still have
                # headroom — their queue soaks up permits up to the MLP
                # population (minus the IRQ-staged share), which is what
                # fills the ToR at the feasibility boundary.
                qb = (y_sta <= lam_b * A * (1.0 + 1e-9)) & (
                    y_sta < cap * (1.0 - 1e-9)
                )
                unc_pop = np.minimum(o_eff, y * R_tor)
                share = y / np.maximum(y.sum(axis=1, keepdims=True), 1e-12)
                pop_w = np.where(
                    qb,
                    np.maximum(
                        o_eff - group.irq_cap[:, None] * share, unc_pop
                    ),
                    unc_pop,
                )

                # Wait relaxation: the queued population (ToR holdings
                # beyond service + flight) sits at the saturated stations
                # of the station-clamped workloads; Little's law converts
                # queue depth to wait.
                d_s = np.einsum("cw,cws->cs", y, route_svc)
                inflow_s = np.einsum("cw,cws->cs", y, route)
                util = d_s / np.maximum(group.slots, 1e-9)
                sat = (util >= 0.98) & (group.slots > 0)
                n_pop = np.minimum(pop_w.sum(axis=1), group.tor_cap)
                base_pop = (y * R_base).sum(axis=1)
                q_total = np.maximum(n_pop - base_pop, 0.0)
                q_max = np.where(
                    qb, np.maximum(pop_w - y * R_base, 0.0), 0.0
                )
                q_sum = q_max.sum(axis=1)
                scale = np.where(
                    q_sum > 1e-12, np.minimum(1.0, q_total / np.maximum(
                        q_sum, 1e-12)), 0.0
                )
                q_w = q_max * scale[:, None]
                w_st = np.where(sat[:, None, :], route_svc, 0.0)
                w_norm = w_st.sum(axis=2, keepdims=True)
                w_st = np.where(
                    w_norm > 1e-12, w_st / np.maximum(w_norm, 1e-12), 0.0
                )
                q_s = np.einsum("cw,cws->cs", q_w, w_st)
                mean_svc = d_s / np.maximum(inflow_s, 1e-12)
                w_new = q_s * mean_svc / np.maximum(group.slots, 1e-9)
                w_new = np.where(sat, w_new, 0.0)
                Wq = _DAMP * Wq + (1.0 - _DAMP) * w_new

        # -- accumulate window counters -----------------------------------
        dt = np.where(active, seg_len, 0.0)
        ins_w = y * dt[:, None]
        r_sta = Wq[:, None, :] + svc_pipe
        R_tor = (route * r_sta).sum(axis=2)
        y_tot = y.sum(axis=1)
        w_irq = np.where(
            coupled, group.irq_cap / np.maximum(y_tot, 1e-9), 0.0
        )
        route_dev = route[:, :, :T]
        ins_dev = np.einsum("cw,cwt->cwt", ins_w, route_dev)
        ins_t += ins_dev.sum(axis=1)
        occ_dev = ins_dev * r_sta[:, :, :T]
        occ_t += occ_dev.sum(axis=1)
        cls_w = np.einsum("cwt,cwo->cto", ins_dev, op_onehot)
        cls_t += cls_w
        bytes_win = ins_w * (frac * group.bytes_t).sum(axis=2)
        bytes_w += bytes_win
        completed_w += ins_w
        lat_mean = R_tor + w_irq[:, None]  # (C, W) analytic mean latency
        latsum_w += ins_w * lat_mean
        if hist_on:
            lat_dev = r_sta[:, :, :T] + w_irq[:, None, None]
            for ci in np.flatnonzero(hist_mask & active):
                hw = hist_w[ci]
                for wi in range(W):
                    cnt = float(ins_w[ci, wi])
                    if cnt > 0.0:
                        hw[wi].record_weighted(float(lat_mean[ci, wi]), cnt)
                ht = hist_t[ci]
                for ti in range(T):
                    cnt = float(ins_dev[ci, :, ti].sum())
                    if cnt > 0.0:
                        mean_t = float(
                            (ins_dev[ci, :, ti] * lat_dev[ci, :, ti]).sum()
                            / cnt
                        )
                        ht[ti].record_weighted(mean_t, cnt)
        tor_inserts += ins_w.sum(axis=1)
        pop = np.minimum((y * R_tor).sum(axis=1), group.tor_cap)
        tor_occ += pop * dt
        tor_peak = np.maximum(tor_peak, pop)
        llc_res = route[:, :, llc] * r_sta[:, :, llc]
        occ_int_t += (
            occ_dev + np.einsum("cw,cwt->cwt", ins_w * llc_res, frac)
        ).sum(axis=1)
        for ci in np.flatnonzero(fire):
            timelines[ci].append(((k + 1) * win, bytes_win[ci].copy()))

        # -- fire the control window (decisions apply to the next one) ----
        if not fire.any():
            continue
        out = None
        if ladder is not None:
            f_ins = ins_dev[:, :, 0].sum(axis=1)
            f_occ = occ_dev[:, :, 0].sum(axis=1)
            f_cls = cls_w[:, 0]
            s_ins = np.zeros((C, U))
            s_occ = np.zeros((C, U))
            s_cls = np.zeros((C, U, n_ops))
            slow_ins_t = ins_dev.sum(axis=1)[:, 1:]  # (C, T-1)
            slow_occ_t = occ_dev.sum(axis=1)[:, 1:]
            slow_cls_t = cls_w[:, 1:]
            per_tier = ~merged
            n_avail = min(U, T - 1)
            s_ins[per_tier, :n_avail] = slow_ins_t[per_tier, :n_avail]
            s_occ[per_tier, :n_avail] = slow_occ_t[per_tier, :n_avail]
            s_cls[per_tier, :n_avail] = slow_cls_t[per_tier, :n_avail]
            s_ins[merged, 0] = slow_ins_t[merged].sum(axis=1)
            s_occ[merged, 0] = slow_occ_t[merged].sum(axis=1)
            s_cls[merged, 0] = slow_cls_t[merged].sum(axis=1)
            out = ladder.window(f_ins, f_occ, f_cls, s_ins, s_occ, s_cls)

        # Tier-addressed apply: per-tier caps/rates for the next window.
        # (has_ctl implies the ladder exists, so ``out`` is never None here.)
        for ci in np.flatnonzero(fire & has_ctl):
            ns = int(n_slow_cell[ci])
            names = group.plans[ci].export["tier_names"][1:]
            ds = []
            for u in range(ns):
                uu = 0 if merged[ci] else u
                if merged[ci] and u > 0:
                    ds.append(ds[0])
                    tier_cap[ci, u] = tier_cap[ci, 0]
                    tier_rate[ci, u] = tier_rate[ci, 0]
                    continue
                cap_v = out["cap"][ci, uu]
                rate_v = out["rate"][ci, uu]
                tier_cap[ci, u] = cap_v
                tier_rate[ci, u] = rate_v
                est = TierEstimate(
                    t_avg=float(out["t_avg"][ci, uu]),
                    alpha=float(out["alpha"][ci, uu]),
                    t_slow=float(out["t_slow"][ci, uu]),
                    t_slow_raw=float(out["t_slow_raw"][ci, uu]),
                    threshold=float(out["threshold"][ci, uu]),
                    backlogged=bool(out["backlogged"][ci, uu]),
                    valid=bool(out["valid"][ci, uu]),
                )
                restricted = bool(out["restricted"][ci, uu])
                ds.append(Decision(
                    max_concurrency=(
                        None if not restricted or math.isinf(cap_v)
                        else int(cap_v)
                    ),
                    rate_factor=float(rate_v),
                    phase=(
                        Phase.RESTRICTED if restricted else Phase.UNRESTRICTED
                    ),
                    estimate=est,
                ))
            decisions[ci].append(
                TierDecisions(tiers=tuple(names), decisions=tuple(ds))
            )

        # -- tiering pass: migrations, hotness, placements (post-fire) ----
        if vt is not None:
            if out is not None:
                budgets = ladder.migration_budgets()
                restr = np.asarray(out["restricted"], bool).copy()
                if merged.any():
                    # The merged law broadcasts its single decision to every
                    # slow tier — same for its restricted bit.
                    restr[merged] = restr[merged][:, :1]
                has_budgets = has_ctl & ~merged
                has_decisions = has_ctl
            else:
                budgets = restr = None
                has_budgets = np.zeros(C, bool)
                has_decisions = np.zeros(C, bool)
            vt.step(
                fire, ins_w, budgets, restr, has_budgets, has_decisions,
                (k + 1) * win, tier_frac_live, effmlp_live,
            )

        # -- vectorized telemetry: window_record_jsonable-shaped dicts ----
        # straight from the stacked per-window arrays (scalar schema: the
        # ControlLoop record, with the tiering hook's block merged in).
        fired_count += fire
        for ci in np.flatnonzero(fire & record_mask):
            has_t = vt is not None and vt.cell_act[ci]
            has_h = bool(hist_on and hist_mask[ci])
            if not has_ctl[ci] and not has_t and not has_h:
                continue  # scalar ControlLoop records nothing either
            rec: dict = {
                "window": int(fired_count[ci]),
                "t_ns": float((k + 1) * win),
            }
            if has_ctl[ci]:
                nt = int(group.n_tiers_cell[ci])
                names = group.plans[ci].export["tier_names"]
                rec["tiers"] = {
                    names[t]: {
                        "inserts": int(round(ins_dev[ci, :, t].sum())),
                        "occupancy_time": float(occ_dev[ci, :, t].sum()),
                        "class_counts": {
                            op.value: int(round(cls_w[ci, t, o]))
                            for o, op in enumerate(_OPS)
                        },
                    }
                    for t in range(nt)
                }
                rec["decision"] = {
                    t: _decision_jsonable(td)
                    for t, td in decisions[ci][-1].items()
                }
            if has_t:
                entry = vt.window_log[ci][-1]
                rec["tiering"] = {
                    key: v for key, v in entry.items()
                    if key not in ("window", "t_ns")
                }
            if has_h:
                # One weighted entry per workload — the window's analytic
                # contribution, same shape as the scalar per-window blocks.
                lh = {}
                for wi, nm in enumerate(group.plans[ci].export["w_names"]):
                    h = LatencyHistogram()
                    cnt = float(ins_w[ci, wi])
                    if cnt > 0.0:
                        h.record_weighted(float(lat_mean[ci, wi]), cnt)
                    lh[nm] = h.to_jsonable()
                rec["latency_hist"] = lh
            records[ci].append(rec)

    # -- materialize SimResults -------------------------------------------
    results: List[SimResult] = []
    for ci, plan in enumerate(group.plans):
        e = plan.export
        nt = e["n_tiers"]
        names = e["tier_names"]
        stats = {}
        for wi, name in enumerate(e["w_names"]):
            st = WorkloadStats()
            st.completed = int(round(completed_w[ci, wi]))
            st.bytes = float(bytes_w[ci, wi])
            st.latency_sum = float(latsum_w[ci, wi])
            st.latency_count = st.completed
            mean = st.latency_sum / max(1, st.latency_count)
            # The fluid lane has no per-request reservoir; percentiles
            # degenerate to the mean (documented in docs/decision-laws.md).
            st.latency_samples = [mean] if st.completed else []
            st.timeline = [
                (t, float(b[wi])) for t, b in timelines[ci]
            ]
            if hist_on and hist_mask[ci]:
                st.latency_hist = hist_w[ci][wi]
            stats[name] = st
        tcs = {}
        for t in range(nt):
            tc = TierCounters()
            tc.inserts = int(round(ins_t[ci, t]))
            tc.occupancy_time = float(occ_t[ci, t])
            tc.class_counts = {
                op: int(round(cls_t[ci, t, o]))
                for o, op in enumerate(_OPS)
            }
            tcs[names[t]] = tc
        results.append(SimResult(
            sim_ns=float(group.sim_ns[ci]),
            stats=stats,
            tier_counters=tcs,
            tor_peak=int(math.ceil(tor_peak[ci])),
            tor_occupancy_integral=float(tor_occ[ci]),
            tor_inserts=int(round(tor_inserts[ci])),
            decisions=decisions[ci],
            per_tier_occupancy_integral={
                names[t]: float(occ_int_t[ci, t]) for t in range(nt)
            },
            window_records=records[ci] if plan.job.record_windows else [],
            tiering=vt.summary(ci) if vt is not None else None,
            tier_latency_hist=(
                {names[t]: hist_t[ci][t] for t in range(nt)}
                if hist_on and hist_mask[ci] else None
            ),
        ))
    return results

"""Vectorized tiering: the ``(cells × regions × pages)`` twin of the hook.

The scalar lane drives one :class:`~repro.tiering.hook.TieringHook` per
simulation — a PageMap of decayed per-page hotness, a MigrationEngine of
per-slow-tier FIFO copy queues, and a policy that turns both into
promotion/demotion jobs each control window.  This module stacks all of
that across a whole cell group, the same trick
:class:`~repro.core.controller.VectorMikuLadder` plays for the decision
law:

* page state lives in padded ``(C, R, P)`` arrays (tier codes, hotness,
  queued flags, active masks) — decay, hot-set weighting, drift and
  placement re-resolution are single numpy expressions over every cell;
* policy candidate selection is a vectorized top-k: one ``np.lexsort``
  over the flattened page axis with the *scalar policy's exact sort keys*
  (``(-hotness, region name, page)`` for promotions, coldest-first for
  demotions), truncated per cell by the same free-capacity / watermark /
  per-window budgets;
* only the migration queues stay per-cell Python deques — FIFO retirement
  order is load-bearing and the per-window job volume is tiny, exactly the
  split the fluid engine makes for per-cell Decision materialization.

The state machine is *identical* to the scalar hook fed the same
per-window completion streams — ``tests/test_batched_tiering.py`` replays
the pinned ``migrate_trace_goldens.json`` decision traces through it and
requires equality, entry for entry.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.tiering.policies import (
    HotnessLRUPolicy,
    MikuCoordinatedPolicy,
    StaticPolicy,
)

_POL_STATIC, _POL_LRU, _POL_MIKU = 0, 1, 2


def _num(x: float):
    """Integral floats as ints (scalar counters are ints; fluid credit is
    real-valued — keep telemetry honest either way)."""
    r = round(x)
    return int(r) if abs(x - r) < 1e-9 else float(x)


def build_tiering(group) -> Optional["VectorTiering"]:
    """The group's stacked tiering twin (None when no cell has a hook).

    Raises ``ValueError`` for policies the vector twin cannot express
    (foreign registrations in :data:`repro.tiering.policies.POLICIES`) —
    the lane catches that and falls the group back to the scalar DES.
    """
    if not any(p.tiering is not None for p in group.plans):
        return None
    return VectorTiering(group.plans, group.n_tiers)


class VectorTiering:
    """Stacked per-cell tiering state over one :class:`BatchGroup`."""

    def __init__(self, plans: Sequence, n_tiers: int) -> None:
        hooks = [p.tiering for p in plans]
        C = len(plans)
        T = n_tiers
        U = max(1, T - 1)
        self.C, self.T, self.U = C, T, U
        self.cell_act = np.array([h is not None for h in hooks], bool)
        R = max(
            (len(h.pagemap.regions) for h in hooks if h is not None),
            default=1,
        ) or 1
        P = max(
            (r.n_pages for h in hooks if h is not None
             for r in h.pagemap.regions.values()),
            default=1,
        ) or 1
        self.R, self.P = R, P

        shape3 = (C, R, P)
        self.tier = np.zeros(shape3, np.int64)
        self.hotness = np.zeros(shape3)
        self.page_act = np.zeros(shape3, bool)
        self.queued = np.zeros(shape3, bool)
        self.region_act = np.zeros((C, R), bool)
        self.n_pages = np.zeros((C, R), np.int64)
        self.page_bytes = np.zeros((C, R), np.int64)
        self.home_slow = np.ones((C, R), np.int64)
        self.region_wi = np.zeros((C, R), np.int64)
        #: Lexicographic region-name rank — the scalar policies' sort
        #: tie-break between regions.
        self.region_rank = np.zeros((C, R), np.int64)
        self.hot_frac = np.full((C, R), 1.0)
        self.hot_weight = np.zeros((C, R))
        self.drift = np.zeros((C, R))
        self.hot_start = np.zeros((C, R))
        self.decay = np.ones(C)
        self.fast_cap = np.zeros(C, np.int64)

        # Per-cell policy parameters (one row per cell, scalar defaults).
        self.pol = np.zeros(C, np.int64)
        self.promote_pw = np.zeros(C, np.int64)
        self.demote_pw = np.zeros(C, np.int64)
        self.high_wm = np.ones(C)
        self.low_wm = np.ones(C)
        self.min_hot = np.zeros(C)
        self.jpbu = np.zeros(C, np.int64)

        # Migration engine state: FIFO queues stay per-cell deques (order
        # matters, volume is small); credit/backlog are arrays.
        self.mig_wi = np.full((C, U), -1, np.int64)
        self.mig_act = np.zeros((C, U), bool)
        self.rpp = np.ones((C, U), np.int64)
        self.mig_base = np.zeros((C, U))
        self.credit = np.zeros((C, U))
        self.qlen = np.zeros((C, U), np.int64)
        self._queues: List[List[deque]] = [
            [deque() for _ in range(U)] for _ in range(C)
        ]
        self.q_promo = np.zeros(C, np.int64)
        self.q_demo = np.zeros(C, np.int64)

        # Lifetime counters + telemetry.
        self.promoted = np.zeros(C, np.int64)
        self.demoted = np.zeros(C, np.int64)
        self.migrated_bytes = np.zeros(C, np.int64)
        self.deferred = np.zeros(C, np.int64)
        self.windows = np.zeros(C, np.int64)
        self.window_log: List[List[dict]] = [[] for _ in range(C)]
        self.region_names: List[List[str]] = [[] for _ in range(C)]
        self.tier_names: List[List[str]] = [
            list(p.export["tier_names"]) for p in plans
        ]
        self.policy_name: List[str] = [""] * C

        for ci, h in enumerate(hooks):
            if h is None:
                continue
            pm = h.pagemap
            names = list(pm.regions)
            self.region_names[ci] = names
            rank = {nm: i for i, nm in enumerate(sorted(names))}
            self.decay[ci] = pm.decay
            self.fast_cap[ci] = pm.fast_capacity_pages
            for ri, nm in enumerate(names):
                reg = pm.regions[nm]
                n = reg.n_pages
                self.region_act[ci, ri] = True
                self.n_pages[ci, ri] = n
                self.page_bytes[ci, ri] = reg.page_bytes
                self.home_slow[ci, ri] = reg.home_slow
                self.region_wi[ci, ri] = h._region_wi[nm]
                self.region_rank[ci, ri] = rank[nm]
                self.tier[ci, ri, :n] = reg.tier
                self.page_act[ci, ri, :n] = True
                pat = reg.pattern
                self.hot_frac[ci, ri] = pat.hot_fraction
                self.hot_weight[ci, ri] = pat.hot_weight
                self.drift[ci, ri] = pat.drift_pages
                self.hot_start[ci, ri] = reg._hot_start
            for code, wi in h._mig_wi.items():
                u = code - 1
                self.mig_wi[ci, u] = wi
                self.mig_act[ci, u] = True
                self.rpp[ci, u] = h.engine.reqs_per_page[code]
                self.mig_base[ci, u] = h._mig_effmlp[wi]
            pol = h.policy
            self.policy_name[ci] = pol.name
            if isinstance(pol, MikuCoordinatedPolicy):
                self.pol[ci] = _POL_MIKU
                self.jpbu[ci] = pol.jobs_per_budget_unit
                base: Optional[HotnessLRUPolicy] = pol.base
            elif isinstance(pol, HotnessLRUPolicy):
                self.pol[ci] = _POL_LRU
                base = pol
            elif isinstance(pol, StaticPolicy):
                self.pol[ci] = _POL_STATIC
                base = None
            else:
                raise ValueError(
                    f"batched lane cannot vectorize tiering policy "
                    f"{getattr(pol, 'name', type(pol).__name__)!r}"
                )
            if base is not None:
                self.promote_pw[ci] = base.promote_per_window
                self.demote_pw[ci] = base.demote_per_window
                self.high_wm[ci] = base.high_watermark
                self.low_wm[ci] = base.low_watermark
                self.min_hot[ci] = base.min_hotness

        # Static sort keys for the flattened (region, page) axis.
        self._pidx = np.arange(P, dtype=np.float64)
        self._page_flat = np.broadcast_to(
            np.arange(P, dtype=np.int64), (R, P)
        ).reshape(-1)
        self._rank_flat = np.broadcast_to(
            self.region_rank[:, :, None], shape3
        ).reshape(C, R * P)

    # -- access model (PageRegion.access_weights, vectorized) ------------
    def _access_weights(self) -> np.ndarray:
        """Per-page access probability ``(C, R, P)`` under each region's
        current hot window (zero on padding)."""
        n = np.maximum(self.n_pages, 1).astype(np.float64)
        n_hot = np.maximum(1.0, np.round(self.hot_frac * n))
        uniform = n_hot >= n
        base = (1.0 - self.hot_weight) / np.maximum(n - n_hot, 1.0)
        rel = (
            self._pidx[None, None, :] - np.trunc(self.hot_start)[:, :, None]
        ) % n[:, :, None]
        is_hot = rel < n_hot[:, :, None]
        w = np.where(
            is_hot, self.hot_weight[:, :, None] / n_hot[:, :, None],
            base[:, :, None],
        )
        w = np.where(uniform[:, :, None], 1.0 / n[:, :, None], w)
        return np.where(self.page_act, w, 0.0)

    # -- one control window ----------------------------------------------
    def step(
        self,
        fire: np.ndarray,
        ins_w: np.ndarray,
        budgets: Optional[np.ndarray],
        restricted: Optional[np.ndarray],
        has_budgets: np.ndarray,
        has_decisions: np.ndarray,
        t_ns: float,
        tier_frac_live: np.ndarray,
        effmlp_live: np.ndarray,
    ) -> None:
        """One per-window tiering pass across every fired cell.

        ``ins_w`` is the window's per-workload completed macro-requests
        (``(C, W)``, the fluid station accounting the scalar hook samples);
        ``budgets``/``restricted`` are the post-window ladder views
        (``(C, U)``), consulted per ``has_budgets``/``has_decisions`` the
        way :class:`~repro.tiering.policies.PolicyContext` is; routing and
        migration issue gating are written into ``tier_frac_live`` /
        ``effmlp_live`` for the *next* window, the fluid image of the
        scalar hook's re-pump."""
        act = fire & self.cell_act
        if not act.any():
            return
        C, R, P, T = self.C, self.R, self.P, self.T
        self.windows += act

        # 1. Completed MIGRATE traffic retires jobs FIFO and flips pages.
        prom_w = np.zeros(C, np.int64)
        dem_w = np.zeros(C, np.int64)
        mig_done: List[Dict[str, object]] = [{} for _ in range(C)]
        for ci in np.flatnonzero(act):
            for u in np.flatnonzero(self.mig_act[ci]):
                d = float(ins_w[ci, self.mig_wi[ci, u]])
                if d <= 0.0:
                    continue
                mig_done[ci][self.tier_names[ci][u + 1]] = _num(d)
                self.credit[ci, u] += d
                rpp = int(self.rpp[ci, u])
                q = self._queues[ci][u]
                n_ret = int(min(len(q), (self.credit[ci, u] + 1e-9) // rpp))
                for _ in range(n_ret):
                    ri, p, _src, dst = q.popleft()
                    self.credit[ci, u] -= rpp
                    self.queued[ci, ri, p] = False
                    self.tier[ci, ri, p] = dst
                    self.migrated_bytes[ci] += self.page_bytes[ci, ri]
                    if dst == 0:
                        prom_w[ci] += 1
                        self.q_promo[ci] -= 1
                    else:
                        dem_w[ci] += 1
                        self.q_demo[ci] -= 1
                self.qlen[ci, u] = len(q)
                if not q:
                    # Surplus credit over an empty queue pays for no page
                    # (over-issued copy traffic), same as the scalar engine.
                    self.credit[ci, u] = 0.0
        self.promoted += prom_w
        self.demoted += dem_w

        # 2. Demand completions feed the hotness tracker, then the hot set
        #    drifts — decay/accumulate/drift in the scalar region's order.
        actR = self.region_act & act[:, None]
        n_acc = np.zeros((C, R))
        ci_i, ri_i = np.nonzero(actR)
        n_acc[ci_i, ri_i] = ins_w[ci_i, self.region_wi[ci_i, ri_i]]
        w_pre = self._access_weights()
        self.hotness[act] *= self.decay[act, None, None]
        self.hotness += np.where(
            ((n_acc > 0) & actR)[:, :, None],
            n_acc[:, :, None] * w_pre, 0.0,
        )
        n_f = np.maximum(self.n_pages, 1).astype(np.float64)
        self.hot_start = np.where(
            actR, (self.hot_start + self.drift) % n_f, self.hot_start
        )

        # 3. Policy pass: vectorized candidate selection (the scalar sort
        #    keys exactly), then per-cell MIKU gating + FIFO enqueue.
        N = R * P
        tier_f = self.tier.reshape(C, N)
        hot_f = self.hotness.reshape(C, N)
        pact_f = self.page_act.reshape(C, N)
        qd_f = self.queued.reshape(C, N)
        page_f = np.broadcast_to(self._page_flat, (C, N))
        fast_used = (pact_f & (tier_f == 0)).sum(axis=1)
        run_pol = act & (self.pol != _POL_STATIC)

        free = self.fast_cap - fast_used - self.q_promo
        budget_p = np.maximum(
            np.where(run_pol, np.minimum(free, self.promote_pw), 0), 0
        )
        cand_p = (
            pact_f & (tier_f != 0) & (hot_f > self.min_hot[:, None])
            & ~qd_f & run_pol[:, None]
        )
        key_p = np.where(cand_p, -hot_f, np.inf)
        order_p = np.lexsort((page_f, self._rank_flat, key_p), axis=-1)
        sort_p = np.take_along_axis(cand_p, order_p, axis=1)
        sel_p = sort_p & (np.cumsum(sort_p, axis=1) <= budget_p[:, None])

        used_d = fast_used - self.q_demo
        over = used_d > self.high_wm * self.fast_cap
        target = np.maximum(
            used_d - np.floor(self.low_wm * self.fast_cap).astype(np.int64),
            0,
        )
        budget_d = np.where(
            run_pol & over, np.minimum(target, self.demote_pw), 0
        )
        cand_d = pact_f & (tier_f == 0) & ~qd_f & run_pol[:, None]
        key_d = np.where(cand_d, hot_f, np.inf)
        order_d = np.lexsort((page_f, self._rank_flat, key_d), axis=-1)
        sort_d = np.take_along_axis(cand_d, order_d, axis=1)
        sel_d = sort_d & (np.cumsum(sort_d, axis=1) <= budget_d[:, None])

        enq_w = np.zeros(C, np.int64)
        def_w = np.zeros(C, np.int64)
        for ci in np.flatnonzero(run_pol):
            jobs: List[tuple] = []
            for fi in order_p[ci][sel_p[ci]]:
                ri, p = divmod(int(fi), P)
                jobs.append((ri, p, int(tier_f[ci, fi]), 0))
            for fi in order_d[ci][sel_d[ci]]:
                ri, p = divmod(int(fi), P)
                jobs.append((ri, p, 0, int(self.home_slow[ci, ri])))
            if not jobs:
                continue
            miku = self.pol[ci] == _POL_MIKU
            taken: Dict[int, int] = {}
            for ri, p, src, dst in jobs:
                code = src if src != 0 else dst
                if miku:
                    if has_budgets[ci]:
                        b = int(budgets[ci, code - 1])
                        if b <= 0 or taken.get(code, 0) >= (
                            b * int(self.jpbu[ci])
                        ):
                            def_w[ci] += 1
                            continue
                    elif has_decisions[ci] and restricted is not None:
                        if bool(restricted[ci, code - 1]):
                            def_w[ci] += 1
                            continue
                    taken[code] = taken.get(code, 0) + 1
                u = code - 1
                self._queues[ci][u].append((ri, p, src, dst))
                self.qlen[ci, u] += 1
                self.queued[ci, ri, p] = True
                if dst == 0:
                    self.q_promo[ci] += 1
                else:
                    self.q_demo[ci] += 1
                enq_w[ci] += 1
        self.deferred += def_w

        # 4. Placement re-resolution: live access-weighted routing vectors
        #    (post-drift weights, exactly PageRegion.tier_fractions).
        w_post = self._access_weights()
        frac_r = np.zeros((C, R, T))
        for t in range(T):
            frac_r[:, :, t] = (w_post * (self.tier == t)).sum(axis=2)
        wis = self.region_wi[ci_i, ri_i]
        tier_frac_live[ci_i, wis, :] = frac_r[ci_i, ri_i, :]

        # 5. Migration issue gating: pseudo-workloads run only with backlog.
        pending = self.qlen * self.rpp - self.credit > 1e-9
        mi, ui = np.nonzero(self.mig_act & act[:, None])
        wim = self.mig_wi[mi, ui]
        effmlp_live[mi, wim] = np.where(
            pending[mi, ui], self.mig_base[mi, ui], 0.0
        )

        # 6. Telemetry: the scalar hook's window_log entry, per cell.
        for ci in np.flatnonzero(act):
            self.window_log[ci].append({
                "window": int(self.windows[ci]),
                "t_ns": float(t_ns),
                "promoted": int(prom_w[ci]),
                "demoted": int(dem_w[ci]),
                "enqueued": int(enq_w[ci]),
                "deferred": int(def_w[ci]),
                "backlog_pages": int(self.qlen[ci].sum()),
                "migrated_bytes": int(self.migrated_bytes[ci]),
                "mig_reqs_completed": mig_done[ci],
                "fast_fraction": {
                    nm: float(frac_r[ci, ri, 0])
                    for ri, nm in enumerate(self.region_names[ci])
                },
            })

    # -- result surface ---------------------------------------------------
    def summary(self, ci: int) -> Optional[dict]:
        """One cell's end-of-run summary, schema-identical to
        :meth:`repro.tiering.hook.TieringHook.summary`."""
        if not self.cell_act[ci]:
            return None
        w = self._access_weights()[ci]
        occupancy = {
            tn: int(((self.tier[ci] == t) & self.page_act[ci]).sum())
            for t, tn in enumerate(self.tier_names[ci])
        }
        return {
            "pages_promoted": int(self.promoted[ci]),
            "pages_demoted": int(self.demoted[ci]),
            "migrated_bytes": int(self.migrated_bytes[ci]),
            "backlog_pages": int(self.qlen[ci].sum()),
            "policy": self.policy_name[ci],
            "windows": int(self.windows[ci]),
            "deferred_jobs": int(self.deferred[ci]),
            "fast_pages_used": int(
                ((self.tier[ci] == 0) & self.page_act[ci]).sum()
            ),
            "occupancy": occupancy,
            "fast_fraction": {
                nm: float((w[ri] * (self.tier[ci, ri] == 0)).sum())
                for ri, nm in enumerate(self.region_names[ci])
            },
        }

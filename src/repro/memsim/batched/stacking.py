"""Stack SimJobs into the batched lane's array form.

One :class:`CellPlan` per job: the un-run DES's exported static state plus
the job's calibrated per-slow-tier MIKU units (built through the ordinary
:mod:`repro.memsim.calibration` factories so the two lanes can never drift
apart).  :class:`BatchGroup` holds the padded ``(n_cells, n_workloads,
n_stations)`` arrays the fluid engine consumes; the lane buckets cells by
control-window cadence first — window lockstep requires one shared
cadence per stacked group.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.des import TieredMemorySim
from repro.memsim.sweep import SimJob


@dataclasses.dataclass
class CellPlan:
    """One job, ready for stacking: exported DES state + MIKU units."""

    job: SimJob
    export: dict
    #: Per-slow-tier SlowTierMiku units (empty = no controller).  For
    #: merged-law cells this is the single merged ladder; ``merged`` says
    #: whether its decision broadcasts to every slow tier.
    units: list
    merged: bool
    #: The job's bound :class:`~repro.tiering.hook.TieringHook` (None when
    #: the job carries no tiering spec).  Bound to the planning sim exactly
    #: like the scalar worker's hook, so the batched lane's vectorized twin
    #: (:mod:`repro.memsim.batched.tiering`) stacks the *same* PageMap,
    #: engine and policy state the scalar DES would start from.
    tiering: object = None


def plan_cell(job: SimJob) -> CellPlan:
    """Build the cell plan: construct (but never run) the sim, export its
    state, and instantiate the job's controller units via the calibration
    factories.  Jobs with a tiering spec build and bind their hook here —
    the export then carries the migration pseudo-workloads (issue-gated
    closed) and the live initial routing vectors."""
    hook = job.tiering.build() if job.tiering is not None else None
    sim = TieredMemorySim(
        job.platform,
        job.workloads,
        seed=job.seed,
        granularity=job.granularity,
        window_ns=job.window_ns,
        tiering=hook,
    )
    export = sim.export_state()
    units: list = []
    merged = False
    if job.miku:
        from repro.memsim.calibration import default_miku, merged_miku

        n_slow = export["n_tiers"] - 1
        slow_names = export["tier_names"][1:]
        if job.miku_law == "merged":
            law = merged_miku(job.platform, job.granularity,
                              **job.miku_overrides).law
            law._ensure_units(1, ["slow"])
            units = [law.units[0]]
            merged = True
        else:
            ctl = default_miku(job.platform, job.granularity,
                               **job.miku_overrides)
            ctl._ensure_units(n_slow, slow_names)
            units = list(ctl.units[:n_slow])
    return CellPlan(job=job, export=export, units=units, merged=merged,
                    tiering=hook)


class BatchGroup:
    """Padded array form of one window-cadence group of cells.

    Stations are the union layout ``[tier 0 .. max_tiers-1, llc]``; cells
    with fewer tiers carry zero-capacity padding.  Workload slots beyond a
    cell's count are inactive (zero cores).
    """

    def __init__(self, cells: Sequence[Tuple[int, CellPlan]]):
        self.indices = [i for i, _ in cells]
        self.plans = [p for _, p in cells]
        C = len(self.plans)
        exps = [p.export for p in self.plans]
        self.window_ns = float(exps[0]["window_ns"])
        T = max(e["n_tiers"] for e in exps)  # tiers (fast first)
        W = max(len(e["w_names"]) for e in exps)
        S = T + 1  # + LLC station
        self.n_tiers, self.n_wl, self.n_st = T, W, S
        self.llc = T

        self.n_tiers_cell = np.array([e["n_tiers"] for e in exps])
        self.sim_ns = np.array([p.job.sim_ns for p in self.plans])
        self.tor_cap = np.array([e["tor_capacity"] for e in exps], float)
        self.irq_cap = np.array([e["irq_capacity"] for e in exps], float)
        self.slots = np.zeros((C, S))  # 0 = padding station
        self.pipe = np.zeros((C, S))
        self.active_w = np.zeros((C, W), bool)
        self.svc = np.ones((C, W, S))
        self.bytes_t = np.zeros((C, W, T))
        self.p_llc = np.full((C, W), -1.0)
        self.tier_frac = np.zeros((C, W, T))
        self.effmlp = np.zeros((C, W))
        self.cores = np.zeros((C, W))
        self.managed = np.zeros((C, W), bool)
        self.op = np.zeros((C, W), int)
        self.phases: List[List[Optional[list]]] = []

        for ci, e in enumerate(exps):
            nt = e["n_tiers"]
            self.slots[ci, :nt] = e["st_slots"][:nt]
            self.slots[ci, self.llc] = e["st_slots"][nt]
            self.pipe[ci, :nt] = e["pipe"]
            nw = len(e["w_names"])
            self.active_w[ci, :nw] = True
            for wi in range(nw):
                self.svc[ci, wi, :nt] = e["w_svc"][wi]
                self.svc[ci, wi, self.llc] = e["w_llc_svc"][wi]
                self.bytes_t[ci, wi, :nt] = e["w_bytes"][wi]
                self.p_llc[ci, wi] = e["w_phit"][wi]
                self.tier_frac[ci, wi, :nt] = e["w_tier_frac"][wi]
                self.effmlp[ci, wi] = e["w_effmlp"][wi]
                self.cores[ci, wi] = e["w_cores"][wi]
                self.managed[ci, wi] = e["w_managed"][wi]
                self.op[ci, wi] = e["w_op"][wi]
            self.phases.append(
                [e["w_phases"][wi] if wi < nw else None for wi in range(W)]
            )

    def window_fracs(
        self, t0: np.ndarray, t1: np.ndarray,
        base: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Per-window tier-routing fractions ``(C, W, T)``.

        Static cells return ``base`` (default :attr:`tier_frac`; the fluid
        engine passes its *live* routing array once tiering re-resolves
        placements per window); phased workloads get the time-weighted tier
        occupancy of their (cycled) phase schedule over ``[t0, t1)`` — the
        fluid counterpart of the DES's mid-window ``_phase_flip`` events."""
        out = (self.tier_frac if base is None else base).copy()
        for ci, row in enumerate(self.phases):
            for wi, seq in enumerate(row):
                if seq is None:
                    continue
                dur = float(t1[ci] - t0[ci])
                if dur <= 0:
                    continue
                out[ci, wi, :] = 0.0
                period = sum(d for d, _ in seq)
                pos = float(t0[ci]) % period
                left = dur
                k = 0
                # find current phase
                acc = 0.0
                for k, (d, _) in enumerate(seq):
                    if pos < acc + d:
                        break
                    acc += d
                offset = pos - acc
                while left > 1e-9:
                    d, tier = seq[k % len(seq)]
                    span = min(left, d - offset)
                    out[ci, wi, tier] += span / dur
                    left -= span
                    offset = 0.0
                    k += 1
        return out

"""Lane entry points: partition a job list, run it batched, fall back scalar.

``run_sweep(jobs, lane="batched")`` lands here.  The lane is *total* over
the job grid: single-workload cells take the exact closed form, everything
else — tiering hooks and ``record_windows`` telemetry included — stacks
into the window-lockstep fluid engine, one group per (window cadence,
ladder rung table) pair so heterogeneous-rung grids still run batched.
Groups are further chunked into blocks of at most ``REPRO_BATCH_BLOCK``
cells (default 1024) to cap the stacked arrays' memory footprint on
10k+-cell grids.

Fallbacks are the exception, not the rule: only a job whose *plan or
stack* is genuinely inexpressible (heterogeneous per-tier rung tables in
one cell, an unregistered tiering policy the vector twin can't replicate)
reruns on the scalar DES — a failing group is re-stacked cell by cell so
an unstackable cell never drags its group-mates to the scalar pool — and
every one of them is recorded as an
``(index, reason)`` pair, whether it fell at the static planning screen or
at dynamic group stacking, so :func:`repro.scenarios.planner.run_scenario`
can report the split in result metadata.
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence, Tuple

from repro.core.des import SimResult
from repro.core.invariants import require, sanitize_enabled
from repro.memsim.batched.stacking import BatchGroup, CellPlan, plan_cell

#: (plans aligned with the job list — None where the job fell back,
#:  [(job_index, reason), ...] for the fallbacks)
Partition = Tuple[List[Optional[CellPlan]], List[Tuple[int, str]]]

#: Cells per stacked fluid group — chunked execution caps peak memory
#: (arrays scale with cells x workloads x stations, plus cells x regions x
#: pages when tiering is stacked).
_DEFAULT_BLOCK = 1024


def batch_block() -> int:
    """The configured chunk size (``REPRO_BATCH_BLOCK``, default 1024)."""
    try:
        return max(1, int(os.environ.get("REPRO_BATCH_BLOCK",
                                         _DEFAULT_BLOCK)))
    except ValueError:
        return _DEFAULT_BLOCK


def can_batch(job) -> Optional[str]:
    """Static screen: the fallback reason, or None when the lane applies.

    The lane is total over the *flat-station* SimJob surface — tiering
    and telemetry jobs run batched too.  Fabric jobs are the exception:
    a platform whose topology puts port-bearing links on some route (and
    likewise the ``peredge`` control law built for such routes) needs the
    multi-hop/backpressure scalar DES, so those jobs fall back with the
    explicit ``"fabric_topology"`` reason — surfaced in
    ``fallback_reason_counts`` and the stderr per-reason summary, never
    silently.  Degenerate all-transparent topologies have no hops and
    batch normally.  The dynamic screen (plan construction,
    ladder/tiering stacking) happens in :func:`partition_jobs` and
    :func:`run_sweep_batched`.
    """
    fabric = getattr(job.platform, "fabric", None)
    if fabric is not None and fabric.has_hops:
        return "fabric_topology"
    if getattr(job, "miku", False) and \
            getattr(job, "miku_law", None) == "peredge":
        return "fabric_topology"
    # Sanitized jobs need the instrumented scalar DES: the fluid/exact
    # engines have no event stream or per-window queue state to check.
    # job.sanitize=None defers to the process-wide REPRO_SANITIZE switch;
    # an explicit False opts the job back into the batched lane.
    san = getattr(job, "sanitize", None)
    if san is None:
        san = sanitize_enabled()
    if san:
        return "sanitize"
    # Open-loop arrival workloads are event-driven by construction: each
    # generated request enters a backlog and gates issue — queue growth
    # and shed accounting have no fluid/closed-form counterpart yet.
    if any(getattr(w, "arrival", None) is not None for w in job.workloads):
        return "arrival"
    # Traced jobs record per-request span chains — an event-level lens the
    # closed-form/fluid engines cannot produce.  (``latency_hist`` jobs DO
    # run batched: the exact lane buckets its full latency vector and the
    # fluid lane synthesizes analytic histograms from station waits.)
    if getattr(job, "trace", 0):
        return "trace"
    return None


def partition_jobs(jobs: Sequence) -> Partition:
    """Split ``jobs`` into batchable cell plans and scalar fallbacks."""
    plans: List[Optional[CellPlan]] = []
    fallbacks: List[Tuple[int, str]] = []
    for i, job in enumerate(jobs):
        reason = can_batch(job)
        if reason is None:
            try:
                plans.append(plan_cell(job))
                continue
            except ValueError as ex:  # e.g. an invalid tiering region
                reason = str(ex)
        plans.append(None)
        fallbacks.append((i, reason))
    return plans, fallbacks


def run_sweep_batched(
    jobs: Sequence,
    processes: Optional[int] = None,
    partition: Optional[Partition] = None,
) -> List[SimResult]:
    """Run ``jobs`` through the batched lane, results in job order.

    Single-workload cells take the exact closed form
    (:mod:`~repro.memsim.batched.exact`); the rest stack into window-lockstep
    fluid groups (:mod:`~repro.memsim.batched.fluid`, one group per control
    cadence, chunked at :func:`batch_block` cells).  Fallback jobs run on
    the scalar lane — through the process pool when ``processes`` says so —
    and dynamic stacking failures are appended to the partition's fallback
    list so callers holding it see the *complete* accounting.
    """
    from repro.memsim.batched import exact as exact_mod
    from repro.memsim.batched import fluid as fluid_mod
    from repro.memsim.batched import tiering as tiering_mod
    from repro.memsim.sweep import run_sweep

    jobs = list(jobs)
    plans, fallbacks = partition if partition is not None else (
        partition_jobs(jobs)
    )
    results: List[Optional[SimResult]] = [None] * len(jobs)

    fluid_cells: List[Tuple[int, CellPlan]] = []
    for i, plan in enumerate(plans):
        if plan is None:
            continue
        if exact_mod.exact_regime(plan) is not None:
            results[i] = exact_mod.run_exact(plan)
        else:
            fluid_cells.append((i, plan))

    # Group by window cadence (lockstep needs one shared cadence) AND by
    # ladder rung sequence (the vector ladder stacks one rung table per
    # group — cells with different MikuConfig.levels go to separate
    # groups and still run batched), then chunk each group to cap memory.
    by_key: dict = {}
    scalar_idxs: List[int] = []
    for i, plan in fluid_cells:
        levels = tuple(plan.units[0].config.levels) if plan.units else ()
        key = (float(plan.export["window_ns"]), levels)
        by_key.setdefault(key, []).append((i, plan))
    def _stack(cells_):
        # Stacking (array layout + vector ladder/tiering build) is the
        # part that can legitimately reject a group (e.g. a cell whose
        # per-tier units mix rung tables, or a tiering policy outside the
        # vectorized registry).  Keep the net that narrow: a failure
        # *running* the fluid engine is a bug and must surface, not
        # silently rerun scalar.
        group = BatchGroup(cells_)
        ladder = fluid_mod.build_ladder(group)
        tiering = tiering_mod.build_tiering(group)
        return group, ladder, tiering

    block = batch_block()
    for _, cells in sorted(by_key.items()):
        for lo in range(0, len(cells), block):
            chunk = cells[lo:lo + block]
            try:
                stacks = [_stack(chunk)]
            except ValueError:
                # One unstackable cell must not drag its group-mates to
                # the scalar pool: re-stack each cell alone and fall back
                # only the ones that genuinely cannot stack.
                stacks = []
                for cell in chunk:
                    try:
                        stacks.append(_stack([cell]))
                    except ValueError as ex:
                        scalar_idxs.append(cell[0])
                        fallbacks.append(
                            (cell[0], f"group stacking failed: {ex}")
                        )
            for group, ladder, tiering in stacks:
                for idx, res in zip(
                    group.indices,
                    fluid_mod.run_fluid(group, ladder, tiering),
                ):
                    results[idx] = res

    # Partition-time fallbacks (plan is None); dynamic stacking fallbacks
    # were appended to ``scalar_idxs`` (and ``fallbacks``) above.
    scalar_idxs.extend(i for i, plan in enumerate(plans) if plan is None)
    if scalar_idxs:
        for idx, res in zip(
            scalar_idxs,
            run_sweep([jobs[i] for i in scalar_idxs], processes,
                      lane="scalar"),
        ):
            results[idx] = res
    require(
        all(r is not None for r in results),
        "lane-total",
        "batched lane dropped jobs: every job must land a result via the "
        "exact, fluid, or scalar-fallback path",
        missing=[i for i, r in enumerate(results) if r is None],
    )
    return results  # type: ignore[return-value]

"""Lane entry points: partition a job list, run it batched, fall back scalar.

``run_sweep(jobs, lane="batched")`` lands here.  Jobs the lane can express
run through the exact closed form (single-workload cells) or the stacked
fluid engine; tiering hooks and ``record_windows`` traces route back
through the ordinary scalar path (process pool included), silently and
per job, and :func:`partition_jobs` reports the split so callers
(:func:`repro.scenarios.planner.run_scenario`) can surface it in result
metadata.  Fluid cells stack into one group per (window cadence, ladder
rung table) pair — heterogeneous-rung grids still run batched, in
separate groups — and any group that nevertheless fails to stack falls
back to the scalar DES rather than aborting the sweep.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.des import SimResult
from repro.memsim.batched.stacking import BatchGroup, CellPlan, plan_cell

#: (plans aligned with the job list — None where the job fell back,
#:  [(job_index, reason), ...] for the fallbacks)
Partition = Tuple[List[Optional[CellPlan]], List[Tuple[int, str]]]


def can_batch(job) -> Optional[str]:
    """Static screen: the fallback reason, or None when the lane applies.

    The dynamic screen (ladder stacking) happens in :func:`partition_jobs`,
    which actually builds the cell plan.
    """
    if job.tiering is not None:
        return "tiering hook requires the scalar DES"
    if job.record_windows:
        return "record_windows telemetry requires the scalar DES"
    return None


def partition_jobs(jobs: Sequence) -> Partition:
    """Split ``jobs`` into batchable cell plans and scalar fallbacks."""
    plans: List[Optional[CellPlan]] = []
    fallbacks: List[Tuple[int, str]] = []
    for i, job in enumerate(jobs):
        reason = can_batch(job)
        if reason is None:
            try:
                plans.append(plan_cell(job))
                continue
            except ValueError as ex:  # e.g. heterogeneous ladder rungs
                reason = str(ex)
        plans.append(None)
        fallbacks.append((i, reason))
    return plans, fallbacks


def run_sweep_batched(
    jobs: Sequence,
    processes: Optional[int] = None,
    partition: Optional[Partition] = None,
) -> List[SimResult]:
    """Run ``jobs`` through the batched lane, results in job order.

    Single-workload cells take the exact closed form
    (:mod:`~repro.memsim.batched.exact`); the rest stack into window-lockstep
    fluid groups (:mod:`~repro.memsim.batched.fluid`, one group per control
    cadence).  Fallback jobs run on the scalar lane — through the process
    pool when ``processes`` says so.
    """
    from repro.memsim.batched import exact as exact_mod
    from repro.memsim.batched import fluid as fluid_mod
    from repro.memsim.sweep import run_sweep

    jobs = list(jobs)
    plans, fallbacks = partition if partition is not None else (
        partition_jobs(jobs)
    )
    results: List[Optional[SimResult]] = [None] * len(jobs)

    fluid_cells: List[Tuple[int, CellPlan]] = []
    for i, plan in enumerate(plans):
        if plan is None:
            continue
        if exact_mod.exact_regime(plan) is not None:
            results[i] = exact_mod.run_exact(plan)
        else:
            fluid_cells.append((i, plan))

    # Group by window cadence (lockstep needs one shared cadence) AND by
    # ladder rung sequence (the vector ladder stacks one rung table per
    # group — cells with different MikuConfig.levels go to separate
    # groups and still run batched).
    by_key: dict = {}
    scalar_idxs: List[int] = []
    for i, plan in fluid_cells:
        levels = tuple(plan.units[0].config.levels) if plan.units else ()
        key = (float(plan.export["window_ns"]), levels)
        by_key.setdefault(key, []).append((i, plan))
    for _, cells in sorted(by_key.items()):
        try:
            # Stacking (array layout + vector-ladder build) is the part
            # that can legitimately reject a group (e.g. a cell whose
            # per-tier units mix rung tables).  Keep the net that narrow:
            # a failure *running* the fluid engine is a bug and must
            # surface, not silently rerun scalar.
            group = BatchGroup(cells)
            ladder = fluid_mod.build_ladder(group)
        except ValueError:
            scalar_idxs.extend(i for i, _ in cells)
            continue
        for idx, res in zip(group.indices,
                            fluid_mod.run_fluid(group, ladder)):
            results[idx] = res

    scalar_idxs.extend(i for i, _ in fallbacks)
    if scalar_idxs:
        for idx, res in zip(
            scalar_idxs,
            run_sweep([jobs[i] for i in scalar_idxs], processes,
                      lane="scalar"),
        ):
            results[idx] = res
    assert all(r is not None for r in results)
    return results  # type: ignore[return-value]

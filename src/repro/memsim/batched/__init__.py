"""repro.memsim.batched — the vectorized sweep-scale execution lane.

Every paper figure is a *grid* of independent simulations, and the scalar
DES pays the full event-loop cost per cell.  This package runs an entire
:class:`~repro.memsim.sweep.SimJob` grid as one stacked, window-lockstep
station-service computation instead:

* :mod:`~repro.memsim.batched.stacking` — builds one (un-run)
  :class:`~repro.core.des.TieredMemorySim` per job, exports its static
  state (:meth:`~repro.core.des.TieredMemorySim.export_state`), and stacks
  the cells into ``(n_jobs, n_workloads, n_stations)`` numpy arrays,
  grouped by control-window cadence.
* :mod:`~repro.memsim.batched.fluid` — advances all cells window-by-window
  in lockstep: each window solves a closed-network fluid equilibrium (fair
  per-core admission, station capacities, the shared ToR population bound)
  and feeds the per-tier counters to the vectorized MIKU ladder
  (:class:`repro.core.controller.VectorMikuLadder`), whose decisions
  throttle the next window — the same feedback loop as the scalar DES, at
  window granularity.
* :mod:`~repro.memsim.batched.exact` — the closed-form fast path for
  single-workload cells (bw-test / lat-test shapes): event counts are
  reproduced exactly, including the DES's float-accumulated event times,
  so bandwidth and completed counts are **bit-identical** to the scalar
  lane.
* :mod:`~repro.memsim.batched.kernel` — the per-window equilibrium solver:
  a numpy bisection by default, or a Pallas kernel when
  ``REPRO_BATCH_BACKEND=pallas`` (``jax.pallas``; interpreted off-TPU).

Entry point: :func:`run_sweep_batched`, normally reached through
``run_sweep(jobs, lane="batched")`` / ``benchmarks/run.py --lane batched``.
Jobs the lane cannot express (tiering hooks, ``record_windows`` traces)
fall back to the scalar DES automatically — :func:`partition_jobs`
reports who fell back and why — and cells with different ladder rung
tables simply stack into separate lockstep groups.

Fidelity contract (see ``docs/decision-laws.md``): single-workload cells
are exact; multi-workload cells are fluid approximations — bandwidths
track the scalar DES to within a few percent on the pinned equivalence
scenarios (``tests/test_batched.py``), latency *percentiles* and
per-request reservoirs are not reproduced.
"""

from repro.memsim.batched.lane import (
    can_batch,
    partition_jobs,
    run_sweep_batched,
)

__all__ = [
    "can_batch",
    "partition_jobs",
    "run_sweep_batched",
]

"""memsim — the paper's micro-benchmark suite (bw-test / lat-test / lat-share)
run against the simulated tiered-memory testbed (:mod:`repro.core.des`).

This package is the characterization half of the reproduction: every figure
in the paper's §2-§6 has a corresponding runner here, producing the numbers
recorded in EXPERIMENTS.md.
"""

from repro.memsim.calibration import calibrate_estimator, default_miku
from repro.memsim.runner import (
    bandwidth_matrix,
    corun_matrix,
    latency_matrix,
    llc_partition_sweep,
    miku_comparison,
    sync_interference,
)
from repro.memsim.sweep import SimJob, run_job, run_sweep

__all__ = [
    "calibrate_estimator",
    "default_miku",
    "bandwidth_matrix",
    "corun_matrix",
    "latency_matrix",
    "llc_partition_sweep",
    "miku_comparison",
    "sync_interference",
    "SimJob",
    "run_job",
    "run_sweep",
]

"""Canonical workload builders mirroring the paper's benchmark setups (§3)."""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.des import WorkloadSpec
from repro.core.littles_law import OpClass


def bw_test(
    tier: str,
    op: OpClass,
    n_threads: int,
    *,
    name: Optional[str] = None,
    mlp: int = 160,
    miku_managed: bool = True,
    wss_mb: float = 32768.0,
    llc_alloc_mb: float = 0.0,
    phases: Optional[Sequence[Tuple[float, str]]] = None,
    ddr_fraction: Optional[float] = None,
    host: Optional[str] = None,
) -> WorkloadSpec:
    """lmbench-style sequential bandwidth test: ``n_threads`` cores, each a
    1 GB non-overlapping region (WSS >> LLC, so all accesses miss).
    ``host`` pins the issuing fabric host on routed-topology platforms."""
    return WorkloadSpec(
        name=name or f"bw-{tier}-{op.value}-{n_threads}t",
        op=op,
        tier=tier,
        n_cores=n_threads,
        mlp=mlp,
        wss_mb=wss_mb,
        llc_alloc_mb=llc_alloc_mb,
        phases=phases,
        miku_managed=miku_managed,
        ddr_fraction=ddr_fraction,
        host=host,
    )


def lat_test(
    tier: str,
    op: OpClass = OpClass.LOAD,
    n_threads: int = 1,
    *,
    name: Optional[str] = None,
) -> WorkloadSpec:
    """Pointer-chasing latency test: randomly-linked circular list, one
    outstanding access per thread (512 MB WSS >> LLC)."""
    return WorkloadSpec(
        name=name or f"lat-{tier}-{op.value}-{n_threads}t",
        op=op,
        tier=tier,
        n_cores=n_threads,
        dependent=True,
        wss_mb=512.0,
    )


def serve_test(
    n_threads: int = 4,
    *,
    name: str = "serve",
    arrival: Optional[object] = None,
    ddr_fraction: Optional[float] = None,
    mlp: int = 8,
    op: OpClass = OpClass.LOAD,
    host: Optional[str] = None,
) -> WorkloadSpec:
    """Open-loop serving workload: ``n_threads`` worker cores with bounded
    per-core concurrency draining an arrival-fed backlog
    (:mod:`repro.workload`).  ``ddr_fraction`` interleaves its requests
    across DDR/CXL (the SLO scenarios' placement axis); the workload is
    never MIKU-managed — it models the latency-critical tenant the
    controller protects, not the batch traffic it throttles."""
    return WorkloadSpec(
        name=name,
        op=op,
        tier="ddr",
        n_cores=n_threads,
        mlp=mlp,
        wss_mb=2048.0,
        miku_managed=False,
        ddr_fraction=ddr_fraction,
        host=host,
        arrival=arrival,
    )


def lat_share(n_threads: int = 2, *, name: str = "lat-share") -> WorkloadSpec:
    """Two threads CAS-updating one shared cacheline (coherence through the
    CHA/ToR; paper §4.4)."""
    return WorkloadSpec(
        name=name,
        op=OpClass.STORE,
        tier="ddr",
        n_cores=n_threads,
        sync=True,
        wss_mb=0.001,
        miku_managed=False,
    )


def alternating_bw_pair(
    op: OpClass,
    n_threads: int = 16,
    period_ns: float = 100_000.0,
) -> List[WorkloadSpec]:
    """Fig. 10's dynamic scenario: two groups alternating DDR and CXL access
    every ``period_ns`` (the paper's 100 s, time-scaled to the simulator)."""
    return [
        WorkloadSpec(
            name="alt-a",
            op=op,
            tier="ddr",
            n_cores=n_threads,
            phases=[(period_ns, "ddr"), (period_ns, "cxl")],
        ),
        WorkloadSpec(
            name="alt-b",
            op=op,
            tier="cxl",
            n_cores=n_threads,
            phases=[(period_ns, "cxl"), (period_ns, "ddr")],
        ),
    ]

"""Figure-level experiment runners — thin wrappers over the scenario API.

Every function here used to hand-build its :class:`~repro.memsim.sweep.SimJob`
matrix imperatively; the matrices now live as declarative, registry-named
scenarios in :mod:`repro.scenarios.library` and these wrappers only preserve
the original call signatures and return shapes (plain dicts/lists, all
bandwidths GB/s, latencies ns, times simulator-ns).  New experiments should
target the scenario registry directly::

    from repro.scenarios import run_scenario
    rows = run_scenario("fig3_bandwidth", {"platform": "A"}).rows

``tests/test_scenarios.py`` pins each registered scenario's job matrix and
result rows against the legacy imperative construction, so wrapper and
scenario cannot drift apart.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.device_model import PlatformModel
from repro.core.littles_law import OpClass


def _rows(name: str, overrides: dict, processes: Optional[int],
          drop: Tuple[str, ...] = ("platform",)) -> List[dict]:
    from repro.scenarios import run_scenario  # local: avoids import cycle

    table = run_scenario(name, overrides, processes)
    return [{k: v for k, v in r.items() if k not in drop}
            for r in table.rows]


# -- Fig. 3: single-threaded and peak bandwidth, DDR vs CXL -----------------


def bandwidth_matrix(
    platform: PlatformModel,
    threads: Tuple[int, ...] = (1, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    """Fig. 3 rows: per-tier bw-test bandwidth over a thread grid."""
    return _rows("fig3_bandwidth",
                 {"platform": platform, "threads": threads}, processes)


# -- Fig. 4: average and tail latency ----------------------------------------


def latency_matrix(
    platform: PlatformModel,
    threads: Tuple[int, ...] = (1, 2, 4, 8, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    """Fig. 4 rows: per-tier loaded avg/p50/p99 latency over threads."""
    return _rows("fig4_latency",
                 {"platform": platform, "threads": threads}, processes)


# -- Fig. 2: tiered memory management schemes --------------------------------


def tiering_schemes(
    platform: PlatformModel, op: OpClass, processes: Optional[int] = None
) -> Dict[str, float]:
    """Aggregate bandwidth of two 16-thread copies under each scheme
    (upper / lower / native / interleave / os_managed / ideal_combined)."""
    (row,) = _rows("fig2_tiering",
                   {"platform": platform, "op": (op,)}, processes,
                   drop=("platform", "op"))
    return row


# -- Fig. 5 + 6: co-run collapse and ToR accounting ---------------------------


def corun_matrix(
    platform: PlatformModel,
    n_threads: int = 16,
    processes: Optional[int] = None,
) -> List[dict]:
    """Fig. 5/6 rows: co-run collapse + ToR accounting per op class."""
    return _rows("fig5_corun",
                 {"platform": platform, "n_threads": n_threads}, processes)


def tor_insert_bandwidth_correlation(
    platform: PlatformModel, processes: Optional[int] = None
) -> float:
    """Pearson correlation between ToR insertion rate and delivered bandwidth
    across scenarios (paper: r = 0.998)."""
    (row,) = _rows("fig6_tor_correlation", {"platform": platform}, processes)
    return row["pearson_r"]


# -- Fig. 7: LLC partitioning (Intel CAT analogue) ----------------------------


def llc_partition_sweep(
    platform: PlatformModel,
    wss_mb: float,
    allocs: Tuple[float, ...] = (0.95, 0.75, 0.5, 0.25, 0.05),
    processes: Optional[int] = None,
) -> List[dict]:
    """Fig. 7 rows: LLC (CAT) allocation sweep under tiered co-run."""
    return _rows(
        "fig7_llc",
        {"platform": platform, "wss_mb": (wss_mb,), "ddr_share": allocs},
        processes,
    )


# -- Fig. 8: inter-core synchronization ---------------------------------------


def sync_interference(
    platform: PlatformModel,
    bg_threads: Tuple[int, ...] = (0, 4, 8, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    """Fig. 8 rows: CAS latency vs per-tier background thread count."""
    return _rows("fig8_sync",
                 {"platform": platform, "bg_threads": bg_threads}, processes)


# -- Fig. 9: service time vs concurrency --------------------------------------


def service_time_curve(
    platform: PlatformModel,
    op: OpClass = OpClass.LOAD,
    threads: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
    processes: Optional[int] = None,
) -> List[dict]:
    return _rows(
        "fig9_service",
        {"platform": platform, "op": op, "threads": threads},
        processes,
    )


# -- Fig. 10: MIKU vs DataRacing vs Opt ---------------------------------------


@dataclasses.dataclass
class MikuComparison:
    op: str
    opt_ddr: float
    opt_cxl: float
    racing_ddr: float
    racing_cxl: float
    miku_ddr: float
    miku_cxl: float
    miku_mba_ddr: float
    miku_mba_cxl: float

    @property
    def miku_ddr_frac_of_opt(self) -> float:
        return self.miku_ddr / max(self.opt_ddr, 1e-9)


def pertier_comparison(
    platform: str = "A-switch",
    op: OpClass = OpClass.STORE,
    *,
    laws: Tuple[str, ...] = ("racing", "merged", "pertier"),
    n_threads: int = 16,
    sim_ns: float = 300_000.0,
    processes: Optional[int] = None,
) -> List[dict]:
    """Three-tier co-run under each control law (``corun3_pertier``):
    per-slow-tier MIKU ladders vs the merged-slow broadcast vs racing.
    Rows carry per-tier mean caps/rates and restricted-window counts —
    under the per-tier law the switch tier's ladder sits below local
    CXL's; under the merged law both columns are identical by
    construction."""
    return _rows(
        "corun3_pertier",
        {"platform": platform, "op": (op,), "law": laws,
         "n_threads": n_threads, "sim_ns": sim_ns},
        processes,
        drop=(),
    )


def miku_comparison(
    platform: PlatformModel,
    op: OpClass,
    *,
    n_threads: int = 16,
    period_ns: float = 100_000.0,
    cycles: int = 3,
    processes: Optional[int] = None,
) -> MikuComparison:
    """The paper's §6 micro-benchmark case study: two 16-thread groups
    alternating DDR/CXL every period (Opt / DataRacing / MIKU / MIKU-MBA)."""
    (row,) = _rows(
        "fig10_miku",
        {
            "platform": platform,
            "op": (op,),
            "n_threads": n_threads,
            "period_ns": period_ns,
            "cycles": cycles,
        },
        processes,
    )
    return MikuComparison(**row)

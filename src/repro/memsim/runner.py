"""Figure-level experiment runners (one per paper table/figure).

Every function returns plain dict/list results; :mod:`benchmarks` formats
them as CSV.  All bandwidths are GB/s, latencies ns, times simulator-ns.

Execution goes through :mod:`repro.memsim.sweep`: each figure builds its
matrix of independent :class:`~repro.memsim.sweep.SimJob` cells and hands
the whole batch to :func:`~repro.memsim.sweep.run_sweep`, which fans out
over a process pool when ``REPRO_SWEEP_PROCS`` (or an explicit
``processes=``) asks for it — serial and parallel runs are bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.des import WorkloadSpec
from repro.core.device_model import PlatformModel
from repro.core.littles_law import OpClass
from repro.memsim.sweep import SimJob, run_sweep
from repro.memsim.workloads import alternating_bw_pair, bw_test, lat_share, lat_test

_BW_SIM_NS = 120_000.0
_CORUN_SIM_NS = 300_000.0


def _job(
    platform: PlatformModel,
    workloads: List[WorkloadSpec],
    sim_ns: float,
    *,
    miku: bool = False,
    seed: int = 0,
    granularity: int = 4,
    window_ns: float = 10_000.0,
) -> SimJob:
    return SimJob(
        platform=platform,
        workloads=workloads,
        sim_ns=sim_ns,
        seed=seed,
        granularity=granularity,
        window_ns=window_ns,
        miku=miku,
    )


# -- Fig. 3: single-threaded and peak bandwidth, DDR vs CXL -----------------


def bandwidth_matrix(
    platform: PlatformModel,
    threads: Tuple[int, ...] = (1, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    cells = [
        (op, n, tier)
        for op in OpClass
        for n in threads
        for tier in ("ddr", "cxl")
    ]
    jobs = [
        _job(platform, [bw_test(tier, op, n)], _BW_SIM_NS)
        for op, n, tier in cells
    ]
    rows = []
    for (op, n, tier), job, res in zip(cells, jobs, run_sweep(jobs, processes)):
        rows.append(
            {
                "op": op.value,
                "tier": tier,
                "threads": n,
                "bandwidth_gbps": res.bandwidth(job.workloads[0].name),
                "peak_model_gbps": platform.device_for(tier).peak_bandwidth_gbps(op),
            }
        )
    return rows


# -- Fig. 4: average and tail latency ----------------------------------------


def latency_matrix(
    platform: PlatformModel,
    threads: Tuple[int, ...] = (1, 2, 4, 8, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    cells = [(tier, n) for tier in ("ddr", "cxl") for n in threads]
    jobs = [
        _job(platform, [lat_test(tier, OpClass.LOAD, n)], 400_000.0, granularity=1)
        for tier, n in cells
    ]
    rows = []
    for (tier, n), job, res in zip(cells, jobs, run_sweep(jobs, processes)):
        st = res.stats[job.workloads[0].name]
        rows.append(
            {
                "tier": tier,
                "threads": n,
                "avg_ns": st.mean_latency_ns(),
                "p50_ns": st.percentile_ns(0.50),
                "p99_ns": st.percentile_ns(0.99),
            }
        )
    return rows


# -- Fig. 2: tiered memory management schemes --------------------------------


def tiering_schemes(
    platform: PlatformModel, op: OpClass, processes: Optional[int] = None
) -> Dict[str, float]:
    """Aggregate bandwidth of two 16-thread copies under each scheme.

    * upper   — one copy, WSS fully in DDR (max achievable).
    * lower   — one copy, WSS fully in CXL (baseline).
    * native  — copy A on DDR, copy B on CXL (application-directed).
    * interleave — both copies page-interleaved at the tier bandwidth ratio.
    * os_managed — interleaved placement plus migration tax: a background
      kernel thread moving pages (load+store on both tiers), the paper's
      "page migrations significantly degrade tiered memory performance".
    """
    out = {}
    up, low = run_sweep(
        [
            _job(platform, [bw_test("ddr", op, 16, name="a")], _BW_SIM_NS),
            _job(platform, [bw_test("cxl", op, 16, name="a")], _BW_SIM_NS),
        ],
        processes,
    )
    out["upper_ddr_only"] = up.bandwidth("a")
    out["lower_cxl_only"] = low.bandwidth("a")

    # The remaining schemes depend on the measured upper/lower split.
    frac = out["upper_ddr_only"] / max(
        out["upper_ddr_only"] + out["lower_cxl_only"], 1e-9
    )
    migration = WorkloadSpec(
        name="kmigrated",
        op=OpClass.STORE,
        tier="cxl",
        n_cores=2,
        mlp=64,
        ddr_fraction=0.5,
        miku_managed=False,
    )
    nat, inter, osm = run_sweep(
        [
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", miku_managed=False),
                    bw_test("cxl", op, 16, name="b"),
                ],
                _CORUN_SIM_NS,
            ),
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", ddr_fraction=frac,
                            miku_managed=False),
                    bw_test("cxl", op, 16, name="b", ddr_fraction=frac,
                            miku_managed=False),
                ],
                _CORUN_SIM_NS,
            ),
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", ddr_fraction=frac,
                            miku_managed=False),
                    bw_test("cxl", op, 16, name="b", ddr_fraction=frac,
                            miku_managed=False),
                    migration,
                ],
                _CORUN_SIM_NS,
            ),
        ],
        processes,
    )
    out["native"] = nat.bandwidth("a") + nat.bandwidth("b")
    out["interleave"] = inter.bandwidth("a") + inter.bandwidth("b")
    out["os_managed"] = osm.bandwidth("a") + osm.bandwidth("b")
    out["ideal_combined"] = out["upper_ddr_only"] + out["lower_cxl_only"]
    return out


# -- Fig. 5 + 6: co-run collapse and ToR accounting ---------------------------


def corun_matrix(
    platform: PlatformModel,
    n_threads: int = 16,
    processes: Optional[int] = None,
) -> List[dict]:
    ops = list(OpClass)
    jobs = []
    for op in ops:
        a = bw_test("ddr", op, n_threads, name="ddr", miku_managed=False)
        c = bw_test("cxl", op, n_threads, name="cxl")
        jobs.append(_job(platform, [a], _BW_SIM_NS))
        jobs.append(_job(platform, [c], _BW_SIM_NS))
        jobs.append(_job(platform, [a, c], _CORUN_SIM_NS))
    results = run_sweep(jobs, processes)
    rows = []
    for i, op in enumerate(ops):
        alone, cxl_alone, both = results[3 * i : 3 * i + 3]
        ddr_alone_bw = alone.bandwidth("ddr")
        cxl_alone_bw = cxl_alone.bandwidth("cxl")
        rows.append(
            {
                "op": op.value,
                "ddr_alone_gbps": ddr_alone_bw,
                "cxl_alone_gbps": cxl_alone_bw,
                "ddr_corun_gbps": both.bandwidth("ddr"),
                "cxl_corun_gbps": both.bandwidth("cxl"),
                "ddr_loss_pct": 100.0 * (1 - both.bandwidth("ddr") / ddr_alone_bw),
                # Fig. 6 quantities:
                "tor_insert_rate_alone_per_ns": alone.tor_inserts / alone.sim_ns,
                "tor_insert_rate_corun_per_ns": both.tor_inserts / both.sim_ns,
                "tor_avg_latency_alone_ns": alone.tor_avg_latency_ns,
                "tor_avg_latency_corun_ns": both.tor_avg_latency_ns,
                "t_ddr_corun_ns": both.tier_counters["ddr"].mean_service_time,
                "t_cxl_corun_ns": both.tier_counters["cxl"].mean_service_time,
            }
        )
    return rows


def tor_insert_bandwidth_correlation(
    platform: PlatformModel, processes: Optional[int] = None
) -> float:
    """Pearson correlation between ToR insertion rate and delivered bandwidth
    across scenarios (paper: r = 0.998)."""
    cells = []
    jobs = []
    for op in OpClass:
        for scenario in ("ddr", "cxl", "both"):
            wls: List[WorkloadSpec] = []
            if scenario in ("ddr", "both"):
                wls.append(bw_test("ddr", op, 16, name="ddr", miku_managed=False))
            if scenario in ("cxl", "both"):
                wls.append(bw_test("cxl", op, 16, name="cxl"))
            cells.append(wls)
            jobs.append(_job(platform, wls, _BW_SIM_NS))
    xs, ys = [], []
    for wls, res in zip(cells, run_sweep(jobs, processes)):
        xs.append(res.tor_inserts / res.sim_ns)
        ys.append(sum(res.bandwidth(w.name) for w in wls))
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    vx = sum((x - mx) ** 2 for x in xs) ** 0.5
    vy = sum((y - my) ** 2 for y in ys) ** 0.5
    return cov / max(vx * vy, 1e-12)


# -- Fig. 7: LLC partitioning (Intel CAT analogue) ----------------------------


def llc_partition_sweep(
    platform: PlatformModel,
    wss_mb: float,
    allocs: Tuple[float, ...] = (0.95, 0.75, 0.5, 0.25, 0.05),
    processes: Optional[int] = None,
) -> List[dict]:
    """Two store bw-tests with strong locality, DDR- vs CXL-backed; sweep the
    DDR workload's LLC share (CAT).  ``free competition`` approximated by the
    proportional 0.5 point for equal-WSS workloads."""
    cap = platform.llc_capacity_mb
    jobs = []
    for alloc in allocs:
        a = bw_test(
            "ddr", OpClass.STORE, 16, name="ddr",
            wss_mb=wss_mb, llc_alloc_mb=alloc * cap, miku_managed=False,
        )
        b = bw_test(
            "cxl", OpClass.STORE, 16, name="cxl",
            wss_mb=wss_mb, llc_alloc_mb=(1.0 - alloc) * cap, miku_managed=False,
        )
        jobs.append(_job(platform, [a, b], _CORUN_SIM_NS))
    rows = []
    for alloc, res in zip(allocs, run_sweep(jobs, processes)):
        rows.append(
            {
                "wss_mb": wss_mb,
                "ddr_llc_share": alloc,
                "ddr_gbps": res.bandwidth("ddr"),
                "cxl_gbps": res.bandwidth("cxl"),
                "total_gbps": res.bandwidth("ddr") + res.bandwidth("cxl"),
            }
        )
    return rows


# -- Fig. 8: inter-core synchronization ---------------------------------------


def sync_interference(
    platform: PlatformModel,
    bg_threads: Tuple[int, ...] = (0, 4, 8, 16),
    processes: Optional[int] = None,
) -> List[dict]:
    cells = [(tier, n) for tier in ("ddr", "cxl") for n in bg_threads]
    jobs = []
    for tier, n in cells:
        wls = [lat_share()]
        if n > 0:
            wls.append(bw_test(tier, OpClass.LOAD, n, name="bg", miku_managed=False))
        jobs.append(_job(platform, wls, 200_000.0, granularity=1))
    rows = []
    for (tier, n), res in zip(cells, run_sweep(jobs, processes)):
        rows.append(
            {
                "bg_tier": tier,
                "bg_threads": n,
                "cas_latency_ns": res.stats["lat-share"].mean_latency_ns(),
            }
        )
    return rows


# -- Fig. 9: service time vs concurrency --------------------------------------


def service_time_curve(
    platform: PlatformModel,
    op: OpClass = OpClass.LOAD,
    threads: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
    processes: Optional[int] = None,
) -> List[dict]:
    cells = [(tier, n) for tier in ("ddr", "cxl") for n in threads]
    jobs = [
        _job(platform, [bw_test(tier, op, n)], _BW_SIM_NS) for tier, n in cells
    ]
    rows = []
    for (tier, n), job, res in zip(cells, jobs, run_sweep(jobs, processes)):
        rows.append(
            {
                "tier": tier,
                "threads": n,
                "service_time_ns": res.tier_counters[tier].mean_service_time,
                "bandwidth_gbps": res.bandwidth(job.workloads[0].name),
            }
        )
    return rows


# -- Fig. 10: MIKU vs DataRacing vs Opt ---------------------------------------


@dataclasses.dataclass
class MikuComparison:
    op: str
    opt_ddr: float
    opt_cxl: float
    racing_ddr: float
    racing_cxl: float
    miku_ddr: float
    miku_cxl: float
    miku_mba_ddr: float
    miku_mba_cxl: float

    @property
    def miku_ddr_frac_of_opt(self) -> float:
        return self.miku_ddr / max(self.opt_ddr, 1e-9)


def miku_comparison(
    platform: PlatformModel,
    op: OpClass,
    *,
    n_threads: int = 16,
    period_ns: float = 100_000.0,
    cycles: int = 3,
    processes: Optional[int] = None,
) -> MikuComparison:
    """The paper's §6 micro-benchmark case study: two 16-thread groups
    alternating DDR/CXL every period.  Opt = each side alone (no
    interference); DataRacing = no control; MIKU = CPU-quota-style dynamic
    control; MIKU-MBA = same controller driving the MBA-style token bucket
    (identical mechanics in simulation — both regulate issue rate; noted in
    DESIGN.md)."""
    sim_ns = 2 * cycles * period_ns

    alt = alternating_bw_pair(op, n_threads, period_ns)
    opt_a, opt_c, racing, miku, mba = run_sweep(
        [
            _job(platform, [bw_test("ddr", op, n_threads, name="a")], _BW_SIM_NS),
            _job(platform, [bw_test("cxl", op, n_threads, name="a")], _BW_SIM_NS),
            _job(platform, alt, sim_ns, window_ns=5_000.0),
            _job(platform, alt, sim_ns, window_ns=5_000.0, miku=True),
            _job(platform, alt, sim_ns, window_ns=5_000.0, miku=True),
        ],
        processes,
    )

    def tier_split(res) -> Tuple[float, float]:
        # Each group spends half its time on each tier; attribute bandwidth
        # by the tier actually served per phase using the per-tier counters.
        g = 4  # granularity
        ddr_bytes = res.tier_counters["ddr"].inserts * platform.ddr.access_bytes * g
        cxl_bytes = res.tier_counters["cxl"].inserts * platform.cxl.access_bytes * g
        return ddr_bytes / res.sim_ns, cxl_bytes / res.sim_ns

    racing_ddr, racing_cxl = tier_split(racing)
    miku_ddr, miku_cxl = tier_split(miku)
    mba_ddr, mba_cxl = tier_split(mba)

    return MikuComparison(
        op=op.value,
        opt_ddr=opt_a.bandwidth("a"),
        opt_cxl=opt_c.bandwidth("a"),
        racing_ddr=racing_ddr,
        racing_cxl=racing_cxl,
        miku_ddr=miku_ddr,
        miku_cxl=miku_cxl,
        miku_mba_ddr=mba_ddr,
        miku_mba_cxl=mba_cxl,
    )

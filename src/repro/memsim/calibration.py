"""Offline calibration of MIKU's estimator from device models (paper §5.2).

The paper measures two constants offline with micro-benchmarks:

  * ``T_ddr`` — the fast tier's ToR residency, treated as constant ("in all
    experiments, DDR memory never caused a backlog in the ToR").
  * the slow-tier *read* latency threshold beyond which device-side queueing
    grows exponentially and throughput declines; the write threshold is ~2x
    the read threshold (footnote 2).

We derive both from the :class:`~repro.core.device_model.DeviceModel`
parameters, in the same units the simulator measures residencies in (one
macro-request = ``granularity`` cachelines serviced back-to-back):

  * ``t_fast``  = fast pipeline + g * read_service * (1 + q_f) — the service
    time plus a modest queueing markup (the fast tier runs loaded but never
    backlogged).
  * ``threshold`` = slow pipeline + g * read_service * (1 + q_s) — allowing
    ``q_s`` service-times of device queueing before calling it a backlog.
    ``q_s`` is the knob trading slow-tier utilization against fast-tier
    protection; the paper's "maximum allowable concurrency without causing a
    backlog" corresponds to the queue depth that just keeps the device's
    slots covered through the pipeline latency.
"""

from __future__ import annotations

from repro.core.controller import MikuConfig, MikuController
from repro.core.device_model import PlatformModel
from repro.core.littles_law import EstimatorConfig, OpClass


def calibrate_estimator(
    platform: PlatformModel,
    granularity: int = 4,
    *,
    slow_queue_markup: float = 4.0,
    ewma: float = 0.5,
) -> EstimatorConfig:
    g = granularity
    ddr, cxl = platform.ddr, platform.cxl
    # Loaded fast-tier residency: with the shared pool (ToR) full of fast
    # requests, Little gives residency = pool_size / service_rate.  This is
    # what the paper's offline saturating bw-test measures.  (Independent of
    # macro-request granularity: pool and rate scale together.)
    pool = platform.tor_entries / g  # macro entries
    mu_fast = ddr.total_slots / (g * ddr.read_service_ns)  # macro/ns
    t_fast = max(pool / mu_fast, ddr.pipeline_ns + g * ddr.read_service_ns)
    # Per-class scaling of the fast residency (stores are RMW: they occupy
    # the queue for read+write service).
    rs, ws = ddr.read_service_ns, ddr.write_service_ns
    per_instr = {
        OpClass.LOAD: rs,
        OpClass.STORE: rs + ws,
        OpClass.NT_STORE: ws,
    }
    class_scale = {c: s / rs for c, s in per_instr.items()}
    # Backlog-free queue depth: enough in-flight to cover the pipeline (the
    # device stays saturated) but no runaway device-side queue.  The pipeline
    # coverage ratio pipeline/(g*service) is the natural floor; add the
    # configured markup on top.
    pipeline_cover = cxl.pipeline_ns / max(g * cxl.read_service_ns, 1e-9)
    depth = max(slow_queue_markup, pipeline_cover)
    threshold = cxl.pipeline_ns + g * cxl.read_service_ns * (1.0 + depth)
    return EstimatorConfig(
        t_fast=t_fast,
        slow_read_threshold=threshold,
        write_threshold_scale=2.0,
        ewma=ewma,
        t_fast_class_scale=class_scale,
    )


def default_miku(
    platform: PlatformModel,
    granularity: int = 4,
    **est_overrides,
) -> MikuController:
    """A MIKU controller calibrated for ``platform`` (paper defaults:
    concurrency ladder 1/2/4/8/16, class caps 8/4/1 for load/store/nt-store)."""
    est = calibrate_estimator(platform, granularity, **est_overrides)
    cfg = MikuConfig(
        levels=(1, 2, 4, 8, 16),
        class_caps={OpClass.LOAD: 8, OpClass.STORE: 4, OpClass.NT_STORE: 1},
    )
    return MikuController(cfg, est)

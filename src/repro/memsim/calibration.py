"""Offline calibration of MIKU's estimator from device models (paper §5.2).

The paper measures two constants offline with micro-benchmarks:

  * ``T_ddr`` — the fast tier's ToR residency, treated as constant ("in all
    experiments, DDR memory never caused a backlog in the ToR").
  * the slow-tier *read* latency threshold beyond which device-side queueing
    grows exponentially and throughput declines; the write threshold is ~2x
    the read threshold (footnote 2).

We derive both from the :class:`~repro.core.device_model.DeviceModel`
parameters, in the same units the simulator measures residencies in (one
macro-request = ``granularity`` cachelines serviced back-to-back):

  * ``t_fast``  = fast pipeline + g * read_service * (1 + q_f) — the service
    time plus a modest queueing markup (the fast tier runs loaded but never
    backlogged).
  * ``threshold`` = slow pipeline + g * read_service * (1 + q_s) — allowing
    ``q_s`` service-times of device queueing before calling it a backlog.
    ``q_s`` is the knob trading slow-tier utilization against fast-tier
    protection; the paper's "maximum allowable concurrency without causing a
    backlog" corresponds to the queue depth that just keeps the device's
    slots covered through the pipeline latency.
"""

from __future__ import annotations

from typing import Optional

from repro.core.controller import MergedSlowPolicy, MikuConfig, MikuController
from repro.core.device_model import DeviceModel, PlatformModel
from repro.core.littles_law import EstimatorConfig, OpClass


def calibrate_estimator(
    platform: PlatformModel,
    granularity: int = 4,
    *,
    slow_queue_markup: float = 4.0,
    ewma: float = 0.5,
    slow_device: Optional[DeviceModel] = None,
    shared_slow_tiers: int = 1,
) -> EstimatorConfig:
    """Estimator calibration for one slow tier (default: the CXL tier).

    ``slow_device`` selects which slow tier's DeviceModel derives the
    backlog threshold — the per-tier ensemble calibrates one estimator per
    slow tier, so a CXL-over-switch tier (longer pipeline) gets a higher
    threshold than a local expander, exactly the paper's per-device
    calibration.  ``shared_slow_tiers`` divides the allowed queue depth:
    the ToR is one shared pool, so when ``n`` slow tiers contend, each
    tier's backlog-free budget is a ``1/n`` share of the depth a lone slow
    tier may hold (``=1`` — a single slow tier — reproduces the seed
    calibration exactly)."""
    g = granularity
    ddr, cxl = platform.ddr, slow_device if slow_device is not None else platform.cxl
    # Loaded fast-tier residency: with the shared pool (ToR) full of fast
    # requests, Little gives residency = pool_size / service_rate.  This is
    # what the paper's offline saturating bw-test measures.  (Independent of
    # macro-request granularity: pool and rate scale together.)
    pool = platform.tor_entries / g  # macro entries
    mu_fast = ddr.total_slots / (g * ddr.read_service_ns)  # macro/ns
    t_fast = max(pool / mu_fast, ddr.pipeline_ns + g * ddr.read_service_ns)
    # Per-class scaling of the fast residency (stores are RMW: they occupy
    # the queue for read+write service).
    rs, ws = ddr.read_service_ns, ddr.write_service_ns
    per_instr = {
        OpClass.LOAD: rs,
        OpClass.STORE: rs + ws,
        OpClass.NT_STORE: ws,
        OpClass.MIGRATE: rs + ws,  # a migrated line is read + written
    }
    class_scale = {c: s / rs for c, s in per_instr.items()}
    # Backlog-free queue depth: enough in-flight to cover the pipeline (the
    # device stays saturated) but no runaway device-side queue.  The pipeline
    # coverage ratio pipeline/(g*service) is the natural floor; add the
    # configured markup on top.
    pipeline_cover = cxl.pipeline_ns / max(g * cxl.read_service_ns, 1e-9)
    depth = max(slow_queue_markup, pipeline_cover) / max(shared_slow_tiers, 1)
    threshold = cxl.pipeline_ns + g * cxl.read_service_ns * (1.0 + depth)
    return EstimatorConfig(
        t_fast=t_fast,
        slow_read_threshold=threshold,
        write_threshold_scale=2.0,
        ewma=ewma,
        t_fast_class_scale=class_scale,
    )


#: Paper defaults: per-instruction-class backlog-free concurrency for the
#: canonical local CXL expander (§5.2: 8/4/1 cores for load/store/nt-store).
#: MIGRATE is the tiering engine's page-copy class: its cap is the ladder's
#: migration budget — copies are RMW-heavy (read at source + write at dest),
#: so the backlog-free budget sits between the store and nt-store caps.
_BASE_CLASS_CAPS = {
    OpClass.LOAD: 8,
    OpClass.STORE: 4,
    OpClass.NT_STORE: 1,
    OpClass.MIGRATE: 2,
}


def _default_config() -> MikuConfig:
    return MikuConfig(levels=(1, 2, 4, 8, 16),
                      class_caps=dict(_BASE_CLASS_CAPS))


def tier_class_caps(
    device: DeviceModel,
    reference: DeviceModel,
    granularity: int = 4,
) -> dict:
    """Backlog-free class caps for one slow tier, scaled from the paper's
    empirically-determined caps for the local expander.

    The ToR-monopolization cost of one core is its *entry-holding time* per
    request — pipeline flight holds an entry exactly like device queueing
    does.  A tier reached through a switch (longer pipeline) therefore
    holds more entry-time per core at equal concurrency, and its
    backlog-free core count scales down by the entry-holding ratio vs the
    reference (the platform's first slow tier, for which the paper's 8/4/1
    caps were determined).  This is what makes the per-tier ladders
    genuinely *different*: same rungs, lower per-class ceilings for farther
    tiers."""
    g = granularity
    hold_ref = reference.pipeline_ns + g * reference.read_service_ns
    hold = device.pipeline_ns + g * device.read_service_ns
    scale = min(1.0, hold_ref / max(hold, 1e-9))
    return {c: max(1, round(n * scale)) for c, n in _BASE_CLASS_CAPS.items()}


def default_miku(
    platform: PlatformModel,
    granularity: int = 4,
    **est_overrides,
) -> MikuController:
    """A per-slow-tier MIKU ensemble calibrated for ``platform``.

    One ladder per slow tier, each derived from that tier's own
    DeviceModel: the backlog threshold from its pipeline + service time
    (with the allowed queue depth split across the slow tiers sharing the
    ToR), and the class caps scaled by its entry-holding time
    (:func:`tier_class_caps`).  For the canonical two-tier platforms this
    is exactly the seed's single-ladder controller — one unit, the paper's
    1/2/4/8/16 ladder and 8/4/1 caps, CXL-calibrated thresholds."""
    slow_devs = platform.tiers[1:]
    n_slow = len(slow_devs)
    reference = slow_devs[0]
    cfgs = [
        MikuConfig(
            levels=(1, 2, 4, 8, 16),
            class_caps=tier_class_caps(dev, reference, granularity),
        )
        for dev in slow_devs
    ]
    ests = [
        calibrate_estimator(
            platform, granularity, slow_device=dev,
            shared_slow_tiers=n_slow, **est_overrides
        )
        for dev in slow_devs
    ]
    return MikuController(cfgs, ests)


def merged_miku(
    platform: PlatformModel,
    granularity: int = 4,
    **est_overrides,
) -> MergedSlowPolicy:
    """The pre-vector merged-slow MIKU as an explicit law adapter: one
    CXL-calibrated ladder fed the fold of all slow tiers' deltas, its
    decision broadcast to every slow tier (comparison baseline for
    ``corun3_pertier``)."""
    est = calibrate_estimator(platform, granularity, **est_overrides)
    return MergedSlowPolicy(MikuController(_default_config(), est))

"""Pass 1 — the repo-specific AST lint (``python -m repro.analysis lint``).

Five rules, each mechanically enforcing a contract the codebase previously
kept by convention only:

* ``counter-mutation`` — :class:`~repro.core.littles_law.TierCounters`
  fields (``inserts`` / ``occupancy_time`` / ``class_counts[...]``) may
  only be written by the counter substrate itself (``littles_law`` /
  ``substrate``) and the engines' result-materialization functions.  The
  PR-1 contract: everything else observes counters through window deltas.
* ``nondeterminism`` — sim hot paths (``core`` / ``memsim`` / ``tiering``
  / ``fabric`` / ``scenarios`` / ``analysis``) may not call unseeded
  ``random.*`` module-level samplers, wall-clock ``time.*`` sources, or
  ``np.random.*`` legacy samplers: every stream must come from a seeded
  generator (``random.Random(seed)`` / ``np.random.default_rng(seed)``).
* ``deprecated-surface`` — no two-positional-arg ``.window(fast, slow)``
  calls (the pre-vector SlowTierMiku surface) outside
  ``core/controller.py`` (which implements the shim), and no
  ``merged=True`` counter construction outside ``core/substrate.py``.
* ``scenario-pickle`` — every ``Scenario(...)`` is declaratively
  constructed (no lambda fields, which defeat pickling across the sweep
  process pool), and — dynamically — every registered scenario actually
  round-trips through ``pickle``.
* ``twin-parity`` — the scalar↔vector twins stay field-complete:
  every :class:`~repro.core.controller.MikuConfig` /
  :class:`~repro.core.littles_law.EstimatorConfig` knob is consumed by
  :meth:`~repro.core.controller.VectorMikuLadder.from_units`, and every
  tiering-policy / :class:`~repro.tiering.engine.MigrationEngine` knob by
  ``VectorTiering.__init__`` — so a knob added to one twin without the
  other fails analysis, not a 1024-cell sweep.

Rule functions take parsed ASTs (or live objects, for the twin rule) and
return :class:`Finding` lists, so tests can drive each rule on minimal
synthetic violations without touching the tree on disk.
"""

from __future__ import annotations

import ast
import dataclasses
import inspect
import textwrap
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple

#: Packages (relative to ``src/repro``) whose files are sim hot paths for
#: the nondeterminism rule.
_SIM_PACKAGES = ("core", "memsim", "tiering", "fabric", "scenarios",
                 "analysis", "workload")

#: TierCounters fields only the substrate may write.
_COUNTER_FIELDS = ("inserts", "occupancy_time")
_COUNTER_SUBSCRIPT = "class_counts"

#: (path suffix, enclosing function) pairs allowed to write counter fields:
#: the engines' result-materialization functions, which *build* the public
#: TierCounters from their flat accumulators.
_MUTATION_ALLOWED_FUNCS = (
    ("core/des.py", "_materialize_counters"),
    ("memsim/batched/fluid.py", "run_fluid"),
    ("memsim/batched/exact.py", "run_exact"),
)
#: Whole modules that own the counter types and their window plumbing.
_MUTATION_ALLOWED_MODULES = ("core/littles_law.py", "core/substrate.py")

_RANDOM_SAMPLERS = frozenset({
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "vonmisesvariate", "paretovariate",
    "weibullvariate", "getrandbits", "randbytes",
})
_TIME_SOURCES = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
    "perf_counter_ns", "process_time", "process_time_ns",
})
_NP_RANDOM_SAMPLERS = frozenset({
    "random", "rand", "randn", "randint", "random_sample", "choice",
    "shuffle", "permutation", "uniform", "normal", "exponential", "seed",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation: rule id, location, and the human message."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _attr_root(node: ast.expr) -> Optional[str]:
    """Dotted root of an attribute chain (``np.random.rand`` -> ``np``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


# ---------------------------------------------------------------------------
# Rule: counter-mutation
# ---------------------------------------------------------------------------


class _CounterMutationVisitor(ast.NodeVisitor):
    def __init__(self, rel: str) -> None:
        self.rel = rel
        self.stack: List[str] = []
        self.findings: List[Finding] = []

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _allowed(self) -> bool:
        for suffix, func in _MUTATION_ALLOWED_FUNCS:
            if self.rel.endswith(suffix) and func in self.stack:
                return True
        return False

    def _flag_target(self, target: ast.expr, lineno: int) -> None:
        if isinstance(target, ast.Attribute) and \
                target.attr in _COUNTER_FIELDS:
            field = target.attr
        elif isinstance(target, ast.Subscript) and \
                isinstance(target.value, ast.Attribute) and \
                target.value.attr == _COUNTER_SUBSCRIPT:
            field = _COUNTER_SUBSCRIPT
        else:
            return
        if self._allowed():
            return
        self.findings.append(Finding(
            "counter-mutation", self.rel, lineno,
            f"TierCounters.{field} written outside the counter substrate "
            "(repro.core.substrate / littles_law own window state; "
            "engines may only write it in their result materializers)",
        ))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._flag_target(t, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._flag_target(node.target, node.lineno)
        self.generic_visit(node)


def rule_counter_mutation(tree: ast.AST, rel: str) -> List[Finding]:
    """No TierCounters/window-state mutation outside the substrate."""
    if any(rel.endswith(m) for m in _MUTATION_ALLOWED_MODULES):
        return []
    v = _CounterMutationVisitor(rel)
    v.visit(tree)
    return v.findings


# ---------------------------------------------------------------------------
# Rule: nondeterminism
# ---------------------------------------------------------------------------


def rule_nondeterminism(tree: ast.AST, rel: str) -> List[Finding]:
    """No unseeded random / wall-clock calls in sim hot paths."""
    parts = Path(rel).parts
    if len(parts) < 2 or parts[0] not in _SIM_PACKAGES:
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or \
                not isinstance(node.func, ast.Attribute):
            continue
        func = node.func
        attr = func.attr
        if isinstance(func.value, ast.Name):
            root = func.value.id
            if root == "random" and attr in _RANDOM_SAMPLERS:
                findings.append(Finding(
                    "nondeterminism", rel, node.lineno,
                    f"module-level random.{attr}() in a sim path; draw "
                    "from a seeded random.Random instance instead",
                ))
            elif root == "time" and attr in _TIME_SOURCES:
                findings.append(Finding(
                    "nondeterminism", rel, node.lineno,
                    f"wall-clock time.{attr}() in a sim path; simulated "
                    "time must come from the engine clock",
                ))
        elif isinstance(func.value, ast.Attribute) and \
                func.value.attr == "random" and \
                isinstance(func.value.value, ast.Name) and \
                func.value.value.id in ("np", "numpy"):
            if attr in _NP_RANDOM_SAMPLERS:
                findings.append(Finding(
                    "nondeterminism", rel, node.lineno,
                    f"global-state np.random.{attr}() in a sim path; use "
                    "a seeded np.random.default_rng(seed)",
                ))
            elif attr == "default_rng" and not node.args and \
                    not node.keywords:
                findings.append(Finding(
                    "nondeterminism", rel, node.lineno,
                    "np.random.default_rng() without a seed in a sim path",
                ))
    return findings


# ---------------------------------------------------------------------------
# Rule: deprecated-surface
# ---------------------------------------------------------------------------


def rule_deprecated_surface(tree: ast.AST, rel: str) -> List[Finding]:
    """No legacy two-arg ``.window()`` / ``merged=True`` counters."""
    findings: List[Finding] = []
    shim_module = rel.endswith("core/controller.py")
    counters_module = rel.endswith("core/substrate.py")
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not shim_module and isinstance(node.func, ast.Attribute) and \
                node.func.attr == "window" and len(node.args) == 2 and \
                not node.keywords:
            findings.append(Finding(
                "deprecated-surface", rel, node.lineno,
                "two-positional-arg .window(fast, slow) is the deprecated "
                "pre-vector surface; pass one TierWindow",
            ))
        if not counters_module:
            for kw in node.keywords:
                if kw.arg == "merged" and \
                        isinstance(kw.value, ast.Constant) and \
                        kw.value.value is True:
                    findings.append(Finding(
                        "deprecated-surface", rel, node.lineno,
                        "merged=True counters are deprecated; consume the "
                        "per-tier TierWindow and merge in the law "
                        "(MergedSlowPolicy)",
                    ))
    return findings


# ---------------------------------------------------------------------------
# Rule: scenario-pickle
# ---------------------------------------------------------------------------


def rule_scenario_pickle_ast(tree: ast.AST, rel: str) -> List[Finding]:
    """Scenario(...) construction must be declarative (no lambda fields)."""
    if "scenarios" not in Path(rel).parts:
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = node.func.id if isinstance(node.func, ast.Name) else (
            node.func.attr if isinstance(node.func, ast.Attribute) else None
        )
        if name not in ("Scenario", "Axis"):
            continue
        for kw in node.keywords:
            if isinstance(kw.value, ast.Lambda):
                findings.append(Finding(
                    "scenario-pickle", rel, kw.value.lineno,
                    f"{name}({kw.arg}=lambda ...) is not picklable across "
                    "the sweep process pool; use a module-level function",
                ))
        for arg in node.args:
            if isinstance(arg, ast.Lambda):
                findings.append(Finding(
                    "scenario-pickle", rel, arg.lineno,
                    f"lambda argument to {name}(...) is not picklable "
                    "across the sweep process pool",
                ))
    return findings


def rule_scenario_pickle_dynamic() -> List[Finding]:
    """Every registered scenario must survive a pickle round-trip."""
    import pickle

    import repro.scenarios.library  # noqa: F401  (registers scenarios)
    from repro.scenarios import registry

    findings: List[Finding] = []
    for sc in registry.all_scenarios():
        try:
            pickle.loads(pickle.dumps(sc))
        except Exception as ex:  # pickle raises a zoo of types
            findings.append(Finding(
                "scenario-pickle", "scenarios/library.py", 0,
                f"registered scenario {sc.name!r} is not picklable: {ex}",
            ))
    return findings


# ---------------------------------------------------------------------------
# Rule: twin-parity
# ---------------------------------------------------------------------------


def consumed_attrs(func, roots: Iterable[str]) -> Set[str]:
    """Attribute names ``func``'s source reads off any expression in
    ``roots`` (dotted-source match, e.g. ``"u.config"``)."""
    src = textwrap.dedent(inspect.getsource(func))
    tree = ast.parse(src)
    roots = set(roots)
    found: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            try:
                base = ast.unparse(node.value)
            except Exception:
                continue
            if base in roots:
                found.add(node.attr)
    return found


def _knob_names(obj) -> Set[str]:
    """Declared knob names: dataclass fields, or __init__ params (minus
    self / **kwargs) for plain classes."""
    if dataclasses.is_dataclass(obj):
        return {f.name for f in dataclasses.fields(obj)}
    sig = inspect.signature(obj.__init__)
    return {
        name for name, p in sig.parameters.items()
        if name != "self" and p.kind not in (
            inspect.Parameter.VAR_KEYWORD, inspect.Parameter.VAR_POSITIONAL
        )
    }


def compare_twin_surfaces(
    label: str,
    fields: Iterable[str],
    consumed: Iterable[str],
    *,
    extra_allowed: Iterable[str] = (),
    path: str = "",
    line: int = 0,
) -> List[Finding]:
    """Bidirectional field/consumption diff for one scalar↔vector pair."""
    fields, consumed = set(fields), set(consumed)
    findings: List[Finding] = []
    for f in sorted(fields - consumed):
        findings.append(Finding(
            "twin-parity", path, line,
            f"{label}: knob {f!r} is not consumed by the vector twin — "
            "a one-sided knob silently diverges the batched lane",
        ))
    for a in sorted(consumed - fields - set(extra_allowed)):
        findings.append(Finding(
            "twin-parity", path, line,
            f"{label}: vector twin reads unknown knob {a!r} — the scalar "
            "side declares no such field",
        ))
    return findings


def twin_pairs() -> List[Tuple[str, Set[str], Set[str], Set[str], str, int]]:
    """The checked pairs: (label, scalar fields, vector-consumed attrs,
    extra allowed reads, consumer path, consumer line)."""
    from repro.core.controller import MikuConfig, VectorMikuLadder
    from repro.core.littles_law import EstimatorConfig
    from repro.memsim.batched.tiering import VectorTiering
    from repro.tiering.engine import MigrationEngine
    from repro.tiering.policies import HotnessLRUPolicy, MikuCoordinatedPolicy

    def loc(func) -> Tuple[str, int]:
        code = getattr(func, "__func__", func).__code__
        return code.co_filename, code.co_firstlineno

    fu_path, fu_line = loc(VectorMikuLadder.from_units)
    vt_path, vt_line = loc(VectorTiering.__init__)
    from_units_cfg = consumed_attrs(
        VectorMikuLadder.from_units, ("cfg", "u.config")
    )
    from_units_est = consumed_attrs(VectorMikuLadder.from_units, ("est",))
    vt_base = consumed_attrs(VectorTiering.__init__, ("base",))
    vt_pol = consumed_attrs(VectorTiering.__init__, ("pol",))
    vt_engine = consumed_attrs(VectorTiering.__init__, ("h.engine",))
    coordinated = _knob_names(MikuCoordinatedPolicy)
    return [
        ("MikuConfig <-> VectorMikuLadder.from_units",
         _knob_names(MikuConfig), from_units_cfg, set(), fu_path, fu_line),
        ("EstimatorConfig <-> VectorMikuLadder.from_units",
         _knob_names(EstimatorConfig), from_units_est, set(),
         fu_path, fu_line),
        ("HotnessLRUPolicy <-> VectorTiering",
         _knob_names(HotnessLRUPolicy), vt_base, set(), vt_path, vt_line),
        ("MikuCoordinatedPolicy <-> VectorTiering",
         coordinated, vt_pol, {"name", "base"}, vt_path, vt_line),
        ("MigrationEngine <-> VectorTiering",
         _knob_names(MigrationEngine), vt_engine, set(), vt_path, vt_line),
    ]


def rule_twin_parity() -> List[Finding]:
    """Every scalar knob has a vector consumer, and vice versa."""
    findings: List[Finding] = []
    for label, fields, consumed, extra, path, line in twin_pairs():
        findings.extend(compare_twin_surfaces(
            label, fields, consumed, extra_allowed=extra,
            path=path, line=line,
        ))
    return findings


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

#: The per-file AST rules, in report order.
AST_RULES = (
    rule_counter_mutation,
    rule_nondeterminism,
    rule_deprecated_surface,
    rule_scenario_pickle_ast,
)


def default_src_root() -> Path:
    """The ``repro`` package directory this module ships in."""
    return Path(__file__).resolve().parents[1]


def lint_file(path: Path, rel: str) -> List[Finding]:
    """Run every AST rule over one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    findings: List[Finding] = []
    for rule in AST_RULES:
        findings.extend(rule(tree, rel))
    return findings


def run_lint(
    src_root: Optional[Path] = None, *, dynamic: bool = True
) -> List[Finding]:
    """Lint the whole package: AST rules per file, then the dynamic
    (import-the-code) rules — twin parity and scenario pickling."""
    root = Path(src_root) if src_root is not None else default_src_root()
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        findings.extend(lint_file(path, rel))
    if dynamic:
        findings.extend(rule_twin_parity())
        findings.extend(rule_scenario_pickle_dynamic())
    return findings


def format_report(findings: Sequence[Finding], n_files: int) -> str:
    if not findings:
        return f"repro.analysis lint: {n_files} files checked, no findings"
    lines = [str(f) for f in findings]
    lines.append(
        f"repro.analysis lint: {len(findings)} finding(s) in "
        f"{n_files} files"
    )
    return "\n".join(lines)

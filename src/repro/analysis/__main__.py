"""CLI: ``python -m repro.analysis lint`` (gating in CI).

Exit status 0 when clean, 1 when any finding is reported, 2 for usage
errors.  ``--no-dynamic`` skips the rules that import the live code (twin
parity, scenario pickling) for pure-AST runs.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import default_src_root, format_report, run_lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="command", required=True)
    lint = sub.add_parser("lint", help="run the repo-specific AST lint")
    lint.add_argument(
        "--src", type=Path, default=None,
        help="package root to lint (default: the installed repro package)",
    )
    lint.add_argument(
        "--no-dynamic", action="store_true",
        help="skip rules that import the live code (twin parity, pickling)",
    )
    args = parser.parse_args(argv)
    root = args.src if args.src is not None else default_src_root()
    findings = run_lint(root, dynamic=not args.no_dynamic)
    n_files = len(list(Path(root).rglob("*.py")))
    print(format_report(findings, n_files))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.analysis — the two-pass correctness-tooling subsystem.

Pass 1 (:mod:`repro.analysis.lint`) is a repo-specific AST lint run as
``python -m repro.analysis lint``; pass 2 (:mod:`repro.analysis.sanitizer`)
is the runtime simulation sanitizer enabled per job
(``SimJob(sanitize=True)``), per sim (``TieredMemorySim(...,
sanitize=True)``), or process-wide (``REPRO_SANITIZE=1``; ``=record`` to
accumulate violations instead of raising).  See ``docs/analysis.md``.
"""

from repro.core.invariants import (
    InvariantViolation,
    require,
    sanitize_enabled,
)

from repro.analysis.lint import Finding, run_lint
from repro.analysis.sanitizer import DesSanitizer, QueueSanitizer

__all__ = [
    "DesSanitizer",
    "Finding",
    "InvariantViolation",
    "QueueSanitizer",
    "require",
    "run_lint",
    "sanitize_enabled",
]

"""Pass 2 — the runtime simulation sanitizer.

:class:`DesSanitizer` threads through :class:`repro.core.des.
TieredMemorySim` (``sanitize=True`` / ``REPRO_SANITIZE=1``) and re-derives,
every control window, the bookkeeping identities the DES's fast path
maintains implicitly:

======================  ====================================================
check id                invariant
======================  ====================================================
``event-order``         no pending event sits before the engine clock (an
                        event scheduled in the past is a corrupted heap)
``free-list``           no request id is double-freed, and no freed id is
                        simultaneously staged in the IRQ
``conservation``        requests are conserved — globally
                        (``tor_inserts == retired + tor_used``) and per
                        tier (``admitted == retired + in-flight``)
``issue-accounting``    outstanding-per-core sums equal live request-pool
                        entries, and never exceed each core's MLP
``entry-limit``         ToR / IRQ occupancy and every fabric port's entry
                        count stay within their configured limits
``station-occupancy``   per-station ``0 <= busy <= slots`` and, for hop
                        stations, ``occupancy == queued + in_service``
``counter-monotone``    cumulative per-tier counters never decrease
``counter-delta``       window deltas handed to the control loop are
                        non-negative (hooked into TierSetWindowedCounters)
``arrival-conservation``  open-loop generated requests are conserved per
                        workload (``generated == issued + shed + backlog``)
``token-bucket``        throttle token buckets never go negative
``migrate-debt``        MigrationEngine completion credit never goes
                        negative
``stall-cycle``         the backpressure holds→waits graph over fabric hop
                        stations has no frozen cycle (the DES analogue of a
                        deadlock detector)
``link-conservation``   TransferQueue links conserve transfers and bytes
                        (:class:`QueueSanitizer`)
======================  ====================================================

Violations raise structured :class:`~repro.core.invariants.
InvariantViolation` (mode ``"raise"``) or accumulate into
``SimResult.sanitizer`` (mode ``"record"``).  Fault-injection tests use
:meth:`DesSanitizer.add_mutation` to corrupt state at a chosen window and
assert the intended check — and only it — fires.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.invariants import InvariantViolation


class DesSanitizer:
    """Per-sim invariant checker; one instance per TieredMemorySim run."""

    def __init__(self, n_tiers: int, mode: str = "raise") -> None:
        if mode not in ("raise", "record"):
            raise ValueError(
                f"unknown sanitizer mode {mode!r}; expected 'raise' or "
                "'record'"
            )
        self.mode = mode
        self.n_tiers = n_tiers
        #: Per-tier ToR admissions / retires, maintained by the DES's
        #: admission and retire paths (guarded increments on the hot path).
        self.adm = [0] * n_tiers
        self.ret = [0] * n_tiers
        self.violations: List[InvariantViolation] = []
        self.windows_checked = 0
        self._tc_ins_mark: Optional[List[int]] = None
        self._tc_occ_mark: Optional[List[float]] = None
        self._mutations: Dict[int, List[Callable[[Any], None]]] = {}

    # -- violation plumbing ------------------------------------------------
    def violate(
        self,
        check: str,
        message: str,
        *,
        window: Optional[int] = None,
        station: Optional[Any] = None,
        **context: Any,
    ) -> None:
        err = InvariantViolation(
            check, message, window=window, station=station, context=context
        )
        if self.mode == "raise":
            raise err
        self.violations.append(err)

    # -- fault-injection hooks ---------------------------------------------
    def add_mutation(self, window: int, fn: Callable[[Any], None]) -> None:
        """Run ``fn(sim)`` right before window ``window``'s checks — the
        seeded corruption hook the fault-injection tests drive."""
        self._mutations.setdefault(window, []).append(fn)

    # -- per-window pass ----------------------------------------------------
    def on_window(self, sim: Any, window: int) -> None:
        """Run every state check at a window boundary (after applying any
        fault-injection mutations registered for this window)."""
        for fn in self._mutations.pop(window, ()):
            fn(sim)
        self._check_state(sim, window)
        self.windows_checked += 1

    def check_final(self, sim: Any) -> None:
        """The same state checks at the simulation horizon."""
        self._check_state(sim, sim._n_windows + 1)

    def _check_state(self, sim: Any, window: int) -> None:
        self._check_event_order(sim, window)
        self._check_free_list(sim, window)
        self._check_conservation(sim, window)
        self._check_issue_accounting(sim, window)
        self._check_entry_limits(sim, window)
        self._check_station_occupancy(sim, window)
        self._check_counter_monotone(sim, window)
        self._check_arrival_conservation(sim, window)
        self._check_token_buckets(sim, window)
        self._check_migrate_debt(sim, window)
        self._check_stall_cycles(sim, window)

    # -- individual checks ---------------------------------------------------
    def _check_event_order(self, sim: Any, window: int) -> None:
        """Every pending event lies at or after the engine clock.  Events
        are only ever scheduled with non-negative delays, so a pending
        event in the past is a corrupted heap — checked here (not per pop)
        so the un-sanitized run loop pays nothing for it."""
        now = sim.now
        for t, _packed in sim._heap:
            if t < now:
                self.violate(
                    "event-order",
                    f"pending event scheduled at t={t}, before the "
                    f"current sim time t={now} — an event was scheduled "
                    "in the past",
                    window=window,
                    t=t,
                    now=now,
                )
                return

    def _check_free_list(self, sim: Any, window: int) -> None:
        free = sim._r_free
        if len(set(free)) != len(free):
            seen: set = set()
            dup = next(r for r in free if r in seen or seen.add(r))
            self.violate(
                "free-list",
                f"request id {dup} appears twice on the free-list "
                "(double-free)",
                window=window,
                rid=dup,
            )
        staged = set(free) & set(sim.irq)
        if staged:
            self.violate(
                "free-list",
                f"request id(s) {sorted(staged)} are simultaneously freed "
                "and staged in the IRQ",
                window=window,
                rids=sorted(staged),
            )

    def _check_conservation(self, sim: Any, window: int) -> None:
        retired = sum(self.ret)
        if sim.tor_inserts != retired + sim.tor_used:
            self.violate(
                "conservation",
                f"ToR admissions ({sim.tor_inserts}) != retired "
                f"({retired}) + in-flight ({sim.tor_used})",
                window=window,
                tor_inserts=sim.tor_inserts,
                retired=retired,
                tor_used=sim.tor_used,
            )
        if retired != sum(sim._stat_completed):
            self.violate(
                "conservation",
                f"per-tier retire count ({retired}) != per-workload "
                f"completed count ({sum(sim._stat_completed)})",
                window=window,
            )
        for t in range(self.n_tiers):
            inflight = sim._tier_inflight[t]
            if self.adm[t] != self.ret[t] + inflight:
                self.violate(
                    "conservation",
                    f"tier {sim._tier_names[t]!r}: admitted "
                    f"({self.adm[t]}) != retired ({self.ret[t]}) + "
                    f"in-flight ({inflight})",
                    window=window,
                    station=sim._tier_names[t],
                )
        if sum(sim._tier_inflight) != sim.tor_used:
            self.violate(
                "conservation",
                f"per-tier in-flight sum ({sum(sim._tier_inflight)}) != "
                f"ToR occupancy ({sim.tor_used})",
                window=window,
            )

    def _check_issue_accounting(self, sim: Any, window: int) -> None:
        live = len(sim._r_wl) - len(sim._r_free)
        if sum(sim._out) != live:
            self.violate(
                "issue-accounting",
                f"outstanding-per-core sum ({sum(sim._out)}) != live "
                f"request-pool entries ({live})",
                window=window,
                pool=len(sim._r_wl),
                free=len(sim._r_free),
            )
        for gi, out in enumerate(sim._out):
            cap = sim._w_effmlp[sim._rr_wi[gi]]
            if out < 0 or out > cap:
                self.violate(
                    "issue-accounting",
                    f"core {gi} holds {out} outstanding requests "
                    f"(MLP bound {cap})",
                    window=window,
                    core=gi,
                )

    def _check_entry_limits(self, sim: Any, window: int) -> None:
        if sim.tor_used > sim.tor_capacity:
            self.violate(
                "entry-limit",
                f"ToR occupancy {sim.tor_used} exceeds capacity "
                f"{sim.tor_capacity}",
                window=window,
                station="tor",
            )
        if len(sim.irq) > sim.irq_capacity:
            self.violate(
                "entry-limit",
                f"IRQ occupancy {len(sim.irq)} exceeds capacity "
                f"{sim.irq_capacity}",
                window=window,
                station="irq",
            )
        link0 = sim._link0
        for i, name in enumerate(sim._link_names):
            st = link0 + i
            if sim._hop_occ[st] > sim._hop_limit[st]:
                self.violate(
                    "entry-limit",
                    f"port {name!r} holds {sim._hop_occ[st]} entries "
                    f"(limit {sim._hop_limit[st]})",
                    window=window,
                    station=name,
                )

    def _check_station_occupancy(self, sim: Any, window: int) -> None:
        link0 = sim._link0
        for st, busy in enumerate(sim._st_busy):
            if busy < 0 or busy > sim._st_slots[st]:
                self.violate(
                    "station-occupancy",
                    f"station {st} has {busy} busy servers "
                    f"(slots {sim._st_slots[st]})",
                    window=window,
                    station=self._station_name(sim, st),
                )
            if st >= link0:
                expect = len(sim._st_q[st]) + busy
                if sim._hop_occ[st] != expect:
                    self.violate(
                        "station-occupancy",
                        f"port entry count {sim._hop_occ[st]} != queued "
                        f"({len(sim._st_q[st])}) + in-service ({busy})",
                        window=window,
                        station=self._station_name(sim, st),
                    )

    def _check_counter_monotone(self, sim: Any, window: int) -> None:
        ins, occ = sim._tc_ins, sim._tc_occ
        if self._tc_ins_mark is not None:
            for t in range(self.n_tiers):
                if ins[t] < self._tc_ins_mark[t]:
                    self.violate(
                        "counter-monotone",
                        f"tier {sim._tier_names[t]!r} insert counter went "
                        f"backwards ({self._tc_ins_mark[t]} -> {ins[t]})",
                        window=window,
                        station=sim._tier_names[t],
                    )
                if occ[t] < self._tc_occ_mark[t]:  # type: ignore[index]
                    self.violate(
                        "counter-monotone",
                        f"tier {sim._tier_names[t]!r} occupancy counter "
                        "went backwards",
                        window=window,
                        station=sim._tier_names[t],
                    )
        self._tc_ins_mark = list(ins)
        self._tc_occ_mark = list(occ)

    def _check_arrival_conservation(self, sim: Any, window: int) -> None:
        """Open-loop arrivals are conserved per workload: every generated
        request was issued into the pipeline, shed at the queue limit, or
        still waits in the backlog — exactly one of the three."""
        for wi, is_open in enumerate(getattr(sim, "_w_open", ())):
            if not is_open:
                continue
            gen = sim._arr_gen[wi]
            issued = sim._arr_issued[wi]
            shed = sim._arr_shed[wi]
            backlog = len(sim._arr_q[wi])
            if gen != issued + shed + backlog:
                self.violate(
                    "arrival-conservation",
                    f"workload {sim.workloads[wi].name!r}: generated "
                    f"({gen}) != issued ({issued}) + shed ({shed}) + "
                    f"backlog ({backlog})",
                    window=window,
                    workload=sim.workloads[wi].name,
                    generated=gen,
                    issued=issued,
                    shed=shed,
                    backlog=backlog,
                )

    def _check_token_buckets(self, sim: Any, window: int) -> None:
        for wi, tokens in enumerate(sim._tokens):
            if tokens < 0.0:
                self.violate(
                    "token-bucket",
                    f"workload {sim.workloads[wi].name!r} token bucket is "
                    f"negative ({tokens})",
                    window=window,
                    workload=sim.workloads[wi].name,
                )

    def _check_migrate_debt(self, sim: Any, window: int) -> None:
        hook = sim._tiering
        engine = getattr(hook, "engine", None) if hook is not None else None
        credit = getattr(engine, "_credit", None)
        if credit is None:
            return
        for code, value in credit.items():
            if value < 0:
                self.violate(
                    "migrate-debt",
                    f"MIGRATE completion credit on tier code {code} is "
                    f"negative ({value})",
                    window=window,
                    station=sim._tier_names[code],
                )

    def _check_stall_cycles(self, sim: Any, window: int) -> None:
        """Deadlock detector over the hop-station backpressure graph.

        A stalled entry ``(rid, upstream)`` at station ``s`` means a
        request *holding a server slot at* ``upstream`` waits for an entry
        at ``s`` — edge ``upstream -> s``.  A station is *frozen* when every
        busy server is such a stall-holder and nothing is queued behind
        them (no completion event can ever free an entry).  A cycle through
        frozen stations can never drain: flag it.
        """
        link0 = sim._link0
        if link0 >= len(sim._st_busy):
            return
        edges: Dict[int, List[int]] = {}
        holders: Dict[int, int] = {}
        for s in range(link0, len(sim._st_busy)):
            for _rid, upstream in sim._hop_stall[s]:
                if upstream >= 0:
                    edges.setdefault(upstream, []).append(s)
                    holders[upstream] = holders.get(upstream, 0) + 1
        if not edges:
            return
        frozen = {
            u for u, n in holders.items()
            if sim._st_busy[u] > 0
            and n >= sim._st_busy[u]
            and not sim._st_q[u]
        }
        # Three-color DFS restricted to frozen stations.
        color: Dict[int, int] = {}

        def visit(u: int, path: List[int]) -> Optional[List[int]]:
            color[u] = 1
            path.append(u)
            for v in edges.get(u, ()):
                if v not in frozen:
                    continue
                if color.get(v) == 1:
                    return path[path.index(v):] + [v]
                if color.get(v, 0) == 0:
                    cyc = visit(v, path)
                    if cyc is not None:
                        return cyc
            color[u] = 2
            path.pop()
            return None

        for u in sorted(frozen):
            if color.get(u, 0) == 0:
                cyc = visit(u, [])
                if cyc is not None:
                    names = [self._station_name(sim, s) for s in cyc]
                    self.violate(
                        "stall-cycle",
                        "head-of-line backpressure cycle with no eligible "
                        f"completer: {' -> '.join(map(str, names))}",
                        window=window,
                        station=names[0],
                        cycle=names,
                    )
                    return

    # -- control-plane hooks -------------------------------------------------
    def check_counter_deltas(self, names: Tuple[str, ...], deltas) -> None:
        """TierSetWindowedCounters delta hook: window deltas handed to the
        decision law must be non-negative."""
        for name, tc in zip(names, deltas):
            if tc.inserts < 0 or tc.occupancy_time < 0:
                self.violate(
                    "counter-delta",
                    f"negative window delta for {name!r}: "
                    f"inserts={tc.inserts}, "
                    f"occupancy_time={tc.occupancy_time}",
                    station=name,
                )

    # -- result surface --------------------------------------------------------
    def summary(self, sim: Any) -> dict:
        """JSON-safe summary for ``SimResult.sanitizer``."""
        return {
            "mode": self.mode,
            "windows_checked": self.windows_checked,
            "admitted": list(self.adm),
            "retired": list(self.ret),
            "violations": [v.to_dict() for v in self.violations],
        }

    @staticmethod
    def _station_name(sim: Any, st: int) -> Any:
        if st < sim._n_tiers:
            return sim._tier_names[st]
        if st == sim._llc:
            return "llc"
        i = st - sim._link0
        if 0 <= i < len(sim._link_names):
            return sim._link_names[i]
        return st


class QueueSanitizer:
    """Transfer/byte conservation for :class:`repro.core.offload.
    TransferQueue`: per link, submissions equal completions plus in-flight
    transfers — counted and in bytes — after every ``advance``."""

    def __init__(self, mode: str = "raise") -> None:
        self.mode = mode
        self.submitted: Dict[str, int] = {}
        self.completed: Dict[str, int] = {}
        self.bytes_submitted: Dict[str, float] = {}
        self.bytes_completed: Dict[str, float] = {}
        self.violations: List[InvariantViolation] = []

    def on_submit(self, tier: str, nbytes: float) -> None:
        self.submitted[tier] = self.submitted.get(tier, 0) + 1
        self.bytes_submitted[tier] = (
            self.bytes_submitted.get(tier, 0.0) + nbytes
        )

    def on_complete(self, tier: str, nbytes: float) -> None:
        self.completed[tier] = self.completed.get(tier, 0) + 1
        self.bytes_completed[tier] = (
            self.bytes_completed.get(tier, 0.0) + nbytes
        )

    def check(self, queue: Any) -> None:
        inflight_n: Dict[str, int] = {}
        inflight_b: Dict[str, float] = {}
        for f in queue._inflight:
            inflight_n[f.tier] = inflight_n.get(f.tier, 0) + 1
            inflight_b[f.tier] = inflight_b.get(f.tier, 0.0) + f.nbytes
        for tier in self.submitted:
            sub = self.submitted[tier]
            done = self.completed.get(tier, 0)
            inf = inflight_n.get(tier, 0)
            if sub != done + inf:
                self._violate(
                    "link-conservation",
                    f"link {tier!r}: submitted ({sub}) != completed "
                    f"({done}) + in-flight ({inf})",
                    station=tier,
                )
            bsub = self.bytes_submitted[tier]
            bdone = self.bytes_completed.get(tier, 0.0)
            binf = inflight_b.get(tier, 0.0)
            if abs(bsub - (bdone + binf)) > 1e-6 * max(1.0, bsub):
                self._violate(
                    "link-conservation",
                    f"link {tier!r}: {bsub} bytes submitted != {bdone} "
                    f"completed + {binf} in-flight",
                    station=tier,
                )

    def check_counter_deltas(self, names, deltas) -> None:
        """TierSetWindowedCounters hook (same contract as
        :meth:`DesSanitizer.check_counter_deltas`)."""
        for name, tc in zip(names, deltas):
            if tc.inserts < 0 or tc.occupancy_time < 0:
                self._violate(
                    "counter-delta",
                    f"negative window delta for link {name!r}: "
                    f"inserts={tc.inserts}, "
                    f"occupancy_time={tc.occupancy_time}",
                    station=name,
                )

    def summary(self) -> dict:
        """JSON-safe summary mirroring :meth:`DesSanitizer.summary`."""
        return {
            "mode": self.mode,
            "submitted": dict(self.submitted),
            "completed": dict(self.completed),
            "violations": [v.to_dict() for v in self.violations],
        }

    def _violate(self, check: str, message: str, **kw: Any) -> None:
        err = InvariantViolation(check, message, **kw)
        if self.mode == "raise":
            raise err
        self.violations.append(err)

"""Memory-device service models for the tiered-memory substrate.

The paper's key architectural finding (§4.1) is that a commercial CXL memory
expander — despite 4-8x the *capacity* of a DDR5 DIMM — exposes roughly the
hardware parallelism (bank/channel slots) of a *single* DIMM, while the host's
DDR pool hardware-interleaves 8-12 DIMMs and therefore aggregates their
parallelism.  Unloaded, CXL behaves like DDR plus a near-constant protocol +
PCIe latency; loaded, its few service slots saturate and queueing delay grows
~exponentially (8-10x observed).

We model every device as ``c`` deterministic servers with per-access service
time ``s`` (64 B cachelines), plus a pipeline (non-slot-occupying) latency for
the interconnect/protocol:

    peak_bw  = c * 64 B / s
    latency(unloaded) = pipeline + s
    latency(loaded)   = pipeline + s + queue_wait          (DES / MVA)

Store semantics follow the paper: an ordinary store is a read-modify-write
(two device accesses); an nt-store is a single write access; device write
service is slower than read service (CXL writes ~2x reads at equal
concurrency, paper footnote 2).

Calibration targets (Platform A, Table 1 + Figs. 3-6):
  * DDR  (8x DDR5-4800, hw-interleaved): peak load ~250 GB/s, store (RMW)
    effective ~85 GB/s of retired-store bandwidth, unloaded latency ~110 ns.
  * CXL  (1x 256 GB PCIe Gen5x8 device): peak load ~28 GB/s (~ one DIMM),
    unloaded latency ~290 ns, loaded latency 8-10x DDR's.
These reproduce the paper's observed ratios; they are inputs, not claims.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.littles_law import ACCESS_MIX, OpClass

CACHELINE = 64  # bytes


class UnknownTierError(ValueError):
    """A workload or lookup named a tier (or link/host) its target lacks.

    The message always lists every known name so a typo'd scenario fails
    with the fix in hand.  ``kind``/``known_desc`` let the non-tier
    namespaces that reuse this error — the transfer queue's per-link
    accessors, the fabric topology's host/device lookups — name *their*
    namespace instead of claiming the argument was a memory tier.
    """

    def __init__(
        self,
        tier: str,
        known: Tuple[str, ...],
        *,
        kind: str = "memory tier",
        known_desc: str = "platform tiers",
    ):
        super().__init__(
            f"unknown {kind} {tier!r}; {known_desc} are "
            f"{', '.join(known)}"
        )
        self.tier = tier
        self.known = tuple(known)
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """A memory device (or a hardware-interleaved group of identical devices).

    ``parallelism`` is the number of concurrently-serviceable accesses (bank x
    channel slots); ``read_service_ns``/``write_service_ns`` is the slot
    occupancy per 64 B access; ``pipeline_ns`` is latency that does not occupy
    a service slot (bus flight, protocol).  ``interleave`` multiplies
    parallelism (hardware interleaving across DIMMs combines their slots —
    the paper's §4.1 "strong correlation between multi-threaded bandwidth and
    DIMM-level parallelism").
    """

    name: str
    tier: str  # tier name this device backs ("ddr", "cxl", "cxl_sw", ...)
    parallelism: int
    read_service_ns: float
    write_service_ns: float
    pipeline_ns: float
    interleave: int = 1
    access_bytes: int = CACHELINE  # 64 B cachelines (x86) or 512 B bursts (TPU)

    @property
    def total_slots(self) -> int:
        return self.parallelism * self.interleave

    def service_ns(self, op: OpClass) -> float:
        """Total slot-occupancy per *retired instruction* of class ``op``.

        RMW stores occupy a slot for read + write back-to-back.
        """
        reads, writes = ACCESS_MIX[op]
        return reads * self.read_service_ns + writes * self.write_service_ns

    def unloaded_latency_ns(self, op: OpClass) -> float:
        return self.pipeline_ns + self.service_ns(op)

    def peak_bandwidth_gbps(self, op: OpClass) -> float:
        """Peak retired-data bandwidth (GB/s) for a pure stream of ``op``."""
        s = self.service_ns(op)
        return self.total_slots * self.access_bytes / s  # B/ns == GB/s

    def scaled(self, interleave: int, name: str = "") -> "DeviceModel":
        return dataclasses.replace(
            self, interleave=interleave, name=name or f"{self.name}x{interleave}"
        )


# --------------------------------------------------------------------------
# Calibrated platforms (paper Table 1).
# --------------------------------------------------------------------------

#: One DDR5-4800 DIMM behind one channel: ~32 GB/s loads.
DDR5_DIMM = DeviceModel(
    name="ddr5-dimm",
    tier="ddr",
    parallelism=16,  # in-flight bank/channel slots per DIMM
    read_service_ns=32.0,  # 16*64/32ns = 32 GB/s per DIMM
    write_service_ns=44.0,
    pipeline_ns=78.0,  # core->CHA->controller flight: ~110ns unloaded load
)

#: One Micron (pre-market) 256 GB CXL expander on PCIe Gen5 x8.  Paper §4.1:
#: "peak bandwidth and hardware parallelism comparable to a single DDR DIMM";
#: unloaded latency ~ DDR + constant CXL.mem/PCIe overhead.
CXL_DEVICE = DeviceModel(
    name="cxl-exp",
    tier="cxl",
    parallelism=14,
    read_service_ns=36.0,  # 14*64/36 = ~25 GB/s peak loads
    write_service_ns=72.0,  # writes ~2x reads (paper footnote 2)
    pipeline_ns=255.0,  # ~290ns unloaded load latency
)

#: The same expander reached through a CXL switch: identical device
#: parallelism/service, plus the switch's store-and-forward hop each way
#: (~90 ns per direction — the CXL-over-switch topologies of
#: "Demystifying CXL Memory", arXiv 2303.15375).
CXL_SWITCH_DEVICE = DeviceModel(
    name="cxl-sw-exp",
    tier="cxl_sw",
    parallelism=14,
    read_service_ns=36.0,
    write_service_ns=72.0,
    pipeline_ns=435.0,  # cxl pipeline + ~180ns round-trip switch hop
)

#: A DDR5 DIMM on the *other* socket: same DIMM-level service, plus the
#: cross-socket interconnect (UPI/xGMI) flight — the paper's Table 1 lists
#: NUMA-remote DDR latency between local DDR and CXL.
DDR_REMOTE_DIMM = DeviceModel(
    name="ddr5-remote-dimm",
    tier="ddr_remote",
    parallelism=16,
    read_service_ns=32.0,
    write_service_ns=44.0,
    pipeline_ns=165.0,  # local 78ns + ~87ns UPI round trip: ~197ns unloaded
)


@dataclasses.dataclass(frozen=True)
class PlatformModel:
    """A host platform: an *ordered list of memory tiers* behind one shared
    request-tracking structure (CHA ToR / CCX equivalent).

    The first tier (``ddr``) is the fast tier the control plane protects;
    every later tier is a slow tier it may throttle.  The classic paper
    platform is the two-tier (DDR, CXL) pair; ``extra_tiers`` appends further
    devices — CXL behind a switch, NUMA-remote DDR pools, heterogeneous
    expanders — each keyed by its :attr:`DeviceModel.tier` name.

    ``tor_entries`` bounds simultaneously-tracked requests (dispatched but not
    completed); ``irq_entries`` bounds staged requests awaiting a ToR entry;
    ``core_mlp`` bounds per-core outstanding misses (LFB/superqueue);
    ``llc_service_ns``/``llc_slots`` model LLC-hit handling, which *also*
    consumes ToR entries (paper §4.3).
    """

    name: str
    ddr: DeviceModel
    cxl: DeviceModel
    tor_entries: int
    irq_entries: int
    core_mlp: int
    n_cores: int
    llc_service_ns: float
    llc_slots: int
    llc_capacity_mb: float
    extra_tiers: Tuple[DeviceModel, ...] = ()
    #: Optional routed switch-fabric topology
    #: (:class:`repro.fabric.topology.FabricTopology` — typed ``object``
    #: here so the core never imports the fabric package).  ``None``, and
    #: topologies whose links are all transparent, mean every tier hangs
    #: directly off the host: the classic flat-station platform.
    fabric: Optional[object] = None

    def __post_init__(self):
        # Frozen dataclass: cache the tier lookup tables once (device_for
        # sits on per-request hot paths; eq/repr/pickle see only the
        # declared fields).
        tiers = (self.ddr, self.cxl) + self.extra_tiers
        names = tuple(d.tier for d in tiers)
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in platform: {names}")
        object.__setattr__(self, "_tiers", tiers)
        object.__setattr__(self, "_tier_names", names)
        object.__setattr__(
            self, "_tier_idx", {t: i for i, t in enumerate(names)}
        )

    @property
    def tiers(self) -> Tuple[DeviceModel, ...]:
        """Ordered tier devices, fast tier first."""
        return self._tiers

    @property
    def tier_names(self) -> Tuple[str, ...]:
        return self._tier_names

    def tier_index(self, tier: str) -> int:
        try:
            return self._tier_idx[tier]
        except KeyError:
            raise UnknownTierError(tier, self._tier_names) from None

    def device_for(self, tier: str) -> DeviceModel:
        try:
            return self._tiers[self._tier_idx[tier]]
        except KeyError:
            raise UnknownTierError(tier, self._tier_names) from None

    def with_extra_tiers(self, *devices: DeviceModel) -> "PlatformModel":
        """A copy of this platform with ``devices`` appended as slow tiers."""
        return dataclasses.replace(
            self, extra_tiers=self.extra_tiers + tuple(devices)
        )


def platform_a(ddr_dimms: int = 8, cxl_devices: int = 2) -> PlatformModel:
    """Intel Xeon Gold 6530 (EMR) socket: 8x DDR5 + 2x CXL (Table 1)."""
    return PlatformModel(
        name=f"intel-emr-{ddr_dimms}ddr-{cxl_devices}cxl",
        ddr=DDR5_DIMM.scaled(ddr_dimms, name=f"ddr5x{ddr_dimms}"),
        cxl=CXL_DEVICE.scaled(cxl_devices, name=f"cxlx{cxl_devices}"),
        tor_entries=2048,  # effective shared tracking pool (cachelines)
        irq_entries=256,
        core_mlp=160,  # outstanding cachelines/core incl. prefetcher streams
        n_cores=32,
        llc_service_ns=18.0,
        llc_slots=96,
        llc_capacity_mb=160.0,
    )


def platform_b(ddr_dimms: int = 12, cxl_devices: int = 4) -> PlatformModel:
    """AMD EPYC 9634 (Genoa) socket: 12x DDR5 + 4x CXL (Table 1)."""
    return PlatformModel(
        name=f"amd-genoa-{ddr_dimms}ddr-{cxl_devices}cxl",
        ddr=DDR5_DIMM.scaled(ddr_dimms, name=f"ddr5x{ddr_dimms}"),
        cxl=CXL_DEVICE.scaled(cxl_devices, name=f"cxlx{cxl_devices}"),
        tor_entries=2304,  # CCX-distributed, logically pooled for the model
        irq_entries=320,
        core_mlp=192,  # Genoa sustains higher per-thread nt-store bw (§4.1)
        n_cores=84,
        llc_service_ns=16.0,
        llc_slots=128,
        llc_capacity_mb=384.0,
    )


def platform_a_switch(
    ddr_dimms: int = 8, cxl_devices: int = 2, switch_devices: int = 2
) -> PlatformModel:
    """Platform A with a third tier: CXL expanders behind a switch.

    The tier set (ddr, cxl, cxl_sw) is the three-tier co-run topology the
    two-tier API could not express — same control plane, one more station.
    """
    base = platform_a(ddr_dimms, cxl_devices)
    return dataclasses.replace(
        base,
        name=f"{base.name}-{switch_devices}sw",
        extra_tiers=(
            CXL_SWITCH_DEVICE.scaled(switch_devices,
                                     name=f"cxlswx{switch_devices}"),
        ),
    )


def platform_a_numa(
    ddr_dimms: int = 8, cxl_devices: int = 2, remote_dimms: int = 8
) -> PlatformModel:
    """Platform A with the remote socket's DDR pool as a third tier
    (ddr, cxl, ddr_remote) — the NUMA-remote-DDR variant."""
    base = platform_a(ddr_dimms, cxl_devices)
    return dataclasses.replace(
        base,
        name=f"{base.name}-{remote_dimms}rddr",
        extra_tiers=(
            DDR_REMOTE_DIMM.scaled(remote_dimms,
                                   name=f"rddr5x{remote_dimms}"),
        ),
    )


# --------------------------------------------------------------------------
# TPU-adapted tier models (DESIGN.md §2): HBM fast tier vs pinned-host slow
# tier behind the per-chip DMA/transfer path.  Units: one "access" = one
# 512 B transfer burst; parallelism = outstanding DMA descriptors.
# --------------------------------------------------------------------------

TPU_BURST = 512  # bytes per modeled DMA burst

# In TPU units one modeled access is a 512 B DMA burst:
# 64 slots * 512 B / 40 ns = 819 GB/s per chip — the v5e HBM roofline number.
TPU_HBM = DeviceModel(
    name="tpu-hbm",
    tier="ddr",
    parallelism=64,
    read_service_ns=40.0,
    write_service_ns=40.0,
    pipeline_ns=350.0,
    access_bytes=TPU_BURST,
)

TPU_HOST = DeviceModel(
    # Host DRAM over PCIe, shared by the chips on one host: the "CXL" tier.
    # 8 outstanding descriptors * 512 B / 64 ns ≈ 64 GB/s, of which a single
    # chip's share is ~16 GB/s with 4 chips/host.
    name="tpu-pinned-host",
    tier="cxl",
    parallelism=8,
    read_service_ns=64.0,
    write_service_ns=128.0,
    pipeline_ns=1800.0,  # PCIe + runtime enqueue
    access_bytes=TPU_BURST,
)


def tpu_host_platform(chips_per_host: int = 4) -> PlatformModel:
    """A TPU host: per-chip HBM (fast) + shared pinned-host pool (slow).

    Used by the serving engine's simulated clock and by the MIKU case-study
    benchmarks in TPU units (bursts of 512 B).
    """
    return PlatformModel(
        name=f"tpu-host-{chips_per_host}chip",
        ddr=TPU_HBM.scaled(chips_per_host, name=f"hbm-x{chips_per_host}"),
        cxl=TPU_HOST,
        tor_entries=512,  # outstanding transfer descriptors tracked per host
        irq_entries=128,
        core_mlp=16,
        n_cores=chips_per_host * 4,  # issue contexts (cores driving DMA)
        llc_service_ns=8.0,
        llc_slots=64,
        llc_capacity_mb=128.0,  # VMEM-ish staging, only used by LLC-style runs
    )


PLATFORMS: Dict[str, PlatformModel] = {
    "A": platform_a(),
    "B": platform_b(),
    "A-1to1": platform_a(ddr_dimms=1, cxl_devices=1),
    "B-1to1": platform_b(ddr_dimms=1, cxl_devices=1),
    "A-switch": platform_a_switch(),
    "A-numa": platform_a_numa(),
    "TPU": tpu_host_platform(),
}

"""Memory-tier specifications and JAX memory-kind placement helpers.

The TPU deployment of the paper's tiered memory (DESIGN.md §2): HBM is the
fast tier (``memory_kind="device"``), pinned host DRAM over PCIe is the slow
tier (``memory_kind="pinned_host"``).  JAX exposes both through shardings'
``with_memory_kind``; XLA compiles explicit device<->host transfers for
arrays annotated this way.

These helpers are runtime-agnostic: on CPU-only containers the pinned_host
memory space exists in recent jaxlibs, and everything degrades gracefully to
"device" when it does not (``host_offload_supported``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

DEVICE = "device"
PINNED_HOST = "pinned_host"


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory tier of the serving/training runtime."""

    name: str
    memory_kind: str  # jax memory kind
    bandwidth_gbps: float  # per chip
    capacity_gib: float  # per chip
    #: Max concurrently in-flight fetch streams before device-side queueing
    #: explodes (the paper's hardware-parallelism disparity).
    parallelism: int


#: TPU v5e-flavoured tiers (roofline constants from the assignment).
HBM_TIER = TierSpec(
    name="hbm", memory_kind=DEVICE, bandwidth_gbps=819.0, capacity_gib=16.0,
    parallelism=64,
)
HOST_TIER = TierSpec(
    name="host", memory_kind=PINNED_HOST, bandwidth_gbps=16.0, capacity_gib=256.0,
    parallelism=8,
)


def host_offload_supported(device: Optional[jax.Device] = None) -> bool:
    """True if this backend exposes a pinned_host memory space."""
    dev = device or jax.devices()[0]
    try:
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:
        return False
    return PINNED_HOST in kinds


def with_memory_kind(sharding: jax.sharding.Sharding, kind: str):
    """Annotate a sharding with a memory kind, if supported."""
    try:
        return sharding.with_memory_kind(kind)
    except Exception:
        return sharding


def put_on_tier(x, tier: TierSpec, sharding: Optional[jax.sharding.Sharding] = None):
    """Place an array on a tier (optionally with an explicit sharding)."""
    if sharding is None:
        dev = jax.devices()[0]
        sharding = jax.sharding.SingleDeviceSharding(dev)
    return jax.device_put(x, with_memory_kind(sharding, tier.memory_kind))


@dataclasses.dataclass(frozen=True)
class TieredLayout:
    """How one logical KV cache (or parameter bank) splits across tiers.

    ``hot_tokens`` is the HBM-resident suffix window (most-recent tokens —
    the ones decode touches every step); everything older lives on the host
    tier in ``page_tokens``-sized pages fetched on demand.  For
    sliding-window-attention layers the hot window naturally equals the
    attention window, making SWA models the ideal tiering citizens
    (DESIGN.md §4).
    """

    total_tokens: int
    hot_tokens: int
    page_tokens: int = 2048

    def __post_init__(self):
        assert 0 < self.hot_tokens <= self.total_tokens
        assert self.page_tokens > 0

    @property
    def cold_tokens(self) -> int:
        return self.total_tokens - self.hot_tokens

    @property
    def n_cold_pages(self) -> int:
        return -(-self.cold_tokens // self.page_tokens)  # ceil

    def page_slice(self, page: int) -> slice:
        start = page * self.page_tokens
        return slice(start, min(start + self.page_tokens, self.cold_tokens))

    def bytes_per_token(self, n_kv_heads: int, head_dim: int, n_layers: int,
                        dtype_bytes: int = 2) -> int:
        return 2 * n_kv_heads * head_dim * n_layers * dtype_bytes  # K and V

    def cold_bytes(self, n_kv_heads: int, head_dim: int, n_layers: int,
                   dtype_bytes: int = 2) -> int:
        return self.cold_tokens * self.bytes_per_token(
            n_kv_heads, head_dim, n_layers, dtype_bytes
        )


def estimate_fetch_ns(nbytes: int, tier: TierSpec) -> float:
    """First-order fetch-time estimate for the simulated serving clock."""
    return nbytes / max(tier.bandwidth_gbps, 1e-9)  # B / (B/ns) = ns


def np_bytes(x) -> int:
    return int(np.prod(x.shape)) * x.dtype.itemsize

"""Unified control-plane substrate — one windowed feedback loop, many backends.

The paper's contribution is a single feedback law: sample shared-queue
counters once per window, estimate per-tier service times (Little's Law),
decide how much slow-tier concurrency/rate to allow, apply the decision.
Before this module the repo re-implemented that window/snapshot/delta/apply
plumbing in five places (DES, TransferQueue, serving cluster, straggler
governor, sweep runner).  Now each of those systems is merely a
:class:`MemorySubstrate` — *what* is measured and *how* decisions take
effect — while :class:`ControlLoop` owns *when*: window scheduling, counter
snapshot/delta bookkeeping, decision history, and per-window telemetry.

A substrate exposes three things:

  * ``clock_ns``        — its notion of time (simulated or wall).
  * ``counters_delta()``— counters accumulated since the previous window,
    consumed on read.  Canonically a
    :class:`~repro.core.littles_law.TierWindow`: the ordered per-tier
    :class:`~repro.core.littles_law.TierCounters` vector (fast tier first,
    tier names carried alongside).  Substrates with a different decision
    law (the straggler governor's per-host step times) may instead return
    any plain tuple their paired controller's ``window(*delta)`` accepts.
  * ``apply(decision)`` — make the controller's decision take effect.
    Vector laws return tier-addressed decisions
    (:class:`~repro.core.controller.TierDecisions`): per-tier core masks +
    token buckets in the DES, per-tier in-flight caps on the transfer
    path, per-host dispatch shares in the launcher.

:class:`TierSetWindowedCounters` is the shared snapshot/delta helper so
substrates never hand-roll mark bookkeeping again (:class:`WindowedCounters`
remains for bare two-tier pairs).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, List, Optional, Protocol, Sequence, Tuple

from repro.core.littles_law import (
    TierCounters,
    TierWindow,
    merge_tier_counters,
)


class MemorySubstrate(Protocol):
    """Anything the control loop can instrument and throttle."""

    @property
    def clock_ns(self) -> float:
        """The substrate's current time in nanoseconds."""
        ...

    def counters_delta(self) -> Tuple[Any, ...]:
        """Counters accumulated since the last call (consumed on read).

        Canonical form is a :class:`~repro.core.littles_law.TierWindow`
        (ordered per-tier TierCounters, fast tier first, names carried).
        """
        ...

    def apply(self, decision: Any) -> None:
        """Apply one window's decision to the substrate."""
        ...


class WindowedCounters:
    """A (fast, slow) pair of cumulative TierCounters with consume-on-read
    window deltas — the snapshot/mark plumbing every substrate used to
    duplicate."""

    __slots__ = ("fast", "slow", "_fast_mark", "_slow_mark")

    def __init__(self) -> None:
        self.fast = TierCounters()
        self.slow = TierCounters()
        self._fast_mark = self.fast.snapshot()
        self._slow_mark = self.slow.snapshot()

    def delta(self) -> Tuple[TierCounters, TierCounters]:
        """(fast, slow) accumulated since the previous ``delta()`` call."""
        df = self.fast.delta(self._fast_mark)
        ds = self.slow.delta(self._slow_mark)
        self._fast_mark = self.fast.snapshot()
        self._slow_mark = self.slow.snapshot()
        return df, ds

    def reset(self) -> None:
        self.fast = TierCounters()
        self.slow = TierCounters()
        self._fast_mark = self.fast.snapshot()
        self._slow_mark = self.slow.snapshot()


class TierSetWindowedCounters:
    """N-tier generalization of :class:`WindowedCounters`.

    One cumulative :class:`TierCounters` per tier (fast tier first, in
    platform order).  ``delta()`` returns the per-tier vector contract: a
    :class:`~repro.core.littles_law.TierWindow` of window deltas, tier
    names carried alongside — what vector decision laws
    (:class:`~repro.core.controller.MikuController`,
    :class:`~repro.core.controller.MergedSlowPolicy`) consume directly.

    ``merged=True`` keeps the deprecated pre-vector behavior: ``delta()``
    returns the ``(fast, merged-slow)`` pair, with tiers 1..n-1 folded into
    one slow delta (a DeprecationWarning fires once per process).  New code
    wanting the merged *law* should drive
    :class:`~repro.core.controller.MergedSlowPolicy` with the vector
    instead of merging at the substrate.
    """

    __slots__ = ("tiers", "names", "_marks", "_merged", "_sanitizer")

    _warned_merged = False  # process-wide: the deprecation fires once

    def __init__(
        self,
        n_tiers: int = 2,
        *,
        names: Optional[Sequence[str]] = None,
        merged: bool = False,
    ) -> None:
        if names is not None:
            n_tiers = len(names)
            self.names = tuple(names)
        else:
            self.names = tuple(f"tier{i}" for i in range(n_tiers))
        self.tiers = [TierCounters() for _ in range(n_tiers)]
        self._marks = [t.snapshot() for t in self.tiers]
        self._merged = merged
        self._sanitizer: Optional[Callable[..., None]] = None
        if merged and not TierSetWindowedCounters._warned_merged:
            TierSetWindowedCounters._warned_merged = True
            warnings.warn(
                "TierSetWindowedCounters(merged=True) is deprecated; consume "
                "the per-tier TierWindow and merge in the law "
                "(MergedSlowPolicy) instead",
                DeprecationWarning,
                stacklevel=2,
            )

    def delta(self) -> Tuple[TierCounters, ...]:
        """Per-tier deltas accumulated since the previous call.

        Vector mode (default): a :class:`TierWindow`.  Merged mode
        (deprecated): the legacy ``(fast, merged-slow)`` pair."""
        ds = [t.delta(m) for t, m in zip(self.tiers, self._marks)]
        self._marks = [t.snapshot() for t in self.tiers]
        if self._sanitizer is not None:
            # Sanitizer hook (repro.analysis): window deltas handed to the
            # decision law must be non-negative — a negative delta means
            # someone rewound a cumulative counter mid-window.
            self._sanitizer(self.names, ds)
        if self._merged:
            return ds[0], merge_tier_counters(ds[1:])
        return TierWindow(ds, self.names)

    def attach_sanitizer(self, hook: Callable[..., None]) -> None:
        """Install a per-delta check hook (``hook(names, deltas)``)."""
        self._sanitizer = hook

    def reset(self) -> None:
        self.tiers = [TierCounters() for _ in self.tiers]
        self._marks = [t.snapshot() for t in self.tiers]


@dataclasses.dataclass
class WindowRecord:
    """Telemetry for one control window."""

    index: int
    t_ns: float
    delta: Tuple[Any, ...]
    decision: Any


def _counters_jsonable(tc: TierCounters) -> dict:
    return {
        "inserts": tc.inserts,
        "occupancy_time": tc.occupancy_time,
        "class_counts": {c.value: n for c, n in tc.class_counts.items()},
    }


def _decision_jsonable(d: Any) -> Any:
    """One tier's decision as plain JSON (best-effort for foreign laws)."""
    est = getattr(d, "estimate", None)
    out = {
        "max_concurrency": getattr(d, "max_concurrency", None),
        "rate_factor": getattr(d, "rate_factor", None),
        "phase": getattr(getattr(d, "phase", None), "value", None),
    }
    if est is not None:
        out["t_slow"] = est.t_slow
        out["t_slow_raw"] = est.t_slow_raw
        out["threshold"] = est.threshold
        out["backlogged"] = est.backlogged
        out["valid"] = est.valid
    return out


def window_record_jsonable(rec: WindowRecord) -> dict:
    """One :class:`WindowRecord` as a plain JSON-safe dict.

    The per-tier telemetry shape ``benchmarks/run.py --trace`` emits: the
    window's per-tier counter deltas (named when the substrate speaks the
    vector contract) and its per-tier decision(s)."""
    out: dict = {"window": rec.index, "t_ns": rec.t_ns}
    delta = rec.delta
    if isinstance(delta, TierWindow):
        out["tiers"] = {
            name: _counters_jsonable(tc)
            for name, tc in zip(delta.names, delta)
        }
    elif (
        isinstance(delta, tuple)
        and all(isinstance(tc, TierCounters) for tc in delta)
    ):
        out["tiers"] = {
            f"tier{i}": _counters_jsonable(tc) for i, tc in enumerate(delta)
        }
    else:
        out["delta"] = repr(delta)
    d = rec.decision
    if hasattr(d, "items") and hasattr(d, "tiers"):  # TierDecisions
        out["decision"] = {t: _decision_jsonable(td) for t, td in d.items()}
    elif d is not None:
        out["decision"] = _decision_jsonable(d)
    return out


class ControlLoop:
    """Drives a decision law over a substrate's windows.

    The loop owns the window schedule (``window_ns`` boundaries on the
    substrate's clock), pulls counter deltas from the substrate, feeds them
    to the controller's ``window(*delta)``, records the decision, and hands
    it back to the substrate via ``apply``.

    Two driving styles, matching the two kinds of hosts:

      * event-driven (the DES schedules :attr:`next_window_ns` as a sim
        event; the transfer queue's ``advance`` interleaves that boundary
        with transfer completions in time order; the trainer fires once per
        step): call :meth:`fire` exactly when a window elapses.
      * poll-driven (hosts that move their clock in large, irregular
        steps): call :meth:`poll` after advancing; every elapsed boundary
        fires, in order.

    ``controller=None`` keeps the window cadence (hosts may piggyback
    periodic work on it) but skips estimation/decisions entirely.
    """

    def __init__(
        self,
        substrate: MemorySubstrate,
        controller: Optional[Any] = None,
        *,
        window_ns: float = 1_000_000.0,
        record: bool = True,
        max_history: Optional[int] = None,
        on_window: Optional[Callable[[WindowRecord], None]] = None,
    ) -> None:
        self.substrate = substrate
        self.controller = controller
        self.window_ns = float(window_ns)
        self.next_window_ns = float(window_ns)
        self.decisions: List[Any] = []
        self.records: List[WindowRecord] = []
        self._record = record
        #: Cap on retained decision/telemetry history — set it for
        #: long-lived loops (a trainer fires one window per step, forever);
        #: None keeps everything (finite sims that return the history).
        self._max_history = max_history
        self._on_window = on_window
        self._windows_run = 0
        # Process-wide observability counters (repro.obs.metrics): every
        # loop instance shares the registry's control.* series.
        from repro.obs.metrics import default_registry

        reg = default_registry()
        self._m_windows = reg.counter("control.windows")
        self._m_decisions = reg.counter("control.decisions")

    # -- driving ----------------------------------------------------------
    def due(self, now: Optional[float] = None) -> bool:
        now = self.substrate.clock_ns if now is None else now
        return now >= self.next_window_ns

    def fire(self) -> Optional[Any]:
        """Run one window now and advance the schedule by ``window_ns``."""
        self.next_window_ns += self.window_ns
        self._m_windows.inc()
        if self.controller is None:
            return None
        delta = self.substrate.counters_delta()
        if isinstance(delta, TierWindow):
            # Vector contract: the law gets the per-tier window whole
            # (names and all); plain tuples splat as before (straggler
            # governor, legacy pairs).
            decision = self.controller.window(delta)
        else:
            decision = self.controller.window(*delta)
        self.decisions.append(decision)
        self._m_decisions.inc()
        self._windows_run += 1
        if self._record or self._on_window is not None:
            rec = WindowRecord(
                index=self._windows_run,
                t_ns=self.substrate.clock_ns,
                delta=delta,
                decision=decision,
            )
            if self._record:
                self.records.append(rec)
            if self._on_window is not None:
                self._on_window(rec)
        if self._max_history is not None:
            m = self._max_history
            if len(self.decisions) > 2 * m:
                del self.decisions[:-m]
            if len(self.records) > 2 * m:
                del self.records[:-m]
        self.substrate.apply(decision)
        return decision

    def poll(self, now: Optional[float] = None) -> List[Any]:
        """Fire every window boundary the clock has passed (in order)."""
        now = self.substrate.clock_ns if now is None else now
        fired: List[Any] = []
        while now >= self.next_window_ns:
            fired.append(self.fire())
        return fired

    # -- bookkeeping ------------------------------------------------------
    @property
    def windows_run(self) -> int:
        return self._windows_run

    def telemetry(self) -> dict:
        """Summary counters for dashboards/benchmark CSVs."""
        restricted = sum(
            1 for d in self.decisions if getattr(d, "restricted", False)
        )
        return {
            "windows": self._windows_run,
            "decisions": len(self.decisions),
            "restricted_windows": restricted,
            "window_ns": self.window_ns,
        }

    def reset(self) -> None:
        self.next_window_ns = self.window_ns
        self.decisions.clear()
        self.records.clear()
        self._windows_run = 0
        if self.controller is not None and hasattr(self.controller, "reset"):
            self.controller.reset()


class ReplaySubstrate:
    """A substrate that replays a recorded counter trace — the harness for
    proving any ControlLoop + controller pairing reproduces a live system's
    decision sequence (see tests/test_substrate.py)."""

    def __init__(
        self,
        deltas: Sequence[Tuple[Any, ...]],
        *,
        window_ns: float = 1.0,
    ) -> None:
        self._deltas = list(deltas)
        self._i = 0
        self.window_ns = window_ns
        self.applied: List[Any] = []

    @property
    def clock_ns(self) -> float:
        return self._i * self.window_ns

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._deltas)

    def counters_delta(self) -> Tuple[Any, ...]:
        delta = self._deltas[self._i]
        self._i += 1
        return delta

    def apply(self, decision: Any) -> None:
        self.applied.append(decision)


class StepTimingSubstrate:
    """Per-host step-service-time substrate for the straggler governor.

    The launcher records each host's step wall time; every window the
    control loop hands the governor one mean step time per host (0.0 for a
    host that missed the window entirely — the governor's worst signal) and
    applies the returned :class:`~repro.core.controller.HostHealth` list as
    per-host dispatch rate factors.
    """

    def __init__(self, n_hosts: int) -> None:
        self.n_hosts = n_hosts
        self._sums = [0.0] * n_hosts
        self._counts = [0] * n_hosts
        self._clock_ns = 0.0
        self.health: List[Any] = []

    @property
    def clock_ns(self) -> float:
        return self._clock_ns

    def record_step(self, host: int, seconds: float) -> None:
        self._sums[host] += seconds
        self._counts[host] += 1
        self._clock_ns += seconds * 1e9

    def counters_delta(self) -> Tuple[List[float], ...]:
        times = [
            self._sums[h] / self._counts[h] if self._counts[h] else 0.0
            for h in range(self.n_hosts)
        ]
        self._sums = [0.0] * self.n_hosts
        self._counts = [0] * self.n_hosts
        return (times,)

    def apply(self, healths: List[Any]) -> None:
        self.health = healths

    def rate_factor(self, host: int) -> float:
        if not self.health:
            return 1.0
        return self.health[host].rate_factor

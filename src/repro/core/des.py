"""Discrete-event simulation of the cores → IRQ → ToR → {DDR, CXL} pipeline.

This is the simulated testbed standing in for the paper's two hardware
platforms (no CXL hardware exists in this container; the TPU is likewise only
a compile target).  It models exactly the structures the paper's root-cause
analysis identifies (§4.2):

  * **Cores** with bounded memory-level parallelism (MLP: LFB/superqueue +
    prefetcher slots) issue requests in a closed loop; ``lat-test`` style
    workloads are dependent (MLP=1, pointer chasing), ``bw-test`` style
    workloads keep MLP slots full.
  * **IRQ** — the CHA ingress queue: a *shared, finite, FIFO* staging queue.
    Only its head may dispatch (head-of-line blocking); when full it
    back-pressures all cores indiscriminately — the paper's "CHA throttles
    both DDR and CXL requests from upstream components".
  * **ToR** — the Table of Requests: a finite shared pool of tracking
    entries.  A request holds its entry from dispatch until data return, so
    entry residency *is* the memory service time (queue wait at the device +
    service + bus flight).  Slow-tier requests with 8-10x residency
    monopolize the pool — the unfair-queuing mechanism.
  * **Devices** — one station per platform tier (the DDR group, the CXL
    group, and any extra tiers — CXL-over-switch, NUMA-remote DDR — in
    :attr:`~repro.core.device_model.PlatformModel.tiers` order), each per
    :mod:`repro.core.device_model`: ``c`` deterministic servers + unbounded
    internal queue (requests wait *while holding ToR entries*).
  * **LLC** — an optional station in front of the devices; hits are serviced
    fast but still consume ToR entries (paper §4.3), so LLC effectiveness
    degrades under slow-tier backlog.  Capacity partitioning (Intel CAT
    analogue) sets per-workload hit rates.

MIKU attaches through :class:`repro.core.substrate.ControlLoop`: the sim is
a :class:`~repro.core.substrate.MemorySubstrate` whose windows the loop
drives as simulator events — every ``window_ns`` the loop pulls per-tier
:class:`TierCounters` deltas and applies the returned concurrency/rate
decision to slow-tier-bound workloads, identical in shape to how the real
MIKU samples uncore counters once per second.

Implementation notes (the fast path): requests live in preallocated
parallel arrays recycled through a free-list — no per-request objects.
Heap entries are ``(time, packed)`` 2-tuples with sequence number, event
kind, and request id packed into one integer; tier/station names are small
integer codes; per-(workload, tier) service times and byte counts are
precomputed at init.  Latencies are reservoir-sampled into a bounded buffer
(drawn from a dedicated RNG so the simulation's own random stream — and
therefore every bandwidth figure — is unchanged by sampling).
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.controller import Decision, MikuController, TierDecisions
from repro.core.device_model import PlatformModel, UnknownTierError
from repro.core.invariants import InvariantViolation, sanitize_enabled
from repro.core.littles_law import (
    OpClass,
    TierCounters,
    TierWindow,
    linear_percentile,
)
from repro.core.substrate import (
    ControlLoop,
    TierSetWindowedCounters,
    window_record_jsonable,
)

# Event kinds.  Heap payloads are (time, packed) with
# packed = (seq << _SEQ_SHIFT) | (kind << _KIND_SHIFT) | arg — seq in the
# high bits preserves strict FIFO tie-breaking on equal timestamps.
_EV_COMPLETE = 0  # service slot frees (device done); data starts return flight
_EV_PHASE = 1
_EV_WINDOW = 2
_EV_TOKEN = 3
_EV_RETIRE = 4  # data returned: ToR entry frees, core slot recycles
_EV_ARRIVAL = 5  # open-loop arrival: one generated request joins its backlog

_KIND_SHIFT = 32
_SEQ_SHIFT = 36
_ARG_MASK = 0xFFFFFFFF

# Tier integer codes for the canonical two-tier platform: tier codes are
# positions in PlatformModel.tiers (fast tier first), stations are the tier
# codes plus one trailing LLC station (code ``n_tiers``, per sim instance).
_DDR, _CXL = 0, 1
_OPS = tuple(OpClass)

#: Default bound on per-workload latency reservoirs (satellite: keep
#: ``percentile_ns`` within tolerance at a fixed memory footprint).
LATENCY_RESERVOIR = 2048


@dataclasses.dataclass
class WorkloadSpec:
    """One co-running benchmark instance (a group of identical cores).

    ``tier`` may be a single tier or a phase schedule (``phases`` overrides
    ``tier`` with (duration_ns, tier) pairs, cycled — the paper's
    alternating-every-100 s micro-benchmark, time-scaled).  ``dependent``
    marks pointer-chasing (lat-test): MLP is forced to 1.  ``sync`` marks the
    lat-share CAS loop: requests are coherence ops serviced at the LLC/CHA
    with exclusive-line bouncing.  ``wss_mb`` with a finite ``llc_alloc_mb``
    yields an LLC hit probability of min(1, alloc/wss) (CAT partitioning).
    """

    name: str
    op: OpClass
    tier: str  # any tier name of the platform ("ddr", "cxl", "cxl_sw", ...)
    n_cores: int
    #: Outstanding cachelines per core, *including* L2-prefetcher stream
    #: depth — bw-test's sequential streams keep the prefetchers saturated,
    #: which is what lets a 16-thread group's aggregate demand exceed the
    #: shared ToR pool (the monopolization precondition, §4.2).
    mlp: int = 160
    dependent: bool = False
    sync: bool = False
    wss_mb: float = 32768.0
    llc_alloc_mb: float = 0.0
    phases: Optional[Sequence[Tuple[float, str]]] = None
    miku_managed: bool = True  # slow-tier workloads MIKU may throttle
    #: Software page-interleaving across the canonical pair: fraction of
    #: requests sent to the fast tier (the rest go to the second tier).
    #: Overrides ``tier`` when set (Fig. 1/2 "Interleaving" scheme; Linux
    #: weighted interleaving).  Shorthand for ``placement={"ddr": f,
    #: "cxl": 1 - f}`` that stays on the two-tier fast path.
    ddr_fraction: Optional[float] = None
    #: General tier-placement vector: tier name -> fraction of requests,
    #: over *any* of the platform's tiers (must sum to 1).  Overrides
    #: ``tier`` when set; mutually exclusive with ``ddr_fraction``.  This is
    #: weighted interleaving over an N-tier platform — e.g. NUMA striping
    #: ``{"ddr": 0.5, "ddr_remote": 0.5}``.
    placement: Optional[Dict[str, float]] = None
    #: Fabric host this workload's cores issue from — selects the
    #: per-tier routes when the platform carries a routed fabric topology
    #: (``PlatformModel.fabric``); default is the topology's first host.
    #: Must be None on fabric-less platforms.
    host: Optional[str] = None
    #: Optional open-loop arrival process
    #: (:class:`repro.workload.arrivals.ArrivalSpec`).  None — the default
    #: and the bit-identical legacy path — keeps the closed-loop MLP
    #: re-issue loop.  Set, the workload's cores only issue while the
    #: arrival backlog is non-empty (still bounded by MLP / IRQ / ToR /
    #: throttles), request latency is measured from *generation* time (so
    #: it includes backlog wait), and generated/issued/shed/backlog
    #: counts are accounted per window when the system falls behind.
    arrival: Optional[object] = None

    def effective_mlp(self, granularity: int = 1) -> int:
        """Outstanding *simulated requests* per core (macro-request units)."""
        if self.dependent or self.sync:
            return 1
        return max(1, self.mlp // granularity)


def validate_workloads(
    platform: PlatformModel, workloads: Sequence["WorkloadSpec"]
) -> None:
    """Check every workload's tier references against ``platform``.

    Raises :class:`~repro.core.device_model.UnknownTierError` naming the
    platform's tier list for any unknown tier, and ``ValueError`` for a
    malformed placement vector.  Runs at :class:`TieredMemorySim` (and
    ``SimJob``) construction so misconfigured scenarios fail loudly instead
    of silently landing on the CXL device.
    """
    known = platform.tier_names
    fabric = getattr(platform, "fabric", None)
    for w in workloads:
        if w.host is not None:
            if fabric is None:
                raise ValueError(
                    f"workload {w.name!r}: host {w.host!r} set but the "
                    "platform carries no fabric topology"
                )
            if w.host not in fabric.hosts:
                raise UnknownTierError(
                    w.host, tuple(fabric.hosts), kind="fabric host",
                    known_desc="topology hosts",
                )
        if w.placement is not None and w.ddr_fraction is not None:
            raise ValueError(
                f"workload {w.name!r}: placement and ddr_fraction are "
                "mutually exclusive"
            )
        if w.arrival is not None and not hasattr(w.arrival, "kind"):
            raise ValueError(
                f"workload {w.name!r}: arrival= expects a "
                "repro.workload.arrivals.ArrivalSpec, got "
                f"{type(w.arrival).__name__}"
            )
        refs = [w.tier]
        if w.phases:
            refs.extend(t for _, t in w.phases)
        if w.placement is not None:
            refs.extend(w.placement)
        for t in refs:
            if t not in known:
                raise UnknownTierError(t, known)
        if w.placement is not None:
            if any(f < 0.0 for f in w.placement.values()):
                raise ValueError(
                    f"workload {w.name!r}: negative placement fraction"
                )
            total = sum(w.placement.values())
            if abs(total - 1.0) > 1e-6:
                raise ValueError(
                    f"workload {w.name!r}: placement fractions sum to "
                    f"{total}, expected 1.0"
                )


@dataclasses.dataclass
class WorkloadStats:
    completed: int = 0
    bytes: float = 0.0
    latency_sum: float = 0.0
    latency_count: int = 0
    #: Bounded reservoir sample of request latencies (uniform over all
    #: completed requests).
    latency_samples: List[float] = dataclasses.field(default_factory=list)
    # timeline of (t_ns, bytes_completed_in_bucket) for bandwidth-over-time
    timeline: List[Tuple[float, float]] = dataclasses.field(default_factory=list)
    #: Mergeable log-bucketed latency histogram over *all* completed
    #: requests (:class:`repro.obs.histogram.LatencyHistogram`); None
    #: unless the sim ran with ``latency_hist=True``.
    latency_hist: Optional[object] = None

    def mean_latency_ns(self) -> float:
        return self.latency_sum / max(1, self.latency_count)

    def percentile_ns(self, q: float) -> float:
        """Reservoir percentile with linear interpolation between order
        statistics (rank ``q * (n - 1)``; see
        :func:`repro.core.littles_law.linear_percentile`).

        NaN with no samples: an open-loop overload can starve a workload
        (or a window) of completions entirely, and 0 ns would read as an
        impossibly *good* latency in an SLO sweep.  NaN propagates
        honestly through downstream aggregation and never satisfies a
        ``p99 <= budget`` comparison."""
        if not self.latency_samples:
            return float("nan")
        return linear_percentile(sorted(self.latency_samples), q)

    def bandwidth_gbps(self, sim_ns: float) -> float:
        return self.bytes / sim_ns  # B/ns == GB/s


@dataclasses.dataclass
class SimResult:
    sim_ns: float
    stats: Dict[str, WorkloadStats]
    tier_counters: Dict[str, TierCounters]
    tor_peak: int
    tor_occupancy_integral: float  # entry-ns, all tiers
    tor_inserts: int
    #: Per-window decisions — tier-addressed
    #: :class:`~repro.core.controller.TierDecisions` under the vector
    #: contract (plain Decisions only for legacy two-arg laws).
    decisions: List[Decision]
    per_tier_occupancy_integral: Dict[str, float]
    #: Per-window control telemetry (JSON-safe dicts); populated only when
    #: the sim was built with ``record_windows=True``.
    window_records: List[dict] = dataclasses.field(default_factory=list)
    #: Tiering-subsystem summary (pages promoted/demoted, migrated bytes,
    #: final placement fractions); None unless a tiering hook was installed.
    tiering: Optional[dict] = None
    #: Fabric hop-station summary, keyed by link name: total backpressure
    #: stall events, peak port-entry occupancy, and the port's entry limit
    #: (macro-request units).  None unless the platform's fabric topology
    #: put at least one port-bearing link on some route.
    fabric: Optional[dict] = None
    #: Runtime-sanitizer summary (windows checked, per-tier admission/retire
    #: counters, recorded violations); None unless the sim ran with
    #: ``sanitize`` enabled (see :mod:`repro.analysis.sanitizer`).
    sanitizer: Optional[dict] = None
    #: Per-tier mergeable latency histograms (full request latency keyed by
    #: the request's tier; LLC hits count toward their tier).  None unless
    #: the sim ran with ``latency_hist=True``.
    tier_latency_hist: Optional[dict] = None
    #: Open-loop arrival accounting per arrival-bearing workload
    #: (generated / issued / shed counts and final backlog depth — the
    #: conservation identity is generated == issued + shed + backlog).
    #: None unless some workload carries an ``arrival=`` spec.
    arrival: Optional[dict] = None
    #: Sampled request-lifecycle trace payload (finalized span records;
    #: see :meth:`repro.obs.trace.RequestTracer.run_payload`).  None unless
    #: the sim ran with ``trace`` enabled.
    trace: Optional[dict] = None
    #: Wall-clock phase profile (setup / event_loop / window_pass seconds);
    #: None unless a :class:`repro.obs.metrics.PhaseProfiler` was attached.
    profile: Optional[dict] = None

    def bandwidth(self, name: str) -> float:
        return self.stats[name].bandwidth_gbps(self.sim_ns)

    def total_bandwidth(self, tier: Optional[str] = None) -> float:
        return sum(s.bandwidth_gbps(self.sim_ns) for s in self.stats.values())

    @property
    def tor_avg_latency_ns(self) -> float:
        """Occupancy/Inserts — exactly the paper's ToR-derived service time."""
        return self.tor_occupancy_integral / max(1, self.tor_inserts)


class TieredMemorySim:
    """The DES engine.  Deterministic given a seed.

    Implements the :class:`~repro.core.substrate.MemorySubstrate` protocol
    (``clock_ns`` / ``counters_delta`` / ``apply``); a
    :class:`~repro.core.substrate.ControlLoop` owns the MIKU windowing.
    """

    def __init__(
        self,
        platform: PlatformModel,
        workloads: Sequence[WorkloadSpec],
        *,
        seed: int = 0,
        granularity: int = 4,
        window_ns: float = 20_000.0,
        controller: Optional[MikuController] = None,
        latency_reservoir: int = LATENCY_RESERVOIR,
        record_windows: bool = False,
        tiering=None,
        control_scope: str = "tier",
        sanitize=None,
        latency_hist: bool = False,
        trace=0,
        profiler=None,
    ):
        self.platform = platform
        self.workloads = list(workloads)
        # Tiering hook (duck-typed; see repro.tiering.hook.TieringHook): the
        # hook contributes its migration pseudo-workloads up front, then
        # re-resolves placement / migration budgets once per window.  With
        # ``tiering=None`` the engine is exactly the hook-free fast path —
        # bit-identical to the pinned two-tier goldens.
        self._tiering = tiering
        if tiering is not None:
            self.workloads.extend(tiering.migration_workloads(platform))
        validate_workloads(platform, self.workloads)
        # Ordered tier table: tier code == position in platform.tiers (fast
        # tier first); the LLC is one extra station after the tiers.
        tiers = platform.tiers
        self._tier_names = platform.tier_names
        self._n_tiers = len(tiers)
        self._tier_idx = {t: i for i, t in enumerate(self._tier_names)}
        self._llc = self._n_tiers  # LLC station code
        self.rng = random.Random(seed)
        # Reservoir sampling draws from its own stream so enabling/resizing
        # it can never perturb the simulated system.
        self._res_rng = random.Random((seed << 16) ^ 0x5EED)
        self._res_random = self._res_rng.random
        self._reservoir_k = latency_reservoir
        # Granularity batches `granularity` cachelines per simulated request:
        # identical bandwidth & queueing structure, ~granularity x fewer
        # events.  Latency-sensitive (dependent/sync) workloads always run at
        # single-access granularity.
        self.granularity = max(1, granularity)
        self.window_ns = window_ns
        self.controller = controller
        self._record_windows = record_windows
        self.control = ControlLoop(
            self, controller, window_ns=window_ns, record=record_windows
        )

        self.now = 0.0
        self._seq = 0
        self._heap: List[Tuple[float, int]] = []

        # Stations: [tier 0, ..., tier n-1, llc, hop stations...] slot
        # counts, busy counts, FIFO queues of request ids.  Queue entries
        # hold ToR slots.  Hop stations (codes > the LLC) materialize the
        # fabric topology's port-bearing links; with no fabric — or an
        # all-transparent one — none exist and the station list is exactly
        # the flat [tiers, llc] layout.
        self._st_slots = [d.total_slots for d in tiers] + [platform.llc_slots]
        self._st_busy = [0] * (self._n_tiers + 1)
        self._st_q: List[deque] = [deque() for _ in range(self._n_tiers + 1)]

        # Shared queues.  Platform capacities are in cachelines; one simulated
        # macro-request covers `granularity` cachelines, so scale down.
        self.tor_capacity = max(1, platform.tor_entries // self.granularity)
        self.tor_used = 0
        self.tor_peak = 0
        self.irq: deque = deque()
        self.irq_capacity = max(1, platform.irq_entries // self.granularity)

        # Request pool: parallel arrays + free-list (no per-request objects).
        self._r_wl: List[int] = []
        self._r_gi: List[int] = []
        self._r_tier: List[int] = []
        self._r_station: List[int] = []
        self._r_tissue: List[float] = []
        self._r_ttor: List[float] = []
        self._r_service: List[float] = []
        self._r_free: List[int] = []
        # Per-rid is-being-traced flag (1 iff the rid is in the tracer's
        # live dict): a bytearray index is cheaper than a dict membership
        # test on the per-event hook guards.  Maintained even with tracing
        # off — one append per *allocated* rid, nothing per event.
        self._r_traced = bytearray()

        # Round-robin arbitration order over every (workload, core) pair:
        # real cores are open-loop instruction streams that re-attempt IRQ
        # insertion every cycle; the IRQ arbitrates fairly *per core*, so the
        # IRQ inflow mix reflects core counts — not completion rates.  This
        # is precisely what makes the paper's collapse: DDR and CXL cores
        # inject at the same rate while CXL entries retire ~10x slower.
        self._rr_wi: List[int] = []
        self._rr_core: List[int] = []
        self._rr_ptr = 0
        self._out: List[int] = []  # outstanding per global core index

        n = len(self.workloads)
        g = self.granularity

        # Per-workload precomputed constants (indexed by wi).
        self._w_g: List[int] = []  # cachelines per macro-request
        self._w_svc: List[Tuple[float, ...]] = []  # device service by tier
        self._w_bytes: List[Tuple[float, ...]] = []  # retired bytes by tier
        self._w_llc_svc: List[float] = []
        self._w_phit: List[float] = []  # <0 disables the LLC lottery
        self._w_frac: List[Optional[float]] = []
        #: General placement: cumulative tier-probability vector (or None).
        #: The last entry is +inf so the routing scan always terminates.
        self._w_cum: List[Optional[Tuple[float, ...]]] = []
        #: Slow tier codes a placement vector puts mass on (per workload;
        #: () for non-placement workloads — their touched set is dynamic).
        self._w_placed_slow: List[Tuple[int, ...]] = []
        self._w_managed: List[bool] = []
        self._w_op: List[int] = []  # index into _OPS
        self._w_effmlp: List[int] = []
        self._gi0: List[int] = []  # first global core index per workload

        # Phase / throttle state per workload.
        self._phase_tier: List[int] = []
        #: Per-workload (duration_ns, tier_code) schedule (None = static).
        self._phase_seq: List[Optional[List[Tuple[float, int]]]] = []
        self._phase_idx: List[int] = [0] * n
        # Tier-addressed decision state: one (core-cap, rate) per tier code,
        # written by ``apply`` and folded into each workload's effective
        # throttle by ``_recompute_throttle`` (index 0 — the fast tier — is
        # never throttled and stays at its defaults).
        self._tier_cap: List[Optional[int]] = [None] * self._n_tiers
        self._tier_rate: List[float] = [1.0] * self._n_tiers
        self._rate: List[float] = [1.0] * n
        self._tokens: List[float] = [0.0] * n
        self._last_refill: List[float] = [0.0] * n
        self._token_wait: List[bool] = [False] * n
        # Effective (cached) throttle state: _limit is the active core cap
        # (None unless managed *and* currently slow-touching); _unthrottled
        # short-circuits the token bucket.
        self._limit: List[Optional[int]] = [None] * n
        self._unthrottled: List[bool] = [True] * n

        for wi, w in enumerate(self.workloads):
            ge = 1 if (w.dependent or w.sync) else g
            self._w_g.append(ge)
            self._w_svc.append(
                tuple(d.service_ns(w.op) * ge for d in tiers)
            )
            self._w_bytes.append(
                tuple(float(d.access_bytes * ge) for d in tiers)
            )
            self._w_llc_svc.append(
                platform.llc_service_ns * 2.0
                if w.sync
                else platform.llc_service_ns * ge
            )
            # LLC routing sentinel: 2.0 = sync (always LLC, line-bounce
            # service); [0, 1] = CAT hit lottery; -1.0 = straight to device.
            if w.sync:
                self._w_phit.append(2.0)
            elif w.llc_alloc_mb > 0:
                self._w_phit.append(min(1.0, w.llc_alloc_mb / max(w.wss_mb, 1e-9)))
            else:
                self._w_phit.append(-1.0)
            if w.placement is not None:
                cum: List[float] = []
                acc = 0.0
                for t in self._tier_names:
                    acc += w.placement.get(t, 0.0)
                    cum.append(acc)
                cum[-1] = float("inf")  # absorb rounding; scan terminates
                self._w_frac.append(None)
                self._w_cum.append(tuple(cum))
                self._w_placed_slow.append(tuple(
                    i for i, t in enumerate(self._tier_names)
                    if i > 0 and w.placement.get(t, 0.0) > 0.0
                ))
            else:
                self._w_frac.append(w.ddr_fraction)
                self._w_cum.append(None)
                self._w_placed_slow.append(())
            self._w_managed.append(w.miku_managed)
            self._w_op.append(_OPS.index(w.op))
            self._w_effmlp.append(w.effective_mlp(g))
            if w.phases:
                self._phase_seq.append(
                    [(dur, self._tier_idx[t]) for dur, t in w.phases]
                )
            else:
                self._phase_seq.append(None)
            tier0 = w.phases[0][1] if w.phases else w.tier
            self._phase_tier.append(self._tier_idx[tier0])
            self._gi0.append(len(self._rr_wi))
            for core in range(w.n_cores):
                self._rr_wi.append(wi)
                self._rr_core.append(core)
                self._out.append(0)

        # -- open-loop arrivals (repro.workload) --------------------------
        # A workload with an ``arrival=`` spec becomes open-loop: a
        # generator feeds a per-workload backlog deque of (t_generated,
        # key) pairs via _EV_ARRIVAL events, and the round-robin issue
        # scan only lets its cores issue while the backlog is non-empty
        # (popping the head and stamping the request's latency clock with
        # its *generation* time).  Generators draw from their own seeded
        # streams (never ``self.rng``), and with no arrival specs the
        # only new cost on the issue path is one flag test per scan step
        # — the arrival=None sim stays bit-identical to the goldens.
        self._w_open: List[bool] = []
        self._arr_q: List[deque] = []
        self._arr_qlimit: List[Optional[int]] = []
        self._arr_gen = [0] * n
        self._arr_issued = [0] * n
        self._arr_shed = [0] * n
        self._arr_iter: List[Optional[object]] = []
        self._arr_pending: List[Optional[Tuple[float, float]]] = []
        for wi, w in enumerate(self.workloads):
            arr = w.arrival
            self._w_open.append(arr is not None)
            self._arr_q.append(deque())
            if arr is not None:
                # Lazy import: the core only depends on repro.workload
                # when a sim actually runs open-loop (same discipline as
                # the sanitizer / obs imports).
                from repro.workload.arrivals import arrival_iter

                it = arrival_iter(arr, stream_seed=(seed << 8) ^ wi)
                self._arr_qlimit.append(arr.queue_limit)
                self._arr_iter.append(it)
                self._arr_pending.append(next(it, None))
            else:
                self._arr_qlimit.append(None)
                self._arr_iter.append(None)
                self._arr_pending.append(None)
        self._open_active = any(self._w_open)
        self._arrival_log: List[dict] = []
        self._arr_gen_mark = [0] * n
        self._arr_issued_mark = [0] * n
        self._arr_shed_mark = [0] * n

        # Device pipeline (return-flight) latency per tier.
        self._pipe = tuple(d.pipeline_ns for d in tiers)

        # -- fabric (routed switch topology) ------------------------------
        # ``platform.fabric`` is an optional FabricTopology, duck-typed so
        # the core never imports repro.fabric.  Each port-bearing link
        # becomes a hop station with a ToR-style entry limit; a request
        # whose route crosses hops visits them in order *before* its
        # device station, holding its ToR entry the whole way, and a full
        # downstream port backpressures upstream hops head-of-line (see
        # the ``_hop_*`` methods).  All-transparent topologies yield empty
        # hop paths everywhere, ``_fabric_active`` stays False, and every
        # fabric branch below is dead — bit-identical to no fabric.
        fabric = getattr(platform, "fabric", None)
        links = tuple(fabric.station_links) if fabric is not None else ()
        self._fabric = fabric
        self._link_names = tuple(l.name for l in links)
        link0 = self._llc + 1  # first hop-station code
        self._link0 = link0
        n_st = link0 + len(links)
        self._st_slots.extend(l.port_slots for l in links)
        self._st_busy.extend(0 for _ in links)
        self._st_q.extend(deque() for _ in links)
        # Per-hop-station port state, indexed by station code (entries
        # below link0 are padding).  ``_hop_occ`` counts entries held at
        # the port (queued + in service, including completed requests
        # stall-held by a full downstream port); ``_hop_stall`` queues
        # (rid, upstream_station) waiters, upstream == -1 for admission
        # stalls (the request holds only its ToR entry so far).
        self._hop_limit = [0] * n_st
        self._hop_occ = [0] * n_st
        self._hop_svc = [0.0] * n_st
        self._hop_stall: List[deque] = [deque() for _ in range(n_st)]
        self._hop_stall_events = [0] * n_st
        self._hop_peak_occ = [0] * n_st
        for i, link in enumerate(links):
            st = link0 + i
            self._hop_limit[st] = max(1, link.queue_entries // self.granularity)
            self._hop_svc[st] = link.service_ns
        # Per-(workload, tier) hop paths: the tuple of hop station codes a
        # request traverses, resolved from the workload host's routes.
        if fabric is not None:
            link_st = {l.name: link0 + i for i, l in enumerate(links)}
            self._w_hops = [
                tuple(
                    tuple(link_st[l.name]
                          for l in fabric.route(
                              w.host if w.host is not None
                              else fabric.hosts[0], t).hops)
                    for t in self._tier_names
                )
                for w in self.workloads
            ]
        else:
            self._w_hops = [((),) * self._n_tiers for _ in self.workloads]
        self._fabric_active = any(
            any(per_tier) for per_tier in self._w_hops
        )
        # Per-request hop state (dicts, not parallel arrays: rids recycle
        # through the free-list and only fabric-routed requests pay).
        self._hop_path: Dict[int, Tuple[int, ...]] = {}
        self._hop_idx: Dict[int, int] = {}
        self._hop_t: Dict[int, float] = {}   # hop-entry time (link edges)
        self._dev_t: Dict[int, float] = {}   # device-entry time (dev edges)
        self._fabric_log: List[dict] = []
        self._n_windows = 0

        # -- control scope ------------------------------------------------
        # "tier": the classic per-slow-tier window/decision addressing.
        # "edge": windows and decisions address *control edges* — one
        # device edge per slow tier (named by the tier) then one link edge
        # per port-bearing fabric link (declaration order, named by the
        # link); see repro.fabric.control.edge_names.  With zero links the
        # edge schedule degenerates to the slow-tier schedule and both
        # scopes are bit-identical.
        if control_scope not in ("tier", "edge"):
            raise ValueError(
                f"unknown control_scope {control_scope!r}; "
                "expected 'tier' or 'edge'"
            )
        self._edge_scope = control_scope == "edge"
        self._edge_names = tuple(self._tier_names[1:]) + self._link_names
        self._edge_station = tuple(
            list(range(1, self._n_tiers))
            + list(range(link0, link0 + len(links)))
        )
        self._n_edges = len(self._edge_station)
        # Per-link decision state, indexed by station code like _hop_*
        # (written by ``apply`` under edge scope, folded into workload
        # throttles by ``_recompute_throttle``).
        self._link_cap: List[Optional[int]] = [None] * n_st
        self._link_rate: List[float] = [1.0] * n_st
        # Edge window accumulators (edge scope only): device edges meter
        # device-side residency (_dev_t to retire), link edges meter
        # port residency (_hop_t to hop exit).
        self._e_ins = [0] * self._n_edges
        self._e_occ = [0.0] * self._n_edges
        self._e_cls = [[0] * len(_OPS) for _ in range(self._n_edges)]

        # Accounting: per-workload flat accumulators, materialized into
        # WorkloadStats at the end of the run.
        self.stats: Dict[str, WorkloadStats] = {
            w.name: WorkloadStats() for w in self.workloads
        }
        self._stat_completed = [0] * n
        self._stat_bytes = [0.0] * n
        self._stat_latsum = [0.0] * n
        self._stat_latcnt = [0] * n
        self._stat_res: List[List[float]] = [[] for _ in range(n)]

        # Tier counters: flat accumulators + a TierSetWindowedCounters the
        # control loop reads per-tier TierWindow deltas from.  Under edge
        # scope the window names are [fast tier, *edges]; device edges are
        # named by their tier, so the degenerate (zero-link) schedule is
        # the tier schedule and windows are bit-identical across scopes.
        cnames = (
            (self._tier_names[0],) + self._edge_names
            if self._edge_scope else self._tier_names
        )
        self._counters = TierSetWindowedCounters(names=cnames)
        self.tier_counters = {
            t: self._counters.tiers[i] for i, t in enumerate(cnames)
        }
        self._tc_ins = [0] * self._n_tiers
        self._tc_occ = [0.0] * self._n_tiers
        self._tc_cls = [[0] * len(_OPS) for _ in range(self._n_tiers)]

        # Occupancy integrals are accumulated as per-request residencies at
        # retire time (Σ residency == ∫ occupancy dt); requests still in
        # flight at the horizon are charged their partial residency at the
        # end of run().  Per-tier sums are keyed by the request's *tier*
        # (LLC hits still hold ToR entries and count toward their tier,
        # paper §4.3); the total integral is their sum.
        self.tor_occupancy_integral = 0.0
        self._occ_tier = [0.0] * self._n_tiers
        self.tor_inserts = 0
        self._tier_inflight = [0] * self._n_tiers
        self._timeline_bucket_ns = window_ns
        self._timeline_acc = [0.0] * n
        self._timeline_next = self._timeline_bucket_ns

        # -- runtime sanitizer --------------------------------------------
        # ``sanitize``: None consults REPRO_SANITIZE; True / "raise" checks
        # every window and raises structured InvariantViolations; "record"
        # accumulates them into SimResult.sanitizer instead.  The sanitizer
        # lives in repro.analysis (imported lazily: the core never depends
        # on the analysis layer unless a sim actually asks for checking).
        if sanitize is None:
            sanitize = sanitize_enabled()
        if sanitize:
            from repro.analysis.sanitizer import DesSanitizer

            mode = sanitize if isinstance(sanitize, str) else "raise"
            self._san: Optional[DesSanitizer] = DesSanitizer(
                self._n_tiers, mode=mode
            )
            self._counters.attach_sanitizer(self._san.check_counter_deltas)
        else:
            self._san = None

        # -- observability (repro.obs) ------------------------------------
        # ``latency_hist``: collect every retire latency into plain per-
        # (workload, tier) lists (bucketing is deferred to end-of-run
        # materialization, off the hot path); ``trace``: 1-in-N sampled
        # request-lifecycle tracing (an int N or a TraceConfig), keyed on
        # the tor_inserts counter so the sampler draws no random numbers;
        # ``profiler``: an attached PhaseProfiler for wall-clock phase
        # accounting.  All default off; the disabled paths cost one int /
        # pointer compare per transition and stay bit-identical to the
        # pinned goldens.  Like the sanitizer, repro.obs is imported
        # lazily — the core never depends on it unless a sim asks.
        self._prof = profiler
        if latency_hist:
            # One flat list per (workload, tier) pair: the retire hot path
            # pays a single cached bound-``append`` call, and the workload /
            # tier / per-window histograms are all exact merges of these
            # shared sublists (bucket counts and water marks are
            # order-independent).
            self._lat_wt: Optional[List[List[List[float]]]] = [
                [[] for _ in range(self._n_tiers)] for _ in self.workloads
            ]
            self._lat_ap: Optional[List[list]] = [
                [lst.append for lst in row] for row in self._lat_wt
            ]
        else:
            self._lat_wt = None
            self._lat_ap = None
        #: (window index, t_ns, per-(workload, tier) sample counts)
        #: snapshots taken at window boundaries — slices of the flat sample
        #: lists, so the per-window histograms merge back to the full
        #: histogram exactly.
        self._hist_marks: List[Tuple[int, float, List[List[int]]]] = []
        if trace:
            from repro.obs.trace import RequestTracer, TraceConfig

            cfg = (
                trace
                if isinstance(trace, TraceConfig)
                else TraceConfig(sample_every=int(trace))
            )
            self._tracer: Optional[RequestTracer] = RequestTracer(
                cfg,
                workload_names=[w.name for w in self.workloads],
                station_names=(
                    list(self._tier_names) + ["llc"] + list(self._link_names)
                ),
                tier_names=list(self._tier_names),
            )
            self._tr_every = cfg.sample_every
        else:
            self._tracer = None
            self._tr_every = 0

        if tiering is not None:
            tiering.bind(self)

    # -- substrate protocol ---------------------------------------------------
    @property
    def clock_ns(self) -> float:
        return self.now

    # -- state export (batched lane) ------------------------------------------
    def export_state(self) -> dict:
        """Static per-sim state as plain Python values, for array stacking.

        The batched sweep lane (:mod:`repro.memsim.batched`) constructs one
        ``TieredMemorySim`` per job *without running it* and stacks these
        exports into ``(n_jobs, n_workloads, n_tiers)`` arrays.  Everything
        here is derived in ``__init__`` — exporting is read-only and must
        never advance the simulation (the scalar path stays bit-identical).

        Keys (per-workload lists are indexed like ``self.workloads``):

        * ``tier_names`` / ``st_slots`` / ``pipe`` — platform topology; the
          station list is the tiers plus one trailing LLC station.
        * ``tor_capacity`` / ``irq_capacity`` — shared-queue bounds in
          macro-request units (granularity already applied).
        * ``w_svc`` / ``w_bytes`` / ``w_llc_svc`` / ``w_phit`` — per-workload
          service/byte constants, the LLC routing sentinel included.
        * ``w_tier_frac`` — each workload's *static* tier-routing
          probability vector (one-hot tier, ``ddr_fraction`` pair, or the
          general placement vector); phased workloads export their schedule
          in ``w_phases`` instead and carry their phase-0 one-hot here.
        """
        n_tiers = self._n_tiers
        fracs: List[List[float]] = []
        for wi, w in enumerate(self.workloads):
            vec = [0.0] * n_tiers
            if self._w_frac[wi] is not None:
                vec[_DDR] = self._w_frac[wi]
                vec[_CXL] = 1.0 - self._w_frac[wi]
            elif self._w_cum[wi] is not None:
                # Live n-tier routing (a tiering hook re-resolves placements
                # into ``_w_cum`` at bind) — export the cumulative draw
                # boundaries as per-tier fractions, not the stale spec
                # placement.
                prev = 0.0
                for t in range(n_tiers):
                    hi = (
                        1.0 if t == n_tiers - 1
                        else min(float(self._w_cum[wi][t]), 1.0)
                    )
                    vec[t] = max(0.0, hi - prev)
                    prev = hi
            elif w.placement is not None:
                for t, f in w.placement.items():
                    vec[self._tier_idx[t]] = f
            else:
                vec[self._phase_tier[wi]] = 1.0
            fracs.append(vec)
        return {
            "tier_names": list(self._tier_names),
            "n_tiers": n_tiers,
            "granularity": self.granularity,
            "window_ns": self.window_ns,
            "st_slots": list(self._st_slots),
            "pipe": list(self._pipe),
            "tor_capacity": self.tor_capacity,
            "irq_capacity": self.irq_capacity,
            "w_names": [w.name for w in self.workloads],
            "w_op": list(self._w_op),
            "w_g": list(self._w_g),
            "w_svc": [list(s) for s in self._w_svc],
            "w_bytes": [list(b) for b in self._w_bytes],
            "w_llc_svc": list(self._w_llc_svc),
            "w_phit": list(self._w_phit),
            "w_tier_frac": fracs,
            "w_effmlp": list(self._w_effmlp),
            "w_cores": [w.n_cores for w in self.workloads],
            "w_managed": list(self._w_managed),
            "w_dependent": [bool(w.dependent) for w in self.workloads],
            "w_sync": [bool(w.sync) for w in self.workloads],
            "w_phases": [
                list(seq) if seq is not None else None
                for seq in self._phase_seq
            ],
        }

    def _materialize_counters(self) -> None:
        if self._edge_scope:
            # [fast tier, *edges]: index 0 from the fast tier's
            # accumulators, the rest from the edge accumulators.
            tiers = self._counters.tiers
            fast = tiers[0]
            fast.inserts = self._tc_ins[0]
            fast.occupancy_time = self._tc_occ[0]
            cls0 = self._tc_cls[0]
            for i, op in enumerate(_OPS):
                fast.class_counts[op] = cls0[i]
            for e in range(self._n_edges):
                tc = tiers[1 + e]
                tc.inserts = self._e_ins[e]
                tc.occupancy_time = self._e_occ[e]
                cls = self._e_cls[e]
                for i, op in enumerate(_OPS):
                    tc.class_counts[op] = cls[i]
            return
        for code, tc in enumerate(self._counters.tiers):
            tc.inserts = self._tc_ins[code]
            tc.occupancy_time = self._tc_occ[code]
            cls = self._tc_cls[code]
            for i, op in enumerate(_OPS):
                tc.class_counts[op] = cls[i]

    def counters_delta(self) -> TierWindow:
        self._materialize_counters()
        return self._counters.delta()

    def apply(self, decision) -> None:
        """Throttle slow-tier-bound workloads per the window's decision.

        Tier-addressed: a :class:`~repro.core.controller.TierDecisions`
        sets each slow tier's core cap and token-bucket rate independently
        (decisions in platform slow-tier order); a plain legacy
        :class:`Decision` broadcasts one cap/rate to every slow tier."""
        n = self._n_tiers
        if isinstance(decision, TierDecisions):
            ds = decision.decisions
            if self._edge_scope:
                # Edge-addressed: decisions in edge-schedule order (device
                # edges land on their tier's cap/rate, link edges on their
                # port's — _recompute_throttle folds both per workload).
                if len(ds) != self._n_edges:
                    raise ValueError(
                        f"edge-addressed decision has {len(ds)} edge(s); "
                        f"platform has {self._n_edges} control edge(s)"
                    )
                for e, d in enumerate(ds):
                    st = self._edge_station[e]
                    if st < n:
                        self._tier_cap[st] = d.max_concurrency
                        self._tier_rate[st] = d.rate_factor
                    else:
                        self._link_cap[st] = d.max_concurrency
                        self._link_rate[st] = d.rate_factor
            elif len(ds) != n - 1:
                raise ValueError(
                    f"tier-addressed decision has {len(ds)} tier(s); "
                    f"platform has {n - 1} slow tier(s)"
                )
            else:
                for code in range(1, n):
                    d = ds[code - 1]
                    self._tier_cap[code] = d.max_concurrency
                    self._tier_rate[code] = d.rate_factor
        else:
            for code in range(1, n):
                self._tier_cap[code] = decision.max_concurrency
                self._tier_rate[code] = decision.rate_factor
        # fill/pump per workload, not hoisted after the loop: the seed
        # applied each workload's new throttle and re-issued immediately,
        # and the issue path draws from the sim RNG — batching the refill
        # would reorder draws and break bit-identity with the recorded
        # traces/goldens.  Cost is per-window (subsequent fill/pump calls
        # no-op unless the preceding recompute opened issue room).
        for wi in range(len(self.workloads)):
            if not self._w_managed[wi]:
                continue
            self._recompute_throttle(wi)
            self._fill_irq()
            self._pump()

    @property
    def decisions(self) -> List[Decision]:
        return self.control.decisions

    # -- throttle cache -------------------------------------------------------
    def _touched_slow(self, wi: int) -> Tuple[int, ...]:
        """Slow tier codes this workload currently sends traffic to.  (MIKU
        identifies slow-tier-accessing threads via sampled physical
        addresses; the simulator knows placement exactly — DESIGN.md §2.)
        Every tier after the first counts as slow."""
        frac = self._w_frac[wi]
        if frac is not None:
            return (_CXL,) if frac < 1.0 else ()
        if self._w_cum[wi] is not None:
            return self._w_placed_slow[wi]
        t = self._phase_tier[wi]
        return (t,) if t != _DDR else ()

    def _recompute_throttle(self, wi: int) -> None:
        """Fold the per-tier decision state into this workload's effective
        core cap / rate (most restrictive across the slow tiers it touches
        — a workload striped over two slow tiers obeys both ladders)."""
        codes = self._touched_slow(wi)
        if not codes or not self._w_managed[wi]:
            self._limit[wi] = None
            self._unthrottled[wi] = True
            return
        cap: Optional[int] = None
        rate = 1.0
        for c in codes:
            tc = self._tier_cap[c]
            if tc is not None and (cap is None or tc < cap):
                cap = tc
            tr = self._tier_rate[c]
            if tr < rate:
                rate = tr
        if self._fabric_active:
            # Fold in the link edges on this workload's routes to the
            # touched slow tiers — a workload obeys every ladder its
            # requests flow through (edge scope writes _link_cap/_rate;
            # tier scope leaves them at the unrestricted defaults).
            w_hops = self._w_hops[wi]
            for c in codes:
                for st in w_hops[c]:
                    lc = self._link_cap[st]
                    if lc is not None and (cap is None or lc < cap):
                        cap = lc
                    lr = self._link_rate[st]
                    if lr < rate:
                        rate = lr
        self._limit[wi] = cap
        self._rate[wi] = rate
        self._unthrottled[wi] = rate >= 1.0

    # -- fabric hop stations --------------------------------------------------
    # A fabric-routed request admitted to the ToR traverses its hop
    # stations in route order before entering its tier's device station,
    # holding its ToR entry (and IRQ-freed core slot accounting) exactly
    # like a flat request.  Each hop has a port entry limit (_hop_limit):
    # a request may only move onto a hop with a free entry; otherwise it
    # *stalls in place* — at admission time holding only its ToR entry,
    # or mid-route holding its upstream hop's server slot (head-of-line
    # backpressure: the stalled request blocks that server until the
    # downstream port frees an entry).

    def _hop_admit(self, rid: int, hops: Tuple[int, ...]) -> None:
        """Route a freshly-admitted request onto its first fabric hop (or
        stall it at the ingress port, holding only its ToR entry)."""
        self._hop_path[rid] = hops
        first = hops[0]
        if self._hop_occ[first] < self._hop_limit[first]:
            self._hop_idx[rid] = 0
            self._hop_enter(rid, first)
        else:
            self._hop_idx[rid] = -1  # not on the fabric yet
            self._hop_stall[first].append((rid, -1))
            self._hop_stall_events[first] += 1
            tr = self._tracer
            if tr is not None and self._r_traced[rid]:
                tr.stall(rid, first, self.now)

    def _hop_enter(self, rid: int, station: int) -> None:
        """Occupy one port entry at ``station`` and start (or queue for)
        its service; service time is the link's per-cacheline rate times
        the request's macro granularity."""
        occ = self._hop_occ[station] + 1
        self._hop_occ[station] = occ
        if occ > self._hop_peak_occ[station]:
            self._hop_peak_occ[station] = occ
        self._hop_t[rid] = self.now
        self._r_station[rid] = station
        service = self._hop_svc[station] * self._w_g[self._r_wl[rid]]
        self._r_service[rid] = service
        tr = self._tracer
        if tr is not None and self._r_traced[rid]:
            tr.station_enter(rid, station, self.now)
        if self._st_busy[station] < self._st_slots[station]:
            self._st_busy[station] += 1
            self._push(self.now + service, _EV_COMPLETE, rid)
        else:
            self._st_q[station].append(rid)

    def _hop_complete(self, rid: int, station: int) -> None:
        """Service done at a hop: advance to the next hop or the device —
        unless the downstream port is full, in which case the request
        stalls holding this hop's server slot (HoL backpressure)."""
        tr = self._tracer
        if tr is not None and self._r_traced[rid]:
            tr.service_done(rid, station, self.now, self._r_service[rid])
        hops = self._hop_path[rid]
        i = self._hop_idx[rid] + 1
        if i < len(hops):
            nxt = hops[i]
            if self._hop_occ[nxt] >= self._hop_limit[nxt]:
                self._hop_stall[nxt].append((rid, station))
                self._hop_stall_events[nxt] += 1
                if tr is not None and self._r_traced[rid]:
                    tr.stall(rid, nxt, self.now)
                return
            self._hop_leave(rid, station)
            self._hop_idx[rid] = i
            self._hop_enter(rid, nxt)
            return
        # Last hop done: leave the fabric, enter the tier device station.
        self._hop_leave(rid, station)
        del self._hop_path[rid], self._hop_idx[rid]
        tier = self._r_tier[rid]
        if self._edge_scope:
            self._dev_t[rid] = self.now
        self._r_station[rid] = tier
        service = self._w_svc[self._r_wl[rid]][tier]
        self._r_service[rid] = service
        if tr is not None and self._r_traced[rid]:
            tr.station_enter(rid, tier, self.now)
        if self._st_busy[tier] < self._st_slots[tier]:
            self._st_busy[tier] += 1
            self._push(self.now + service, _EV_COMPLETE, rid)
        else:
            self._st_q[tier].append(rid)

    def _hop_leave(self, rid: int, station: int) -> None:
        """Release the server slot and port entry at ``station`` (pulling
        the next queued request into service) and wake stalled upstream
        waiters into the freed entry."""
        q = self._st_q[station]
        if q:
            nxt = q.popleft()
            self._push(self.now + self._r_service[nxt], _EV_COMPLETE, nxt)
        else:
            self._st_busy[station] -= 1
        self._hop_occ[station] -= 1
        if self._edge_scope:
            e = self._n_tiers - 1 + (station - self._link0)
            self._e_ins[e] += 1
            self._e_occ[e] += self.now - self._hop_t[rid]
            self._e_cls[e][self._w_op[self._r_wl[rid]]] += 1
        del self._hop_t[rid]
        if self._hop_stall[station]:
            self._hop_unstall(station)

    def _hop_unstall(self, station: int) -> None:
        """Admit stalled waiters into freed entries at ``station``; waking
        a mid-route waiter frees its upstream slot, which can cascade
        further unstalls up the route."""
        stall = self._hop_stall[station]
        while stall and self._hop_occ[station] < self._hop_limit[station]:
            rid, upstream = stall.popleft()
            if upstream >= 0:
                self._hop_idx[rid] += 1
                self._hop_leave(rid, upstream)
            else:  # admission stall: first entry onto the fabric
                self._hop_idx[rid] = 0
            self._hop_enter(rid, station)

    # -- event plumbing -------------------------------------------------------
    def _push(self, t: float, kind: int, arg: int) -> None:
        self._seq += 1
        heapq.heappush(
            self._heap, (t, (self._seq << _SEQ_SHIFT) | (kind << _KIND_SHIFT) | arg)
        )

    # -- issue path -----------------------------------------------------------
    def _take_token(self, wi: int, cost: float) -> bool:
        """Token bucket in request-cost units; rate_factor scales refill.
        Only reached when the workload is actually rate-throttled (the
        ``_unthrottled`` fast path filters everything else)."""
        rate = self._rate[wi]
        dt = self.now - self._last_refill[wi]
        self._tokens[wi] = min(cost * 4.0, self._tokens[wi] + dt * rate)
        self._last_refill[wi] = self.now
        if self._tokens[wi] >= cost:
            self._tokens[wi] -= cost
            return True
        if not self._token_wait[wi]:
            self._token_wait[wi] = True
            wait = (cost - self._tokens[wi]) / max(rate, 1e-6)
            self._push(self.now + wait, _EV_TOKEN, wi)
        return False

    def _fill_irq(self) -> None:
        """Round-robin core arbitration into free IRQ space (open-loop issue
        pressure: every core with MLP headroom re-attempts continuously)."""
        irq = self.irq
        cap = self.irq_capacity
        if len(irq) >= cap:
            return
        rr_wi, rr_core = self._rr_wi, self._rr_core
        n = len(rr_wi)
        ptr = self._rr_ptr
        out = self._out
        effmlp, limit = self._w_effmlp, self._limit
        frac_of, cur_tier = self._w_frac, self._phase_tier
        cum_of = self._w_cum
        unthrottled, svc = self._unthrottled, self._w_svc
        rnd = self.rng.random
        free = self._r_free
        open_w, arr_q = self._w_open, self._arr_q
        arr_issued = self._arr_issued
        now = self.now
        misses = 0
        while len(irq) < cap and misses < n:
            gi = ptr
            ptr += 1
            if ptr == n:
                ptr = 0
            wi = rr_wi[gi]
            if out[gi] >= effmlp[wi]:
                misses += 1
                continue
            lim = limit[wi]
            if lim is not None and rr_core[gi] >= lim:
                misses += 1
                continue
            # Open-loop gate: an arrival-fed workload only issues while
            # its backlog holds a generated request (the head's key draws
            # keyed tier routing without touching the sim RNG).
            if open_w[wi]:
                aq = arr_q[wi]
                if not aq:
                    misses += 1
                    continue
                key = aq[0][1]
            else:
                key = -1.0
            frac = frac_of[wi]
            if frac is None:
                cum = cum_of[wi]
                if cum is None:
                    tier = cur_tier[wi]
                else:  # general placement lottery (one draw, like frac)
                    r = key if key >= 0.0 else rnd()
                    tier = 0
                    while r >= cum[tier]:
                        tier += 1
            else:
                r = key if key >= 0.0 else rnd()
                tier = _DDR if r < frac else _CXL
            if not unthrottled[wi] and not self._take_token(wi, svc[wi][tier]):
                misses += 1
                continue
            if open_w[wi]:
                tissue = arr_q[wi].popleft()[0]
                arr_issued[wi] += 1
            else:
                tissue = now
            if free:
                rid = free.pop()
                self._r_wl[rid] = wi
                self._r_gi[rid] = gi
                self._r_tier[rid] = tier
                self._r_tissue[rid] = tissue
            else:
                rid = len(self._r_wl)
                self._r_wl.append(wi)
                self._r_gi.append(gi)
                self._r_tier.append(tier)
                self._r_station.append(tier)
                self._r_tissue.append(tissue)
                self._r_ttor.append(0.0)
                self._r_service.append(0.0)
                self._r_traced.append(0)
            out[gi] += 1
            irq.append(rid)
            misses = 0
        self._rr_ptr = ptr

    def _refill_issue(self, wi: int) -> None:
        del wi
        self._fill_irq()
        self._pump()

    # -- IRQ -> ToR -> station ------------------------------------------------
    def _pump(self) -> None:
        """Admit IRQ heads into the ToR while entries are free (HoL FIFO),
        letting cores refill freed IRQ space round-robin; route each admitted
        request to its station (LLC lottery included).  The round-robin issue
        scan is inlined (same arbitration as :meth:`_fill_irq`) — in steady
        state every admission frees exactly one IRQ slot and one core issues
        into it, so this loop is the simulator's hottest path."""
        irq = self.irq
        cap = self.tor_capacity
        irq_cap = self.irq_capacity
        now = self.now
        r_wl, r_tier, r_station = self._r_wl, self._r_tier, self._r_station
        r_ttor, r_tissue, r_service = self._r_ttor, self._r_tissue, self._r_service
        r_gi = self._r_gi
        phit, llc_svc, svc = self._w_phit, self._w_llc_svc, self._w_svc
        st_busy, st_slots, st_q = self._st_busy, self._st_slots, self._st_q
        rnd = self.rng.random
        heap = self._heap
        push = heapq.heappush
        rr_wi, rr_core = self._rr_wi, self._rr_core
        n_rr = len(rr_wi)
        out = self._out
        effmlp, limit = self._w_effmlp, self._limit
        frac_of, cur_tier = self._w_frac, self._phase_tier
        cum_of = self._w_cum
        unthrottled = self._unthrottled
        free = self._r_free
        open_w, arr_q = self._w_open, self._arr_q
        arr_issued = self._arr_issued
        tier_inflight = self._tier_inflight
        llc = self._llc
        fabric_on = self._fabric_active
        w_hops = self._w_hops
        san = self._san
        tr_every = self._tr_every
        tracer = self._tracer
        r_traced = self._r_traced
        while irq and self.tor_used < cap:
            rid = irq.popleft()
            self.tor_used += 1
            if self.tor_used > self.tor_peak:
                self.tor_peak = self.tor_used
            self.tor_inserts += 1
            tier = r_tier[rid]
            tier_inflight[tier] += 1
            if san is not None:
                san.adm[tier] += 1
            r_ttor[rid] = now
            # Deterministic 1-in-N trace sampling, keyed on the insert
            # counter (no RNG draws — the tracing-off sim is bit-identical).
            if tr_every and (self.tor_inserts - 1) % tr_every == 0:
                if tracer.admit(rid, r_wl[rid], tier, r_tissue[rid], now):
                    r_traced[rid] = 1
            # Route (inlined): sync → LLC bounce; else LLC lottery, else
            # the tier device.
            wi = r_wl[rid]
            p = phit[wi]
            if p == 2.0:  # sync workloads: coherence ops at the LLC
                station = llc
                service = llc_svc[wi]
            elif p >= 0.0 and rnd() < p:
                station = llc
                service = llc_svc[wi]
            else:
                station = tier
                service = svc[wi][tier]
            if fabric_on and station != llc and w_hops[wi][tier]:
                # Routed: traverse the fabric hops before the device.
                self._hop_admit(rid, w_hops[wi][tier])
            else:
                r_station[rid] = station
                r_service[rid] = service
                if tr_every and r_traced[rid]:
                    tracer.station_enter(rid, station, now)
                if st_busy[station] < st_slots[station]:
                    st_busy[station] += 1
                    self._seq += 1
                    push(
                        heap,
                        (
                            now + service,
                            (self._seq << _SEQ_SHIFT)
                            | (_EV_COMPLETE << _KIND_SHIFT)
                            | rid,
                        ),
                    )
                else:
                    st_q[station].append(rid)
            # Refill freed IRQ space (inlined _fill_irq: identical
            # round-robin arbitration, shared pointer).
            if len(irq) < irq_cap:
                ptr = self._rr_ptr
                misses = 0
                while len(irq) < irq_cap and misses < n_rr:
                    gi = ptr
                    ptr += 1
                    if ptr == n_rr:
                        ptr = 0
                    iwi = rr_wi[gi]
                    if out[gi] >= effmlp[iwi]:
                        misses += 1
                        continue
                    lim = limit[iwi]
                    if lim is not None and rr_core[gi] >= lim:
                        misses += 1
                        continue
                    if open_w[iwi]:
                        aq = arr_q[iwi]
                        if not aq:
                            misses += 1
                            continue
                        key = aq[0][1]
                    else:
                        key = -1.0
                    frac = frac_of[iwi]
                    if frac is None:
                        icum = cum_of[iwi]
                        if icum is None:
                            itier = cur_tier[iwi]
                        else:
                            r = key if key >= 0.0 else rnd()
                            itier = 0
                            while r >= icum[itier]:
                                itier += 1
                    else:
                        r = key if key >= 0.0 else rnd()
                        itier = _DDR if r < frac else _CXL
                    if not unthrottled[iwi] and not self._take_token(
                        iwi, svc[iwi][itier]
                    ):
                        misses += 1
                        continue
                    if open_w[iwi]:
                        tissue = arr_q[iwi].popleft()[0]
                        arr_issued[iwi] += 1
                    else:
                        tissue = now
                    if free:
                        nrid = free.pop()
                        r_wl[nrid] = iwi
                        r_gi[nrid] = gi
                        r_tier[nrid] = itier
                        r_tissue[nrid] = tissue
                    else:
                        nrid = len(r_wl)
                        r_wl.append(iwi)
                        r_gi.append(gi)
                        r_tier.append(itier)
                        r_station.append(itier)
                        r_tissue.append(tissue)
                        r_ttor.append(0.0)
                        r_service.append(0.0)
                        r_traced.append(0)
                    out[gi] += 1
                    irq.append(nrid)
                    misses = 0
                self._rr_ptr = ptr

    def _retire(self, rid: int) -> None:
        # NOTE: the run() event loop has an inlined copy of this body for
        # _EV_RETIRE events (the hottest handler); keep the two in sync.
        # This method serves the synchronous paths (LLC hits retiring
        # directly from their completion, zero-pipeline devices).
        now = self.now
        self.tor_used -= 1
        tier = self._r_tier[rid]
        self._tier_inflight[tier] -= 1
        if self._san is not None:
            self._san.ret[tier] += 1
        wi = self._r_wl[rid]
        residency = now - self._r_ttor[rid]
        self._occ_tier[tier] += residency
        if self._r_station[rid] != self._llc:
            self._tc_ins[tier] += 1
            self._tc_occ[tier] += residency
            self._tc_cls[tier][self._w_op[wi]] += 1
            if self._edge_scope and tier != _DDR:
                # Device edge: device-side residency only (see the inlined
                # copy in run()).
                dres = now - self._dev_t.pop(rid, self._r_ttor[rid])
                self._e_ins[tier - 1] += 1
                self._e_occ[tier - 1] += dres
                self._e_cls[tier - 1][self._w_op[wi]] += 1
        # Account workload stats.
        self._stat_completed[wi] += 1
        nbytes = self._w_bytes[wi][tier]
        self._stat_bytes[wi] += nbytes
        self._timeline_acc[wi] += nbytes
        latency = now - self._r_tissue[rid]
        self._stat_latsum[wi] += latency
        cnt = self._stat_latcnt[wi] + 1
        self._stat_latcnt[wi] = cnt
        # Reservoir sampling (algorithm R) on a dedicated RNG stream.
        res = self._stat_res[wi]
        k = self._reservoir_k
        if len(res) < k:
            res.append(latency)
        else:
            j = int(self._res_random() * cnt)
            if j < k:
                res[j] = latency
        if self._lat_ap is not None:
            self._lat_ap[wi][tier](latency)
        if self._tr_every and self._r_traced[rid]:
            self._tracer.retire(rid, now)
            self._r_traced[rid] = 0
        # Core slot freed: reissue (round-robin with everyone else), admit.
        self._out[self._r_gi[rid]] -= 1
        self._r_free.append(rid)
        if len(self.irq) < self.irq_capacity:
            self._fill_irq()
        if self.irq and self.tor_used < self.tor_capacity:
            self._pump()

    # -- open-loop arrivals ---------------------------------------------------
    def _schedule_arrivals(self) -> None:
        """Schedule each open-loop workload's first generated arrival."""
        for wi, pend in enumerate(self._arr_pending):
            if pend is not None:
                self._push(pend[0], _EV_ARRIVAL, wi)

    def _arrival(self, wi: int) -> None:
        """One generated request lands: join the backlog (or shed at the
        queue limit), schedule the generator's next arrival, and re-open
        the issue path — the newly backlogged request may issue now."""
        pend = self._arr_pending[wi]
        self._arr_gen[wi] += 1
        q = self._arr_q[wi]
        lim = self._arr_qlimit[wi]
        if lim is not None and len(q) >= lim:
            self._arr_shed[wi] += 1
        else:
            q.append(pend)
        nxt = next(self._arr_iter[wi], None)
        self._arr_pending[wi] = nxt
        if nxt is not None:
            self._push(nxt[0], _EV_ARRIVAL, wi)
        self._fill_irq()
        self._pump()

    # -- phases / windows ------------------------------------------------------
    def _schedule_phases(self) -> None:
        for wi, w in enumerate(self.workloads):
            if w.phases:
                dur, _ = w.phases[0]
                self._push(dur, _EV_PHASE, wi)

    def _phase_flip(self, wi: int) -> None:
        seq = self._phase_seq[wi]
        if seq is None:
            # Structured (python -O-proof) replacement for the old assert:
            # a phase event for a schedule-less workload is a corrupted
            # event stream.
            raise InvariantViolation(
                "phase-schedule",
                f"phase-flip event for workload "
                f"{self.workloads[wi].name!r}, which has no phase schedule",
                window=self._n_windows + 1,
                context={"workload": wi},
            )
        self._phase_idx[wi] = (self._phase_idx[wi] + 1) % len(seq)
        dur, tier_code = seq[self._phase_idx[wi]]
        self._phase_tier[wi] = tier_code
        self._recompute_throttle(wi)
        self._push(self.now + dur, _EV_PHASE, wi)
        self._refill_issue(wi)

    def _window(self) -> None:
        # Sanitizer pass first: the window boundary is the quiescent point
        # where every conservation identity must hold exactly (and where
        # fault-injection mutations land).  The control loop's ``fire``
        # may legitimately skip counters_delta (no controller), so the
        # counter checks live here, not only in the delta hook.
        prof = self._prof
        if prof is not None:
            _pt0 = prof.clock()
        if self._san is not None:
            self._san.on_window(self, self._n_windows + 1)
        # The control loop consumes counter deltas, runs the controller, and
        # applies the decision (see ``apply``); with no controller it still
        # keeps the window cadence for the timeline flush below.
        self.control.fire()
        self._n_windows += 1
        if self._lat_wt is not None and self._record_windows:
            # Snapshot per-(workload, tier) sample counts: per-window
            # histograms are built from these exact slices at
            # materialization, so merging them reproduces the full
            # histogram bucket-for-bucket.
            self._hist_marks.append(
                (self._n_windows, self.now,
                 [[len(s) for s in row] for row in self._lat_wt])
            )
        if self._fabric_active and self._record_windows:
            # Per-hop port telemetry, sampled at the window boundary.  The
            # window index matches ControlLoop's record indexing (1-based)
            # and is kept by the sim itself: with no controller the loop
            # records nothing, so this log alone carries the trace.
            self._fabric_log.append({
                "window": self._n_windows,
                "t_ns": self.now,
                "links": {
                    name: {
                        "queued": len(self._st_q[self._link0 + i]),
                        "in_service": self._st_busy[self._link0 + i],
                        "occupancy": self._hop_occ[self._link0 + i],
                        "stalled": len(self._hop_stall[self._link0 + i]),
                        "stall_events":
                            self._hop_stall_events[self._link0 + i],
                    }
                    for i, name in enumerate(self._link_names)
                },
            })
        if self._open_active and self._record_windows:
            # Per-window open-loop accounting: arrival/issue/shed deltas
            # since the previous boundary plus the instantaneous backlog
            # depth (queue growth is *the* open-loop overload signal).
            gen, iss = self._arr_gen, self._arr_issued
            shed = self._arr_shed
            self._arrival_log.append({
                "window": self._n_windows,
                "t_ns": self.now,
                "workloads": {
                    w.name: {
                        "generated": gen[wi] - self._arr_gen_mark[wi],
                        "issued": iss[wi] - self._arr_issued_mark[wi],
                        "shed": shed[wi] - self._arr_shed_mark[wi],
                        "queue_depth": len(self._arr_q[wi]),
                    }
                    for wi, w in enumerate(self.workloads)
                    if self._w_open[wi]
                },
            })
            self._arr_gen_mark = list(gen)
            self._arr_issued_mark = list(iss)
            self._arr_shed_mark = list(shed)
        if self._tiering is not None:
            # Per-window tiering pass: sample accesses into the PageMap, run
            # the migration policy, re-resolve placement vectors, gate the
            # migration pseudo-workloads — then re-open the issue path if the
            # hook changed routing or budgets.
            if self._tiering.on_window(self):
                self._fill_irq()
                self._pump()
        # Flush bandwidth timeline buckets.
        while self.now >= self._timeline_next:
            acc = self._timeline_acc
            for wi, w in enumerate(self.workloads):
                self.stats[w.name].timeline.append((self._timeline_next, acc[wi]))
                acc[wi] = 0.0
            self._timeline_next += self._timeline_bucket_ns
        self._push(self.control.next_window_ns, _EV_WINDOW, 0)
        if prof is not None:
            prof.add("window_pass", prof.clock() - _pt0)

    # -- run --------------------------------------------------------------------
    def run(self, sim_ns: float) -> SimResult:
        self._schedule_phases()
        self._schedule_arrivals()
        self._push(self.control.next_window_ns, _EV_WINDOW, 0)
        self._fill_irq()
        self._pump()
        heap = self._heap
        pop = heapq.heappop
        push = heapq.heappush
        retire = self._retire
        kshift, amask = _KIND_SHIFT, _ARG_MASK
        ev_complete, ev_retire, ev_phase, ev_window = (
            _EV_COMPLETE, _EV_RETIRE, _EV_PHASE, _EV_WINDOW,
        )
        ev_arrival = _EV_ARRIVAL
        complete_bits = ev_complete << kshift
        retire_bits = ev_retire << kshift
        # Loop-stable array bindings for the two inlined hot handlers (these
        # list objects are appended to but never rebound).
        r_wl, r_gi, r_tier = self._r_wl, self._r_gi, self._r_tier
        r_station, r_tissue = self._r_station, self._r_tissue
        r_ttor, r_service = self._r_ttor, self._r_service
        st_busy, st_q = self._st_busy, self._st_q
        tier_inflight, occ_tier = self._tier_inflight, self._occ_tier
        tc_ins, tc_occ, tc_cls = self._tc_ins, self._tc_occ, self._tc_cls
        w_op, w_bytes = self._w_op, self._w_bytes
        stat_completed, stat_bytes = self._stat_completed, self._stat_bytes
        stat_latsum, stat_latcnt = self._stat_latsum, self._stat_latcnt
        stat_res, timeline_acc = self._stat_res, self._timeline_acc
        out, free = self._out, self._r_free
        irq = self.irq
        irq_cap = self.irq_capacity
        pipe = self._pipe
        res_random = self._res_random
        rk = self._reservoir_k
        # Bindings for the inlined admission/issue path (see _pump).
        tor_cap = self.tor_capacity
        st_slots = self._st_slots
        phit, llc_svc, svc = self._w_phit, self._w_llc_svc, self._w_svc
        rnd = self.rng.random
        rr_wi, rr_core = self._rr_wi, self._rr_core
        n_rr = len(rr_wi)
        effmlp, limit = self._w_effmlp, self._limit
        frac_of, cur_tier = self._w_frac, self._phase_tier
        cum_of = self._w_cum
        unthrottled = self._unthrottled
        open_w, arr_q = self._w_open, self._arr_q
        arr_issued = self._arr_issued
        llc = self._llc
        fabric_on = self._fabric_active
        w_hops = self._w_hops
        edge_on = self._edge_scope
        e_ins, e_occ, e_cls = self._e_ins, self._e_occ, self._e_cls
        dev_t = self._dev_t
        # Sanitizer binding: None-guarded on the retire / admission paths
        # only, so the un-sanitized hot path pays one pointer compare per
        # request transition, nothing per event (the event-order check
        # scans the pending heap at window boundaries instead).
        san = self._san
        # Observability bindings (same discipline): tracing-off pays one
        # int-truthiness test per admission/retire, histograms-off one
        # pointer compare per retire.  ``r_traced`` is the per-rid traced
        # flag (bytearray indexing beats a dict membership test on the
        # per-event hook guards).
        tr_every = self._tr_every
        tracer = self._tracer
        tr_limit = tracer.config.limit if tracer is not None else 0
        r_traced = self._r_traced
        lat_ap = self._lat_ap
        prof = self._prof
        if prof is not None:
            _rl0 = prof.clock()
        while heap:
            t, packed = pop(heap)
            if t > sim_ns:
                break
            self.now = t
            kind = (packed >> kshift) & 0xF
            if kind == ev_retire:
                # --- inlined _retire (keep in sync with the method) -------
                rid = packed & amask
                tor_used = self.tor_used - 1
                tier = r_tier[rid]
                tier_inflight[tier] -= 1
                if san is not None:
                    san.ret[tier] += 1
                wi = r_wl[rid]
                residency = t - r_ttor[rid]
                occ_tier[tier] += residency
                if r_station[rid] != llc:
                    tc_ins[tier] += 1
                    tc_occ[tier] += residency
                    tc_cls[tier][w_op[wi]] += 1
                    if edge_on and tier != _DDR:
                        # Device edge: device-side residency only (from
                        # _dev_t when the request crossed fabric hops;
                        # falls back to full ToR residency — identical —
                        # on hop-free routes).
                        e_ins[tier - 1] += 1
                        e_occ[tier - 1] += t - dev_t.pop(rid, r_ttor[rid])
                        e_cls[tier - 1][w_op[wi]] += 1
                stat_completed[wi] += 1
                nbytes = w_bytes[wi][tier]
                stat_bytes[wi] += nbytes
                timeline_acc[wi] += nbytes
                latency = t - r_tissue[rid]
                stat_latsum[wi] += latency
                cnt = stat_latcnt[wi] + 1
                stat_latcnt[wi] = cnt
                res = stat_res[wi]
                if len(res) < rk:
                    res.append(latency)
                else:
                    j = int(res_random() * cnt)
                    if j < rk:
                        res[j] = latency
                if lat_ap is not None:
                    lat_ap[wi][tier](latency)
                if tr_every and r_traced[rid]:
                    tracer.retire(rid, t)
                    r_traced[rid] = 0
                    if not tracer.live and len(tracer.done) >= tr_limit:
                        # Sample budget exhausted: done+live is monotone at
                        # the limit, so no future admission can ever be
                        # admitted, and with no live spans left every hook
                        # is a no-op — drop the loop back to the
                        # tracing-off fast path.  ``n_dropped`` is
                        # recomputed in closed form at materialization.
                        tr_every = 0
                out[r_gi[rid]] -= 1
                free.append(rid)
                if len(irq) < irq_cap:
                    self.tor_used = tor_used
                    self._fill_irq()
                # --- inlined _pump (keep in sync with the method): admit
                # IRQ heads into freed ToR entries, refill issue slots ------
                while irq and tor_used < tor_cap:
                    arid = irq.popleft()
                    tor_used += 1
                    if tor_used > self.tor_peak:
                        self.tor_peak = tor_used
                    self.tor_inserts += 1
                    atier = r_tier[arid]
                    tier_inflight[atier] += 1
                    if san is not None:
                        san.adm[atier] += 1
                    r_ttor[arid] = t
                    if tr_every and (self.tor_inserts - 1) % tr_every == 0:
                        if tracer.admit(arid, r_wl[arid], atier,
                                        r_tissue[arid], t):
                            r_traced[arid] = 1
                    awi = r_wl[arid]
                    p = phit[awi]
                    if p == 2.0:
                        station = llc
                        service = llc_svc[awi]
                    elif p >= 0.0 and rnd() < p:
                        station = llc
                        service = llc_svc[awi]
                    else:
                        station = atier
                        service = svc[awi][atier]
                    if fabric_on and station != llc and w_hops[awi][atier]:
                        self._hop_admit(arid, w_hops[awi][atier])
                    else:
                        r_station[arid] = station
                        r_service[arid] = service
                        if tr_every and r_traced[arid]:
                            tracer.station_enter(arid, station, t)
                        if st_busy[station] < st_slots[station]:
                            st_busy[station] += 1
                            seq = self._seq + 1
                            self._seq = seq
                            push(heap,
                                 (t + service,
                                  (seq << _SEQ_SHIFT) | complete_bits | arid))
                        else:
                            st_q[station].append(arid)
                    if len(irq) < irq_cap:
                        ptr = self._rr_ptr
                        misses = 0
                        while len(irq) < irq_cap and misses < n_rr:
                            gi = ptr
                            ptr += 1
                            if ptr == n_rr:
                                ptr = 0
                            iwi = rr_wi[gi]
                            if out[gi] >= effmlp[iwi]:
                                misses += 1
                                continue
                            lim = limit[iwi]
                            if lim is not None and rr_core[gi] >= lim:
                                misses += 1
                                continue
                            if open_w[iwi]:
                                aq = arr_q[iwi]
                                if not aq:
                                    misses += 1
                                    continue
                                key = aq[0][1]
                            else:
                                key = -1.0
                            frac = frac_of[iwi]
                            if frac is None:
                                icum = cum_of[iwi]
                                if icum is None:
                                    itier = cur_tier[iwi]
                                else:
                                    r = key if key >= 0.0 else rnd()
                                    itier = 0
                                    while r >= icum[itier]:
                                        itier += 1
                            else:
                                r = key if key >= 0.0 else rnd()
                                itier = _DDR if r < frac else _CXL
                            if not unthrottled[iwi] and not self._take_token(
                                iwi, svc[iwi][itier]
                            ):
                                misses += 1
                                continue
                            if open_w[iwi]:
                                tissue = arr_q[iwi].popleft()[0]
                                arr_issued[iwi] += 1
                            else:
                                tissue = t
                            if free:
                                nrid = free.pop()
                                r_wl[nrid] = iwi
                                r_gi[nrid] = gi
                                r_tier[nrid] = itier
                                r_tissue[nrid] = tissue
                            else:
                                nrid = len(r_wl)
                                r_wl.append(iwi)
                                r_gi.append(gi)
                                r_tier.append(itier)
                                r_station.append(itier)
                                r_tissue.append(tissue)
                                r_ttor.append(0.0)
                                r_service.append(0.0)
                                r_traced.append(0)
                            out[gi] += 1
                            irq.append(nrid)
                            misses = 0
                        self._rr_ptr = ptr
                self.tor_used = tor_used
            elif kind == ev_complete:
                # --- inlined _complete: free the server, pull the next
                # queued request, start the return flight ------------------
                rid = packed & amask
                station = r_station[rid]
                if station > llc:
                    # Fabric hop done: advance along the route (or stall
                    # holding this hop's server under backpressure).
                    self._hop_complete(rid, station)
                    continue
                if tr_every and r_traced[rid]:
                    tracer.service_done(rid, station, t, r_service[rid])
                q = st_q[station]
                if q:
                    nxt = q.popleft()
                    seq = self._seq + 1
                    self._seq = seq
                    push(heap, (t + r_service[nxt],
                                (seq << _SEQ_SHIFT) | complete_bits | nxt))
                else:
                    st_busy[station] -= 1
                if station == llc:
                    retire(rid)  # LLC: no return flight, retire in place
                else:
                    pipeline = pipe[r_tier[rid]]
                    if pipeline > 0.0:
                        seq = self._seq + 1
                        self._seq = seq
                        push(heap, (t + pipeline,
                                    (seq << _SEQ_SHIFT) | retire_bits | rid))
                    else:
                        retire(rid)
            elif kind == ev_arrival:
                self._arrival(packed & amask)
            elif kind == ev_phase:
                self._phase_flip(packed & amask)
            elif kind == ev_window:
                self._window()
            else:  # _EV_TOKEN
                wi = packed & amask
                self._token_wait[wi] = False
                self._refill_issue(wi)
        if prof is not None:
            prof.add("event_loop", prof.clock() - _rl0)
        self.now = sim_ns
        # Charge partial residency for requests still holding ToR entries at
        # the horizon (admitted = allocated minus free-list minus staged in
        # the IRQ): Σ residency == ∫ occupancy dt, exactly.
        dead = set(free)
        dead.update(irq)
        for rid in range(len(r_wl)):
            if rid not in dead:
                occ_tier[r_tier[rid]] += sim_ns - r_ttor[rid]
        self.tor_occupancy_integral = sum(occ_tier)
        if san is not None:
            san.check_final(self)
        self._materialize_counters()
        # Materialize flat accumulators into the public WorkloadStats.
        for wi, w in enumerate(self.workloads):
            st = self.stats[w.name]
            st.completed = self._stat_completed[wi]
            st.bytes = self._stat_bytes[wi]
            st.latency_sum = self._stat_latsum[wi]
            st.latency_count = self._stat_latcnt[wi]
            st.latency_samples = self._stat_res[wi]
        # Bucket the raw latency lists into mergeable histograms (deferred
        # off the hot path — one ``from_samples`` pass per (workload, tier)
        # sublist; the workload and tier histograms are exact merges of the
        # shared sub-histograms).
        tier_hists = None
        lat_wt = self._lat_wt
        if lat_wt is not None:
            from repro.obs.histogram import LatencyHistogram, merge_all

            sub = [
                [LatencyHistogram.from_samples(lst) for lst in row]
                for row in lat_wt
            ]
            for wi, w in enumerate(self.workloads):
                self.stats[w.name].latency_hist = merge_all(sub[wi])
            tier_hists = {
                name: merge_all(row[i] for row in sub)
                for i, name in enumerate(self._tier_names)
            }
        # Fleet metrics: cumulative run counters on the process-default
        # registry (a handful of dict lookups per *run*, not per event).
        from repro.obs.metrics import default_registry

        reg = default_registry()
        reg.counter("des.runs").inc()
        reg.counter("des.requests").inc(float(sum(self._stat_completed)))
        reg.counter("des.tor_inserts").inc(float(self.tor_inserts))
        reg.counter("des.windows").inc(float(self._n_windows))
        if tracer is not None:
            reg.counter("des.traced_requests").inc(float(len(tracer.done)))
            # Closed-form dropped count: the sampler hits exactly the
            # (k*every + 1)th ToR inserts, and every hit either landed in
            # done/live or was dropped at the limit.  (The run loop stops
            # calling ``admit`` once the budget is exhausted, so the
            # tracer's own running count under-counts.)
            every = tracer.config.sample_every
            sampled = (
                (self.tor_inserts - 1) // every + 1 if self.tor_inserts else 0
            )
            tracer.dropped = sampled - len(tracer.done) - len(tracer.live)
        return SimResult(
            sim_ns=sim_ns,
            stats=self.stats,
            tier_counters=self.tier_counters,
            tor_peak=self.tor_peak,
            tor_occupancy_integral=self.tor_occupancy_integral,
            tor_inserts=self.tor_inserts,
            decisions=self.control.decisions,
            per_tier_occupancy_integral={
                t: self._occ_tier[i]
                for i, t in enumerate(self._tier_names)
            },
            window_records=self._window_records(),
            tiering=(
                self._tiering.summary() if self._tiering is not None else None
            ),
            fabric=(
                {
                    name: {
                        "stall_events":
                            self._hop_stall_events[self._link0 + i],
                        "peak_occupancy":
                            self._hop_peak_occ[self._link0 + i],
                        "entry_limit": self._hop_limit[self._link0 + i],
                    }
                    for i, name in enumerate(self._link_names)
                }
                if self._fabric_active else None
            ),
            sanitizer=(
                self._san.summary(self) if self._san is not None else None
            ),
            arrival=(
                {
                    w.name: {
                        "generated": self._arr_gen[wi],
                        "issued": self._arr_issued[wi],
                        "shed": self._arr_shed[wi],
                        "backlog": len(self._arr_q[wi]),
                    }
                    for wi, w in enumerate(self.workloads)
                    if self._w_open[wi]
                }
                if self._open_active else None
            ),
            tier_latency_hist=tier_hists,
            trace=(tracer.run_payload() if tracer is not None else None),
            profile=(prof.snapshot() if prof is not None else None),
        )

    def _window_records(self) -> List[dict]:
        if not self._record_windows:
            return []
        records = [window_record_jsonable(r) for r in self.control.records]
        if self._hist_marks:
            # Per-window latency histograms from the sample-count snapshots
            # taken at each window boundary: window w's histogram is built
            # from the exact slice of retire latencies that landed in w, so
            # merging the per-window histograms reproduces the full-run
            # histogram bucket-for-bucket (same by-window-index merge model
            # as the fabric log below).
            from repro.obs.histogram import LatencyHistogram, merge_all

            by_idx = {r["window"]: r for r in records}
            n_tiers = self._n_tiers
            prev = [[0] * n_tiers for _ in self.workloads]
            for widx, t_ns, lens in self._hist_marks:
                rec = by_idx.get(widx)
                if rec is None:
                    rec = {"window": widx, "t_ns": t_ns}
                    by_idx[widx] = rec
                    records.append(rec)
                rec["latency_hist"] = {
                    w.name: merge_all(
                        LatencyHistogram.from_samples(
                            self._lat_wt[wi][t][prev[wi][t]:lens[wi][t]]
                        )
                        for t in range(n_tiers)
                    ).to_jsonable()
                    for wi, w in enumerate(self.workloads)
                }
                prev = lens
            records.sort(key=lambda r: r["window"])
        if self._arrival_log:
            # Merge the open-loop arrival accounting in by window index
            # (same model as the fabric log: base records are synthesized
            # for windows the control loop never recorded).
            by_idx = {r["window"]: r for r in records}
            for entry in self._arrival_log:
                rec = by_idx.get(entry["window"])
                if rec is None:
                    rec = {"window": entry["window"], "t_ns": entry["t_ns"]}
                    by_idx[entry["window"]] = rec
                    records.append(rec)
                rec["arrival"] = entry["workloads"]
            records.sort(key=lambda r: r["window"])
        if self._fabric_log:
            # Merge the per-hop port telemetry in by window index,
            # synthesizing base records for windows the control loop never
            # recorded (no controller — same model as the tiering merge).
            by_idx = {r["window"]: r for r in records}
            for entry in self._fabric_log:
                rec = by_idx.get(entry["window"])
                if rec is None:
                    rec = {"window": entry["window"], "t_ns": entry["t_ns"]}
                    by_idx[entry["window"]] = rec
                    records.append(rec)
                rec["fabric"] = entry["links"]
            records.sort(key=lambda r: r["window"])
        if self._tiering is None:
            return records
        # Merge the tiering hook's per-window migration counters in by window
        # index.  With no controller the ControlLoop records nothing, so the
        # hook's log alone carries the trace (naive-migration cells still get
        # per-window telemetry).
        by_index = {r["window"]: r for r in records}
        merged: List[dict] = []
        for entry in self._tiering.window_log:
            rec = by_index.pop(entry["window"], None)
            if rec is None:
                rec = {"window": entry["window"], "t_ns": entry["t_ns"]}
            rec["tiering"] = {
                k: v for k, v in entry.items() if k not in ("window", "t_ns")
            }
            merged.append(rec)
        merged.extend(by_index.values())  # windows the hook never saw
        merged.sort(key=lambda r: r["window"])
        return merged


# ---------------------------------------------------------------------------
# Convenience runners used by memsim + benchmarks.
# ---------------------------------------------------------------------------


def run_bw_test(
    platform: PlatformModel,
    *,
    op: OpClass,
    tier: str,
    n_threads: int,
    sim_ns: float = 150_000.0,
    mlp: int = 160,
    seed: int = 0,
) -> SimResult:
    wl = WorkloadSpec(
        name=f"bw-{tier}-{op.value}", op=op, tier=tier, n_cores=n_threads, mlp=mlp
    )
    sim = TieredMemorySim(platform, [wl], seed=seed)
    return sim.run(sim_ns)


def run_lat_test(
    platform: PlatformModel,
    *,
    op: OpClass,
    tier: str,
    n_threads: int = 1,
    sim_ns: float = 300_000.0,
    seed: int = 0,
) -> SimResult:
    wl = WorkloadSpec(
        name=f"lat-{tier}-{op.value}",
        op=op,
        tier=tier,
        n_cores=n_threads,
        dependent=True,
    )
    sim = TieredMemorySim(platform, [wl], seed=seed, granularity=1)
    return sim.run(sim_ns)


def run_corun(
    platform: PlatformModel,
    *,
    op: OpClass,
    n_threads: int = 16,
    sim_ns: float = 200_000.0,
    controller: Optional[MikuController] = None,
    mlp: int = 160,
    seed: int = 0,
    window_ns: float = 10_000.0,
) -> SimResult:
    """Two co-running bw-tests: one on DDR, one on CXL (paper Fig. 5/10)."""
    wls = [
        WorkloadSpec(
            name="ddr", op=op, tier="ddr", n_cores=n_threads, mlp=mlp,
            miku_managed=False,
        ),
        WorkloadSpec(name="cxl", op=op, tier="cxl", n_cores=n_threads, mlp=mlp),
    ]
    sim = TieredMemorySim(
        platform, wls, seed=seed, controller=controller, window_ns=window_ns
    )
    return sim.run(sim_ns)

"""Host-offload runtime: real JAX tier transfers + the simulated transfer clock.

Two cooperating pieces:

* :class:`HostOffloader` — executes *real* JAX device<->host transfers
  (``jax.device_put`` with memory-kind shardings) with double-buffered
  prefetch.  JAX's async dispatch gives natural overlap; ``block()`` fences.
  On backends without a pinned_host space it degrades to device-resident
  copies (still exercising the full control path).

* :class:`TransferQueue` — the *timing* model of the shared transfer path
  (per-chip DMA descriptors): a simulated clock charging each transfer its
  tier service time, with bounded in-flight slots.  This is the structure
  MIKU instruments (per-tier TierCounters) and throttles (per-tier max
  in-flight + byte-rate), exactly like the DES's ToR — but driven by the
  serving engine's actual request stream instead of synthetic cores.  The
  queue speaks the vector control-plane contract: ``counters_delta()``
  returns the per-tier :class:`~repro.core.littles_law.TierWindow` (fast
  tier first) and ``apply`` accepts tier-addressed
  :class:`~repro.core.controller.TierDecisions`, so each slow link (the
  default pinned-host path, plus any ``extra_slow`` tiers) gets its own
  in-flight cap and byte-rate.  On real TPU hardware this class would be
  replaced by reading transfer-completion timestamps from the runtime; the
  control law is unchanged (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.controller import Decision, MikuController, TierDecisions
from repro.core.device_model import UnknownTierError
from repro.core.invariants import sanitize_enabled
from repro.core.littles_law import OpClass, TierCounters, TierWindow
from repro.core.substrate import ControlLoop, TierSetWindowedCounters
from repro.core.tiers import (
    HBM_TIER,
    HOST_TIER,
    TierSpec,
    host_offload_supported,
    with_memory_kind,
)


class HostOffloader:
    """Real JAX transfers between the device tier and the host tier."""

    def __init__(self, sharding: Optional[jax.sharding.Sharding] = None):
        if sharding is None:
            sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        self._base = sharding
        self.supported = host_offload_supported()
        self._host_sharding = (
            with_memory_kind(sharding, HOST_TIER.memory_kind)
            if self.supported
            else sharding
        )
        self._device_sharding = with_memory_kind(sharding, HBM_TIER.memory_kind)

    def to_host(self, tree: Any) -> Any:
        """Offload a pytree to the host tier (async)."""
        return jax.device_put(tree, self._host_sharding)

    def to_device(self, tree: Any) -> Any:
        """Fetch a pytree back into HBM (async)."""
        return jax.device_put(tree, self._device_sharding)

    @staticmethod
    def block(tree: Any) -> Any:
        return jax.block_until_ready(tree)


@dataclasses.dataclass
class _InFlight:
    nbytes: int
    op: OpClass
    tier: str
    t_enqueue: float
    t_complete: float


class TransferQueue:
    """Simulated shared transfer path with MIKU instrumentation + control.

    ``submit`` charges a transfer; the clock is advanced by the engine
    (``advance``).  Fast-tier traffic (HBM reads/writes of the step itself)
    is reported via ``account_fast`` so the controller sees the same two-tier
    picture as on the x86 platforms.

    The queue is a :class:`~repro.core.substrate.MemorySubstrate`: a
    :class:`~repro.core.substrate.ControlLoop` owns window scheduling,
    counter deltas, and the decision history; ``advance`` merely interleaves
    the loop's window boundaries with transfer completions in time order.
    """

    def __init__(
        self,
        fast: TierSpec = HBM_TIER,
        slow: TierSpec = HOST_TIER,
        controller: Optional[MikuController] = None,
        window_ns: float = 1_000_000.0,
        extra_slow: Sequence[TierSpec] = (),
        sanitize=None,
        trace: int = 0,
    ):
        self.fast = fast
        self.slow = slow
        #: Ordered slow links by label: the canonical pinned-host path keeps
        #: its legacy "slow" label; extra tiers are addressed by TierSpec
        #: name (e.g. a second host pool or a disaggregated tier).
        self.slow_tiers: Dict[str, TierSpec] = {"slow": slow}
        for spec in extra_slow:
            if spec.name in self.slow_tiers or spec.name == "fast":
                raise ValueError(f"duplicate slow tier label {spec.name!r}")
            self.slow_tiers[spec.name] = spec
        self.controller = controller
        self.now = 0.0
        self._counters = TierSetWindowedCounters(
            names=("fast", *self.slow_tiers)
        )
        self.counters: Dict[str, TierCounters] = {
            name: tc
            for name, tc in zip(self._counters.names, self._counters.tiers)
        }
        self._inflight: List[_InFlight] = []
        self._pending: List[Tuple[int, OpClass]] = []
        self._decision = Decision(
            max_concurrency=None, rate_factor=1.0, phase=None  # type: ignore[arg-type]
        )
        # record=False: nothing consumes per-window telemetry records here,
        # and a long-lived queue fires windows forever.
        self.control = ControlLoop(
            self, controller, window_ns=window_ns, record=False
        )
        # Runtime sanitizer (repro.analysis): per-link transfer/byte
        # conservation after every ``advance``.  None consults
        # REPRO_SANITIZE, mirroring the DES.
        if sanitize is None:
            sanitize = sanitize_enabled()
        if sanitize:
            from repro.analysis.sanitizer import QueueSanitizer

            mode = sanitize if isinstance(sanitize, str) else "raise"
            self._san: Optional[QueueSanitizer] = QueueSanitizer(mode=mode)
            self._counters.attach_sanitizer(self._san.check_counter_deltas)
        else:
            self._san = None
        # Sampled transfer tracing (repro.obs.trace): every Nth chunk's
        # enqueue→service→complete span, request-shaped for to_chrome.
        if trace:
            from repro.obs.trace import TransferTracer

            self._tracer: Optional[TransferTracer] = TransferTracer(
                sample_every=int(trace)
            )
        else:
            self._tracer = None
        # Process-wide observability counters (repro.obs.metrics).
        from repro.obs.metrics import default_registry

        reg = default_registry()
        self._m_transfers = reg.counter("offload.transfers")
        self._m_bytes = reg.counter("offload.bytes")

    # -- substrate protocol -------------------------------------------------
    @property
    def clock_ns(self) -> float:
        return self.now

    def counters_delta(self) -> TierWindow:
        return self._counters.delta()

    def apply(self, decision) -> None:
        self._decision = decision

    def _check_tier(self, tier: str) -> None:
        """Unknown slow-link names are a loud error (the DES already does
        this at construction; the queue used to fall back silently).  The
        message names the *link* namespace — this queue's links, not the
        platform's tiers — and lists every known link name."""
        if tier not in self.slow_tiers:
            raise UnknownTierError(
                tier, ("fast", *self.slow_tiers),
                kind="transfer link",
                known_desc="this queue's links",
            )

    def decision_for(self, tier: str = "slow") -> Decision:
        """The decision governing one slow link: its own tier-addressed
        entry, or the broadcast legacy decision."""
        self._check_tier(tier)
        d = self._decision
        if isinstance(d, TierDecisions) and tier in d.tiers:
            return d.for_tier(tier)
        return d

    @property
    def window_ns(self) -> float:
        return self.control.window_ns

    @property
    def decisions(self) -> List[Decision]:
        return self.control.decisions

    # -- instrumentation ----------------------------------------------------
    def account_fast(self, nbytes: int, duration_ns: float, op: OpClass) -> None:
        self.counters["fast"].record(op, duration_ns)
        del nbytes

    def _service_ns(self, nbytes: int, tier: TierSpec, op: OpClass) -> float:
        t = nbytes / tier.bandwidth_gbps  # B / (B/ns)
        if op is not OpClass.LOAD:
            t *= 2.0 if op is OpClass.NT_STORE else 1.5
        return t

    # -- submission / progress ------------------------------------------------
    def slow_inflight(self, tier: str = "slow") -> int:
        """One slow link's transfers holding descriptors *now* (enqueued,
        incomplete)."""
        self._check_tier(tier)
        return sum(
            1 for f in self._inflight
            if f.tier == tier and f.t_enqueue <= self.now
        )

    def submit_slow(self, nbytes: int, op: OpClass = OpClass.LOAD) -> float:
        return self.submit_slow_stream(int(nbytes), 1, op)

    def submit_slow_stream(
        self,
        total_bytes: int,
        n_chunks: int,
        op: OpClass = OpClass.LOAD,
        tier: str = "slow",
    ) -> float:
        """Submit one logical stream as ``n_chunks`` transfers (per-layer
        weight/KV chunks) over one bandwidth-bound slow link.

        The link serializes chunks, so total duration is ~bytes/bw however
        they are queued — which is exactly why a MIKU in-flight cap is
        work-conserving: it bounds how many *descriptors* the stream holds
        (chunk i enqueues only when chunk i-cap completes) without slowing
        the stream.  Uncapped, every chunk enqueues immediately — the deep
        backlog that starves fast-tier request slots.  rate_factor < 1
        additionally stretches per-chunk service (the MBA/quota analogue).
        Cap and rate are this link's own (tier-addressed decision), so two
        co-resident slow links can run different ladders.  Returns the
        stream's completion time.
        """
        self._check_tier(tier)
        spec = self.slow_tiers[tier]
        decision = self.decision_for(tier)
        cap = decision.max_concurrency
        rate = max(decision.rate_factor, 1e-3)
        chunk = max(1, int(total_bytes) // max(1, n_chunks))
        service = self._service_ns(chunk, spec, op) / rate
        link_free = max(
            [f.t_complete for f in self._inflight if f.tier == tier],
            default=self.now,
        )
        done = max(self.now, link_free)
        dones: List[float] = []
        san = self._san
        tr = self._tracer
        for i in range(n_chunks):
            done = done + service
            if cap is None or i < cap:
                enq = self.now
            else:
                enq = dones[i - cap]
            self._inflight.append(_InFlight(chunk, op, tier, enq, done))
            if san is not None:
                san.on_submit(tier, chunk)
            if tr is not None:
                tr.on_chunk(tier, enq, done, service)
            dones.append(done)
        self._m_transfers.inc(float(n_chunks))
        self._m_bytes.inc(float(chunk * n_chunks))
        return done

    def slow_backlog(self, tier: Optional[str] = None) -> int:
        """In-flight slow transfers beyond the tier's parallel slots —
        the descriptor backlog that blocks fast-tier request slots (the
        IRQ/ToR unfairness, TPU rendition).  ``tier=None`` sums every slow
        link's backlog."""
        if tier is not None:
            self._check_tier(tier)
        tiers = self.slow_tiers if tier is None else (tier,)
        return sum(
            max(0, self.slow_inflight(t) - self.slow_tiers[t].parallelism)
            for t in tiers
        )

    def fast_penalty(self, pool: int = 56, c: float = 0.45) -> float:
        """Service-time multiplier for fast-tier steps while slow-tier
        backlog occupies shared descriptors.  Calibrated so full racing
        (pool exhausted) degrades the fast tier to ~70% (paper Fig. 12) and
        a backlog-free slow stream costs ~nothing."""
        return 1.0 + c * min(1.0, self.slow_backlog() / pool)

    def advance(self, dt_ns: float) -> None:
        """Move the simulated clock; retire completed transfers; fire MIKU
        windows (via the control loop) on schedule, in time order."""
        target = self.now + dt_ns
        while True:
            next_evt = min(
                [f.t_complete for f in self._inflight if f.t_complete <= target],
                default=None,
            )
            nw = self.control.next_window_ns
            boundary = nw if nw <= target else None
            if next_evt is None and boundary is None:
                break
            if boundary is not None and (next_evt is None or boundary <= next_evt):
                self.now = boundary
                self.control.fire()
            else:
                self.now = next_evt  # type: ignore[assignment]
                done = [f for f in self._inflight if f.t_complete <= self.now]
                self._inflight = [
                    f for f in self._inflight if f.t_complete > self.now
                ]
                for f in done:
                    self.counters[f.tier].record(f.op, f.t_complete - f.t_enqueue)
                    if self._san is not None:
                        self._san.on_complete(f.tier, f.nbytes)
        self.now = target
        if self._san is not None:
            self._san.check(self)

    @property
    def decision(self) -> Decision:
        return self._decision

    @property
    def trace_records(self) -> List[dict]:
        """Sampled transfer spans (empty when tracing is off); the
        request-shaped records :func:`repro.obs.trace.to_chrome` accepts."""
        return [] if self._tracer is None else self._tracer.records

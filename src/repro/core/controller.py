"""MIKU — Dynamic Memory Request Control (paper §5.2).

The controller half of MIKU.  Given per-window Little's-Law estimates of the
slow-tier service time (:mod:`repro.core.littles_law`), it decides how much
concurrency and issue rate slow-tier traffic may use, so that:

  * fast-tier (DDR / HBM) requests are never queued behind a slow-tier
    backlog in the shared request structure, and
  * slow-tier traffic still gets its maximum backlog-free throughput
    (work-conserving, best-effort service — no static reservation).

Mechanism, mirroring the paper:

  1. **Detection** — slow-tier backlog ⇔ estimated ``T_slow`` exceeds a
     calibrated, read/write-mix-adjusted threshold (and keeps growing).
  2. **Hierarchical throttling** — on detection, all slow-tier-bound actors
     are demoted to *level-3*, the most restrictive concurrency level
     (1 core / 1 in-flight stream).  If ``T_slow`` still exceeds target, the
     issue *rate* at level-3 is reduced (the MBA-% / CPU-quota analogue).
  3. **Work-conserving promotion** — while ``T_slow`` sits comfortably below
     threshold, actors are promoted one level per calm window, up to the
     instruction-class cap (the paper's empirically-determined backlog-free
     concurrency: 8 / 4 / 1 cores for load / store / nt-store), and fully
     unrestricted once the fast tier goes idle.

The controller is deliberately decoupled from any particular substrate: the
DES applies its decisions as active-core counts + token-bucket rates; the
serving engine applies them as max-in-flight host-tier fetches + byte-rate
caps; the straggler governor applies them to per-host dispatch.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Sequence

from repro.core.littles_law import (
    EstimatorConfig,
    LittlesLawEstimator,
    OpClass,
    TierCounters,
    TierEstimate,
)


class Phase(enum.Enum):
    UNRESTRICTED = "unrestricted"
    RESTRICTED = "restricted"


@dataclasses.dataclass(frozen=True)
class MikuConfig:
    """Controller calibration (paper §5.2 "Effective CXL request throttling")."""

    #: Ascending concurrency ladder.  levels[0] is "level-3" in the paper's
    #: naming (most restrictive: one core); the top is least restrictive.
    levels: Sequence[int] = (1, 2, 4, 8, 16)
    #: Per-instruction-class backlog-free concurrency caps (paper: 8/4/1
    #: cores for load/store/nt-store).  Promotion stops here while the fast
    #: tier is active; caps are lifted when the fast tier idles.
    class_caps: Dict[OpClass, int] = dataclasses.field(
        default_factory=lambda: {
            OpClass.LOAD: 8,
            OpClass.STORE: 4,
            OpClass.NT_STORE: 1,
        }
    )
    #: Multiplicative rate steps applied *below* the most restrictive level
    #: (the MBA/cgroup-quota analogue).
    min_rate: float = 0.1
    rate_backoff: float = 0.5
    rate_recover: float = 2.0
    #: Consecutive calm (sub-threshold) windows required before a promotion.
    promote_patience: int = 1
    #: Promote only while t_slow < margin * threshold (hysteresis band).
    target_margin: float = 0.85
    #: While restricted, a backlog estimate that shrank by at least this
    #: factor vs the previous window is a *draining* backlog: hold position
    #: instead of throttling further (the paper's "multiple rounds of
    #: adjustment before T_cxl stabilizes").
    drain_factor: float = 0.9
    #: Fast-tier insert share below which the fast tier is considered idle
    #: and all restrictions are released (work conservation).
    fast_idle_alpha: float = 0.02


@dataclasses.dataclass
class Decision:
    """What slow-tier traffic is allowed during the next window."""

    max_concurrency: Optional[int]  # None = unrestricted
    rate_factor: float  # 1.0 = unthrottled issue rate
    phase: Phase
    estimate: Optional[TierEstimate] = None

    @property
    def restricted(self) -> bool:
        return self.phase is Phase.RESTRICTED


class MikuController:
    """The MIKU feedback loop over estimation windows."""

    def __init__(
        self,
        config: MikuConfig,
        estimator_config: EstimatorConfig,
    ):
        self.config = config
        self.estimator = LittlesLawEstimator(estimator_config)
        self.phase = Phase.UNRESTRICTED
        self._level_idx = len(config.levels) - 1
        self._rate = 1.0
        self._calm_windows = 0
        self._prev_raw: Optional[float] = None
        self.decisions: list = []

    # -- helpers ----------------------------------------------------------
    def _class_cap(self, slow_classes: Sequence[OpClass]) -> int:
        """The most permissive backlog-free cap among active traffic classes
        is bounded by the least permissive one actually present — a window
        containing nt-stores must respect the nt-store cap."""
        caps = [self.config.class_caps[c] for c in slow_classes]
        return min(caps) if caps else max(self.config.levels)

    def _level_value(self) -> int:
        return self.config.levels[self._level_idx]

    def _demote_fully(self) -> None:
        """Paper: 'MIKU moves all threads accessing CXL memory to level-3,
        the most restrictive level ... to ensure the backlog is promptly
        resolved'."""
        self._level_idx = 0
        self._calm_windows = 0
        self.phase = Phase.RESTRICTED

    # -- main entry point --------------------------------------------------
    def window(
        self,
        fast_delta: TierCounters,
        slow_delta: TierCounters,
    ) -> Decision:
        cfg = self.config
        est = self.estimator.update(fast_delta, slow_delta)
        slow_classes = [c for c, n in slow_delta.class_counts.items() if n > 0]

        raw = est.t_slow_raw if est.valid else None
        if self.phase is Phase.UNRESTRICTED:
            # Detection uses the smoothed estimate (robust to one noisy
            # window, like the paper's 1 s sampling).
            if est.valid and est.backlogged:
                self._demote_fully()
                self._rate = 1.0
        else:
            fast_idle = (not est.valid and fast_delta.inserts == 0) or (
                est.valid and est.alpha < cfg.fast_idle_alpha
            )
            if fast_idle:
                # Work conservation: nobody is being hurt — release.
                self.phase = Phase.UNRESTRICTED
                self._level_idx = len(cfg.levels) - 1
                self._rate = 1.0
                self._calm_windows = 0
            elif raw is not None and raw > est.threshold:
                self._calm_windows = 0
                draining = (
                    self._prev_raw is not None
                    and raw < self._prev_raw * cfg.drain_factor
                )
                if draining:
                    pass  # the restriction is working; let the queue empty
                elif self._level_idx > 0:
                    self._demote_fully()
                else:
                    # Already at level-3: fine-grained rate control.
                    self._rate = max(cfg.min_rate, self._rate * cfg.rate_backoff)
            elif raw is not None and raw < cfg.target_margin * est.threshold:
                self._calm_windows += 1
                if self._calm_windows >= cfg.promote_patience:
                    self._calm_windows = 0
                    if self._rate < 1.0:
                        self._rate = min(1.0, self._rate * cfg.rate_recover)
                    else:
                        cap = self._class_cap(slow_classes)
                        nxt = self._level_idx + 1
                        if (
                            nxt < len(cfg.levels)
                            and cfg.levels[nxt] <= max(cap, cfg.levels[0])
                        ):
                            self._level_idx = nxt
            else:
                # In the hysteresis band (or invalid window): hold position.
                self._calm_windows = 0
        if raw is not None:
            self._prev_raw = raw

        if self.phase is Phase.UNRESTRICTED:
            decision = Decision(
                max_concurrency=None, rate_factor=1.0, phase=self.phase, estimate=est
            )
        else:
            decision = Decision(
                max_concurrency=self._level_value(),
                rate_factor=self._rate,
                phase=self.phase,
                estimate=est,
            )
        self.decisions.append(decision)
        return decision

    def reset(self) -> None:
        self.phase = Phase.UNRESTRICTED
        self._level_idx = len(self.config.levels) - 1
        self._rate = 1.0
        self._calm_windows = 0
        self._prev_raw = None
        self.estimator.reset()
        self.decisions.clear()


# ---------------------------------------------------------------------------
# Straggler governor — the same estimator applied to per-host step service
# times (DESIGN.md §5).  A slow host is "an overloaded slow tier": its step
# service time is estimated per window; hosts whose estimate exceeds the
# threshold get their input shard rate-capped / redispatched by the launcher.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HostHealth:
    host: int
    t_step: float
    healthy: bool
    rate_factor: float


class StragglerGovernor:
    """Detect and mitigate straggler hosts via service-time estimation.

    ``threshold_scale`` x median step time flags a straggler; mitigation
    follows MIKU's ladder: first cap the straggler's microbatch share
    (rate_factor), then exclude it (rate 0 ⇒ its shard is redispatched to
    healthy hosts) if it keeps degrading.  Recovery is gradual, mirroring the
    work-conserving promotion.
    """

    def __init__(
        self,
        n_hosts: int,
        threshold_scale: float = 1.35,
        ewma: float = 0.4,
        patience: int = 2,
    ):
        self.n_hosts = n_hosts
        self.threshold_scale = threshold_scale
        self.ewma = ewma
        self.patience = patience
        self._t = [0.0] * n_hosts
        self._bad_windows = [0] * n_hosts
        self._rate = [1.0] * n_hosts

    def window(self, step_times: Sequence[float]) -> list:
        assert len(step_times) == self.n_hosts
        for h, t in enumerate(step_times):
            if t <= 0:  # host missed the window entirely: worst signal
                self._bad_windows[h] += 1
                continue
            self._t[h] = (
                t if self._t[h] == 0.0 else self.ewma * t + (1 - self.ewma) * self._t[h]
            )
        alive = sorted(t for t in self._t if t > 0)
        if not alive:
            return [HostHealth(h, 0.0, True, 1.0) for h in range(self.n_hosts)]
        median = alive[len(alive) // 2]
        threshold = self.threshold_scale * median
        out = []
        for h in range(self.n_hosts):
            if self._t[h] > threshold:
                self._bad_windows[h] += 1
                if self._bad_windows[h] >= self.patience:
                    # Demote: halve its shard; floor at exclusion.
                    self._rate[h] = 0.0 if self._rate[h] <= 0.25 else self._rate[h] / 2
            else:
                self._bad_windows[h] = 0
                if self._rate[h] < 1.0:
                    self._rate[h] = min(1.0, max(self._rate[h], 0.25) * 2)
            out.append(
                HostHealth(
                    host=h,
                    t_step=self._t[h],
                    healthy=self._rate[h] >= 1.0,
                    rate_factor=self._rate[h],
                )
            )
        return out

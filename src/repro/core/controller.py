"""MIKU — Dynamic Memory Request Control (paper §5.2), per slow tier.

The controller half of MIKU.  Given per-window Little's-Law estimates of
each slow tier's service time (:mod:`repro.core.littles_law`), it decides
how much concurrency and issue rate each slow tier's traffic may use, so
that:

  * fast-tier (DDR / HBM) requests are never queued behind a slow-tier
    backlog in the shared request structure, and
  * every slow tier still gets its maximum backlog-free throughput
    (work-conserving, best-effort service — no static reservation).

Mechanism, mirroring the paper (per slow tier):

  1. **Detection** — slow-tier backlog ⇔ estimated ``T_slow`` exceeds a
     calibrated, read/write-mix-adjusted threshold (and keeps growing).
  2. **Hierarchical throttling** — on detection, all actors bound for that
     tier are demoted to *level-3*, the most restrictive concurrency level
     (1 core / 1 in-flight stream).  If ``T_slow`` still exceeds target, the
     issue *rate* at level-3 is reduced (the MBA-% / CPU-quota analogue).
  3. **Work-conserving promotion** — while ``T_slow`` sits comfortably below
     threshold, actors are promoted one level per calm window, up to the
     instruction-class cap (the paper's empirically-determined backlog-free
     concurrency: 8 / 4 / 1 cores for load / store / nt-store), and fully
     unrestricted once the fast tier goes idle.

The vector contract (one ladder per slow tier)
----------------------------------------------
:class:`MikuController` is an *ensemble* of :class:`SlowTierMiku` units —
one Little's-Law estimator, one throttle ladder, and one work-conserving
promotion state per slow tier, each with its own device-derived thresholds
(paper §5.2's per-device calibration; the device heterogeneity measured in
"Demystifying CXL Memory").  The canonical law entry point is
``window(deltas)`` with one :class:`~repro.core.littles_law.TierWindow`
(per-tier deltas, fast tier first); it returns a tier-addressed
:class:`TierDecisions`.  The legacy two-argument
``window(fast_delta, slow_delta)`` form is kept signature-compatible but
deprecated (it drives unit 0 only and returns a plain :class:`Decision`).
:class:`MergedSlowPolicy` is the explicit adapter reproducing the
pre-vector behavior — merge tiers 1..n-1 into one slow delta, run one
ladder, broadcast its decision to every slow tier — for comparison runs.

The controller is deliberately decoupled from any particular substrate: the
DES applies its decisions as per-tier active-core counts + token-bucket
rates; the serving engine applies them as per-tier max-in-flight host
fetches + byte-rate caps; the straggler governor applies them to per-host
dispatch.
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.littles_law import (
    ACCESS_MIX,
    EstimatorConfig,
    LittlesLawEstimator,
    OpClass,
    TierCounters,
    TierEstimate,
    merge_tier_counters,
)
from repro.core.invariants import require


class Phase(enum.Enum):
    UNRESTRICTED = "unrestricted"
    RESTRICTED = "restricted"


@dataclasses.dataclass(frozen=True)
class MikuConfig:
    """Controller calibration (paper §5.2 "Effective CXL request throttling")."""

    #: Ascending concurrency ladder.  levels[0] is "level-3" in the paper's
    #: naming (most restrictive: one core); the top is least restrictive.
    levels: Sequence[int] = (1, 2, 4, 8, 16)
    #: Per-instruction-class backlog-free concurrency caps (paper: 8/4/1
    #: cores for load/store/nt-store).  Promotion stops here while the fast
    #: tier is active; caps are lifted when the fast tier idles.  MIGRATE is
    #: the tiering subsystem's page-copy class: its cap is the ladder's
    #: *migration budget* — the concurrency best-effort migration traffic
    #: may use on this tier while demand traffic is active.
    class_caps: Dict[OpClass, int] = dataclasses.field(
        default_factory=lambda: {
            OpClass.LOAD: 8,
            OpClass.STORE: 4,
            OpClass.NT_STORE: 1,
            OpClass.MIGRATE: 2,
        }
    )
    #: Multiplicative rate steps applied *below* the most restrictive level
    #: (the MBA/cgroup-quota analogue).
    min_rate: float = 0.1
    rate_backoff: float = 0.5
    rate_recover: float = 2.0
    #: Consecutive calm (sub-threshold) windows required before a promotion.
    promote_patience: int = 1
    #: Promote only while t_slow < margin * threshold (hysteresis band).
    target_margin: float = 0.85
    #: While restricted, a backlog estimate that shrank by at least this
    #: factor vs the previous window is a *draining* backlog: hold position
    #: instead of throttling further (the paper's "multiple rounds of
    #: adjustment before T_cxl stabilizes").
    drain_factor: float = 0.9
    #: Fast-tier insert share below which the fast tier is considered idle
    #: and all restrictions are released (work conservation).
    fast_idle_alpha: float = 0.02


@dataclasses.dataclass
class Decision:
    """What one slow tier's traffic is allowed during the next window."""

    max_concurrency: Optional[int]  # None = unrestricted
    rate_factor: float  # 1.0 = unthrottled issue rate
    phase: Phase
    estimate: Optional[TierEstimate] = None

    @property
    def restricted(self) -> bool:
        return self.phase is Phase.RESTRICTED


@dataclasses.dataclass
class TierDecisions:
    """A tier-addressed window decision: one :class:`Decision` per slow tier.

    ``tiers``/``decisions`` are parallel, in platform slow-tier order
    (tiers 1..n-1 of the vector the law consumed).  Substrates apply each
    tier's decision to that tier's traffic only — per-tier active-core caps
    and token buckets in the DES, per-tier in-flight caps and byte-rates on
    the transfer path.

    For legacy consumers the object also reads like a single merged
    :class:`Decision` (most-restrictive view across tiers), so decision
    histories, telemetry, and the recorded two-tier MIKU traces — where the
    vector has exactly one slow tier and the view is that tier's decision
    verbatim — keep working unchanged.
    """

    tiers: Tuple[str, ...]
    decisions: Tuple[Decision, ...]

    def __post_init__(self) -> None:
        if len(self.tiers) != len(self.decisions) or not self.decisions:
            raise ValueError(
                f"TierDecisions needs one decision per slow tier, got "
                f"{len(self.tiers)} tier(s) / {len(self.decisions)} decision(s)"
            )

    def for_tier(self, tier: str) -> Decision:
        """The named slow tier's :class:`Decision` (ValueError if absent)."""
        return self.decisions[self.tiers.index(tier)]

    def items(self) -> Tuple[Tuple[str, Decision], ...]:
        """``(tier_name, Decision)`` pairs in platform slow-tier order."""
        return tuple(zip(self.tiers, self.decisions))

    # -- merged (most-restrictive) legacy view ----------------------------
    @property
    def max_concurrency(self) -> Optional[int]:
        caps = [d.max_concurrency for d in self.decisions
                if d.max_concurrency is not None]
        return min(caps) if caps else None

    @property
    def rate_factor(self) -> float:
        return min(d.rate_factor for d in self.decisions)

    @property
    def phase(self) -> Phase:
        return Phase.RESTRICTED if self.restricted else Phase.UNRESTRICTED

    @property
    def restricted(self) -> bool:
        return any(d.restricted for d in self.decisions)

    @property
    def estimate(self) -> Optional[TierEstimate]:
        return self.decisions[0].estimate


class SlowTierMiku:
    """One slow tier's MIKU state machine (paper §5.2, for a single tier).

    Estimator + throttle ladder + work-conserving promotion state for one
    slow tier, fed ``(fast_delta, this_tier_delta)`` windows.  This is
    exactly the seed's single-ladder controller body;
    :class:`MikuController` runs one instance per slow tier.
    """

    def __init__(
        self,
        config: MikuConfig,
        estimator_config: EstimatorConfig,
        tier: str = "slow",
    ):
        self.tier = tier
        self.config = config
        self.estimator = LittlesLawEstimator(estimator_config)
        self.phase = Phase.UNRESTRICTED
        self._level_idx = len(config.levels) - 1
        self._rate = 1.0
        self._calm_windows = 0
        self._prev_raw: Optional[float] = None

    # -- helpers ----------------------------------------------------------
    def _class_cap(self, slow_classes: Sequence[OpClass]) -> int:
        """The most permissive backlog-free cap among active traffic classes
        is bounded by the least permissive one actually present — a window
        containing nt-stores must respect the nt-store cap.  Classes with no
        configured cap (e.g. MIGRATE under a pre-tiering config) default to
        the most restrictive stance (1)."""
        caps = [self.config.class_caps.get(c, 1) for c in slow_classes]
        return min(caps) if caps else max(self.config.levels)

    def migration_budget(self) -> int:
        """Concurrent migration streams this ladder currently tolerates on
        its tier: the MIGRATE class cap while unrestricted, the ladder's
        current level (bounded by that cap) while restricted, and zero once
        fine-grained rate control has engaged — by then even level-3 demand
        concurrency is too much, so best-effort copies must stand down."""
        cap = self.config.class_caps.get(OpClass.MIGRATE, 1)
        if self.phase is Phase.UNRESTRICTED:
            return cap
        if self._rate < 1.0:
            return 0
        return min(cap, self._level_value())

    def _level_value(self) -> int:
        return self.config.levels[self._level_idx]

    def _demote_fully(self) -> None:
        """Paper: 'MIKU moves all threads accessing CXL memory to level-3,
        the most restrictive level ... to ensure the backlog is promptly
        resolved'."""
        self._level_idx = 0
        self._calm_windows = 0
        self.phase = Phase.RESTRICTED

    # -- one estimation window --------------------------------------------
    def window(
        self,
        fast_delta: TierCounters,
        slow_delta: TierCounters,
    ) -> Decision:
        """One estimation window: update the estimator with the
        ``(fast, this-tier)`` counter deltas, advance the ladder state
        machine, and return this tier's :class:`Decision`."""
        cfg = self.config
        est = self.estimator.update(fast_delta, slow_delta)
        slow_classes = [c for c, n in slow_delta.class_counts.items() if n > 0]

        raw = est.t_slow_raw if est.valid else None
        if self.phase is Phase.UNRESTRICTED:
            # Detection uses the smoothed estimate (robust to one noisy
            # window, like the paper's 1 s sampling).
            if est.valid and est.backlogged:
                self._demote_fully()
                self._rate = 1.0
        else:
            fast_idle = (not est.valid and fast_delta.inserts == 0) or (
                est.valid and est.alpha < cfg.fast_idle_alpha
            )
            if fast_idle:
                # Work conservation: nobody is being hurt — release.
                self.phase = Phase.UNRESTRICTED
                self._level_idx = len(cfg.levels) - 1
                self._rate = 1.0
                self._calm_windows = 0
            elif raw is not None and raw > est.threshold:
                self._calm_windows = 0
                draining = (
                    self._prev_raw is not None
                    and raw < self._prev_raw * cfg.drain_factor
                )
                if draining:
                    pass  # the restriction is working; let the queue empty
                elif self._level_idx > 0:
                    self._demote_fully()
                else:
                    # Already at level-3: fine-grained rate control.
                    self._rate = max(cfg.min_rate, self._rate * cfg.rate_backoff)
            elif raw is not None and raw < cfg.target_margin * est.threshold:
                self._calm_windows += 1
                if self._calm_windows >= cfg.promote_patience:
                    self._calm_windows = 0
                    if self._rate < 1.0:
                        self._rate = min(1.0, self._rate * cfg.rate_recover)
                    else:
                        cap = self._class_cap(slow_classes)
                        nxt = self._level_idx + 1
                        if (
                            nxt < len(cfg.levels)
                            and cfg.levels[nxt] <= max(cap, cfg.levels[0])
                        ):
                            self._level_idx = nxt
            else:
                # In the hysteresis band (or invalid window): hold position.
                self._calm_windows = 0
        if raw is not None:
            self._prev_raw = raw

        if self.phase is Phase.UNRESTRICTED:
            return Decision(
                max_concurrency=None, rate_factor=1.0, phase=self.phase, estimate=est
            )
        return Decision(
            max_concurrency=self._level_value(),
            rate_factor=self._rate,
            phase=self.phase,
            estimate=est,
        )

    def reset(self) -> None:
        """Forget all ladder and estimator state (back to unrestricted)."""
        self.phase = Phase.UNRESTRICTED
        self._level_idx = len(self.config.levels) - 1
        self._rate = 1.0
        self._calm_windows = 0
        self._prev_raw = None
        self.estimator.reset()


def _as_seq(value, n: int, what: str) -> list:
    """Broadcast a single config to ``n`` units, or validate a sequence."""
    if isinstance(value, (list, tuple)):
        if len(value) < n:
            raise ValueError(
                f"MikuController got {len(value)} per-tier {what}(s) for "
                f"{n} slow tier(s)"
            )
        return list(value[:n])
    return [value] * n


def split_tier_window(
    deltas: Sequence[TierCounters],
) -> Tuple[TierCounters, Tuple[TierCounters, ...], Tuple[str, ...]]:
    """``(fast, slows, slow_names)`` from one per-tier delta vector.

    The one place the vector's shape is interpreted: names come from a
    :class:`~repro.core.littles_law.TierWindow` when present, else the
    ``slow{i}`` fallback — every vector law unpacks through here so tier
    labels cannot diverge between laws."""
    if len(deltas) < 2:
        raise ValueError(
            "per-tier window needs the fast tier plus >=1 slow tier, "
            f"got {len(deltas)} tier(s)"
        )
    names = getattr(deltas, "names", None)
    slows = tuple(deltas[1:])
    slow_names = (
        tuple(names[1:]) if names is not None
        else tuple(f"slow{i}" for i in range(len(slows)))
    )
    return deltas[0], slows, slow_names


class MikuController:
    """A per-slow-tier ensemble of MIKU ladders over estimation windows.

    ``config`` / ``estimator_config`` may each be a single value (every
    slow tier gets its own unit with that calibration — the seed signature,
    unchanged) or a sequence with one entry per slow tier in platform order
    (per-device ladders and thresholds;
    :func:`repro.memsim.calibration.default_miku` derives these from each
    tier's :class:`~repro.core.device_model.DeviceModel`).

    Units are materialized lazily when the first window reveals the slow
    tier count; unit 0 exists from construction so the legacy single-ladder
    attributes (``.estimator``, ``.config``) and the deprecated two-argument
    ``window(fast, slow)`` keep working bit-identically.
    """

    _warned_pair = False  # process-wide: the deprecation fires once

    def __init__(
        self,
        config: Union[MikuConfig, Sequence[MikuConfig]],
        estimator_config: Union[EstimatorConfig, Sequence[EstimatorConfig]],
    ):
        self._configs = config
        self._est_configs = estimator_config
        self.units: List[SlowTierMiku] = []
        self._ensure_units(1)
        self.decisions: list = []

    # -- unit management ---------------------------------------------------
    def _ensure_units(
        self, n_slow: int, names: Optional[Sequence[str]] = None
    ) -> None:
        if len(self.units) < n_slow:
            cfgs = _as_seq(self._configs, n_slow, "MikuConfig")
            ests = _as_seq(self._est_configs, n_slow, "EstimatorConfig")
            for i in range(len(self.units), n_slow):
                tier = (
                    names[i] if names is not None and i < len(names)
                    else f"slow{i}"
                )
                self.units.append(SlowTierMiku(cfgs[i], ests[i], tier=tier))
        if names is not None:
            # Eagerly-created units learn their real tier name on the first
            # named window.
            for i in range(min(len(names), len(self.units))):
                self.units[i].tier = names[i]

    @property
    def config(self) -> MikuConfig:
        """Unit 0's ladder config (legacy single-ladder attribute)."""
        return self.units[0].config

    @property
    def estimator(self) -> LittlesLawEstimator:
        """Unit 0's estimator (legacy single-ladder attribute)."""
        return self.units[0].estimator

    # -- law entry points --------------------------------------------------
    def window(self, *deltas):
        """Canonical form: ``window(deltas)`` with one per-tier vector
        (:class:`~repro.core.littles_law.TierWindow` or any sequence of
        TierCounters, fast tier first) → :class:`TierDecisions`.

        The legacy ``window(fast_delta, slow_delta)`` two-argument form is
        deprecated but kept signature-compatible: it runs unit 0 and returns
        that unit's plain :class:`Decision`, exactly as the seed did.
        """
        if len(deltas) == 1 and not isinstance(deltas[0], TierCounters):
            return self.window_vector(deltas[0])
        if len(deltas) == 2:
            if not MikuController._warned_pair:
                MikuController._warned_pair = True
                warnings.warn(
                    "MikuController.window(fast_delta, slow_delta) is "
                    "deprecated; pass one per-tier TierWindow "
                    "(window(deltas)) instead",
                    DeprecationWarning,
                    stacklevel=2,
                )
            return self.pair_window(*deltas)
        raise TypeError(
            "MikuController.window expects one per-tier delta vector or "
            f"the legacy (fast, slow) pair; got {len(deltas)} argument(s)"
        )

    def pair_window(
        self, fast_delta: TierCounters, slow_delta: TierCounters
    ) -> Decision:
        """Drive unit 0 with one merged ``(fast, slow)`` window.

        The non-deprecated backing of the legacy two-argument form —
        :class:`MergedSlowPolicy` calls this to run the merged law without
        tripping the deprecation."""
        decision = self.units[0].window(fast_delta, slow_delta)
        self.decisions.append(decision)
        return decision

    def window_vector(
        self, deltas: Sequence[TierCounters]
    ) -> TierDecisions:
        """One window of the vector contract: per-tier deltas in, one
        :class:`Decision` per slow tier out (each unit sees the shared fast
        delta and its own tier's delta)."""
        fast, slows, slow_names = split_tier_window(deltas)
        self._ensure_units(len(slows), slow_names)
        decision = TierDecisions(
            tiers=slow_names,
            decisions=tuple(
                unit.window(fast, s)
                for unit, s in zip(self.units, slows)
            ),
        )
        self.decisions.append(decision)
        return decision

    def migration_budgets(self) -> Dict[str, int]:
        """Per-slow-tier migration budgets (tier name → allowed concurrent
        migration streams) from each ladder's current state — what a
        MIKU-coordinated tiering policy consults before enqueueing copies."""
        return {u.tier: u.migration_budget() for u in self.units}

    def reset(self) -> None:
        """Reset every per-tier unit and clear the decision history."""
        for unit in self.units:
            unit.reset()
        self.decisions.clear()


class MergedSlowPolicy:
    """Law adapter: the pre-vector merged-slow behavior, made explicit.

    Wraps a two-input ``(fast, slow)`` decision law (a
    :class:`MikuController`, whose unit 0 is used via :meth:`~MikuController.
    pair_window`, or any object with ``window(fast, slow)``).  Each window it
    folds tiers 1..n-1 of the per-tier vector into one merged slow delta,
    runs the wrapped law once, and broadcasts the single decision to every
    slow tier — exactly what the substrate hard-coded before the vector
    contract.  Kept as a first-class law so merged-vs-per-tier comparison
    scenarios (``corun3_pertier``) can run both under the same
    tier-addressed ``apply()``.
    """

    def __init__(self, law):
        self.law = law
        self.decisions: list = []

    def window(self, *deltas) -> TierDecisions:
        if len(deltas) == 1 and not isinstance(deltas[0], TierCounters):
            vec = deltas[0]
        else:
            vec = deltas
        fast, slows, slow_names = split_tier_window(vec)
        slow = merge_tier_counters(slows)
        pair = getattr(self.law, "pair_window", None)
        d = pair(fast, slow) if pair is not None else self.law.window(fast, slow)
        decision = TierDecisions(
            tiers=slow_names, decisions=(d,) * len(slow_names)
        )
        self.decisions.append(decision)
        return decision

    def reset(self) -> None:
        if hasattr(self.law, "reset"):
            self.law.reset()
        self.decisions.clear()


class VectorMikuLadder:
    """The MIKU decision law over ``(n_cells, n_units)`` state arrays.

    One window step for a whole sweep grid at once: every (cell, slow-tier)
    pair carries its own estimator EWMA, ladder level, rate and promotion
    state, and :meth:`window` advances all of them with numpy masks — the
    vectorized twin of driving one :class:`SlowTierMiku` per cell per tier.
    The state machine is *identical* to the scalar unit (same Eq.-1
    estimator, detection, hierarchical throttling, draining hysteresis and
    work-conserving promotion), so feeding both the same per-window counter
    sequences produces the same decision sequences
    (``tests/test_batched.py`` pins this with randomized traces).

    Built from per-(cell, unit) :class:`SlowTierMiku` instances via
    :meth:`from_units` — the batched sweep lane constructs those through the
    ordinary calibration factories
    (:func:`repro.memsim.calibration.default_miku` /
    :func:`~repro.memsim.calibration.merged_miku`), so calibration can never
    drift between lanes.  All ladders in one batch must share the same rung
    sequence (:class:`MikuConfig.levels`); heterogeneous-ladder jobs belong
    on the scalar lane.
    """

    def __init__(self, cells: int, units: int, levels: Sequence[int]):
        import numpy as np

        self._np = np
        self.cells = cells
        self.units = units
        self.levels_arr = np.asarray(levels, dtype=np.float64)
        self.n_levels = len(levels)
        shape = (cells, units)
        n_ops = len(OpClass)
        # Per-unit calibration (filled by from_units).
        self.t_fast = np.zeros(shape)
        self.slow_read_threshold = np.zeros(shape)
        self.write_scale = np.full(shape, 2.0)
        self.ewma_a = np.full(shape, 0.5)
        self.alpha_calm = np.full(shape, 0.97)
        self.min_window_inserts = np.full(shape, 16.0)
        self.min_slow_inserts = np.full(shape, 4.0)
        self.t_fast_scale = np.ones(shape + (n_ops,))
        self.class_caps = np.ones(shape + (n_ops,))
        self.min_rate = np.full(shape, 0.1)
        self.rate_backoff = np.full(shape, 0.5)
        self.rate_recover = np.full(shape, 2.0)
        self.patience = np.full(shape, 1.0)
        self.target_margin = np.full(shape, 0.85)
        self.drain_factor = np.full(shape, 0.9)
        self.fast_idle_alpha = np.full(shape, 0.02)
        # ACCESS_MIX weights, (n_ops,) each, in OpClass declaration order.
        ops = tuple(OpClass)
        self.mix_reads = np.asarray([ACCESS_MIX[c][0] for c in ops], float)
        self.mix_writes = np.asarray([ACCESS_MIX[c][1] for c in ops], float)
        self.reset()

    def reset(self) -> None:
        """Reset every (cell, unit) ladder/estimator to the initial state."""
        np = self._np
        shape = (self.cells, self.units)
        self.level = np.full(shape, self.n_levels - 1, dtype=np.int64)
        self.rate = np.ones(shape)
        self.calm = np.zeros(shape, dtype=np.int64)
        self.restricted = np.zeros(shape, dtype=bool)
        self.prev_raw = np.zeros(shape)
        self.has_prev = np.zeros(shape, dtype=bool)
        self.t_slow = np.zeros(shape)
        self.has_ewma = np.zeros(shape, dtype=bool)

    @classmethod
    def from_units(
        cls, unit_grid: Sequence[Sequence[Optional[SlowTierMiku]]]
    ) -> "VectorMikuLadder":
        """Stack per-cell lists of :class:`SlowTierMiku` (None pads inactive
        slots) into one vector ladder; every real unit must share the rung
        sequence."""
        import numpy as np

        cells = len(unit_grid)
        units = max((len(row) for row in unit_grid), default=0) or 1
        levels: Optional[Tuple[int, ...]] = None
        for row in unit_grid:
            for u in row:
                if u is None:
                    continue
                lv = tuple(u.config.levels)
                if levels is None:
                    levels = lv
                elif lv != levels:
                    raise ValueError(
                        "VectorMikuLadder requires one shared ladder rung "
                        f"sequence; got {levels} and {lv}"
                    )
        self = cls(cells, units, levels or MikuConfig().levels)
        ops = tuple(OpClass)
        for ci, row in enumerate(unit_grid):
            for ui, u in enumerate(row):
                if u is None:
                    continue
                cfg, est = u.config, u.estimator.config
                self.t_fast[ci, ui] = est.t_fast
                self.slow_read_threshold[ci, ui] = est.slow_read_threshold
                self.write_scale[ci, ui] = est.write_threshold_scale
                self.ewma_a[ci, ui] = est.ewma
                self.alpha_calm[ci, ui] = est.alpha_calm
                self.min_window_inserts[ci, ui] = est.min_window_inserts
                self.min_slow_inserts[ci, ui] = est.min_slow_inserts
                scales = est.t_fast_class_scale or {}
                self.t_fast_scale[ci, ui] = np.asarray(
                    [scales.get(c, 1.0) for c in ops]
                )
                self.class_caps[ci, ui] = np.asarray(
                    [cfg.class_caps.get(c, 1) for c in ops]
                )
                self.min_rate[ci, ui] = cfg.min_rate
                self.rate_backoff[ci, ui] = cfg.rate_backoff
                self.rate_recover[ci, ui] = cfg.rate_recover
                self.patience[ci, ui] = cfg.promote_patience
                self.target_margin[ci, ui] = cfg.target_margin
                self.drain_factor[ci, ui] = cfg.drain_factor
                self.fast_idle_alpha[ci, ui] = cfg.fast_idle_alpha
        return self

    def window(self, fast_ins, fast_occ, fast_cls, slow_ins, slow_occ,
               slow_cls) -> dict:
        """Advance every (cell, unit) ladder by one estimation window.

        ``fast_*`` are per-cell fast-tier window deltas (``fast_cls`` shaped
        ``(cells, n_ops)``); ``slow_*`` are per-(cell, unit) deltas
        (``slow_cls`` shaped ``(cells, units, n_ops)``).  Returns the
        decision arrays plus the estimate fields the scalar law exposes via
        :class:`~repro.core.littles_law.TierEstimate` — ``cap`` is +inf for
        unrestricted (cell, unit) pairs.
        """
        np = self._np
        f_ins = np.asarray(fast_ins, float)[:, None]
        f_occ = np.asarray(fast_occ, float)[:, None]
        f_cls = np.asarray(fast_cls, float)[:, None, :]
        slow_ins = np.asarray(slow_ins, float)
        slow_occ = np.asarray(slow_occ, float)
        slow_cls = np.asarray(slow_cls, float)

        # -- estimator (LittlesLawEstimator.update, vectorized) ------------
        total_ins = f_ins + slow_ins
        total_occ = f_occ + slow_occ
        reads = (slow_cls * self.mix_reads).sum(-1)
        writes = (slow_cls * self.mix_writes).sum(-1)
        tot_rw = reads + writes
        rf = np.where(tot_rw > 0, reads / np.maximum(tot_rw, 1e-300), 1.0)
        wf = np.where(tot_rw > 0, writes / np.maximum(tot_rw, 1e-300), 0.0)
        threshold = self.slow_read_threshold * (rf + wf * self.write_scale)
        num = (f_cls * self.t_fast_scale).sum(-1)
        den = np.maximum(f_cls.sum(-1), 1.0)
        t_fast = np.where(f_ins > 0, self.t_fast * num / den, self.t_fast)
        valid = (total_ins >= self.min_window_inserts) & (
            slow_ins >= self.min_slow_inserts
        )
        t_avg = np.where(
            total_ins > 0, total_occ / np.maximum(total_ins, 1e-300), 0.0
        )
        alpha_v = f_ins / np.maximum(total_ins, 1e-300)
        alpha = np.where(valid, alpha_v, np.where(slow_ins == 0, 1.0, 0.0))
        slow_mean = np.where(
            slow_ins > 0, slow_occ / np.maximum(slow_ins, 1e-300), 0.0
        )
        raw_eq1 = (t_avg - alpha * t_fast) / np.maximum(1.0 - alpha, 1e-12)
        raw = np.maximum(np.where(alpha > self.alpha_calm, slow_mean,
                                  raw_eq1), 0.0)
        raw = np.where(valid, raw, 0.0)
        upd = np.where(
            self.has_ewma,
            self.ewma_a * raw + (1.0 - self.ewma_a) * self.t_slow,
            raw,
        )
        self.t_slow = np.where(valid, upd, self.t_slow)
        self.has_ewma = self.has_ewma | valid
        backlogged = valid & (self.t_slow > threshold)

        # -- ladder (SlowTierMiku.window, vectorized) ----------------------
        was_restricted = self.restricted
        demote_unres = ~was_restricted & backlogged
        fast_idle = (~valid & (f_ins == 0)) | (
            valid & (alpha < self.fast_idle_alpha)
        )
        release = was_restricted & fast_idle
        over = was_restricted & ~fast_idle & valid & (raw > threshold)
        draining = over & self.has_prev & (
            raw < self.prev_raw * self.drain_factor
        )
        demote_again = over & ~draining & (self.level > 0)
        back_off = over & ~draining & (self.level == 0)
        under = (
            was_restricted & ~fast_idle & ~over & valid
            & (raw < self.target_margin * threshold)
        )
        hold = was_restricted & ~fast_idle & ~over & ~under

        calm = np.where(over | hold, 0, self.calm)
        calm = np.where(under, calm + 1, calm)
        do_promote = under & (calm >= self.patience)
        calm = np.where(do_promote | release | demote_unres, 0, calm)
        recover = do_promote & (self.rate < 1.0)
        promote = do_promote & (self.rate >= 1.0)
        present = slow_cls > 0
        caps_masked = np.where(present, self.class_caps, np.inf)
        class_cap = np.where(
            present.any(-1), caps_masked.min(-1), self.levels_arr[-1]
        )
        nxt = self.level + 1
        nxt_val = self.levels_arr[np.minimum(nxt, self.n_levels - 1)]
        can = (nxt < self.n_levels) & (
            nxt_val <= np.maximum(class_cap, self.levels_arr[0])
        )

        level = np.where(demote_unres | demote_again, 0, self.level)
        level = np.where(release, self.n_levels - 1, level)
        level = np.where(promote & can, self.level + 1, level)
        rate = np.where(demote_unres | release, 1.0, self.rate)
        rate = np.where(
            back_off, np.maximum(self.min_rate, self.rate * self.rate_backoff),
            rate,
        )
        rate = np.where(
            recover, np.minimum(1.0, self.rate * self.rate_recover), rate
        )
        restricted = (was_restricted | demote_unres) & ~release

        self.level, self.rate, self.calm = level, rate, calm
        self.restricted = restricted
        self.prev_raw = np.where(valid, raw, self.prev_raw)
        self.has_prev = self.has_prev | valid

        return {
            "cap": np.where(restricted, self.levels_arr[level], np.inf),
            "rate": np.where(restricted, rate, 1.0),
            "restricted": restricted,
            "t_avg": t_avg,
            "alpha": alpha,
            "t_slow": self.t_slow.copy(),
            "t_slow_raw": raw,
            "threshold": threshold,
            "backlogged": backlogged,
            "valid": valid,
        }

    def migration_budgets(self) -> Any:
        """Per-(cell, unit) migration budgets from the current ladder state —
        the vectorized twin of :meth:`SlowTierMiku.migration_budget`: the
        MIGRATE class cap while unrestricted, zero once fine-grained rate
        control has engaged, otherwise the current level bounded by that
        cap.  Call after :meth:`window` to read the post-window state the
        scalar hook sees."""
        np = self._np
        mig = tuple(OpClass).index(OpClass.MIGRATE)
        cap = self.class_caps[:, :, mig]
        lvl = self.levels_arr[self.level]
        return np.where(
            ~self.restricted,
            cap,
            np.where(self.rate < 1.0, 0.0, np.minimum(cap, lvl)),
        ).astype(np.int64)


# ---------------------------------------------------------------------------
# Straggler governor — the same estimator applied to per-host step service
# times (DESIGN.md §5).  A slow host is "an overloaded slow tier": its step
# service time is estimated per window; hosts whose estimate exceeds the
# threshold get their input shard rate-capped / redispatched by the launcher.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HostHealth:
    host: int
    t_step: float
    healthy: bool
    rate_factor: float


class StragglerGovernor:
    """Detect and mitigate straggler hosts via service-time estimation.

    ``threshold_scale`` x median step time flags a straggler; mitigation
    follows MIKU's ladder: first cap the straggler's microbatch share
    (rate_factor), then exclude it (rate 0 ⇒ its shard is redispatched to
    healthy hosts) if it keeps degrading.  Recovery is gradual, mirroring the
    work-conserving promotion.
    """

    def __init__(
        self,
        n_hosts: int,
        threshold_scale: float = 1.35,
        ewma: float = 0.4,
        patience: int = 2,
    ):
        self.n_hosts = n_hosts
        self.threshold_scale = threshold_scale
        self.ewma = ewma
        self.patience = patience
        self._t = [0.0] * n_hosts
        self._bad_windows = [0] * n_hosts
        self._rate = [1.0] * n_hosts

    def window(self, step_times: Sequence[float]) -> list:
        require(
            len(step_times) == self.n_hosts,
            "host-count",
            "one step time per host required",
            expected=self.n_hosts,
            got=len(step_times),
        )
        for h, t in enumerate(step_times):
            if t <= 0:  # host missed the window entirely: worst signal
                self._bad_windows[h] += 1
                continue
            self._t[h] = (
                t if self._t[h] == 0.0 else self.ewma * t + (1 - self.ewma) * self._t[h]
            )
        alive = sorted(t for t in self._t if t > 0)
        if not alive:
            return [HostHealth(h, 0.0, True, 1.0) for h in range(self.n_hosts)]
        median = alive[len(alive) // 2]
        threshold = self.threshold_scale * median
        out = []
        for h in range(self.n_hosts):
            if self._t[h] > threshold:
                self._bad_windows[h] += 1
                if self._bad_windows[h] >= self.patience:
                    # Demote: halve its shard; floor at exclusion.
                    self._rate[h] = 0.0 if self._rate[h] <= 0.25 else self._rate[h] / 2
            else:
                self._bad_windows[h] = 0
                if self._rate[h] < 1.0:
                    self._rate[h] = min(1.0, max(self._rate[h], 0.25) * 2)
            out.append(
                HostHealth(
                    host=h,
                    t_step=self._t[h],
                    healthy=self._rate[h] >= 1.0,
                    rate_factor=self._rate[h],
                )
            )
        return out

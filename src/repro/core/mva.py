"""Approximate Mean-Value Analysis of the tiered-memory queueing network, in JAX.

A differentiable analytical counterpart to the DES (:mod:`repro.core.des`):
a closed queueing network with two memory stations (fast / slow), a delay
stage (the non-slot-occupying pipeline/bus flight), and the shared tracking
pool (ToR) as a population constraint.  Two customer classes — fast-bound and
slow-bound request streams — each with its own population (threads x MLP).

Uses the multi-server approximation R = s * (1 + Q / c) (Seidmann/Schweitzer
style) iterated to a fixed point with ``jax.lax.while_loop``.  Being pure JAX
it is: (a) fast enough for dense sweeps (the DES cross-validates it), (b)
differentiable, so MIKU-style controllers can gradient-search issue rates,
and (c) vmappable over populations for the Fig. 9 service-time curves.

Accuracy note: approximate MVA ignores the FIFO head-of-line coupling that
produces the *unfairness* (that is inherently a transient/discipline effect —
the DES owns it).  MVA is used for per-tier loaded service times and
throughput ceilings, where it tracks the DES within a few percent.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.device_model import DeviceModel, PlatformModel
from repro.core.littles_law import OpClass


@dataclasses.dataclass(frozen=True)
class MvaResult:
    throughput_fast: jax.Array  # macro-requests / ns
    throughput_slow: jax.Array
    residency_fast: jax.Array  # ns at the station (incl. queueing), + pipeline
    residency_slow: jax.Array
    bandwidth_fast_gbps: jax.Array
    bandwidth_slow_gbps: jax.Array


def _station_params(dev: DeviceModel, op: OpClass, granularity: int):
    service = dev.service_ns(op) * granularity  # slot time per macro request
    return service, float(dev.total_slots), dev.pipeline_ns


@partial(jax.jit, static_argnames=("granularity", "max_iter"))
def solve(
    n_fast: jax.Array,
    n_slow: jax.Array,
    fast_service: jax.Array,
    fast_slots: jax.Array,
    fast_pipeline: jax.Array,
    slow_service: jax.Array,
    slow_slots: jax.Array,
    slow_pipeline: jax.Array,
    tor_entries: jax.Array,
    granularity: int = 4,
    max_iter: int = 200,
):
    """Fixed-point iteration of two-class approximate MVA.

    Populations are first scaled down proportionally if their sum exceeds the
    ToR pool (the shared-structure constraint): a request not holding a ToR
    entry cannot be in service anywhere.
    """
    n_total = n_fast + n_slow
    scale = jnp.minimum(1.0, tor_entries / jnp.maximum(n_total, 1e-9))
    n_f = n_fast * scale
    n_s = n_slow * scale

    def body(state):
        q_f, q_s, _, _ = state
        # Residency at each station with the multi-server correction: a
        # request arriving sees the current queue; below c servers there is
        # no wait.
        r_f = fast_service * (1.0 + jnp.maximum(q_f - fast_slots, 0.0) / fast_slots)
        r_s = slow_service * (1.0 + jnp.maximum(q_s - slow_slots, 0.0) / slow_slots)
        x_f = n_f / (r_f + fast_pipeline)
        x_s = n_s / (r_s + slow_pipeline)
        new_q_f = x_f * r_f
        new_q_s = x_s * r_s
        # Damping for stability.
        q_f2 = 0.5 * q_f + 0.5 * new_q_f
        q_s2 = 0.5 * q_s + 0.5 * new_q_s
        return (q_f2, q_s2, x_f, x_s)

    def cond(state_iter):
        state, i = state_iter
        return i < max_iter

    def loop(state_iter):
        state, i = state_iter
        return (body(state), i + 1)

    init = (n_f * 0.5, n_s * 0.5, jnp.zeros_like(n_f), jnp.zeros_like(n_s))
    (q_f, q_s, x_f, x_s), _ = jax.lax.while_loop(cond, loop, (init, 0))
    # Throughputs are additionally capped by station service capacity.
    x_f = jnp.minimum(x_f, fast_slots / fast_service)
    x_s = jnp.minimum(x_s, slow_slots / slow_service)
    r_f = jnp.where(x_f > 0, q_f / jnp.maximum(x_f, 1e-12), fast_service)
    r_s = jnp.where(x_s > 0, q_s / jnp.maximum(x_s, 1e-12), slow_service)
    return x_f, x_s, r_f + fast_pipeline, r_s + slow_pipeline


def analyze(
    platform: PlatformModel,
    op: OpClass,
    fast_threads: int,
    slow_threads: int,
    *,
    mlp: int = 160,
    granularity: int = 4,
) -> MvaResult:
    """Convenience wrapper in the DES's units (threads x MLP populations)."""
    g = granularity
    f_svc, f_slots, f_pipe = _station_params(platform.ddr, op, g)
    s_svc, s_slots, s_pipe = _station_params(platform.cxl, op, g)
    n_f = jnp.asarray(fast_threads * mlp / g, dtype=jnp.float32)
    n_s = jnp.asarray(slow_threads * mlp / g, dtype=jnp.float32)
    x_f, x_s, r_f, r_s = solve(
        n_f,
        n_s,
        jnp.asarray(f_svc, jnp.float32),
        jnp.asarray(f_slots, jnp.float32),
        jnp.asarray(f_pipe, jnp.float32),
        jnp.asarray(s_svc, jnp.float32),
        jnp.asarray(s_slots, jnp.float32),
        jnp.asarray(s_pipe, jnp.float32),
        jnp.asarray(platform.tor_entries / g, jnp.float32),
        granularity=g,
    )
    bytes_per_macro_f = platform.ddr.access_bytes * g
    bytes_per_macro_s = platform.cxl.access_bytes * g
    return MvaResult(
        throughput_fast=x_f,
        throughput_slow=x_s,
        residency_fast=r_f,
        residency_slow=r_s,
        bandwidth_fast_gbps=x_f * bytes_per_macro_f,
        bandwidth_slow_gbps=x_s * bytes_per_macro_s,
    )

"""Structured invariant errors for the simulation sanitizer layer.

This is the one leaf module both the core engines and the analysis
subsystem share: :class:`InvariantViolation` is what every sanitizer check
raises (carrying the window index and station/link context the golden
tests never surface), and :func:`require` replaces bare ``assert``
statements on correctness-critical paths — unlike ``assert``, it survives
``python -O``.

The module deliberately imports nothing from the rest of the package so
``repro.core`` never gains a dependency on ``repro.analysis``; the
analysis package re-exports these names.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional


class InvariantViolation(RuntimeError):
    """A mechanically-checked simulation invariant failed.

    Attributes carry the context a raw assert loses: which named check
    fired (``check``), at which control window (``window``), at which
    station/link (``station``), plus free-form key/value context.
    """

    def __init__(
        self,
        check: str,
        message: str,
        *,
        window: Optional[int] = None,
        station: Optional[Any] = None,
        context: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.check = check
        self.window = window
        self.station = station
        self.context = dict(context or {})
        parts = [f"[{check}]"]
        if window is not None:
            parts.append(f"window {window}")
        if station is not None:
            parts.append(f"station {station}")
        head = " ".join(parts)
        detail = ""
        if self.context:
            detail = " (" + ", ".join(
                f"{k}={v!r}" for k, v in sorted(self.context.items())
            ) + ")"
        super().__init__(f"{head}: {message}{detail}")

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe form for ``SimResult.sanitizer`` / telemetry."""
        return {
            "check": self.check,
            "window": self.window,
            "station": self.station,
            "message": str(self),
            "context": dict(self.context),
        }


def require(
    cond: bool,
    check: str,
    message: str,
    *,
    window: Optional[int] = None,
    station: Optional[Any] = None,
    **context: Any,
) -> None:
    """``assert`` that ``python -O`` cannot strip: raise a structured
    :class:`InvariantViolation` when ``cond`` is false."""
    if not cond:
        raise InvariantViolation(
            check, message, window=window, station=station, context=context
        )


def sanitize_enabled() -> Optional[str]:
    """The process-wide sanitizer switch (``REPRO_SANITIZE``).

    Returns None when unset/empty/``0``; the string ``"record"`` selects
    record-only mode (violations accumulate into ``SimResult.sanitizer``
    instead of raising); any other value means raise-on-violation.
    """
    val = os.environ.get("REPRO_SANITIZE", "").strip()
    if val in ("", "0"):
        return None
    return "record" if val.lower() == "record" else "raise"

"""Little's-Law service-time estimation — MIKU's measurement half (§5.2, Eq. 1).

The paper measures two cumulative uncore events on Intel EMR:

  * ``UNC_CHA_TOR_INSERTS.all``   — requests inserted into the Table of Requests (ToR)
  * ``UNC_CHA_TOR_OCCUPANCY.all`` — active ToR entries, accumulated per cycle

and derives the average memory service time of all requests currently flowing
through the shared queue:

    T_avg = ToR.Occupancy / ToR.Inserts
          = alpha% * T_ddr + (1 - alpha%) * T_cxl                      (Eq. 1)

With ``T_ddr`` measured offline (the paper treats it as a constant — DDR never
backlogs the ToR) and ``alpha`` tracked from per-tier request counts, MIKU
solves Eq. 1 for ``T_cxl`` and compares it against a calibrated threshold.

This module is the exact, hardware-agnostic version of that estimator.  The
"ToR" here is whatever shared request-tracking structure the embedding system
has: the DES's ToR pool, the serving engine's transfer/batch-slot queue, or a
launcher's per-host step pipeline (straggler governor).  Counters are
maintained by the embedding system via :class:`TierCounters`; the estimator is
pure arithmetic over counter snapshots and therefore unit-testable in
isolation.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Optional, Sequence, Tuple


class OpClass(enum.Enum):
    """Memory instruction classes from the paper's bw-test (§3, §5.2).

    * ``LOAD``     — pure reads.
    * ``STORE``    — ordinary stores: read-modify-write, i.e. one read + one
      write per retired store (paper: "involve an equal number of reads and
      writes").
    * ``NT_STORE`` — non-temporal stores: write-only streams.
    * ``MIGRATE``  — page-migration traffic (the tiering subsystem's
      promotion/demotion copies): each migrated line is read at the source
      tier and written at the destination, so one retired migration request
      carries a read + a write over the slow link — a best-effort request
      class the control plane may budget separately from demand traffic.
    """

    LOAD = "load"
    STORE = "store"
    NT_STORE = "nt_store"
    MIGRATE = "migrate"


#: The application-issued instruction classes (what bw-tests and workload op
#: grids enumerate).  MIGRATE is engine-generated background traffic, never a
#: demand-workload op — keep it out of figure matrices.
DEMAND_CLASSES = (OpClass.LOAD, OpClass.STORE, OpClass.NT_STORE)

#: Device-level accesses generated per retired request of each class
#: (reads, writes) — used both by the device models and by the threshold
#: calibration (paper footnote 2: write threshold ~ 2x read threshold).
ACCESS_MIX: Dict[OpClass, tuple] = {
    OpClass.LOAD: (1, 0),
    OpClass.STORE: (1, 1),
    OpClass.NT_STORE: (0, 1),
    OpClass.MIGRATE: (1, 1),
}


@dataclasses.dataclass
class TierCounters:
    """Cumulative counters for one memory tier, mirroring the uncore events.

    ``occupancy_time`` integrates (entries-in-flight x dt) — the continuous
    analogue of per-cycle ToR occupancy accumulation.  ``inserts`` counts
    completed insertions.  Per-class counts drive the alpha decomposition and
    the read/write-weighted threshold.
    """

    inserts: int = 0
    occupancy_time: float = 0.0  # entry-seconds (or entry-cycles)
    class_counts: Dict[OpClass, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in OpClass}
    )

    def record(self, op: OpClass, residency: float) -> None:
        """Record one request that held a shared-queue entry for ``residency``."""
        self.inserts += 1
        self.occupancy_time += residency
        self.class_counts[op] += 1

    def merge(self, other: "TierCounters") -> None:
        """Accumulate ``other``'s counts into this counter, in place."""
        self.inserts += other.inserts
        self.occupancy_time += other.occupancy_time
        # .get: counters deserialized from traces recorded before a class
        # existed (e.g. MIGRATE) simply lack that key — treat as zero.
        for c in OpClass:
            self.class_counts[c] = (
                self.class_counts.get(c, 0) + other.class_counts.get(c, 0)
            )

    def snapshot(self) -> "TierCounters":
        """An independent copy, for later :meth:`delta` marks."""
        return TierCounters(
            inserts=self.inserts,
            occupancy_time=self.occupancy_time,
            class_counts=dict(self.class_counts),
        )

    def delta(self, since: "TierCounters") -> "TierCounters":
        """Counters accumulated since an earlier snapshot (window counters)."""
        return TierCounters(
            inserts=self.inserts - since.inserts,
            occupancy_time=self.occupancy_time - since.occupancy_time,
            class_counts={
                c: self.class_counts.get(c, 0) - since.class_counts.get(c, 0)
                for c in OpClass
            },
        )

    @property
    def mean_service_time(self) -> float:
        if self.inserts == 0:
            return 0.0
        return self.occupancy_time / self.inserts

    def read_write_fractions(self) -> tuple:
        """(read_fraction, write_fraction) of device-level accesses."""
        reads = writes = 0
        for c, n in self.class_counts.items():
            r, w = ACCESS_MIX[c]
            reads += r * n
            writes += w * n
        total = reads + writes
        if total == 0:
            return (1.0, 0.0)
        return (reads / total, writes / total)


def linear_percentile(sorted_xs: "Sequence[float]", q: float) -> float:
    """Order statistic with linear interpolation (numpy's default rule).

    ``sorted_xs`` must be sorted ascending.  The rank is ``q * (n - 1)``;
    a fractional rank interpolates linearly between the two bracketing
    order statistics.  This is the percentile rule shared by the latency
    reservoir (:meth:`repro.core.des.WorkloadStats.percentile_ns`) and the
    :class:`repro.obs.histogram.LatencyHistogram` read-back, so the two
    are comparable within the histogram's bucket tolerance.
    """
    n = len(sorted_xs)
    if n == 0:
        return 0.0
    r = min(max(q, 0.0), 1.0) * (n - 1)
    lo = int(r)
    if lo >= n - 1:
        return float(sorted_xs[-1])
    frac = r - lo
    a = float(sorted_xs[lo])
    return a + (float(sorted_xs[lo + 1]) - a) * frac


def merge_tier_counters(counters: "Sequence[TierCounters]") -> "TierCounters":
    """Fold several per-tier window deltas into one merged delta.

    Pure (non-mutating) counterpart of :meth:`TierCounters.merge`; the merge
    is associative and commutative (plain sums), which is what lets the
    legacy merged-slow contract be recovered exactly from a per-tier vector
    (see :class:`repro.core.controller.MergedSlowPolicy`).
    """
    out = TierCounters()
    for tc in counters:
        out.merge(tc)
    return out


class TierWindow(tuple):
    """One window's ordered per-tier counter deltas (fast tier first).

    The canonical payload of the vector control-plane contract: a tuple of
    :class:`TierCounters` — one per platform tier, in platform order — with
    the tier names carried alongside in :attr:`names`.  Substrates return it
    from ``counters_delta()``; :class:`~repro.core.substrate.ControlLoop`
    hands it *whole* to the decision law's ``window(deltas)`` (a plain tuple
    is still splatted into ``window(*delta)`` for non-tier laws such as the
    straggler governor).
    """

    def __new__(
        cls,
        counters: "Sequence[TierCounters]",
        names: Optional["Sequence[str]"] = None,
    ) -> "TierWindow":
        self = super().__new__(cls, tuple(counters))
        if names is None:
            names = tuple(f"tier{i}" for i in range(len(self)))
        names = tuple(names)
        if len(names) != len(self):
            raise ValueError(
                f"TierWindow got {len(self)} counter(s) but "
                f"{len(names)} name(s)"
            )
        self._names = names
        return self

    def __reduce__(self):
        return (TierWindow, (tuple(self), self._names))

    @property
    def names(self) -> Tuple[str, ...]:
        return self._names

    @property
    def fast(self) -> TierCounters:
        return self[0]

    @property
    def slow_names(self) -> Tuple[str, ...]:
        return self._names[1:]

    def merged_slow(self) -> TierCounters:
        """Tiers 1..n-1 folded into one delta — the legacy slow window."""
        return merge_tier_counters(self[1:])

    @classmethod
    def zero(cls, names: "Sequence[str]") -> "TierWindow":
        """The identity window: one empty TierCounters per named tier."""
        return cls(tuple(TierCounters() for _ in names), tuple(names))

    def merge(self, other: "TierWindow") -> "TierWindow":
        """Element-wise fold of two windows over the *same* tier set.

        Aggregating windows across runs/processes only makes sense when the
        tier vectors describe the same platform, so a name mismatch is a
        loud error rather than a silent positional fold.  Merging with
        :meth:`zero` is the identity (pinned in tests/test_pertier.py).
        """
        if self.names != other.names:
            raise ValueError(
                f"cannot merge TierWindows over different tier sets: "
                f"{self.names} vs {other.names}"
            )
        return TierWindow(
            tuple(merge_tier_counters((a, b)) for a, b in zip(self, other)),
            self.names,
        )


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    """Calibration for the estimator (paper §5.2, measured offline).

    ``t_fast`` is the fast-tier (DDR) service time for pure loads *under
    load* — the paper measures it offline with a saturating bw-test and
    treats it as constant ("DDR never caused a backlog in the ToR").
    ``t_fast_class_scale`` adjusts it for the instruction mix (stores are
    read-modify-write and occupy the queue longer).  The slow-tier backlog
    threshold is expressed for pure reads; writes use
    ``write_threshold_scale`` x that (paper footnote 2: ~2x), and mixed
    windows interpolate by the read/write access fractions.

    Eq. 1 becomes ill-conditioned as alpha -> 1 (almost no slow-tier
    traffic): the (1 - alpha) denominator amplifies any t_fast calibration
    residue into nonsense.  Above ``alpha_calm`` the estimator therefore
    falls back to the slow tier's *direct* windowed residency (on Intel
    derivable from IMC RPQ/WPQ occupancy counters; in our substrates the
    engine keeps exact per-tier counters) — physically, a slow tier
    receiving a negligible share of inserts cannot be monopolizing the
    shared queue.
    """

    t_fast: float  # offline-measured loaded fast-tier service time
    slow_read_threshold: float  # backlog threshold for slow-tier reads
    write_threshold_scale: float = 2.0
    ewma: float = 0.5  # smoothing for windowed estimates
    min_window_inserts: int = 16  # below this, a window is not trustworthy
    min_slow_inserts: int = 4  # need at least this many slow retires
    alpha_calm: float = 0.97  # above this fast share, use direct slow counters
    #: Per-class multipliers on t_fast (from the device model's read/write
    #: service asymmetry); None = loads only.
    t_fast_class_scale: Optional[Dict["OpClass", float]] = None


@dataclasses.dataclass
class TierEstimate:
    """One estimation window's output."""

    t_avg: float  # Eq.1 LHS: occupancy/inserts over both tiers
    alpha: float  # fast-tier share of inserts
    t_slow: float  # solved slow-tier service time (EWMA-smoothed)
    t_slow_raw: float  # unsmoothed per-window estimate
    threshold: float  # mix-adjusted backlog threshold for this window
    backlogged: bool  # t_slow > threshold
    valid: bool  # window had enough samples to trust


class LittlesLawEstimator:
    """Decompose shared-queue occupancy into per-tier service times (Eq. 1).

    Usage: the embedding system keeps one :class:`TierCounters` per tier and
    periodically calls :meth:`update` with window deltas.  The estimator
    solves ``T_slow`` and flags backlog.  It never throttles anything itself —
    that is :class:`repro.core.controller.MikuController`'s job.
    """

    def __init__(self, config: EstimatorConfig):
        self.config = config
        self._t_slow_ewma: Optional[float] = None
        self.history: list = []  # list[TierEstimate], for diagnostics

    def reset(self) -> None:
        """Forget the EWMA state and the estimate history."""
        self._t_slow_ewma = None
        self.history.clear()

    def threshold_for_mix(self, slow_window: TierCounters) -> float:
        """Interpolate the backlog threshold by the window's read/write mix.

        Paper: CXL write latency ~= 2x read latency at equal concurrency, and
        the write threshold is ~2x the read threshold; ordinary stores behave
        like the average of a read and a write.  Weighting the read threshold
        by the device-level access mix reproduces exactly that calibration:
        pure loads -> thr, nt-stores -> 2*thr, stores -> 1.5*thr.
        """
        rf, wf = slow_window.read_write_fractions()
        scale = rf * 1.0 + wf * self.config.write_threshold_scale
        return self.config.slow_read_threshold * scale

    def t_fast_for_mix(self, fast_window: TierCounters) -> float:
        """t_fast adjusted for the fast window's instruction-class mix."""
        scales = self.config.t_fast_class_scale
        if not scales or fast_window.inserts == 0:
            return self.config.t_fast
        total = num = 0
        for c, n in fast_window.class_counts.items():
            num += n * scales.get(c, 1.0)
            total += n
        return self.config.t_fast * (num / max(total, 1))

    def update(
        self, fast_window: TierCounters, slow_window: TierCounters
    ) -> TierEstimate:
        """Solve Eq. 1 for one window's ``(fast, slow)`` counter deltas,
        returning the smoothed :class:`TierEstimate` (and appending it to
        :attr:`history`)."""
        cfg = self.config
        total_inserts = fast_window.inserts + slow_window.inserts
        total_occ = fast_window.occupancy_time + slow_window.occupancy_time
        threshold = self.threshold_for_mix(slow_window)

        if (
            total_inserts < cfg.min_window_inserts
            or slow_window.inserts < cfg.min_slow_inserts
        ):
            # Not enough slow-tier traffic to estimate: decay towards "no
            # backlog" so a quiet tier is eventually unthrottled.
            est = TierEstimate(
                t_avg=total_occ / total_inserts if total_inserts else 0.0,
                alpha=1.0 if slow_window.inserts == 0 else 0.0,
                t_slow=self._t_slow_ewma or 0.0,
                t_slow_raw=0.0,
                threshold=threshold,
                backlogged=False,
                valid=False,
            )
            self.history.append(est)
            return est

        t_avg = total_occ / total_inserts
        alpha = fast_window.inserts / total_inserts
        if alpha > cfg.alpha_calm:
            # Ill-conditioned corner of Eq. 1: use the slow tier's directly
            # measured window residency instead of the decomposition.
            t_slow_raw = slow_window.mean_service_time
        else:
            # Eq. 1 solved for T_slow.
            t_slow_raw = (t_avg - alpha * self.t_fast_for_mix(fast_window)) / (
                1.0 - alpha
            )
        # Mixed queues can transiently yield estimates below the physical
        # floor; clamp at zero (a *negative* service time is measurement
        # noise, not information).
        t_slow_raw = max(t_slow_raw, 0.0)

        if self._t_slow_ewma is None:
            self._t_slow_ewma = t_slow_raw
        else:
            a = cfg.ewma
            self._t_slow_ewma = a * t_slow_raw + (1.0 - a) * self._t_slow_ewma

        est = TierEstimate(
            t_avg=t_avg,
            alpha=alpha,
            t_slow=self._t_slow_ewma,
            t_slow_raw=t_slow_raw,
            threshold=threshold,
            backlogged=self._t_slow_ewma > threshold,
            valid=True,
        )
        self.history.append(est)
        return est

    def growth_rate(self, n: int = 3) -> float:
        """Geometric growth of recent raw estimates — the paper triggers on a
        threshold crossing *that keeps growing exponentially* (device-side
        queueing).  Returns ~1.0 when flat; >1 when growing."""
        valid = [h.t_slow_raw for h in self.history if h.valid and h.t_slow_raw > 0]
        if len(valid) < n + 1:
            return 1.0
        window = valid[-(n + 1):]
        ratios = [b / a for a, b in zip(window, window[1:]) if a > 0]
        if not ratios:
            return 1.0
        return math.exp(sum(math.log(max(r, 1e-9)) for r in ratios) / len(ratios))

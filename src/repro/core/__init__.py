# The paper's primary contribution: tiered-memory characterization substrate
# (device models, DES, MVA) + MIKU dynamic memory request control
# (Little's-Law estimator + hierarchical throttle controller), plus the
# TPU-native tier/offload runtime they govern.

from repro.core.controller import (
    Decision,
    MergedSlowPolicy,
    MikuConfig,
    MikuController,
    Phase,
    SlowTierMiku,
    StragglerGovernor,
    TierDecisions,
)
from repro.core.des import SimResult, TieredMemorySim, WorkloadSpec
from repro.core.des import validate_workloads
from repro.core.device_model import (
    CXL_DEVICE,
    CXL_SWITCH_DEVICE,
    DDR5_DIMM,
    DDR_REMOTE_DIMM,
    DeviceModel,
    PlatformModel,
    PLATFORMS,
    UnknownTierError,
    platform_a,
    platform_a_numa,
    platform_a_switch,
    platform_b,
    tpu_host_platform,
)
from repro.core.littles_law import (
    EstimatorConfig,
    LittlesLawEstimator,
    OpClass,
    TierCounters,
    TierEstimate,
    TierWindow,
    merge_tier_counters,
)
from repro.core.offload import HostOffloader, TransferQueue
from repro.core.substrate import (
    ControlLoop,
    MemorySubstrate,
    ReplaySubstrate,
    StepTimingSubstrate,
    TierSetWindowedCounters,
    WindowedCounters,
    WindowRecord,
    window_record_jsonable,
)
from repro.core.tiers import (
    HBM_TIER,
    HOST_TIER,
    TieredLayout,
    TierSpec,
    host_offload_supported,
)

__all__ = [
    "Decision",
    "MergedSlowPolicy",
    "MikuConfig",
    "MikuController",
    "Phase",
    "SlowTierMiku",
    "StragglerGovernor",
    "TierDecisions",
    "SimResult",
    "TieredMemorySim",
    "WorkloadSpec",
    "validate_workloads",
    "CXL_DEVICE",
    "CXL_SWITCH_DEVICE",
    "DDR5_DIMM",
    "DDR_REMOTE_DIMM",
    "DeviceModel",
    "PlatformModel",
    "PLATFORMS",
    "UnknownTierError",
    "platform_a",
    "platform_a_numa",
    "platform_a_switch",
    "platform_b",
    "tpu_host_platform",
    "EstimatorConfig",
    "LittlesLawEstimator",
    "OpClass",
    "TierCounters",
    "TierEstimate",
    "TierWindow",
    "merge_tier_counters",
    "HostOffloader",
    "TransferQueue",
    "ControlLoop",
    "MemorySubstrate",
    "ReplaySubstrate",
    "StepTimingSubstrate",
    "TierSetWindowedCounters",
    "WindowedCounters",
    "WindowRecord",
    "window_record_jsonable",
    "HBM_TIER",
    "HOST_TIER",
    "TieredLayout",
    "TierSpec",
    "host_offload_supported",
]

"""Batched LLM serving engine with tiered placement and MIKU admission
control — the TPU deployment of the paper's §6 case study.

Architecture
------------
* :class:`ServingEngine` — one model instance: continuous batching over a
  fixed slot array, real jitted prefill/decode steps, per-slot lengths.
  The instance's *placement* decides which memory tier its weights and KV
  live on: ``device`` (HBM — the DDR analogue) or ``host`` (pinned host
  memory over PCIe — the CXL analogue).  Host-placed state is genuinely put
  on the host memory space when the backend supports it.

* :class:`TieredServingCluster` — co-locates several engines on one chip's
  shared transfer path (:class:`repro.core.offload.TransferQueue`).  Every
  decode step charges the queue its tier traffic: HBM-resident steps
  account fast-tier bytes (weights + KV read once per token — the
  memory-bound decode reality); host-resident steps *submit* their weight/KV
  stream as slow-tier transfers on the queue's "slow" link.  A MIKU
  controller attached to the queue watches the same per-tier Little's-Law
  counters (the :class:`~repro.core.littles_law.TierWindow` vector
  contract) as on the x86 platforms and throttles each slow link's
  concurrency via tier-addressed decisions — reproducing Figure 11/12's
  DataRacing -> MIKU recovery end to end with real model math and modeled
  PCIe timing (this container has no TPU; DESIGN.md §2).

The wall-clock of the cluster is the simulated queue clock; model outputs
(tokens) are real.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import MikuController
from repro.core.littles_law import OpClass
from repro.core.offload import TransferQueue
from repro.core.tiers import HBM_TIER, HOST_TIER, host_offload_supported
from repro.models.transformer import DecodeState, ModelConfig, TransformerLM
from repro.serving import sampler as sampler_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival_ns: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    name: str
    model: ModelConfig
    max_slots: int = 8
    max_len: int = 1024
    placement: str = "device"  # "device" | "host" (weights+KV tier)
    sampler: str = "greedy"
    #: serve_step bytes model: fraction of weight bytes actually streamed
    #: per decode step (1.0 = classic memory-bound decode).
    weight_stream_fraction: float = 1.0
    #: host-tier transfer chunks per decode step (None => 2 x n_layers:
    #: one weight + one KV chunk per layer).
    stream_chunks: Optional[int] = None


class ServingEngine:
    """One model instance with continuous batching.

    ``kv_pagemap`` (optional) hands KV-cache offload placement to the
    tiering subsystem: a :class:`repro.tiering.pagemap.PageMap` carrying a
    region named after this engine.  Instead of the all-or-nothing
    ``placement`` split, each decode step's KV bytes divide between the HBM
    path and the host link by the region's *live* access-weighted tier
    fractions — so promoting hot KV pages genuinely moves their stream off
    the slow link mid-run.  The engine feeds the region one access sample
    per decoded token (station accounting, same contract as the DES hook).
    """

    def __init__(self, cfg: EngineConfig, params: Any, *,
                 rng: Optional[jax.Array] = None, kv_pagemap: Any = None):
        self.cfg = cfg
        self.kv_pagemap = kv_pagemap
        self.model = TransformerLM(cfg.model)
        self.params = params
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._place_state()
        self.state = self.model.init_decode_state(cfg.max_slots, cfg.max_len)
        self.slot_req: List[Optional[Request]] = [None] * cfg.max_slots
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._tokens = jnp.zeros((cfg.max_slots,), jnp.int32)
        self._active = np.zeros((cfg.max_slots,), bool)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._prefill_cache: Dict[int, Callable] = {}

        # Tier accounting constants.
        self.param_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(self.params)
        )
        cfgm = cfg.model
        if cfgm.uses_attention:
            self.kv_bytes_per_token = (
                2 * cfgm.n_kv_heads * cfgm.head_dim * cfgm.n_layers * 2
            )
        else:
            self.kv_bytes_per_token = 0

    def _place_state(self) -> None:
        self._host_resident = False
        if self.cfg.placement == "host" and host_offload_supported():
            dev = jax.devices()[0]
            host_sh = jax.sharding.SingleDeviceSharding(
                dev, memory_kind=HOST_TIER.memory_kind
            )
            self.params = jax.device_put(self.params, host_sh)
            self._device_sh = jax.sharding.SingleDeviceSharding(
                dev, memory_kind=HBM_TIER.memory_kind
            )
            self._host_resident = True

    def step_params(self) -> Any:
        """Working copy of the weights for one step.  Host-resident
        instances FETCH them device-ward — the PCIe stream the transfer
        queue charges (a TPU build would pipeline this per-layer inside the
        step; the aggregate bytes are identical)."""
        if self._host_resident:
            return jax.device_put(self.params, self._device_sh)
        return self.params

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _prefill_fn(self, plen: int) -> Callable:
        if plen not in self._prefill_cache:
            model = self.model

            def fn(params, tokens):
                state1 = model.init_decode_state(1, self.cfg.max_len)
                return model.prefill(params, tokens, state1)

            self._prefill_cache[plen] = jax.jit(fn)
        return self._prefill_cache[plen]

    def _insert_state(self, slot: int, state1: DecodeState,
                      plen: int) -> None:
        def put(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        st = self.state
        kv = st.kv
        if kv is not None:
            kv = {k: put(kv[k], state1.kv[k]) for k in kv}
        ssm = st.ssm
        if ssm is not None:
            ssm = {k: put(ssm[k], state1.ssm[k]) for k in ssm}
        length = st.length.at[slot].set(plen)
        self.state = DecodeState(kv=kv, ssm=ssm, cross_kv=st.cross_kv,
                                 length=length)

    def admit(self, now_ns: float) -> List[Tuple[Request, int]]:
        """Prefill queued requests into free slots.  Returns admissions
        (request, prompt_bytes_touched) for tier accounting."""
        admitted = []
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            plen = len(req.prompt)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, state1 = self._prefill_fn(plen)(self.step_params(), tokens)
            first = self._sample(logits)
            req.output.append(int(first[0]))
            req.t_first_token = now_ns
            self._insert_state(slot, state1, plen)
            self._tokens = self._tokens.at[slot].set(int(first[0]))
            self.slot_req[slot] = req
            self._active[slot] = True
            admitted.append((req, plen * self.kv_bytes_per_token))
        return admitted

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.cfg.sampler == "greedy":
            return sampler_lib.greedy(logits)
        self.rng, sub = jax.random.split(self.rng)
        return sampler_lib.temperature(logits, sub)

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    def step_bytes(self) -> Tuple[int, int]:
        """(weight_bytes, kv_bytes) one decode step streams."""
        wb = int(self.param_bytes * self.cfg.weight_stream_fraction)
        lengths = np.asarray(jax.device_get(self.state.length))
        kvb = int(
            sum(
                int(lengths[i]) * self.kv_bytes_per_token
                for i in range(self.cfg.max_slots)
                if self._active[i]
            )
        )
        return wb, kvb

    def kv_tier_bytes(self, kv_bytes: int) -> Tuple[int, int]:
        """Split one step's KV stream into (fast_bytes, slow_bytes).

        Without a PageMap the split follows the static placement (the
        pre-tiering behavior, bit-for-bit).  With one, the engine's KV
        region decides: its access-weighted fast fraction stays on HBM and
        only the slow remainder crosses the host link."""
        if self.kv_pagemap is None or self.cfg.name not in getattr(
            self.kv_pagemap, "regions", {}
        ):
            if self.cfg.placement == "host":
                return 0, kv_bytes
            return kv_bytes, 0
        self.kv_pagemap.record_window(self.cfg.name, float(self.n_active))
        fast = self.kv_pagemap.fast_fraction(self.cfg.name)
        fast_bytes = int(kv_bytes * fast)
        return fast_bytes, kv_bytes - fast_bytes

    def decode_once(self, now_ns: float) -> int:
        """One real decode step for all active slots.  Returns #tokens."""
        if self.n_active == 0:
            return 0
        logits, self.state = self._decode(self.step_params(), self.state,
                                          self._tokens)
        nxt = self._sample(logits)
        self._tokens = nxt
        produced = 0
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.output.append(int(nxt[slot]))
            produced += 1
            done = len(req.output) >= req.max_new_tokens
            overflow = int(self.state.length[slot]) >= self.cfg.max_len - 1
            if done or overflow:
                req.t_done = now_ns
                self.done.append(req)
                self.slot_req[slot] = None
                self._active[slot] = False
                # Slot length is reset on next admit's insert.
        return produced

    @property
    def finished(self) -> bool:
        return not self.queue and self.n_active == 0


class TieredServingCluster:
    """Co-located engines sharing one chip's transfer path + MIKU control.

    ``run`` drives all engines until completion: per simulated tick every
    engine that is *admissible* takes one decode step; host-placed engines
    must first get their weight/KV stream admitted by the transfer queue —
    whose in-flight cap and rate are MIKU's decision.  Step durations come
    from the tier bandwidth model (decode is bandwidth-bound, paper §6).
    """

    def __init__(
        self,
        engines: List[ServingEngine],
        *,
        controller: Optional[MikuController] = None,
        window_ns: float = 2e6,
        hbm_bw: float = HBM_TIER.bandwidth_gbps,  # B/ns per chip
        trace: int = 0,
    ):
        self.engines = engines
        self.queue = TransferQueue(
            controller=controller, window_ns=window_ns, trace=trace
        )
        #: The cluster's control plane is the transfer queue's ControlLoop —
        #: same substrate interface as the DES and the launcher.
        self.control = self.queue.control
        self.hbm_bw = hbm_bw
        self.timeline: List[Dict[str, float]] = []
        self._host_busy_until: Dict[str, float] = {
            e.cfg.name: 0.0 for e in engines
        }

    def run(self, max_ticks: int = 10_000) -> Dict[str, Dict[str, float]]:
        q = self.queue
        tick = 0
        produced: Dict[str, int] = {e.cfg.name: 0 for e in self.engines}
        started: Dict[str, Optional[float]] = {
            e.cfg.name: None for e in self.engines
        }
        finished_at: Dict[str, float] = {e.cfg.name: 0.0 for e in self.engines}
        while tick < max_ticks and not all(e.finished for e in self.engines):
            tick += 1
            fast_time = 0.0
            for eng in self.engines:
                eng.admit(q.now)
                if eng.n_active == 0:
                    continue
                name = eng.cfg.name
                if started[name] is None:
                    started[name] = q.now
                wb, kvb = eng.step_bytes()
                if eng.cfg.placement == "host":
                    # One decode step = one weight/KV stream over the slow
                    # tier, submitted as per-layer chunks.  Uncapped, the
                    # chunk backlog floods the shared descriptor pool (the
                    # unfair-queuing mechanism); a MIKU cap bounds it at no
                    # throughput cost (chunks still saturate the link).
                    if q.now < self._host_busy_until[name]:
                        continue
                    n_chunks = (eng.cfg.stream_chunks
                                or 2 * eng.cfg.model.n_layers)
                    # A KV PageMap routes the hot share of the KV stream
                    # over HBM; only the slow remainder crosses the link.
                    # The HBM share costs exactly what it would cost an
                    # hbm-placed engine (fast_penalty included) and the
                    # step completes only when both paths have.
                    kv_fast, kv_slow = eng.kv_tier_bytes(kvb)
                    fast_dur = 0.0
                    if kv_fast:
                        fast_dur = kv_fast / self.hbm_bw * q.fast_penalty()
                        q.account_fast(kv_fast, fast_dur, OpClass.LOAD)
                        fast_time += fast_dur
                    done_t = q.submit_slow_stream(wb + kv_slow, n_chunks,
                                                  OpClass.LOAD, tier="slow")
                    done_t = max(done_t, q.now + fast_dur)
                    self._host_busy_until[name] = done_t
                    n = eng.decode_once(done_t)
                    finished_at[name] = done_t
                else:
                    dur = (wb + kvb) / self.hbm_bw * q.fast_penalty()
                    q.account_fast(wb + kvb, dur, OpClass.LOAD)
                    fast_time += dur
                    n = eng.decode_once(q.now + dur)
                    finished_at[name] = q.now + dur
                produced[name] += n
            # Advance the clock by the fast-tier step time (engines on HBM
            # run back-to-back; host engines progress via queue completions).
            q.advance(max(fast_time, 1e3))
            self.timeline.append(
                {"t_ns": q.now,
                 "slow_backlog": float(q.slow_backlog()),
                 **{f"tok_{k}": float(v) for k, v in produced.items()}}
            )
        out: Dict[str, Dict[str, float]] = {}
        from repro.obs.metrics import default_registry

        reg = default_registry()
        for eng in self.engines:
            name = eng.cfg.name
            toks = sum(len(r.output) for r in eng.done)
            t0 = started[name] or 0.0
            span = max(finished_at[name] - t0, 1.0)
            out[name] = {
                "tokens": float(toks),
                "wall_ns": span,
                "tokens_per_s": toks / span * 1e9,
                "requests": float(len(eng.done)),
            }
            reg.counter("serving.tokens").inc(float(toks))
            reg.counter("serving.requests").inc(float(len(eng.done)))
        return out

from repro.serving.engine import (
    EngineConfig,
    Request,
    ServingEngine,
    TieredServingCluster,
)

__all__ = ["EngineConfig", "Request", "ServingEngine", "TieredServingCluster"]

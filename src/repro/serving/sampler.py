"""Token samplers (greedy / temperature / top-k)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array, key=None) -> jax.Array:
    del key
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 0.8,
                top_k: int = 0) -> jax.Array:
    logits = logits.astype(jnp.float32) / max(temp, 1e-6)
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

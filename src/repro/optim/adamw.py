"""Sharded AdamW with optional fp32 master weights.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
logical-axis tuples (and therefore the same NamedShardings) apply — fully
sharded optimizer state (ZeRO-style) falls out of the FSDP param rules for
free.  ``master=False`` drops the fp32 master copy (params updated in their
own dtype) for memory-tight configs; m/v stay fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array  # [] int32
    m: Any  # fp32 tree
    v: Any  # fp32 tree
    master: Optional[Any]  # fp32 master params (or None)


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master: bool = True

    def init(self, params: Any) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros2 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = (
            jax.tree.map(lambda p: p.astype(jnp.float32), params)
            if self.master
            else None
        )
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2,
                        master=master)

    def init_shapes(self, param_specs: Any) -> OptState:
        """ShapeDtypeStruct version (dry-run)."""
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
        return OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(f32, param_specs),
            v=jax.tree.map(f32, param_specs),
            master=jax.tree.map(f32, param_specs) if self.master else None,
        )

    def state_axes(self, param_axes: Any) -> OptState:
        """Logical axes matching init's tree (same as params)."""
        return OptState(
            step=(),
            m=param_axes,
            v=param_axes,
            master=param_axes if self.master else None,
        )

    def update(
        self, grads: Any, state: OptState, params: Any, lr: jax.Array
    ) -> Tuple[Any, OptState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p, ref):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g32
            v2 = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m2 / bc1
            vhat = v2 / bc2
            base = ref if ref is not None else p.astype(jnp.float32)
            new = base - lr * (
                mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * base
            )
            return new, m2, v2

        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        flat_p = jax.tree.leaves(params)
        flat_ref = (
            jax.tree.leaves(state.master) if state.master is not None
            else [None] * len(flat_p)
        )
        treedef = jax.tree.structure(params)
        news, m2s, v2s = [], [], []
        for g, m, v, p, ref in zip(flat_g, flat_m, flat_v, flat_p, flat_ref):
            new, m2, v2 = upd(g, m, v, p, ref)
            news.append(new)
            m2s.append(m2)
            v2s.append(v2)
        new_master = (
            jax.tree.unflatten(treedef, news) if self.master else None
        )
        new_params = jax.tree.unflatten(
            treedef,
            [n.astype(p.dtype) for n, p in zip(news, flat_p)],
        )
        return new_params, OptState(
            step=step,
            m=jax.tree.unflatten(treedef, m2s),
            v=jax.tree.unflatten(treedef, v2s),
            master=new_master,
        )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm

from repro.optim.adamw import AdamW, OptState, clip_by_global_norm
from repro.optim.schedule import warmup_cosine, constant

__all__ = ["AdamW", "OptState", "clip_by_global_norm", "warmup_cosine", "constant"]

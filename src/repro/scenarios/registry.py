"""The named-scenario registry.

Scenarios register at import time (``repro.scenarios.library``) in
declaration order; that order is the public presentation order — the
benchmark harness derives its figure-module list from it, so the registry
and the module list cannot drift.
"""

from __future__ import annotations

import difflib
from typing import Dict, List

from repro.scenarios.spec import Scenario

_REGISTRY: Dict[str, Scenario] = {}


class UnknownScenarioError(KeyError):
    """Raised by :func:`get` for an unregistered name.

    Subclasses KeyError so existing ``except KeyError`` callers keep
    working, but overrides ``__str__`` (KeyError quotes its lone arg) so
    the message — including close-match suggestions — prints cleanly.
    """

    def __init__(self, name: str, known: List[str]):
        self.name = name
        self.known = known
        self.suggestions = difflib.get_close_matches(name, known, n=3)
        msg = f"unknown scenario {name!r}"
        if self.suggestions:
            msg += "; did you mean: " + ", ".join(self.suggestions) + "?"
        msg += f"\nregistered scenarios: {', '.join(known)}"
        super().__init__(msg)

    def __str__(self) -> str:
        return self.args[0]


def register(scenario: Scenario) -> Scenario:
    """Register ``scenario`` under its name (duplicate names are a bug)."""
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    """Look up a registered scenario by ``name``.

    Unknown names raise :class:`UnknownScenarioError` (a KeyError) whose
    message lists near-miss suggestions and every registered name.
    """
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownScenarioError(name, names()) from None


def names() -> List[str]:
    """Registered scenario names, in declaration (presentation) order."""
    return list(_REGISTRY)


def all_scenarios() -> List[Scenario]:
    """All registered scenarios, in declaration (presentation) order."""
    return list(_REGISTRY.values())

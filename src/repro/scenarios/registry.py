"""The named-scenario registry.

Scenarios register at import time (``repro.scenarios.library``) in
declaration order; that order is the public presentation order — the
benchmark harness derives its figure-module list from it, so the registry
and the module list cannot drift.
"""

from __future__ import annotations

from typing import Dict, List

from repro.scenarios.spec import Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Register ``scenario`` under its name (duplicate names are a bug)."""
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    """Look up a registered scenario by ``name`` (KeyError lists all)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered scenarios: "
            f"{', '.join(names())}"
        ) from None


def names() -> List[str]:
    """Registered scenario names, in declaration (presentation) order."""
    return list(_REGISTRY)


def all_scenarios() -> List[Scenario]:
    """All registered scenarios, in declaration (presentation) order."""
    return list(_REGISTRY.values())

"""Declarative scenario specs: Axis / Metric / Scenario / ResultTable.

A :class:`Scenario` is a *description* of one experiment family: its
parameter axes (grid axes expand into cells, scalar axes are shared
knobs), the metrics its rows report, and either

  * ``build`` + ``reduce`` — the declarative grid form: ``build(platform,
    cell)`` returns the :class:`~repro.memsim.sweep.SimJob` list for one
    cell and ``reduce(platform, cell, jobs, results)`` turns that cell's
    results into result-table rows; the planner batches every cell's jobs
    through one :func:`~repro.memsim.sweep.run_sweep`; or
  * ``run_cell`` — the escape hatch for multi-stage experiments whose
    later jobs depend on earlier results (Fig. 2's measured interleave
    split) or that do not run on the DES at all (Fig. 11's serving
    engine).

Scenarios carry no execution state; :mod:`repro.scenarios.planner` owns
expansion and execution, :mod:`repro.scenarios.registry` owns naming.
"""

from __future__ import annotations

import csv
import dataclasses
import enum
import io
import json
from typing import Any, Callable, Dict, List, Optional, Tuple


def _parse_bool(text: str) -> bool:
    t = text.strip().lower()
    if t in ("1", "true", "yes", "on"):
        return True
    if t in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"not a boolean: {text!r}")


def _infer_parse(sample: Any) -> Callable[[str], Any]:
    if isinstance(sample, bool):  # before int: bool is an int subclass
        return _parse_bool
    if isinstance(sample, enum.Enum):
        return type(sample)  # e.g. OpClass("load")
    if isinstance(sample, int):
        return int
    if isinstance(sample, float):
        return float
    return str


@dataclasses.dataclass(frozen=True)
class Axis:
    """One scenario parameter.

    ``default`` being a tuple/list makes this a *grid* axis: the planner
    expands the cartesian product of all grid axes into cells.  A scalar
    default is a shared knob every cell sees unchanged.  ``parse`` converts
    one ``--set axis=value`` CLI token (default: inferred from the default
    value's type; comma-separated tokens become grids).
    """

    name: str
    default: Any
    help: str = ""
    parse: Optional[Callable[[str], Any]] = None

    @property
    def is_grid(self) -> bool:
        return isinstance(self.default, (tuple, list))

    def parse_text(self, text: str) -> Any:
        """Parse one ``--set`` token for this axis (comma lists -> grids)."""
        sample = self.default[0] if self.is_grid else self.default
        fn = self.parse or _infer_parse(sample)
        if self.is_grid:
            return tuple(fn(p.strip()) for p in text.split(","))
        if "," in text:
            raise ValueError(
                f"axis {self.name!r} is a scalar knob, got list {text!r}"
            )
        return fn(text.strip())


@dataclasses.dataclass(frozen=True)
class Metric:
    """One column the scenario's result rows report."""

    name: str
    unit: str = ""
    help: str = ""


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, declarative experiment over an N-tier platform model."""

    name: str
    title: str
    axes: Tuple[Axis, ...] = ()
    metrics: Tuple[Metric, ...] = ()
    figure: str = ""  # paper figure label, e.g. "Fig. 3"
    module: str = ""  # benchmarks module that presents this scenario
    #: (platform, cell) -> List[SimJob] — one grid cell's job batch.
    build: Optional[Callable[..., List[Any]]] = None
    #: (platform, cell, jobs, results) -> List[dict] — that cell's rows.
    reduce: Optional[Callable[..., List[Dict[str, Any]]]] = None
    #: (platform, cell, processes) -> List[dict] — multi-stage escape hatch.
    #: run_cell scenarios always execute scalar (the batched sweep lane
    #: covers grid scenarios only); bodies that call run_sweep internally
    #: must pin lane="scalar" so REPRO_SWEEP_LANE cannot leak in.
    run_cell: Optional[Callable[..., List[Dict[str, Any]]]] = None
    slow: bool = False  # heavy scenario: CI runs it in the non-gating lane

    def __post_init__(self):
        grid_form = self.build is not None and self.reduce is not None
        if grid_form == (self.run_cell is not None):
            raise ValueError(
                f"scenario {self.name!r} needs either build+reduce or "
                "run_cell (exactly one form)"
            )

    def axis(self, name: str) -> Axis:
        """This scenario's axis named ``name`` (KeyError lists the axes)."""
        for a in self.axes:
            if a.name == name:
                return a
        raise KeyError(
            f"scenario {self.name!r} has no axis {name!r}; axes: "
            f"{', '.join(a.name for a in self.axes) or '(none)'}"
        )

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)


def _plain(v: Any) -> Any:
    """JSON/CSV-safe cell value (enums flatten to their value)."""
    if isinstance(v, enum.Enum):
        return v.value
    return v


def format_default(v: Any) -> str:
    """One axis default as display text (enums by value, sequences comma-
    joined, whole floats without the trailing ``.0``).

    The single formatter behind both ``benchmarks/run.py --list`` and the
    generated catalog (``docs/scenarios.md``), so the two listings cannot
    render the same default differently."""
    if isinstance(v, enum.Enum):
        return str(v.value)
    if isinstance(v, (tuple, list)):
        return ", ".join(format_default(x) for x in v)
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return str(v)


@dataclasses.dataclass
class ResultTable:
    """A uniform result table: one scenario, ordered rows of plain dicts."""

    scenario: str
    rows: List[Dict[str, Any]]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: Per-window control-plane telemetry (one entry per cell, each with
    #: its jobs' per-tier window records) — populated only when the
    #: scenario ran with ``trace=True`` (``benchmarks/run.py --trace``).
    traces: Optional[List[Dict[str, Any]]] = None
    #: Execution metadata: which lane ran the sweep and, for the batched
    #: lane, how many jobs it expressed vs routed back to the scalar DES
    #: (``fallback_reasons`` says why) — see ``run_scenario(..., lane=)``.
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: Sampled request-lifecycle span records (one entry per cell, each
    #: with its jobs' ``SimResult.trace`` payloads) — populated only under
    #: ``run_scenario(..., perfetto=True)`` (``benchmarks/run.py
    #: --perfetto``).  Excluded from :meth:`to_json`; the CLI exports it
    #: separately as Chrome trace-event JSON via
    #: :func:`repro.obs.trace.to_chrome`.
    request_traces: Optional[List[Dict[str, Any]]] = None

    def __post_init__(self):
        self.rows = [{k: _plain(v) for k, v in r.items()} for r in self.rows]
        self.params = {k: _plain(v) for k, v in self.params.items()}

    @property
    def columns(self) -> List[str]:
        cols: List[str] = []
        for r in self.rows:
            for k in r:
                if k not in cols:
                    cols.append(k)
        return cols

    def to_csv(self) -> str:
        """The rows as CSV text (union of row keys, declaration order)."""
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=self.columns, restval="",
                           lineterminator="\n")
        w.writeheader()
        w.writerows(self.rows)
        return buf.getvalue()

    def to_json(self, indent: Optional[int] = 2) -> str:
        """Scenario, params, rows (and non-empty meta) as a JSON document."""
        def default(o: Any) -> Any:
            plain = _plain(o)
            return plain if plain is not o else str(o)

        payload = {"scenario": self.scenario, "params": self.params,
                   "rows": self.rows}
        if self.meta:
            payload["meta"] = self.meta
        return json.dumps(payload, indent=indent, default=default)

"""Generated scenario catalog — the single source for ``docs/scenarios.md``.

``benchmarks/run.py --list --format md`` prints :func:`catalog_md`; CI
regenerates ``docs/scenarios.md`` from it and fails on any diff, so the
registry and its documentation cannot drift (see ``tests/test_docs.py``
and the ``docs-freshness`` CI step).  Everything here must therefore be a
pure, deterministic function of the registry.
"""

from __future__ import annotations

from typing import List

from repro.scenarios.registry import all_scenarios
from repro.scenarios.spec import Scenario, format_default

_HEADER = """\
# Scenario catalog

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with:
         PYTHONPATH=src python benchmarks/run.py --list --format md > docs/scenarios.md
     CI fails if this file is stale. -->

Every experiment in this repo is a registered, declarative
[`Scenario`](../src/repro/scenarios/spec.py): parameter axes (grid axes
expand into cells), result metrics, and the builder that turns one cell
into [`SimJob`](../src/repro/memsim/sweep.py)s.  Run any of them with:

```bash
PYTHONPATH=src python benchmarks/run.py --scenario NAME \\
    [--set axis=value ...] [--format csv|json] [--trace NAME] \\
    [--lane scalar|batched] [--jobs N]
```

Grid axes are marked `*` — comma lists in `--set` sweep them
(`--set threads=1,16`).  `--lane batched` runs the whole grid through the
vectorized sweep lane (`repro.memsim.batched`); `--trace NAME` records
per-window control-plane telemetry (see [telemetry.md](telemetry.md)).
"""


def _scenario_md(sc: Scenario) -> List[str]:
    lines = [f"## `{sc.name}`", ""]
    bits = [sc.title]
    if sc.figure:
        bits.append(f"reproduces **{sc.figure}**")
    lines.append(".  ".join(bits) + ".")
    lines.append("")
    facts = []
    facts.append("multi-stage (`run_cell`)" if sc.run_cell is not None
                 else "grid (`build` + `reduce`)")
    if sc.slow:
        facts.append("slow — CI runs it in the non-gating lane")
    if sc.module:
        facts.append(f"legacy figure module `benchmarks/{sc.module}.py`")
    lines.append(f"*Form:* {'; '.join(facts)}.")
    lines.append("")
    if sc.axes:
        lines.append("| axis | default | description |")
        lines.append("|---|---|---|")
        for a in sc.axes:
            mark = "\\*" if a.is_grid else ""
            lines.append(
                f"| `{a.name}`{mark} | `{format_default(a.default)}` "
                f"| {a.help} |"
            )
        lines.append("")
    if sc.metrics:
        lines.append("| metric | unit | description |")
        lines.append("|---|---|---|")
        for m in sc.metrics:
            unit = f"`{m.unit}`" if m.unit else ""
            lines.append(f"| `{m.name}` | {unit} | {m.help} |")
        lines.append("")
    return lines


def catalog_md() -> str:
    """The full markdown catalog, in registry declaration order."""
    lines = [_HEADER]
    scs = all_scenarios()
    lines.append("| scenario | figure | title |")
    lines.append("|---|---|---|")
    for sc in scs:
        lines.append(
            f"| [`{sc.name}`](#{sc.name}) | {sc.figure or '—'} "
            f"| {sc.title} |"
        )
    lines.append("")
    for sc in scs:
        lines.extend(_scenario_md(sc))
    return "\n".join(lines).rstrip() + "\n"

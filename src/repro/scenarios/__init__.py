"""Declarative scenario API over the N-tier platform model.

The experiment-definition surface of the reproduction: a
:class:`~repro.scenarios.spec.Scenario` declares parameter axes and
metrics; the planner expands the axis grid into
:class:`~repro.memsim.sweep.SimJob` batches, executes them through
:func:`~repro.memsim.sweep.run_sweep`, and collects a uniform
:class:`~repro.scenarios.spec.ResultTable` with CSV/JSON emission.

    from repro.scenarios import run_scenario
    table = run_scenario("fig3_bandwidth", {"platform": "A"})
    print(table.to_csv())

All paper figures are registered in :mod:`repro.scenarios.library`
(imported here so the registry is populated on package import), plus
N-tier scenarios (``corun3_switch``, ``numa_remote``) the legacy
two-tier API could not express.  ``benchmarks/run.py --list`` shows
everything; ``--scenario NAME --set axis=value`` runs one.
"""

from repro.scenarios import library as _library  # populate the registry
from repro.scenarios.planner import (
    expand_cells,
    parse_set_args,
    plan,
    resolve_axes,
    resolve_platform,
    run_scenario,
)
from repro.scenarios.registry import (
    UnknownScenarioError,
    all_scenarios,
    get,
    names,
    register,
)
from repro.scenarios.spec import Axis, Metric, ResultTable, Scenario

del _library

__all__ = [
    "Axis",
    "Metric",
    "ResultTable",
    "Scenario",
    "UnknownScenarioError",
    "all_scenarios",
    "expand_cells",
    "get",
    "names",
    "parse_set_args",
    "plan",
    "register",
    "resolve_axes",
    "resolve_platform",
    "run_scenario",
]

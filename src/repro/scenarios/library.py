"""The registered scenario library.

Every paper figure is declared here as a named :class:`Scenario` over the
N-tier platform model, replacing the imperative figure functions that used
to live in :mod:`repro.memsim.runner` (which is now a thin compatibility
wrapper over this registry).  Declaration order is presentation order —
``benchmarks/run.py`` derives its module list from it.

Three scenarios exercise tier sets the legacy two-tier API could not
express: ``corun3_switch`` (DDR + local CXL + CXL-over-switch),
``numa_remote`` (weighted interleave across local and NUMA-remote DDR
while CXL traffic co-runs), and ``corun3_pertier`` (per-slow-tier MIKU
ladders vs the merged-slow broadcast law on the three-tier co-run — the
per-tier vector contract's demonstrator: independent DDR recovery with
*different* ladders per slow tier).

Three more exercise the routed fabric layer (:mod:`repro.fabric`):
``fabric_spine_congestion`` (two hosts share a spine downlink — racing
collapses DDR through ToR monopolization by spine-stalled requests,
per-edge MIKU recovers it), ``fabric_port_overflow`` (the port-queue
limit vs ToR limit crossover behind one switch port), and ``fabric_miku``
(asymmetric uplinks: per-tier throttling punishes the innocent host,
per-edge throttles only the congested route).

Two SLO scenarios exercise the open-loop arrival layer
(:mod:`repro.workload`): ``slo_knee`` sweeps offered load to find where
each placement/policy blows the p99 budget (CXL-heavy placement knees at a
fraction of the DDR rate; MIKU moves the knee well above racing), and
``flash_crowd`` steps the offered rate mid-run to measure the control
plane's transient response (peak backlog, surge p99, drain time).
"""

from __future__ import annotations

from typing import Dict, List

from repro.core.des import WorkloadSpec
from repro.core.device_model import PlatformModel
from repro.core.littles_law import DEMAND_CLASSES, OpClass
# Importing the fabric package also registers the "A-direct"/"A-spine"
# platforms into PLATFORMS for the benchmark CLI.
from repro.fabric import single_switch_platform, spine_leaf_platform
from repro.memsim.sweep import SimJob, run_sweep
from repro.memsim.workloads import (
    alternating_bw_pair,
    bw_test,
    lat_share,
    lat_test,
    serve_test,
)
from repro.obs.histogram import LatencyHistogram
from repro.workload import ArrivalSpec
from repro.scenarios.registry import register
from repro.scenarios.spec import Axis, Metric, Scenario

_BW_SIM_NS = 120_000.0
_CORUN_SIM_NS = 300_000.0

_OPS = DEMAND_CLASSES  # workload op grids never include MIGRATE
_TWO_TIERS = ("ddr", "cxl")


def _job(
    platform: PlatformModel,
    workloads: List[WorkloadSpec],
    sim_ns: float,
    *,
    miku: bool = False,
    seed: int = 0,
    granularity: int = 4,
    window_ns: float = 10_000.0,
    miku_law: str = "pertier",
    tiering=None,
    latency_hist: bool = False,
    record_windows: bool = False,
) -> SimJob:
    return SimJob(
        platform=platform,
        workloads=workloads,
        sim_ns=sim_ns,
        seed=seed,
        granularity=granularity,
        window_ns=window_ns,
        miku=miku,
        miku_law=miku_law,
        tiering=tiering,
        latency_hist=latency_hist,
        record_windows=record_windows,
    )


def _platform_axis(default="A") -> Axis:
    return Axis("platform", default,
                help="platform name (repro.core.device_model.PLATFORMS)")


def _op_axis(default=_OPS) -> Axis:
    return Axis("op", default, help="memory instruction class",
                parse=OpClass)


# -- Fig. 2: tiered memory management schemes --------------------------------


def _fig2_run_cell(platform, cell, processes) -> List[dict]:
    """Two-stage cell: measure the upper/lower split first, then run the
    placement schemes at the measured interleave fraction (the reason this
    figure is a ``run_cell`` scenario, not a static grid).  The internal
    sweeps pin ``lane="scalar"``: run_cell scenarios are documented as
    scalar-only, so ``REPRO_SWEEP_LANE`` must not leak in."""
    op = cell["op"]
    out: Dict[str, float] = {}
    up, low = run_sweep(
        [
            _job(platform, [bw_test("ddr", op, 16, name="a")], _BW_SIM_NS),
            _job(platform, [bw_test("cxl", op, 16, name="a")], _BW_SIM_NS),
        ],
        processes,
        lane="scalar",
    )
    out["upper_ddr_only"] = up.bandwidth("a")
    out["lower_cxl_only"] = low.bandwidth("a")

    frac = out["upper_ddr_only"] / max(
        out["upper_ddr_only"] + out["lower_cxl_only"], 1e-9
    )
    migration = WorkloadSpec(
        name="kmigrated",
        op=OpClass.STORE,
        tier="cxl",
        n_cores=2,
        mlp=64,
        ddr_fraction=0.5,
        miku_managed=False,
    )
    nat, inter, osm = run_sweep(
        [
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", miku_managed=False),
                    bw_test("cxl", op, 16, name="b"),
                ],
                _CORUN_SIM_NS,
            ),
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", ddr_fraction=frac,
                            miku_managed=False),
                    bw_test("cxl", op, 16, name="b", ddr_fraction=frac,
                            miku_managed=False),
                ],
                _CORUN_SIM_NS,
            ),
            _job(
                platform,
                [
                    bw_test("ddr", op, 16, name="a", ddr_fraction=frac,
                            miku_managed=False),
                    bw_test("cxl", op, 16, name="b", ddr_fraction=frac,
                            miku_managed=False),
                    migration,
                ],
                _CORUN_SIM_NS,
            ),
        ],
        processes,
        lane="scalar",
    )
    out["native"] = nat.bandwidth("a") + nat.bandwidth("b")
    out["interleave"] = inter.bandwidth("a") + inter.bandwidth("b")
    out["os_managed"] = osm.bandwidth("a") + osm.bandwidth("b")
    out["ideal_combined"] = out["upper_ddr_only"] + out["lower_cxl_only"]
    return [{"platform": cell["platform"], "op": op.value, **out}]


register(Scenario(
    name="fig2_tiering",
    title="Aggregated bandwidth of tiered-memory management schemes",
    figure="Fig. 2",
    module="fig2_tiering",
    axes=(_platform_axis(), _op_axis()),
    metrics=(
        Metric("upper_ddr_only", "GB/s", "one copy, WSS fully in DDR"),
        Metric("lower_cxl_only", "GB/s", "one copy, WSS fully in CXL"),
        Metric("native", "GB/s", "application-directed placement"),
        Metric("interleave", "GB/s", "page-interleaved at the bw ratio"),
        Metric("os_managed", "GB/s", "interleaved + page-migration tax"),
        Metric("ideal_combined", "GB/s", "upper + lower"),
    ),
    run_cell=_fig2_run_cell,
))


# -- Fig. 3: single-threaded and peak bandwidth per tier ----------------------


def _fig3_build(platform, cell) -> List[SimJob]:
    wl = bw_test(cell["tier"], cell["op"], cell["threads"])
    return [_job(platform, [wl], _BW_SIM_NS)]


def _fig3_reduce(platform, cell, jobs, results) -> List[dict]:
    (job,), (res,) = jobs, results
    return [{
        "platform": cell["platform"],
        "op": cell["op"].value,
        "tier": cell["tier"],
        "threads": cell["threads"],
        "bandwidth_gbps": res.bandwidth(job.workloads[0].name),
        "peak_model_gbps":
            platform.device_for(cell["tier"]).peak_bandwidth_gbps(cell["op"]),
    }]


register(Scenario(
    name="fig3_bandwidth",
    title="DDR vs CXL single/multi-thread bandwidth",
    figure="Fig. 3",
    module="fig3_bandwidth",
    axes=(
        _platform_axis(("A", "A-1to1", "B", "B-1to1")),
        _op_axis(),
        Axis("threads", (1, 16), help="bw-test thread count"),
        Axis("tier", _TWO_TIERS, help="tier under test"),
    ),
    metrics=(
        Metric("bandwidth_gbps", "GB/s", "delivered bandwidth"),
        Metric("peak_model_gbps", "GB/s", "device-model peak"),
    ),
    build=_fig3_build,
    reduce=_fig3_reduce,
))


# -- Fig. 4: average and tail latency ----------------------------------------


def _fig4_build(platform, cell) -> List[SimJob]:
    wl = lat_test(cell["tier"], OpClass.LOAD, cell["threads"])
    return [_job(platform, [wl], 400_000.0, granularity=1,
                 latency_hist=True)]


def _fig4_reduce(platform, cell, jobs, results) -> List[dict]:
    (job,), (res,) = jobs, results
    st = res.stats[job.workloads[0].name]
    # p50/p99 stay on the reservoir (the pinned-golden source); p95 comes
    # from the mergeable histogram (bucket relative error <= 1/16 — see
    # docs/observability.md).
    hist = st.latency_hist
    return [{
        "platform": cell["platform"],
        "tier": cell["tier"],
        "threads": cell["threads"],
        "avg_ns": st.mean_latency_ns(),
        "p50_ns": st.percentile_ns(0.50),
        "p95_ns": hist.percentile(0.95) if hist is not None else 0.0,
        "p99_ns": st.percentile_ns(0.99),
    }]


register(Scenario(
    name="fig4_latency",
    title="Average and tail (p99) loaded latency per tier",
    figure="Fig. 4",
    module="fig4_latency",
    axes=(
        _platform_axis(),
        Axis("tier", _TWO_TIERS, help="tier under test"),
        Axis("threads", (1, 2, 4, 8, 16), help="lat-test thread count"),
    ),
    metrics=(
        Metric("avg_ns", "ns"), Metric("p50_ns", "ns"),
        Metric("p95_ns", "ns", "from the mergeable latency histogram"),
        Metric("p99_ns", "ns"),
    ),
    build=_fig4_build,
    reduce=_fig4_reduce,
))


# -- Loaded latency: a latency probe against a bandwidth load ladder ----------


def _loaded_lat_build(platform, cell) -> List[SimJob]:
    wls = [lat_test(cell["tier"], OpClass.LOAD, 1, name="probe")]
    n = cell["load_threads"]
    if n > 0:
        wls.append(bw_test(cell["tier"], cell["op"], n, name="load",
                           miku_managed=False))
    return [_job(platform, wls, 400_000.0, granularity=1,
                 latency_hist=True)]


def _loaded_lat_reduce(platform, cell, jobs, results) -> List[dict]:
    (job,), (res,) = jobs, results
    st = res.stats["probe"]
    hist = st.latency_hist
    return [{
        "platform": cell["platform"],
        "tier": cell["tier"],
        "load_threads": cell["load_threads"],
        "load_gbps":
            res.bandwidth("load") if cell["load_threads"] > 0 else 0.0,
        "avg_ns": st.mean_latency_ns(),
        "p50_ns": st.percentile_ns(0.50),
        "p95_ns": hist.percentile(0.95) if hist is not None else 0.0,
        "p99_ns": st.percentile_ns(0.99),
    }]


register(Scenario(
    name="loaded_latency",
    title="Latency-under-load curve: probe latency vs bandwidth load",
    figure="Fig. 4",
    module="loaded_latency",
    axes=(
        _platform_axis(),
        Axis("tier", _TWO_TIERS, help="tier under test"),
        Axis("load_threads", (0, 2, 4, 8, 16),
             help="bw-test threads loading the same tier (0 = unloaded)"),
        _op_axis(OpClass.LOAD),
    ),
    metrics=(
        Metric("load_gbps", "GB/s", "bandwidth the load workload delivers"),
        Metric("avg_ns", "ns", "probe mean latency"),
        Metric("p50_ns", "ns"),
        Metric("p95_ns", "ns", "from the mergeable latency histogram"),
        Metric("p99_ns", "ns"),
    ),
    build=_loaded_lat_build,
    reduce=_loaded_lat_reduce,
))


# -- Fig. 5 + 6: co-run collapse and ToR accounting ---------------------------


def _fig5_build(platform, cell) -> List[SimJob]:
    op, n = cell["op"], cell["n_threads"]
    a = bw_test("ddr", op, n, name="ddr", miku_managed=False)
    c = bw_test("cxl", op, n, name="cxl")
    return [
        _job(platform, [a], _BW_SIM_NS),
        _job(platform, [c], _BW_SIM_NS),
        _job(platform, [a, c], _CORUN_SIM_NS),
    ]


def _fig5_reduce(platform, cell, jobs, results) -> List[dict]:
    alone, cxl_alone, both = results
    ddr_alone_bw = alone.bandwidth("ddr")
    cxl_alone_bw = cxl_alone.bandwidth("cxl")
    return [{
        "platform": cell["platform"],
        "op": cell["op"].value,
        "ddr_alone_gbps": ddr_alone_bw,
        "cxl_alone_gbps": cxl_alone_bw,
        "ddr_corun_gbps": both.bandwidth("ddr"),
        "cxl_corun_gbps": both.bandwidth("cxl"),
        "ddr_loss_pct": 100.0 * (1 - both.bandwidth("ddr") / ddr_alone_bw),
        # Fig. 6 quantities:
        "tor_insert_rate_alone_per_ns": alone.tor_inserts / alone.sim_ns,
        "tor_insert_rate_corun_per_ns": both.tor_inserts / both.sim_ns,
        "tor_avg_latency_alone_ns": alone.tor_avg_latency_ns,
        "tor_avg_latency_corun_ns": both.tor_avg_latency_ns,
        "t_ddr_corun_ns": both.tier_counters["ddr"].mean_service_time,
        "t_cxl_corun_ns": both.tier_counters["cxl"].mean_service_time,
    }]


register(Scenario(
    name="fig5_corun",
    title="Co-run bandwidth collapse and ToR accounting",
    figure="Fig. 5-6",
    module="fig5_corun",
    axes=(
        _platform_axis(("A", "B")),
        _op_axis(),
        Axis("n_threads", 16, help="threads per co-running group"),
    ),
    metrics=(
        Metric("ddr_loss_pct", "%", "fast-tier loss under co-run"),
        Metric("t_cxl_corun_ns", "ns", "loaded slow-tier ToR residency"),
    ),
    build=_fig5_build,
    reduce=_fig5_reduce,
))


def _fig6_build(platform, cell) -> List[SimJob]:
    jobs = []
    for op in DEMAND_CLASSES:
        for scenario in ("ddr", "cxl", "both"):
            wls: List[WorkloadSpec] = []
            if scenario in ("ddr", "both"):
                wls.append(bw_test("ddr", op, 16, name="ddr",
                                   miku_managed=False))
            if scenario in ("cxl", "both"):
                wls.append(bw_test("cxl", op, 16, name="cxl"))
            jobs.append(_job(platform, wls, _BW_SIM_NS))
    return jobs


def _fig6_reduce(platform, cell, jobs, results) -> List[dict]:
    xs, ys = [], []
    for job, res in zip(jobs, results):
        xs.append(res.tor_inserts / res.sim_ns)
        ys.append(sum(res.bandwidth(w.name) for w in job.workloads))
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    vx = sum((x - mx) ** 2 for x in xs) ** 0.5
    vy = sum((y - my) ** 2 for y in ys) ** 0.5
    return [{"platform": cell["platform"],
             "pearson_r": cov / max(vx * vy, 1e-12)}]


register(Scenario(
    name="fig6_tor_correlation",
    title="ToR insertion rate vs delivered bandwidth (Pearson r)",
    figure="Fig. 6",
    module="fig5_corun",
    axes=(_platform_axis(),),
    metrics=(Metric("pearson_r", "", "paper reports r = 0.998"),),
    build=_fig6_build,
    reduce=_fig6_reduce,
))


# -- Fig. 7: LLC partitioning (Intel CAT analogue) ----------------------------


def _fig7_build(platform, cell) -> List[SimJob]:
    cap = platform.llc_capacity_mb
    alloc, wss_mb = cell["ddr_share"], cell["wss_mb"]
    a = bw_test(
        "ddr", OpClass.STORE, 16, name="ddr",
        wss_mb=wss_mb, llc_alloc_mb=alloc * cap, miku_managed=False,
    )
    b = bw_test(
        "cxl", OpClass.STORE, 16, name="cxl",
        wss_mb=wss_mb, llc_alloc_mb=(1.0 - alloc) * cap, miku_managed=False,
    )
    return [_job(platform, [a, b], _CORUN_SIM_NS)]


def _fig7_reduce(platform, cell, jobs, results) -> List[dict]:
    (res,) = results
    return [{
        "platform": cell["platform"],
        "wss_mb": cell["wss_mb"],
        "ddr_llc_share": cell["ddr_share"],
        "ddr_gbps": res.bandwidth("ddr"),
        "cxl_gbps": res.bandwidth("cxl"),
        "total_gbps": res.bandwidth("ddr") + res.bandwidth("cxl"),
    }]


register(Scenario(
    name="fig7_llc",
    title="LLC partition (CAT) sweep under tiered co-run",
    figure="Fig. 7",
    module="fig7_llc",
    axes=(
        _platform_axis(),
        Axis("wss_mb", (60.0, 120.0), help="per-workload working-set size"),
        Axis("ddr_share", (0.95, 0.75, 0.5, 0.25, 0.05),
             help="DDR workload's LLC allocation fraction"),
    ),
    metrics=(
        Metric("ddr_gbps", "GB/s"), Metric("cxl_gbps", "GB/s"),
        Metric("total_gbps", "GB/s"),
    ),
    build=_fig7_build,
    reduce=_fig7_reduce,
))


# -- Fig. 8: inter-core synchronization ---------------------------------------


def _fig8_build(platform, cell) -> List[SimJob]:
    wls = [lat_share()]
    if cell["bg_threads"] > 0:
        wls.append(bw_test(cell["bg_tier"], OpClass.LOAD, cell["bg_threads"],
                           name="bg", miku_managed=False))
    return [_job(platform, wls, 200_000.0, granularity=1)]


def _fig8_reduce(platform, cell, jobs, results) -> List[dict]:
    (res,) = results
    return [{
        "platform": cell["platform"],
        "bg_tier": cell["bg_tier"],
        "bg_threads": cell["bg_threads"],
        "cas_latency_ns": res.stats["lat-share"].mean_latency_ns(),
    }]


register(Scenario(
    name="fig8_sync",
    title="Cross-core CAS latency under tier background traffic",
    figure="Fig. 8",
    module="fig8_sync",
    axes=(
        _platform_axis(),
        Axis("bg_tier", _TWO_TIERS, help="background bw-test tier"),
        Axis("bg_threads", (0, 4, 8, 16), help="background thread count"),
    ),
    metrics=(Metric("cas_latency_ns", "ns"),),
    build=_fig8_build,
    reduce=_fig8_reduce,
))


# -- Fig. 9: service time vs concurrency --------------------------------------


def _fig9_build(platform, cell) -> List[SimJob]:
    wl = bw_test(cell["tier"], cell["op"], cell["threads"])
    return [_job(platform, [wl], _BW_SIM_NS)]


def _fig9_reduce(platform, cell, jobs, results) -> List[dict]:
    (job,), (res,) = jobs, results
    return [{
        "platform": cell["platform"],
        "tier": cell["tier"],
        "threads": cell["threads"],
        "service_time_ns": res.tier_counters[cell["tier"]].mean_service_time,
        "bandwidth_gbps": res.bandwidth(job.workloads[0].name),
    }]


register(Scenario(
    name="fig9_service",
    title="Memory service time vs thread count (MIKU's signal)",
    figure="Fig. 9",
    module="fig9_service",
    axes=(
        _platform_axis(),
        _op_axis(OpClass.LOAD),
        Axis("tier", _TWO_TIERS, help="tier under test"),
        Axis("threads", (1, 2, 4, 8, 16, 32), help="bw-test thread count"),
    ),
    metrics=(
        Metric("service_time_ns", "ns", "ToR-derived mean service time"),
        Metric("bandwidth_gbps", "GB/s"),
    ),
    build=_fig9_build,
    reduce=_fig9_reduce,
))


# -- Fig. 10: MIKU vs DataRacing vs Opt ---------------------------------------


def _fig10_build(platform, cell) -> List[SimJob]:
    op, n = cell["op"], cell["n_threads"]
    period_ns, cycles = cell["period_ns"], cell["cycles"]
    sim_ns = 2 * cycles * period_ns
    alt = alternating_bw_pair(op, n, period_ns)
    return [
        _job(platform, [bw_test("ddr", op, n, name="a")], _BW_SIM_NS),
        _job(platform, [bw_test("cxl", op, n, name="a")], _BW_SIM_NS),
        _job(platform, alt, sim_ns, window_ns=5_000.0),
        _job(platform, alt, sim_ns, window_ns=5_000.0, miku=True),
        _job(platform, alt, sim_ns, window_ns=5_000.0, miku=True),
    ]


def _fig10_reduce(platform, cell, jobs, results) -> List[dict]:
    opt_a, opt_c, racing, miku, mba = results

    def tier_split(res):
        # Each group spends half its time on each tier; attribute bandwidth
        # by the tier actually served per phase using the per-tier counters.
        g = 4  # granularity
        ddr_bytes = (res.tier_counters["ddr"].inserts
                     * platform.ddr.access_bytes * g)
        cxl_bytes = (res.tier_counters["cxl"].inserts
                     * platform.cxl.access_bytes * g)
        return ddr_bytes / res.sim_ns, cxl_bytes / res.sim_ns

    racing_ddr, racing_cxl = tier_split(racing)
    miku_ddr, miku_cxl = tier_split(miku)
    mba_ddr, mba_cxl = tier_split(mba)
    return [{
        "platform": cell["platform"],
        "op": cell["op"].value,
        "opt_ddr": opt_a.bandwidth("a"),
        "opt_cxl": opt_c.bandwidth("a"),
        "racing_ddr": racing_ddr,
        "racing_cxl": racing_cxl,
        "miku_ddr": miku_ddr,
        "miku_cxl": miku_cxl,
        "miku_mba_ddr": mba_ddr,
        "miku_mba_cxl": mba_cxl,
    }]


register(Scenario(
    name="fig10_miku",
    title="MIKU vs DataRacing vs Opt on alternating micro-benchmarks",
    figure="Fig. 10",
    module="fig10_miku",
    axes=(
        _platform_axis(),
        _op_axis(),
        Axis("n_threads", 16, help="threads per alternating group"),
        Axis("period_ns", 100_000.0, help="tier-alternation period"),
        Axis("cycles", 3, help="alternation cycles simulated"),
    ),
    metrics=(
        Metric("racing_ddr", "GB/s"), Metric("miku_ddr", "GB/s"),
        Metric("miku_cxl", "GB/s"), Metric("opt_ddr", "GB/s"),
    ),
    build=_fig10_build,
    reduce=_fig10_reduce,
))


# -- Fig. 11/12: co-located LLM serving (real jitted decode steps) ------------


def _fig11_run_cell(platform, cell, processes) -> List[dict]:
    """Serving-engine scenario (no DES): HBM-resident vs host-tier-resident
    instance, DataRacing vs MIKU vs Opt.  Heavy imports stay local so the
    registry imports fast."""
    del platform, processes
    import jax

    from repro.configs import get_arch
    from repro.core.controller import MikuConfig, MikuController
    from repro.core.littles_law import EstimatorConfig
    from repro.models.transformer import TransformerLM
    from repro.serving.engine import (
        EngineConfig,
        Request,
        ServingEngine,
        TieredServingCluster,
    )

    n_fast, n_slow = cell["n_req_fast"], cell["n_req_slow"]
    new_tokens, chunks = cell["new_tokens"], cell["chunks"]

    cfg = get_arch(cell["arch"]).smoke
    model = TransformerLM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    def mk(name, placement, n_req):
        e = ServingEngine(
            EngineConfig(name=name, model=cfg, max_slots=4, max_len=96,
                         placement=placement, stream_chunks=chunks),
            params,
        )
        for i in range(n_req):
            e.submit(Request(rid=i, prompt=list(range(1, 9)),
                             max_new_tokens=new_tokens))
        return e

    probe = mk("probe", "host", 0)
    chunk_service = probe.param_bytes / chunks / 16.0  # host link B/ns
    est = EstimatorConfig(
        t_fast=1.2e3,
        slow_read_threshold=8 * chunk_service,
        ewma=0.5,
        min_window_inserts=4,
        min_slow_inserts=1,
    )

    a = TieredServingCluster([mk("hbm", "device", n_fast)]).run(20000)
    b = TieredServingCluster([mk("host", "host", n_slow)]).run(20000)
    opt = (a["hbm"]["tokens_per_s"], b["host"]["tokens_per_s"])

    racing = TieredServingCluster(
        [mk("hbm", "device", n_fast), mk("host", "host", n_slow)]
    ).run(40000)

    ctl = MikuController(MikuConfig(levels=(1, 2, 4, 8)), est)
    miku = TieredServingCluster(
        [mk("hbm", "device", n_fast), mk("host", "host", n_slow)],
        controller=ctl, window_ns=3e4,
    ).run(40000)
    restricted = sum(1 for d in ctl.decisions if d.restricted)

    def row(variant, fast_tps, slow_tps, **extra):
        return {
            "variant": variant,
            "hbm_tokens_per_s": fast_tps,
            "host_tokens_per_s": slow_tps,
            "hbm_pct_of_opt": 100.0 * fast_tps / max(opt[0], 1e-9),
            "host_pct_of_opt": 100.0 * slow_tps / max(opt[1], 1e-9),
            **extra,
        }

    return [
        row("opt", *opt),
        row("racing", racing["hbm"]["tokens_per_s"],
            racing["host"]["tokens_per_s"]),
        row("miku", miku["hbm"]["tokens_per_s"],
            miku["host"]["tokens_per_s"],
            restricted_windows=restricted, windows=len(ctl.decisions)),
    ]


register(Scenario(
    name="fig11_llm",
    title="Co-located LLM serving: HBM vs host tier, racing vs MIKU",
    figure="Fig. 11-12",
    module="fig11_llm",
    axes=(
        Axis("arch", "llama31-8b", help="model architecture (smoke config)"),
        Axis("n_req_fast", 48), Axis("n_req_slow", 16),
        Axis("new_tokens", 24), Axis("chunks", 64),
    ),
    metrics=(
        Metric("hbm_tokens_per_s", "tok/s"),
        Metric("host_tokens_per_s", "tok/s"),
        Metric("hbm_pct_of_opt", "%"),
    ),
    run_cell=_fig11_run_cell,
    slow=True,
))


# -- Fig. 13: big-data (Spark/TPC-H) analog -----------------------------------


def _spark_workload(name, tier, miku_managed=True):
    # 16 executor threads with deep prefetched scan/shuffle streams — the
    # memory pressure that makes the paper's Spark runs collapse to 30%.
    return WorkloadSpec(
        name=name, op=OpClass.STORE, tier=tier, n_cores=16, mlp=160,
        phases=[(60_000.0, tier)] * 1, miku_managed=miku_managed,
    )


def _fig13_build(platform, cell) -> List[SimJob]:
    sim_ns = cell["sim_ns"]
    ddr = _spark_workload("ddr", "ddr", False)
    cxl = _spark_workload("cxl", "cxl")
    return [
        _job(platform, [ddr], sim_ns, window_ns=20_000.0),
        _job(platform, [cxl], sim_ns, window_ns=20_000.0),
        _job(platform, [ddr, cxl], sim_ns, window_ns=20_000.0),
        _job(platform, [ddr, cxl], sim_ns, window_ns=10_000.0, miku=True),
    ]


def _fig13_reduce(platform, cell, jobs, results) -> List[dict]:
    opt_a, opt_b, racing, miku = results
    opt = (opt_a.bandwidth("ddr"), opt_b.bandwidth("cxl"))

    def row(variant, res):
        return {
            "platform": cell["platform"],
            "variant": variant,
            "ddr_gbps": res.bandwidth("ddr"),
            "cxl_gbps": res.bandwidth("cxl"),
            "ddr_pct_of_opt": 100.0 * res.bandwidth("ddr") / max(opt[0], 1e-9),
            "cxl_pct_of_opt": 100.0 * res.bandwidth("cxl") / max(opt[1], 1e-9),
        }

    return [
        {"platform": cell["platform"], "variant": "opt",
         "ddr_gbps": opt[0], "cxl_gbps": opt[1],
         "ddr_pct_of_opt": 100.0, "cxl_pct_of_opt": 100.0},
        row("racing", racing),
        row("miku", miku),
    ]


register(Scenario(
    name="fig13_spark",
    title="Shuffle-heavy big-data phases co-running, racing vs MIKU",
    figure="Fig. 13",
    module="fig13_spark",
    axes=(
        _platform_axis(),
        Axis("sim_ns", 400_000.0, help="simulated horizon"),
    ),
    metrics=(
        Metric("ddr_pct_of_opt", "%", "paper: MIKU >= 81%"),
        Metric("cxl_pct_of_opt", "%"),
    ),
    build=_fig13_build,
    reduce=_fig13_reduce,
))


# -- Fig. 14: concurrent-hashmap (YCSB) analog --------------------------------


def _kv_workloads(name, tier, ratio, managed) -> List[WorkloadSpec]:
    # ratio r reads per write: split cores between get (load) and insert
    # (store) streams; hash probing limits MLP.
    total = 16
    readers = round(total * ratio / (ratio + 1))
    wls = []
    if readers:
        wls.append(WorkloadSpec(name=f"{name}-get", op=OpClass.LOAD,
                                tier=tier, n_cores=readers, mlp=32,
                                miku_managed=managed))
    if total - readers:
        wls.append(WorkloadSpec(name=f"{name}-ins", op=OpClass.STORE,
                                tier=tier, n_cores=total - readers, mlp=128,
                                miku_managed=managed))
    return wls


def _fig14_build(platform, cell) -> List[SimJob]:
    sim_ns = cell["sim_ns"]
    wls = (_kv_workloads("ddr", "ddr", cell["ratio"], False)
           + _kv_workloads("cxl", "cxl", cell["ratio"], True))
    return [
        _job(platform, wls, sim_ns, window_ns=20_000.0),
        _job(platform, wls, sim_ns, window_ns=10_000.0, miku=True),
    ]


def _fig14_reduce(platform, cell, jobs, results) -> List[dict]:
    race, miku = results
    ddr = [w for w in jobs[0].workloads if w.name.startswith("ddr")]
    cxl = [w for w in jobs[0].workloads if w.name.startswith("cxl")]
    race_ddr = sum(race.bandwidth(w.name) for w in ddr)
    miku_ddr = sum(miku.bandwidth(w.name) for w in ddr)
    miku_cxl = sum(miku.bandwidth(w.name) for w in cxl)
    return [{
        "platform": cell["platform"],
        "ratio": cell["ratio"],
        "racing_ddr_gbps": race_ddr,
        "miku_ddr_gbps": miku_ddr,
        "miku_cxl_gbps": miku_cxl,
        "miku_gain": miku_ddr / max(race_ddr, 1e-9),
    }]


register(Scenario(
    name="fig14_kv",
    title="Concurrent hashmap (YCSB) read:write sweep, racing vs MIKU",
    figure="Fig. 14",
    module="fig14_kv",
    axes=(
        _platform_axis(),
        Axis("ratio", (0, 1, 4), help="reads per write"),
        Axis("sim_ns", 300_000.0, help="simulated horizon"),
    ),
    metrics=(
        Metric("racing_ddr_gbps", "GB/s"), Metric("miku_ddr_gbps", "GB/s"),
        Metric("miku_gain", "x", "MIKU / racing fast-tier bandwidth"),
    ),
    build=_fig14_build,
    reduce=_fig14_reduce,
))


# -- N-tier scenarios the two-tier API could not express ----------------------


def _corun3_build(platform, cell) -> List[SimJob]:
    op, n, sim_ns = cell["op"], cell["n_threads"], cell["sim_ns"]
    a = bw_test("ddr", op, n, name="ddr", miku_managed=False)
    b = bw_test("cxl", op, n, name="cxl")
    c = bw_test("cxl_sw", op, n, name="cxl_sw")
    return [
        _job(platform, [a], _BW_SIM_NS),
        _job(platform, [b], _BW_SIM_NS),
        _job(platform, [c], _BW_SIM_NS),
        _job(platform, [a, b, c], sim_ns, miku=cell["miku"]),
    ]


def _corun3_reduce(platform, cell, jobs, results) -> List[dict]:
    a, b, c, corun = results
    alone = {
        "ddr": a.bandwidth("ddr"),
        "cxl": b.bandwidth("cxl"),
        "cxl_sw": c.bandwidth("cxl_sw"),
    }
    row = {
        "platform": cell["platform"],
        "op": cell["op"].value,
        "miku": cell["miku"],
    }
    for tier in ("ddr", "cxl", "cxl_sw"):
        row[f"{tier}_alone_gbps"] = alone[tier]
        row[f"{tier}_corun_gbps"] = corun.bandwidth(tier)
        row[f"t_{tier}_corun_ns"] = corun.tier_counters[tier].mean_service_time
    row["ddr_loss_pct"] = 100.0 * (
        1 - corun.bandwidth("ddr") / max(alone["ddr"], 1e-9)
    )
    return [row]


register(Scenario(
    name="corun3_switch",
    title="Three-tier co-run: DDR + local CXL + CXL-over-switch",
    module="",  # no legacy figure module — registry/CLI native
    axes=(
        _platform_axis("A-switch"),
        _op_axis(),
        Axis("n_threads", 16, help="threads per co-running group"),
        Axis("miku", (False, True), help="enable the MIKU controller"),
        Axis("sim_ns", 300_000.0, help="co-run simulated horizon"),
    ),
    metrics=(
        Metric("ddr_loss_pct", "%", "fast-tier loss under 3-tier co-run"),
        Metric("cxl_sw_corun_gbps", "GB/s", "switched-CXL bandwidth"),
        Metric("t_cxl_sw_corun_ns", "ns", "switched-CXL ToR residency"),
    ),
    build=_corun3_build,
    reduce=_corun3_reduce,
))


_CORUN3P_SLOW = ("cxl", "cxl_sw")


def _corun3p_build(platform, cell) -> List[SimJob]:
    op, n, sim_ns = cell["op"], cell["n_threads"], cell["sim_ns"]
    law = cell["law"]
    a = bw_test("ddr", op, n, name="ddr", miku_managed=False)
    b = bw_test("cxl", op, n, name="cxl")
    c = bw_test("cxl_sw", op, n, name="cxl_sw")
    return [
        _job(platform, [a], _BW_SIM_NS),
        _job(platform, [b], _BW_SIM_NS),
        _job(platform, [c], _BW_SIM_NS),
        _job(platform, [a, b, c], sim_ns,
             miku=law != "racing",
             miku_law=law if law != "racing" else "pertier"),
    ]


def _corun3p_reduce(platform, cell, jobs, results) -> List[dict]:
    a, b, c, corun = results
    alone = {
        "ddr": a.bandwidth("ddr"),
        "cxl": b.bandwidth("cxl"),
        "cxl_sw": c.bandwidth("cxl_sw"),
    }
    row = {
        "platform": cell["platform"],
        "op": cell["op"].value,
        "law": cell["law"],
    }
    for tier in ("ddr", "cxl", "cxl_sw"):
        row[f"{tier}_alone_gbps"] = alone[tier]
        row[f"{tier}_corun_gbps"] = corun.bandwidth(tier)
    row["ddr_pct_of_opt"] = 100.0 * corun.bandwidth("ddr") / max(
        alone["ddr"], 1e-9
    )
    # Per-slow-tier ladder telemetry — the thing the merged contract cannot
    # differentiate (its broadcast makes both columns identical).
    top = 16.0  # ladder ceiling stands in for "unrestricted" in the mean
    for tier in _CORUN3P_SLOW:
        if cell["law"] == "racing":
            row[f"{tier}_restricted_windows"] = 0
            row[f"{tier}_mean_cap"] = top
            row[f"{tier}_mean_rate"] = 1.0
            continue
        ds = [d.for_tier(tier) for d in corun.decisions]
        caps = [float(d.max_concurrency) if d.max_concurrency is not None
                else top for d in ds]
        row[f"{tier}_restricted_windows"] = sum(1 for d in ds if d.restricted)
        row[f"{tier}_mean_cap"] = sum(caps) / max(len(caps), 1)
        row[f"{tier}_mean_rate"] = (
            sum(d.rate_factor for d in ds) / max(len(ds), 1)
        )
    return [row]


register(Scenario(
    name="corun3_pertier",
    title="Per-tier vs merged MIKU ladders on the three-tier co-run",
    module="",  # registry/CLI native
    axes=(
        _platform_axis("A-switch"),
        _op_axis(OpClass.STORE),
        Axis("law", ("racing", "merged", "pertier"),
             help="control law for the co-run "
                  "(racing = no controller, merged = MergedSlowPolicy "
                  "broadcast, pertier = per-slow-tier ensemble)"),
        Axis("n_threads", 16, help="threads per co-running group"),
        Axis("sim_ns", 300_000.0, help="co-run simulated horizon"),
    ),
    metrics=(
        Metric("ddr_pct_of_opt", "%",
               "fast-tier recovery vs running alone"),
        Metric("cxl_mean_cap", "cores",
               "mean local-CXL core cap over the run"),
        Metric("cxl_sw_mean_cap", "cores",
               "mean switched-CXL core cap (per-tier law: < cxl_mean_cap)"),
        Metric("cxl_sw_restricted_windows", "",
               "windows the switch tier spent restricted"),
    ),
    build=_corun3p_build,
    reduce=_corun3p_reduce,
))


# -- Sweep-scale co-run grid (the batched lane's showcase) --------------------


def _corun_sweep_build(platform, cell) -> List[SimJob]:
    op, n = cell["op"], cell["threads"]
    wls = [
        bw_test("ddr", op, n, name="ddr", mlp=cell["mlp"],
                miku_managed=False),
        bw_test("cxl", op, n, name="cxl", mlp=cell["mlp"]),
    ]
    return [_job(platform, wls, cell["sim_ns"], miku=cell["miku"])]


def _corun_sweep_reduce(platform, cell, jobs, results) -> List[dict]:
    (res,) = results
    return [{
        "platform": cell["platform"],
        "op": cell["op"].value,
        "threads": cell["threads"],
        "mlp": cell["mlp"],
        "miku": cell["miku"],
        "ddr_gbps": res.bandwidth("ddr"),
        "cxl_gbps": res.bandwidth("cxl"),
        "restricted_windows": sum(
            1 for d in res.decisions if d.restricted
        ),
    }]


register(Scenario(
    name="corun_sweep",
    title="Sweep-scale co-run grid (96 cells): threads x op x MIKU x platform",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(("A", "B")),
        _op_axis(),
        Axis("threads", (2, 4, 8, 16), help="threads per co-running group"),
        Axis("miku", (False, True), help="enable the MIKU controller"),
        Axis("mlp", (96, 160), help="outstanding cachelines per core"),
        Axis("sim_ns", 300_000.0, help="co-run simulated horizon"),
    ),
    metrics=(
        Metric("ddr_gbps", "GB/s", "fast-tier co-run bandwidth"),
        Metric("cxl_gbps", "GB/s", "slow-tier co-run bandwidth"),
        Metric("restricted_windows", "", "windows MIKU spent restricting"),
    ),
    build=_corun_sweep_build,
    reduce=_corun_sweep_reduce,
    slow=True,
))


register(Scenario(
    name="corun_sweep_1k",
    title="Kilo-cell co-run grid (1024 cells): the batched lane at scale",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(("A", "B")),
        _op_axis((OpClass.LOAD, OpClass.STORE)),
        Axis("threads", (1, 2, 3, 4, 6, 8, 12, 16),
             help="threads per co-running group"),
        Axis("miku", (False, True), help="enable the MIKU controller"),
        Axis("mlp", (32, 40, 48, 56, 64, 80, 96, 112,
                     128, 144, 160, 176, 192, 208, 224, 256),
             help="outstanding cachelines per core"),
        Axis("sim_ns", 100_000.0, help="co-run simulated horizon"),
    ),
    metrics=(
        Metric("ddr_gbps", "GB/s", "fast-tier co-run bandwidth"),
        Metric("cxl_gbps", "GB/s", "slow-tier co-run bandwidth"),
        Metric("restricted_windows", "", "windows MIKU spent restricting"),
    ),
    build=_corun_sweep_build,
    reduce=_corun_sweep_reduce,
    slow=True,
))


# -- Tiering subsystem scenarios (repro.tiering) ------------------------------


def _mig_spec(policy: str, managed: bool, drift: float, mig_cores: int,
              mig_mlp: int):
    """TieringSpec for the migrate_interference co-run: the CXL demand
    workload's pages all start slow, with a drifting hot set that keeps the
    promotion/demotion engine busy for the whole run."""
    from repro.tiering import HotSetPattern, RegionSpec, TieringSpec

    return TieringSpec(
        regions=(RegionSpec(
            workload="cxl",
            n_pages=2048,
            placement={"cxl": 1.0},
            pattern=HotSetPattern(hot_fraction=0.125, hot_weight=0.9,
                                  drift_pages=drift),
        ),),
        policy=policy,
        fast_capacity_pages=384,
        mig_cores=mig_cores,
        mig_mlp=mig_mlp,
        mig_miku_managed=managed,
    )


_MIGRATE_VARIANTS = ("demand_only", "naive", "miku")


def _migif_build(platform, cell) -> List[SimJob]:
    op, n, sim_ns = cell["op"], cell["n_threads"], cell["sim_ns"]
    drift = cell["drift_pages"]
    a = bw_test("ddr", op, n, name="ddr", miku_managed=False)
    b = bw_test("cxl", op, n, name="cxl")
    wls = [a, b]
    # naive: the migration daemon races outside MIKU's reach (hotness_lru,
    # unmanaged, aggressive); miku: the same candidates but migration is a
    # MIKU-governed request class (managed workloads + coordinated deferral).
    naive = _mig_spec("hotness_lru", managed=False, drift=drift,
                      mig_cores=cell["mig_cores"], mig_mlp=cell["mig_mlp"])
    coord = _mig_spec("miku_coordinated", managed=True, drift=drift,
                      mig_cores=cell["mig_cores"], mig_mlp=cell["mig_mlp"])
    return [
        _job(platform, wls, sim_ns, miku=True),
        _job(platform, wls, sim_ns, miku=True, tiering=naive),
        _job(platform, wls, sim_ns, miku=True, tiering=coord),
    ]


def _migif_reduce(platform, cell, jobs, results) -> List[dict]:
    baseline = results[0].bandwidth("ddr")
    rows = []
    for variant, res in zip(_MIGRATE_VARIANTS, results):
        row = {
            "platform": cell["platform"],
            "op": cell["op"].value,
            "variant": variant,
            "ddr_gbps": res.bandwidth("ddr"),
            "cxl_gbps": res.bandwidth("cxl"),
            "ddr_pct_of_demand_only":
                100.0 * res.bandwidth("ddr") / max(baseline, 1e-9),
        }
        t = res.tiering
        row["mig_gbps"] = (
            res.bandwidth("mig-cxl") if t is not None else 0.0
        )
        row["pages_promoted"] = t["pages_promoted"] if t else 0
        row["pages_demoted"] = t["pages_demoted"] if t else 0
        row["deferred_jobs"] = t["deferred_jobs"] if t else 0
        row["cxl_fast_fraction"] = (
            t["fast_fraction"]["cxl"] if t else 0.0
        )
        rows.append(row)
    return rows


register(Scenario(
    name="migrate_interference",
    title="Migration traffic as a request class: naive vs MIKU-coordinated",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(),
        _op_axis(OpClass.LOAD),
        Axis("n_threads", 16, help="threads per demand group"),
        Axis("drift_pages", 64.0, help="hot-set drift per window (churn)"),
        Axis("mig_cores", 8, help="migration-daemon cores per slow tier"),
        Axis("mig_mlp", 160, help="migration-daemon MLP per core"),
        Axis("sim_ns", 300_000.0, help="co-run simulated horizon"),
    ),
    metrics=(
        Metric("ddr_pct_of_demand_only", "%",
               "DDR demand bandwidth vs the no-migration co-run"),
        Metric("mig_gbps", "GB/s", "migration-engine copy bandwidth"),
        Metric("pages_promoted", "pages"),
        Metric("deferred_jobs", "",
               "migrations MIKU coordination pushed past throttled windows"),
    ),
    build=_migif_build,
    reduce=_migif_reduce,
))


def _tierpol_build(platform, cell) -> List[SimJob]:
    from repro.tiering import HotSetPattern, RegionSpec, TieringSpec

    op, n, sim_ns = cell["op"], cell["n_threads"], cell["sim_ns"]
    n_pages = 1024
    # A quarter of the region starts fast; the slow remainder is spread
    # evenly over however many slow tiers the platform has (the 3-tier
    # A-switch cell exercises promotion from two different slow devices).
    slow = platform.tier_names[1:]
    placement = {"ddr": 0.25}
    for t in slow:
        placement[t] = 0.75 / len(slow)
    # The hot set starts inside the slow-resident portion (page n/4 is the
    # first slow page under the contiguous initial placement): a static
    # placement serves it from the slow tier(s) forever, a hotness policy
    # promotes it — and then has to chase it as it drifts.
    spec = TieringSpec(
        regions=(RegionSpec(
            workload="app",
            n_pages=n_pages,
            placement=placement,
            pattern=HotSetPattern(hot_fraction=0.125, hot_weight=0.9,
                                  drift_pages=cell["drift_pages"],
                                  hot_start=n_pages // 4),
        ),),
        policy=cell["policy"],
        fast_capacity_pages=320,
        mig_cores=8,
    )
    app = bw_test("ddr", op, n, name="app", miku_managed=False)
    return [_job(platform, [app], sim_ns, tiering=spec)]


def _tierpol_reduce(platform, cell, jobs, results) -> List[dict]:
    (res,) = results
    t = res.tiering
    return [{
        "platform": cell["platform"],
        "policy": cell["policy"],
        "drift_pages": cell["drift_pages"],
        "app_gbps": res.bandwidth("app"),
        "app_fast_fraction": t["fast_fraction"]["app"],
        "pages_promoted": t["pages_promoted"],
        "pages_demoted": t["pages_demoted"],
        "migrated_gb": t["migrated_bytes"] / 1e9,
    }]


register(Scenario(
    name="tiering_policies",
    title="Hot-set drift vs tiering policy on 2- and 3-tier platforms",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(("A", "A-switch")),
        Axis("policy", ("static", "hotness_lru"),
             help="tiering policy (repro.tiering.policies registry)"),
        _op_axis(OpClass.LOAD),
        Axis("n_threads", 16, help="app thread count"),
        Axis("drift_pages", 4.0,
             help="hot-set drift per window (fast drift outruns migration "
                  "bandwidth and the copy tax wins — try 16)"),
        Axis("sim_ns", 300_000.0, help="simulated horizon"),
    ),
    metrics=(
        Metric("app_gbps", "GB/s", "delivered app bandwidth"),
        Metric("app_fast_fraction", "",
               "access-weighted share served by the fast tier at the end"),
        Metric("pages_promoted", "pages"),
        Metric("migrated_gb", "GB", "total migration copy traffic"),
    ),
    build=_tierpol_build,
    reduce=_tierpol_reduce,
))


def _numa_build(platform, cell) -> List[SimJob]:
    op, n, f = cell["op"], cell["n_threads"], cell["remote_fraction"]
    striped = WorkloadSpec(
        name="striped", op=op, tier="ddr", n_cores=n, mlp=160,
        miku_managed=False,
        placement={"ddr": 1.0 - f, "ddr_remote": f},
    )
    cxl_bg = bw_test("cxl", op, n, name="cxl")
    return [
        _job(platform, [striped], cell["sim_ns"]),
        _job(platform, [striped, cxl_bg], cell["sim_ns"]),
    ]


def _numa_reduce(platform, cell, jobs, results) -> List[dict]:
    alone, corun = results
    return [{
        "platform": cell["platform"],
        "op": cell["op"].value,
        "remote_fraction": cell["remote_fraction"],
        "striped_alone_gbps": alone.bandwidth("striped"),
        "striped_corun_gbps": corun.bandwidth("striped"),
        "cxl_corun_gbps": corun.bandwidth("cxl"),
        "striped_avg_lat_ns": alone.stats["striped"].mean_latency_ns(),
        "local_inserts": alone.tier_counters["ddr"].inserts,
        "remote_inserts": alone.tier_counters["ddr_remote"].inserts,
    }]


register(Scenario(
    name="numa_remote",
    title="NUMA-remote DDR striping (placement vector) under CXL co-run",
    module="",  # registry/CLI native
    axes=(
        _platform_axis("A-numa"),
        _op_axis(OpClass.LOAD),
        Axis("remote_fraction", (0.0, 0.25, 0.5),
             help="request fraction striped to the remote socket's DDR"),
        Axis("n_threads", 16, help="striped-workload thread count"),
        Axis("sim_ns", 200_000.0, help="simulated horizon"),
    ),
    metrics=(
        Metric("striped_alone_gbps", "GB/s",
               "NUMA striping adds DIMM parallelism"),
        Metric("striped_avg_lat_ns", "ns"),
        Metric("remote_inserts", "", "requests served by the remote pool"),
    ),
    build=_numa_build,
    reduce=_numa_reduce,
))


# -- Fabric scenarios (repro.fabric: routed switch topologies) ----------------
# These scenarios carry no platform axis: each cell *builds* its platform
# from topology knob axes via the fabric factories (importing repro.fabric
# above also registers the named "A-direct"/"A-spine" platforms for the
# CLI).  Fabric jobs run scalar-only — the batched lane screens them out
# with the explicit "fabric_topology" fallback reason.

_FABRIC_SIM_NS = 300_000.0


def _fabric_spine_build(platform, cell) -> List[SimJob]:
    del platform  # built from the topology axes, not the platform axis
    op, n, law = cell["op"], cell["n_threads"], cell["law"]
    pm = spine_leaf_platform(
        spine_slots=cell["spine_slots"],
        spine_service_ns=cell["spine_service_ns"],
    )
    ddr = bw_test("ddr", op, n, name="ddr", miku_managed=False,
                  host="host0")
    cxl0 = bw_test("cxl", op, n, name="cxl0", host="host0")
    cxl1 = bw_test("cxl", op, n, name="cxl1", host="host1")
    return [
        _job(pm, [ddr], _BW_SIM_NS),
        _job(pm, [cxl0], _BW_SIM_NS),
        _job(pm, [ddr, cxl0, cxl1], cell["sim_ns"],
             miku=law != "racing",
             miku_law="peredge" if law != "racing" else "pertier"),
    ]


def _fabric_spine_reduce(platform, cell, jobs, results) -> List[dict]:
    del platform, jobs
    ddr_alone, cxl_alone, corun = results
    fab = corun.fabric or {}
    spine = fab.get("spine-cxl", {})
    row = {
        "law": cell["law"],
        "op": cell["op"].value,
        "ddr_alone_gbps": ddr_alone.bandwidth("ddr"),
        "cxl_alone_gbps": cxl_alone.bandwidth("cxl0"),
        "ddr_corun_gbps": corun.bandwidth("ddr"),
        "cxl0_corun_gbps": corun.bandwidth("cxl0"),
        "cxl1_corun_gbps": corun.bandwidth("cxl1"),
        "ddr_pct_of_alone": 100.0 * corun.bandwidth("ddr")
        / max(ddr_alone.bandwidth("ddr"), 1e-9),
        "tor_peak": corun.tor_peak,
        "spine_stall_events": spine.get("stall_events", 0),
        "spine_peak_occupancy": spine.get("peak_occupancy", 0),
    }
    if cell["law"] == "peredge" and corun.decisions:
        row["spine_restricted_windows"] = sum(
            1 for d in corun.decisions
            if d.for_tier("spine-cxl").restricted
        )
    else:
        row["spine_restricted_windows"] = 0
    return [row]


register(Scenario(
    name="fabric_spine_congestion",
    title="Two hosts share a spine downlink: congestion collapse vs "
          "per-edge MIKU recovery",
    module="",  # registry/CLI native
    axes=(
        _op_axis(OpClass.LOAD),
        Axis("law", ("racing", "peredge"),
             help="control law: racing (no controller) or the per-edge "
                  "ladder ensemble"),
        Axis("n_threads", 16, help="threads per workload"),
        Axis("spine_slots", 8, help="shared spine downlink port servers"),
        Axis("spine_service_ns", 36.0,
             help="spine per-cacheline service time"),
        Axis("sim_ns", _FABRIC_SIM_NS, help="simulated horizon"),
    ),
    metrics=(
        Metric("ddr_corun_gbps", "GB/s",
               "DDR under spine-stalled CXL ToR monopolization"),
        Metric("ddr_pct_of_alone", "%",
               "racing collapses DDR; per-edge MIKU recovers it"),
        Metric("spine_stall_events", "",
               "backpressure stalls at the shared spine port"),
        Metric("spine_restricted_windows", "",
               "windows the spine edge ladder spent restricted"),
    ),
    build=_fabric_spine_build,
    reduce=_fabric_spine_reduce,
))


def _fabric_port_build(platform, cell) -> List[SimJob]:
    del platform
    pm = single_switch_platform(
        port_slots=cell["port_slots"],
        port_service_ns=cell["port_service_ns"],
        port_queue=cell["port_queue"],
    )
    wl = bw_test("cxl", cell["op"], cell["n_threads"], name="cxl",
                 host="host0")
    return [_job(pm, [wl], cell["sim_ns"])]


def _fabric_port_reduce(platform, cell, jobs, results) -> List[dict]:
    del platform, jobs
    (res,) = results
    port = (res.fabric or {}).get("sw0-cxl", {})
    return [{
        "op": cell["op"].value,
        "port_queue": cell["port_queue"],
        "cxl_gbps": res.bandwidth("cxl"),
        "tor_peak": res.tor_peak,
        "port_peak_occupancy": port.get("peak_occupancy", 0),
        "port_entry_limit": port.get("entry_limit", 0),
        "port_stall_events": port.get("stall_events", 0),
        "port_limited": int(
            port.get("peak_occupancy", 0) >= port.get("entry_limit", 0)
        ),
    }]


register(Scenario(
    name="fabric_port_overflow",
    title="Port-queue limit vs ToR limit crossover behind one switch port",
    module="",  # registry/CLI native
    axes=(
        _op_axis(OpClass.LOAD),
        Axis("port_queue", (64, 256, 1024, 2048),
             help="switch port entry limit (cachelines; ToR is 2048)"),
        Axis("port_slots", 8, help="switch port servers"),
        Axis("port_service_ns", 36.0,
             help="port per-cacheline service time"),
        Axis("n_threads", 8, help="CXL workload thread count"),
        Axis("sim_ns", _BW_SIM_NS, help="simulated horizon"),
    ),
    metrics=(
        Metric("cxl_gbps", "GB/s", "port-service-bound throughput"),
        Metric("port_peak_occupancy", "",
               "== entry limit while the port binds; < once the ToR does"),
        Metric("port_stall_events", "",
               "admission backpressure events (0 once the ToR binds)"),
        Metric("port_limited", "", "1 while the port queue is the binding "
               "limit, 0 past the crossover"),
    ),
    build=_fabric_port_build,
    reduce=_fabric_port_reduce,
))


def _fabric_miku_build(platform, cell) -> List[SimJob]:
    del platform
    op, n, law = cell["op"], cell["n_threads"], cell["law"]
    # Narrow uplink1 (host1) behind a wide spine: host1's CXL stream is
    # the congestion source, host0's is innocent.
    pm = spine_leaf_platform(
        uplink_slots=(16, cell["narrow_slots"]),
        uplink_service_ns=(18.0, cell["narrow_service_ns"]),
        spine_slots=14,
        spine_service_ns=18.0,
    )
    ddr = bw_test("ddr", op, n, name="ddr", miku_managed=False,
                  host="host0")
    cxl0 = bw_test("cxl", op, n, name="cxl0", host="host0")
    cxl1 = bw_test("cxl", op, n, name="cxl1", host="host1")
    return [
        _job(pm, [cxl0], _BW_SIM_NS),
        _job(pm, [ddr, cxl0, cxl1], cell["sim_ns"],
             miku=law != "racing",
             miku_law=law if law != "racing" else "pertier"),
    ]


def _fabric_miku_reduce(platform, cell, jobs, results) -> List[dict]:
    del platform, jobs
    cxl0_alone, corun = results
    law = cell["law"]
    row = {
        "law": law,
        "op": cell["op"].value,
        "ddr_corun_gbps": corun.bandwidth("ddr"),
        "cxl0_corun_gbps": corun.bandwidth("cxl0"),
        "cxl1_corun_gbps": corun.bandwidth("cxl1"),
        "cxl0_alone_gbps": cxl0_alone.bandwidth("cxl0"),
        "cxl0_pct_of_alone": 100.0 * corun.bandwidth("cxl0")
        / max(cxl0_alone.bandwidth("cxl0"), 1e-9),
        "tor_peak": corun.tor_peak,
    }
    # Where did the restriction land: the whole cxl tier (pertier punishes
    # the innocent host too) or just the congested uplink edge (peredge)?
    cxl_restricted = uplink1_restricted = 0
    for d in corun.decisions:
        if "cxl" in d.tiers and d.for_tier("cxl").restricted:
            cxl_restricted += 1
        if "uplink1" in d.tiers and d.for_tier("uplink1").restricted:
            uplink1_restricted += 1
    row["cxl_restricted_windows"] = cxl_restricted
    row["uplink1_restricted_windows"] = uplink1_restricted
    return [row]


register(Scenario(
    name="fabric_miku",
    title="Asymmetric uplinks: per-edge ladders throttle only the "
          "congested route",
    module="",  # registry/CLI native
    axes=(
        _op_axis(OpClass.LOAD),
        Axis("law", ("racing", "pertier", "peredge"),
             help="control law under asymmetric uplink congestion"),
        Axis("n_threads", 16, help="threads per workload"),
        Axis("narrow_slots", 4, help="host1 uplink port servers"),
        Axis("narrow_service_ns", 36.0,
             help="host1 uplink per-cacheline service time"),
        Axis("sim_ns", _FABRIC_SIM_NS, help="simulated horizon"),
    ),
    metrics=(
        Metric("ddr_corun_gbps", "GB/s", "fast tier held near solo"),
        Metric("cxl0_pct_of_alone", "%",
               "the innocent host's CXL bandwidth — pertier punishes it, "
               "peredge spares it"),
        Metric("cxl_restricted_windows", "",
               "windows the whole cxl tier spent restricted"),
        Metric("uplink1_restricted_windows", "",
               "windows only the congested uplink spent restricted"),
    ),
    build=_fabric_miku_build,
    reduce=_fabric_miku_reduce,
))


# -- SLO scenarios: open-loop offered load (repro.workload) -------------------

_SLO_SIM_NS = 300_000.0
#: p99 latency budget for the serving tenant; NaN percentiles (a window
#: with zero completions) never satisfy ``p99 <= budget`` and so count as
#: blown.
_SLO_BUDGET_NS = 10_000.0
#: Placement axis: the serving tenant's DDR interleave fraction.
_SLO_PLACEMENTS = {"ddr": 1.0, "split": 0.5, "cxl_heavy": 0.25}


def _slo_workloads(cell, arrival) -> List[WorkloadSpec]:
    """The SLO co-run: an open-loop latency-critical serving tenant
    (never MIKU-managed) against a closed-loop CXL bandwidth hog (the
    MIKU throttling candidate)."""
    serve = serve_test(
        4, arrival=arrival,
        ddr_fraction=_SLO_PLACEMENTS[cell["placement"]],
    )
    hog = bw_test("cxl", cell["op"], 16, name="hog")
    return [serve, hog]


def _slo_knee_build(platform, cell) -> List[SimJob]:
    arr = ArrivalSpec("poisson", rate=cell["rate"], seed=7)
    return [_job(platform, _slo_workloads(cell, arr), cell["sim_ns"],
                 miku=cell["policy"] == "miku", latency_hist=True)]


def _slo_knee_reduce(platform, cell, jobs, results) -> List[dict]:
    del platform, jobs
    (res,) = results
    st = res.stats["serve"]
    a = res.arrival["serve"]
    hist = st.latency_hist
    p99 = st.percentile_ns(0.99)
    budget = cell["budget_ns"]
    return [{
        "placement": cell["placement"],
        "policy": cell["policy"],
        "rate_rpns": cell["rate"],
        "p50_ns": st.percentile_ns(0.50),
        "p95_ns": hist.percentile(0.95) if hist is not None else float("nan"),
        "p99_ns": p99,
        "budget_ns": budget,
        # `not (p99 <= budget)` so a NaN p99 (zero completions) is blown.
        "budget_blown": int(not (p99 <= budget)),
        "generated": a["generated"],
        "issued": a["issued"],
        "shed": a["shed"],
        "backlog": a["backlog"],
    }]


register(Scenario(
    name="slo_knee",
    title="Offered-load sweep: where each placement/policy blows the "
          "p99 latency budget",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(),
        _op_axis(OpClass.LOAD),
        Axis("placement", ("ddr", "cxl_heavy"),
             help="serving tenant's tier placement "
                  "(DDR interleave fraction: ddr=1.0, cxl_heavy=0.25)"),
        Axis("policy", ("racing", "miku"),
             help="control policy over the co-running CXL hog"),
        Axis("rate", (0.002, 0.005, 0.010, 0.020, 0.032),
             help="offered arrival rate (requests/ns), Poisson",
             parse=float),
        Axis("budget_ns", _SLO_BUDGET_NS,
             help="p99 latency budget defining the knee", parse=float),
        Axis("sim_ns", _SLO_SIM_NS, help="simulated horizon"),
    ),
    metrics=(
        Metric("p50_ns", "ns", "serving tenant median latency "
               "(arrival to retire, backlog wait included)"),
        Metric("p95_ns", "ns", "from the mergeable latency histogram"),
        Metric("p99_ns", "ns", "the SLO-governing tail"),
        Metric("budget_blown", "", "1 when p99 exceeds budget_ns — the "
               "knee is the lowest blown rate; CXL-heavy placement knees "
               "before DDR, MIKU moves the knee above racing"),
        Metric("generated", "", "open-loop arrivals generated"),
        Metric("issued", "", "arrivals issued into the pipeline"),
        Metric("shed", "", "arrivals shed at the queue limit"),
        Metric("backlog", "", "arrival-queue depth at horizon end — "
               "nonzero means the offered rate exceeds capacity"),
    ),
    build=_slo_knee_build,
    reduce=_slo_knee_reduce,
))


def _flash_crowd_build(platform, cell) -> List[SimJob]:
    arr = ArrivalSpec(
        "flash_crowd", rate=cell["rate"], seed=7,
        t_step_ns=cell["t_step_ns"], surge=cell["surge"],
        surge_ns=cell["surge_ns"],
    )
    return [_job(platform, _slo_workloads(cell, arr), cell["sim_ns"],
                 miku=cell["policy"] == "miku", latency_hist=True,
                 record_windows=True)]


def _flash_crowd_reduce(platform, cell, jobs, results) -> List[dict]:
    del platform
    (job,), (res,) = jobs, results
    st = res.stats["serve"]
    a = res.arrival["serve"]
    t0 = cell["t_step_ns"]
    t1 = t0 + cell["surge_ns"]
    peak_q = 0
    surge_hist = LatencyHistogram()
    recovery_windows = 0
    for rec in res.window_records or ():
        arr_blk = rec.get("arrival", {}).get("serve")
        if arr_blk is None:
            continue
        peak_q = max(peak_q, arr_blk["queue_depth"])
        w_end = rec["t_ns"]
        w_start = w_end - job.window_ns
        if w_start < t1 and w_end > t0:  # window overlaps the surge
            blob = rec.get("latency_hist", {}).get("serve")
            if blob:
                surge_hist = surge_hist.merge(
                    LatencyHistogram.from_jsonable(blob))
        elif w_start >= t1 and arr_blk["queue_depth"] > 0:
            recovery_windows += 1
    return [{
        "placement": cell["placement"],
        "policy": cell["policy"],
        "peak_queue_depth": peak_q,
        "surge_p99_ns": surge_hist.percentile(0.99),
        "recovery_windows": recovery_windows,
        "p99_ns": st.percentile_ns(0.99),
        "shed": a["shed"],
        "backlog": a["backlog"],
    }]


register(Scenario(
    name="flash_crowd",
    title="Flash crowd: control-plane transient response to an offered-"
          "load step",
    module="",  # registry/CLI native
    axes=(
        _platform_axis(),
        _op_axis(OpClass.LOAD),
        Axis("placement", "split",
             help="serving tenant's tier placement (see slo_knee)"),
        Axis("policy", ("racing", "miku"),
             help="control policy over the co-running CXL hog"),
        Axis("rate", 0.004, help="base offered rate (requests/ns)",
             parse=float),
        Axis("surge", 6.0, help="rate multiplier during the crowd",
             parse=float),
        Axis("t_step_ns", 100_000.0, help="crowd onset", parse=float),
        Axis("surge_ns", 60_000.0, help="crowd duration", parse=float),
        Axis("sim_ns", _SLO_SIM_NS, help="simulated horizon"),
    ),
    metrics=(
        Metric("peak_queue_depth", "", "worst arrival-backlog depth — "
               "racing lets the queue run away, MIKU caps it"),
        Metric("surge_p99_ns", "ns",
               "p99 over windows overlapping the surge"),
        Metric("recovery_windows", "",
               "post-surge windows with a nonzero backlog (drain time)"),
        Metric("p99_ns", "ns", "whole-run serving p99"),
        Metric("shed", "", "arrivals shed at the queue limit"),
        Metric("backlog", "", "arrival-queue depth at horizon end — "
               "nonzero means the crowd never drained"),
    ),
    build=_flash_crowd_build,
    reduce=_flash_crowd_reduce,
))

"""The scenario planner: axis grid → cells → SimJob batches → result table.

``plan()`` expands a grid scenario into ``(cell, platform, jobs)`` triples
without running anything — the unit the equivalence tests pin against the
legacy imperative runners.  ``run_scenario()`` executes: every cell's jobs
go through one :func:`~repro.memsim.sweep.run_sweep` batch (so figure-wide
matrices fan out over the process pool exactly like the legacy runners),
then each cell's ``reduce`` collects rows into a :class:`ResultTable`.

``run_scenario(..., trace=True)`` additionally records every job's
ControlLoop per-window decision telemetry (per-tier counter deltas +
tier-addressed decisions) and attaches it as ``ResultTable.traces`` —
the payload ``benchmarks/run.py --trace`` dumps as JSON next to the
scenario's CSV.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.device_model import PLATFORMS, PlatformModel
from repro.memsim.sweep import SimJob, run_sweep
from repro.scenarios import registry
from repro.scenarios.spec import ResultTable, Scenario

ScenarioRef = Union[str, Scenario]


def _scenario(ref: ScenarioRef) -> Scenario:
    return registry.get(ref) if isinstance(ref, str) else ref


def resolve_platform(value: Any) -> Tuple[str, PlatformModel]:
    """(label, model) for a platform axis value (name or model instance)."""
    if isinstance(value, PlatformModel):
        return value.name, value
    if value in PLATFORMS:
        return value, PLATFORMS[value]
    raise KeyError(
        f"unknown platform {value!r}; known platforms: "
        f"{', '.join(PLATFORMS)}"
    )


def resolve_axes(
    scenario: ScenarioRef, overrides: Optional[Dict[str, Any]] = None
) -> Dict[str, Any]:
    """Axis values for a run: defaults overlaid with ``overrides``.

    String overrides are parsed via the axis (the ``--set`` path);
    non-string overrides pass through.  A scalar override on a grid axis
    becomes a one-point grid.
    """
    sc = _scenario(scenario)
    values: Dict[str, Any] = {a.name: a.default for a in sc.axes}
    for k, v in (overrides or {}).items():
        axis = sc.axis(k)  # raises with the axis list on unknown names
        if isinstance(v, str):
            v = axis.parse_text(v)
        if axis.is_grid and not isinstance(v, (tuple, list)):
            v = (v,)
        elif axis.is_grid:
            v = tuple(v)
        values[k] = v
    return values


def expand_cells(
    scenario: ScenarioRef, values: Dict[str, Any]
) -> List[Dict[str, Any]]:
    """Cartesian product of the grid axes (declaration order, row-major),
    with scalar axes constant in every cell."""
    sc = _scenario(scenario)
    grid = [a for a in sc.axes if a.is_grid]
    scalars = {a.name: values[a.name] for a in sc.axes if not a.is_grid}
    cells = []
    for combo in itertools.product(*[values[a.name] for a in grid]):
        cell = dict(scalars)
        cell.update({a.name: v for a, v in zip(grid, combo)})
        cells.append(cell)
    return cells


def _resolved_cells(
    sc: Scenario, values: Dict[str, Any]
) -> List[Tuple[Dict[str, Any], Optional[PlatformModel]]]:
    out = []
    for cell in expand_cells(sc, values):
        pm: Optional[PlatformModel] = None
        if "platform" in cell:
            label, pm = resolve_platform(cell["platform"])
            cell = {**cell, "platform": label}
        out.append((cell, pm))
    return out


def plan(
    scenario: ScenarioRef, overrides: Optional[Dict[str, Any]] = None
) -> List[Tuple[Dict[str, Any], Optional[PlatformModel], List[SimJob]]]:
    """Expand a grid scenario into (cell, platform, jobs) without running."""
    sc = _scenario(scenario)
    if sc.build is None:
        raise ValueError(
            f"scenario {sc.name!r} is multi-stage (run_cell); it has no "
            "static job plan"
        )
    values = resolve_axes(sc, overrides)
    return [
        (cell, pm, sc.build(pm, cell))
        for cell, pm in _resolved_cells(sc, values)
    ]


def run_scenario(
    scenario: ScenarioRef,
    overrides: Optional[Dict[str, Any]] = None,
    processes: Optional[int] = None,
    *,
    trace: bool = False,
    lane: Optional[str] = None,
    perfetto: bool = False,
    profile: bool = False,
) -> ResultTable:
    """Execute a scenario and collect its uniform result table.

    ``trace=True`` (grid scenarios only) turns on per-window control-plane
    telemetry recording in every job and attaches the per-cell window
    records as ``ResultTable.traces``.

    ``perfetto=True`` (grid scenarios only) turns on sampled
    request-lifecycle tracing (:mod:`repro.obs.trace`, every 16th ToR
    admission) in every job and attaches the per-cell span payloads as
    ``ResultTable.request_traces`` — the records ``benchmarks/run.py
    --perfetto`` exports as Chrome trace-event JSON.  Traced jobs always
    run on the scalar DES.

    ``profile=True`` records a wall-clock phase profile (plan / sweep /
    reduce, plus each scalar job's setup / event-loop / window split) into
    ``ResultTable.meta["profile"]`` and snapshots the process-wide
    observability counters into ``meta["metrics"]``.

    ``lane="batched"`` routes the whole grid through the vectorized sweep
    lane (:mod:`repro.memsim.batched`); jobs it cannot express fall back to
    the scalar DES, and ``ResultTable.meta`` records the split (lane name,
    batched vs fallback job counts, fallback reasons).  Multi-stage
    (``run_cell``) scenarios always run scalar; the meta notes it.
    """
    from repro.memsim.sweep import default_lane

    sc = _scenario(scenario)
    values = resolve_axes(sc, overrides)
    rows: List[Dict[str, Any]] = []
    traces: Optional[List[Dict[str, Any]]] = [] if trace else None
    req_traces: Optional[List[Dict[str, Any]]] = [] if perfetto else None
    prof = None
    if profile:
        from repro.obs.metrics import PhaseProfiler

        prof = PhaseProfiler()
    # Resolve the effective lane up front so meta reports what actually ran
    # (lane=None defers to REPRO_SWEEP_LANE, exactly like run_sweep).
    lane = lane or default_lane()
    meta: Dict[str, Any] = {"lane": lane}
    if sc.run_cell is not None:
        if trace:
            raise ValueError(
                f"scenario {sc.name!r} is multi-stage (run_cell); per-window "
                "decision tracing supports grid scenarios only"
            )
        if perfetto:
            raise ValueError(
                f"scenario {sc.name!r} is multi-stage (run_cell); request-"
                "lifecycle tracing supports grid scenarios only"
            )
        if lane == "batched":
            meta = {"lane": "scalar",
                    "note": "multi-stage (run_cell) scenario; the batched "
                            "lane applies to grid scenarios only"}
        if prof is not None:
            _pt = prof.clock()
        for cell, pm in _resolved_cells(sc, values):
            rows.extend(sc.run_cell(pm, cell, processes))
        if prof is not None:
            prof.add("run_cell", prof.clock() - _pt)
    else:
        if prof is not None:
            _pt = prof.clock()
        planned = [
            (cell, pm, sc.build(pm, cell))
            for cell, pm in _resolved_cells(sc, values)
        ]
        if trace:
            planned = [
                (cell, pm,
                 [dataclasses.replace(j, record_windows=True) for j in jobs])
                for cell, pm, jobs in planned
            ]
        if perfetto:
            # Every 16th ToR admission: dense enough that even a short CI
            # cell lands spans, sparse enough to keep the export small.
            planned = [
                (cell, pm,
                 [dataclasses.replace(j, trace=16) for j in jobs])
                for cell, pm, jobs in planned
            ]
        if prof is not None:
            planned = [
                (cell, pm,
                 [dataclasses.replace(j, profile=True) for j in jobs])
                for cell, pm, jobs in planned
            ]
            prof.add("plan", prof.clock() - _pt)
            _pt = prof.clock()
        all_jobs: List[SimJob] = [j for _, _, jobs in planned for j in jobs]
        if lane == "batched":
            from repro.memsim.batched import partition_jobs, run_sweep_batched

            partition = partition_jobs(all_jobs)
            results = run_sweep_batched(all_jobs, processes,
                                        partition=partition)
            # Account fallbacks *after* the run: run_sweep_batched appends
            # dynamic stacking failures to the partition's fallback list.
            _, fallbacks = partition
            reason_counts: Dict[str, int] = {}
            for _, r in fallbacks:
                reason_counts[r] = reason_counts.get(r, 0) + 1
            meta.update(
                batched_jobs=len(all_jobs) - len(fallbacks),
                scalar_fallback_jobs=len(fallbacks),
                fallback_reasons=sorted(reason_counts),
                fallback_reason_counts=dict(sorted(reason_counts.items())),
            )
        else:
            results = run_sweep(all_jobs, processes, lane=lane)
        if prof is not None:
            prof.add("sweep", prof.clock() - _pt)
            _pt = prof.clock()
        i = 0
        for cell, pm, jobs in planned:
            chunk = results[i: i + len(jobs)]
            i += len(jobs)
            rows.extend(sc.reduce(pm, cell, jobs, chunk))
            if traces is not None:
                traces.append({
                    "cell": {k: getattr(v, "value", v)
                             for k, v in cell.items()},
                    "jobs": [
                        {
                            "job": j,
                            "workloads": [w.name for w in job.workloads],
                            "windows": res.window_records,
                        }
                        for j, (job, res) in enumerate(zip(jobs, chunk))
                    ],
                })
            if req_traces is not None:
                req_traces.append({
                    "cell": {k: getattr(v, "value", v)
                             for k, v in cell.items()},
                    "jobs": [
                        {
                            "job": j,
                            "workloads": [w.name for w in job.workloads],
                            "trace": res.trace,
                        }
                        for j, (job, res) in enumerate(zip(jobs, chunk))
                    ],
                })
        if prof is not None:
            prof.add("reduce", prof.clock() - _pt)
    if prof is not None:
        from repro.obs.metrics import default_registry

        meta["profile"] = prof.snapshot()
        meta["profile"]["jobs"] = [
            r.profile for r in results if getattr(r, "profile", None)
        ] if sc.run_cell is None else []
        meta["metrics"] = default_registry().snapshot()
    return ResultTable(scenario=sc.name, rows=rows, params=values,
                       traces=traces, meta=meta,
                       request_traces=req_traces)


def parse_set_args(
    scenario: ScenarioRef, pairs: Sequence[str]
) -> Dict[str, Any]:
    """``--set axis=value`` tokens → an overrides dict (parsed per axis)."""
    sc = _scenario(scenario)
    overrides: Dict[str, Any] = {}
    for pair in pairs:
        if "=" not in pair:
            raise ValueError(f"--set expects axis=value, got {pair!r}")
        k, v = pair.split("=", 1)
        overrides[k.strip()] = sc.axis(k.strip()).parse_text(v)
    return overrides

from repro.data.pipeline import SyntheticTokenDataset, HostDataLoader, pack_documents

__all__ = ["SyntheticTokenDataset", "HostDataLoader", "pack_documents"]

"""Deterministic synthetic data pipeline with document packing and host
sharding.

The training substrate the paper's framework needs, built without external
datasets: a seeded Zipf-ish token source generates variable-length
"documents", which are packed into fixed-length training sequences (EOS
separators, greedy first-fit) and sharded per host.  Every host computes its
shard purely from (seed, step, shard_index) — no coordination, bit-exact
restarts (critical for checkpoint/resume determinism) and elastic resharding
(a host picks up any shard index after a topology change).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

EOS = 0


@dataclasses.dataclass(frozen=True)
class SyntheticTokenDataset:
    """Zipf-distributed tokens in variable-length documents."""

    vocab: int
    seed: int = 1234
    mean_doc_len: int = 512
    zipf_a: float = 1.3

    def documents(self, shard: int, start_doc: int = 0) -> Iterator[np.ndarray]:
        i = start_doc
        while True:
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + shard) * 1_000_003 + i
            )
            length = max(8, int(rng.exponential(self.mean_doc_len)))
            toks = rng.zipf(self.zipf_a, size=length)
            toks = np.clip(toks, 1, self.vocab - 1).astype(np.int32)
            yield toks
            i += 1


def pack_documents(
    docs: Iterator[np.ndarray], seq_len: int, batch: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy packing into [batch, seq_len+1]; returns (tokens, labels)."""
    rows: List[np.ndarray] = []
    cur: List[int] = []
    need = seq_len + 1
    while len(rows) < batch:
        doc = next(docs)
        pos = 0
        while pos < len(doc) and len(rows) < batch:
            space = need - len(cur)
            take = min(space, len(doc) - pos)
            cur.extend(doc[pos : pos + take].tolist())
            pos += take
            if len(cur) == need:
                rows.append(np.asarray(cur, np.int32))
                cur = []
            elif pos >= len(doc):
                cur.append(EOS)
                if len(cur) == need:
                    rows.append(np.asarray(cur, np.int32))
                    cur = []
    arr = np.stack(rows)  # [B, S+1]
    return arr[:, :-1], arr[:, 1:]


@dataclasses.dataclass
class HostDataLoader:
    """Per-host loader: yields this host's [B_host, S] shard of each global
    batch, deterministically from (seed, step, shard)."""

    dataset: SyntheticTokenDataset
    global_batch: int
    seq_len: int
    shard_index: int = 0
    num_shards: int = 1
    step: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        # Each (step, shard) gets a disjoint deterministic document stream.
        stream_id = self.step * self.num_shards + self.shard_index
        docs = self.dataset.documents(shard=stream_id)
        self.step += 1
        return pack_documents(docs, self.seq_len, self.host_batch)

    def state_dict(self) -> dict:
        return {"step": self.step, "shard_index": self.shard_index,
                "num_shards": self.num_shards}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        # shard/num_shards may legitimately change on elastic resharding.

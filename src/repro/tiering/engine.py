"""MigrationEngine: policy decisions → MIGRATE request traffic → page moves.

A migration job (move page P from tier S to tier D) is not an instantaneous
bookkeeping flip: the copy must travel the slow link.  The engine charges it
through the *existing* DES machinery — each queued job owes
``reqs_per_page`` best-effort :attr:`~repro.core.littles_law.OpClass.MIGRATE`
macro-requests on its *traffic tier* (the slow side of the move: the source
of a promotion, the destination of a demotion), issued by the hook's
per-slow-tier migration pseudo-workloads.  The requests occupy real ToR
entries and station slots, queue behind demand traffic, are counted in the
per-tier :class:`~repro.core.littles_law.TierWindow` deltas MIKU watches,
and obey MIKU's tier-addressed throttles like any other slow-tier actor.

Only when enough MIGRATE requests have *completed* does the engine retire
the job and flip the page's tier in the :class:`~repro.tiering.pagemap.
PageMap` — so placement improvements lag the modeled copy bandwidth, and a
throttled migration path visibly delays them.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, Set, Tuple

from repro.tiering.pagemap import PageMap


@dataclasses.dataclass(frozen=True)
class MigrationJob:
    """One page move, in platform tier codes (0 = fast tier)."""

    region: str
    page: int
    src: int
    dst: int

    @property
    def traffic_tier(self) -> int:
        """The slow link the copy crosses: src for promotions, dst for
        demotions (a fast↔slow move always has exactly one slow side)."""
        return self.src if self.src != 0 else self.dst

    @property
    def is_promotion(self) -> bool:
        return self.dst == 0


class MigrationEngine:
    """Per-slow-tier migration job queues + completion-credit accounting.

    ``reqs_per_page`` maps each slow tier code to the MIGRATE macro-requests
    one page copy costs on that tier (page_bytes / bytes-per-macro-request).
    ``on_completions`` consumes completed-request credit FIFO: jobs retire in
    enqueue order, each flipping its page in the PageMap.
    """

    def __init__(self, reqs_per_page: Dict[int, int]) -> None:
        self.reqs_per_page = {
            t: max(1, int(n)) for t, n in reqs_per_page.items()
        }
        self._queues: Dict[int, Deque[MigrationJob]] = {
            t: deque() for t in self.reqs_per_page
        }
        self._credit: Dict[int, int] = {t: 0 for t in self.reqs_per_page}
        self._queued: Set[Tuple[str, int]] = set()
        # Lifetime counters (the per-window deltas are the hook's job).
        self.pages_promoted = 0
        self.pages_demoted = 0
        self.migrated_bytes = 0

    # -- queue management --------------------------------------------------
    def is_queued(self, region: str, page: int) -> bool:
        """Whether this (region, page) already has copy traffic queued."""
        return (region, page) in self._queued

    def queued_promotions(self) -> int:
        """Promotions in flight — they already claim fast-tier capacity."""
        return sum(
            1 for q in self._queues.values() for j in q if j.is_promotion
        )

    def queued_demotions(self) -> int:
        """Demotions in flight — fast-tier pages already on their way out
        (watermark logic must not re-demote for the same occupancy gap)."""
        return sum(
            1 for q in self._queues.values() for j in q if not j.is_promotion
        )

    def enqueue(self, jobs: Iterable[MigrationJob]) -> int:
        """Queue migration jobs (deduped per page); returns how many were
        accepted."""
        n = 0
        for job in jobs:
            key = (job.region, job.page)
            if key in self._queued:
                continue
            tier = job.traffic_tier
            if tier not in self._queues:
                raise KeyError(
                    f"migration job targets slow tier code {tier}, but the "
                    f"engine only carries {sorted(self._queues)}"
                )
            self._queues[tier].append(job)
            self._queued.add(key)
            n += 1
        return n

    def pending_reqs(self, tier_code: int) -> int:
        """MIGRATE macro-requests still owed on one slow tier (issue gate
        for that tier's migration pseudo-workload)."""
        q = self._queues.get(tier_code)
        if not q:
            return 0
        rpp = self.reqs_per_page[tier_code]
        return max(0, len(q) * rpp - self._credit[tier_code])

    def backlog_pages(self) -> int:
        """Pages whose copy traffic has not yet completed."""
        return sum(len(q) for q in self._queues.values())

    # -- completion path ---------------------------------------------------
    def on_completions(
        self, tier_code: int, n_reqs: int, pagemap: PageMap
    ) -> Tuple[int, int]:
        """Credit ``n_reqs`` completed MIGRATE requests on one slow tier;
        retire fully-paid jobs FIFO, flipping their pages.  Returns
        (pages_promoted, pages_demoted) this call."""
        if tier_code not in self._queues:
            return (0, 0)
        self._credit[tier_code] += int(n_reqs)
        rpp = self.reqs_per_page[tier_code]
        q = self._queues[tier_code]
        promoted = demoted = 0
        while q and self._credit[tier_code] >= rpp:
            job = q.popleft()
            self._credit[tier_code] -= rpp
            self._queued.discard((job.region, job.page))
            pagemap.move(job.region, job.page, job.dst)
            self.migrated_bytes += pagemap.regions[job.region].page_bytes
            if job.is_promotion:
                promoted += 1
            else:
                demoted += 1
        if not q:
            # Surplus credit with an empty queue is over-issued traffic (the
            # pseudo-workload drains its outstanding window after the
            # backlog empties) — real overhead, but it pays for no page.
            self._credit[tier_code] = 0
        self.pages_promoted += promoted
        self.pages_demoted += demoted
        return promoted, demoted

    def counters(self) -> Dict[str, int]:
        """Cumulative engine counters (promoted/demoted pages, bytes, backlog)."""
        return {
            "pages_promoted": self.pages_promoted,
            "pages_demoted": self.pages_demoted,
            "migrated_bytes": self.migrated_bytes,
            "backlog_pages": self.backlog_pages(),
        }

"""Page-granularity address-space model: page → tier + decayed hotness.

A :class:`PageMap` holds one :class:`PageRegion` per tracked workload: an
array of per-page tier assignments (tier *codes* — positions in the
platform's ordered tier list, fast tier first) and an exponentially-decayed
per-page hotness counter, the software analogue of TPP's NUMA-hint-fault /
PEBS access sampling.

Access tracking is *sampled from real station accounting*: each control
window the DES hook feeds the region the number of requests its workload
actually completed, and the region distributes them over its pages per its
access pattern (a drifting hot set — the canonical tiered-memory stressor).
Hotness therefore scales with delivered bandwidth, not with offered load:
a throttled workload generates proportionally fewer promotion signals,
exactly like hint-fault sampling on real hardware.

Placement *re-resolution* closes the loop: the access-weighted per-tier
fractions (:meth:`PageRegion.tier_fractions`) become the workload's live
routing vector, so migrating a page genuinely moves its future accesses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HotSetPattern:
    """A drifting hot-set access distribution over a region's pages.

    ``hot_fraction`` of the pages receive ``hot_weight`` of the accesses
    (uniform within each group); the hot window is circular and advances
    ``drift_pages`` per window — hot-set *drift*, the workload property that
    separates tiering policies (a static placement decays as the hot set
    walks off it; a hotness policy chases it).
    """

    hot_fraction: float = 0.125
    hot_weight: float = 0.9
    drift_pages: float = 0.0
    hot_start: int = 0

    def __post_init__(self) -> None:
        if not 0.0 < self.hot_fraction <= 1.0:
            raise ValueError(f"hot_fraction must be in (0, 1], got "
                             f"{self.hot_fraction}")
        if not 0.0 <= self.hot_weight <= 1.0:
            raise ValueError(f"hot_weight must be in [0, 1], got "
                             f"{self.hot_weight}")


class PageRegion:
    """One workload's pages: tier codes, hotness, and its access pattern."""

    def __init__(
        self,
        name: str,
        n_pages: int,
        page_bytes: int,
        tier_codes: Sequence[int],
        pattern: HotSetPattern,
        n_tiers: int,
        home_slow: int = 1,
    ) -> None:
        if n_pages <= 0:
            raise ValueError(f"region {name!r}: n_pages must be positive")
        self.name = name
        self.n_pages = n_pages
        self.page_bytes = page_bytes
        self.tier = np.asarray(tier_codes, dtype=np.int64).copy()
        if self.tier.shape != (n_pages,):
            raise ValueError(
                f"region {name!r}: {n_pages} pages but "
                f"{self.tier.shape[0]} tier assignments"
            )
        self.hotness = np.zeros(n_pages, dtype=np.float64)
        self.pattern = pattern
        self.n_tiers = n_tiers
        #: Demotion target: the slow tier this region's cold pages fall back
        #: to (its dominant initial slow tier).
        self.home_slow = home_slow
        self._hot_start = float(pattern.hot_start % n_pages)

    # -- access model ------------------------------------------------------
    def access_weights(self) -> np.ndarray:
        """Per-page access probability under the current hot window."""
        n = self.n_pages
        n_hot = max(1, int(round(self.pattern.hot_fraction * n)))
        if n_hot >= n:
            return np.full(n, 1.0 / n)
        w = np.full(n, (1.0 - self.pattern.hot_weight) / (n - n_hot))
        hot_idx = (np.arange(n_hot) + int(self._hot_start)) % n
        w[hot_idx] = self.pattern.hot_weight / n_hot
        return w

    def record_window(self, n_accesses: float, decay: float) -> None:
        """Fold one window's sampled accesses into the hotness counters
        (exponential decay, TPP/Autotiering style), then drift the hot set."""
        self.hotness *= decay
        if n_accesses > 0:
            self.hotness += n_accesses * self.access_weights()
        if self.pattern.drift_pages:
            self._hot_start = (
                self._hot_start + self.pattern.drift_pages
            ) % self.n_pages

    # -- placement views ---------------------------------------------------
    def tier_fractions(self) -> np.ndarray:
        """Access-weighted fraction of this region's traffic per tier code —
        the workload's live routing vector (sums to 1)."""
        return np.bincount(
            self.tier, weights=self.access_weights(), minlength=self.n_tiers
        )

    def resident_pages(self, tier_code: int) -> int:
        """How many of this region's pages currently live on ``tier_code``."""
        return int(np.count_nonzero(self.tier == tier_code))

    def pages_on(self, tier_code: int) -> np.ndarray:
        """Page indices currently resident on ``tier_code``."""
        return np.flatnonzero(self.tier == tier_code)


class PageMap:
    """The tracked address space: regions + the shared fast-tier budget.

    ``fast_capacity_pages`` bounds how many pages (across all regions) the
    fast tier can hold — the capacity pressure that forces watermark
    demotion.  ``move`` is the only mutation path; the migration engine
    calls it when a page's copy traffic has actually completed through the
    modeled stations, so placement lags bandwidth exactly as on hardware.
    """

    def __init__(
        self,
        tier_names: Sequence[str],
        fast_capacity_pages: int,
        decay: float = 0.5,
    ) -> None:
        if len(tier_names) < 2:
            raise ValueError("PageMap needs a fast tier plus >= 1 slow tier")
        self.tier_names: Tuple[str, ...] = tuple(tier_names)
        self.fast_capacity_pages = int(fast_capacity_pages)
        self.decay = float(decay)
        self.regions: Dict[str, PageRegion] = {}

    # -- construction ------------------------------------------------------
    def add_region(
        self,
        name: str,
        n_pages: int,
        page_bytes: int,
        placement: Dict[str, float],
        pattern: Optional[HotSetPattern] = None,
    ) -> PageRegion:
        """Add a region with contiguous initial placement: the first
        ``placement[tier0] * n_pages`` pages on tier 0, the next run on the
        next named tier, and so on (tier order = platform order)."""
        if name in self.regions:
            raise ValueError(f"duplicate region {name!r}")
        unknown = set(placement) - set(self.tier_names)
        if unknown:
            raise ValueError(
                f"region {name!r}: unknown tier(s) {sorted(unknown)}; "
                f"page map tiers are {', '.join(self.tier_names)}"
            )
        total = sum(placement.values())
        if abs(total - 1.0) > 1e-6:
            raise ValueError(
                f"region {name!r}: placement fractions sum to {total}, "
                "expected 1.0"
            )
        # Cumulative-boundary assignment: per-tier runs are the rounded
        # cumulative fractions, so counts always sum to exactly n_pages (no
        # per-tier rounding drift, no truncated final run) and slow_counts
        # reflects the pages actually assigned.
        codes = np.zeros(n_pages, dtype=np.int64)
        bounds = []
        acc = 0.0
        for tier in self.tier_names:
            acc += placement.get(tier, 0.0)
            bounds.append(int(round(acc * n_pages)))
        bounds[-1] = n_pages  # absorb the validated <=1e-6 residue exactly
        start = 0
        slow_counts: Dict[int, int] = {}
        for code, end in enumerate(bounds):
            end = max(start, min(end, n_pages))
            codes[start:end] = code
            if code > 0 and end > start:
                slow_counts[code] = end - start
            start = end
        home = max(slow_counts, key=slow_counts.get) if slow_counts else 1
        region = PageRegion(
            name, n_pages, page_bytes, codes,
            pattern or HotSetPattern(), len(self.tier_names), home_slow=home,
        )
        self.regions[name] = region
        return region

    # -- accounting --------------------------------------------------------
    def record_window(self, name: str, n_accesses: float) -> None:
        """Feed one window's sampled accesses into region ``name``'s hotness."""
        self.regions[name].record_window(n_accesses, self.decay)

    def fast_pages_used(self) -> int:
        """Total pages resident on the fast tier across all regions."""
        return sum(r.resident_pages(0) for r in self.regions.values())

    def fast_fraction(self, name: str) -> float:
        """Access-weighted fraction of a region's traffic on the fast tier."""
        return float(self.regions[name].tier_fractions()[0])

    def placement_fractions(self, name: str) -> Dict[str, float]:
        """Region ``name``'s live access-weighted tier fractions, by tier name."""
        fr = self.regions[name].tier_fractions()
        return {t: float(fr[i]) for i, t in enumerate(self.tier_names)}

    def move(self, name: str, page: int, dst_code: int) -> None:
        """Flip one page's resident tier (called on migration completion)."""
        self.regions[name].tier[page] = dst_code

    def occupancy(self) -> Dict[str, int]:
        """Resident page counts per tier name, across regions."""
        out = {t: 0 for t in self.tier_names}
        for r in self.regions.values():
            for code, t in enumerate(self.tier_names):
                out[t] += r.resident_pages(code)
        return out

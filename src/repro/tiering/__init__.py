"""repro.tiering — page-granularity hotness tracking + migration engine.

The simulator's workloads used to carry *static* placement vectors frozen at
construction; every real tiered-memory system instead tracks per-page
hotness, promotes hot pages toward the fast tier, demotes cold ones — and
pays for it, because the promotion/demotion copies travel the same CXL links
as demand requests ("Demystifying CXL Memory"; CXL-DMSim's explicit
data-movement path).  This package is that vertical slice:

* :mod:`repro.tiering.pagemap` — the address-space model: page → tier,
  per-page hotness with exponential decay, sampled access tracking fed from
  the DES's station accounting.
* :mod:`repro.tiering.policies` — the policy registry (``static``,
  ``hotness_lru`` TPP-style promotion + watermark demotion, and
  ``miku_coordinated``, which consults the MIKU ladders' migration budgets
  and defers copies while a tier is throttling).
* :mod:`repro.tiering.engine` — the MigrationEngine: policy decisions become
  migration jobs executed as best-effort ``OpClass.MIGRATE`` requests
  through the existing DES stations, so copies consume real modeled
  bandwidth, queue behind demand traffic, and are visible to the per-tier
  :class:`~repro.core.littles_law.TierWindow` counters.
* :mod:`repro.tiering.hook` — the DES integration: a picklable
  :class:`~repro.tiering.hook.TieringSpec` builds a per-sim hook that
  :class:`~repro.core.des.TieredMemorySim` drives once per control window
  (``tiering=`` argument); with no hook installed the engine's two-tier fast
  path is bit-identical to the pinned goldens.
"""

from repro.tiering.engine import MigrationEngine, MigrationJob
from repro.tiering.hook import RegionSpec, TieringHook, TieringSpec
from repro.tiering.pagemap import HotSetPattern, PageMap, PageRegion
from repro.tiering.policies import (
    POLICIES,
    HotnessLRUPolicy,
    MikuCoordinatedPolicy,
    PolicyContext,
    StaticPolicy,
    make_policy,
)

__all__ = [
    "HotSetPattern",
    "HotnessLRUPolicy",
    "MigrationEngine",
    "MigrationJob",
    "MikuCoordinatedPolicy",
    "POLICIES",
    "PageMap",
    "PageRegion",
    "PolicyContext",
    "RegionSpec",
    "StaticPolicy",
    "TieringHook",
    "TieringSpec",
    "make_policy",
]

"""Tiering policy registry: static / hotness_lru / miku_coordinated.

A policy is a per-window pure-ish function ``decide(pagemap, ctx) ->
[MigrationJob]``; the hook enqueues whatever comes back into the
:class:`~repro.tiering.engine.MigrationEngine`.  The context carries the
control plane's view of the world — the latest tier-addressed
:class:`~repro.core.controller.TierDecisions` and the MIKU ladders' per-tier
migration budgets — so a policy can coordinate with (or ignore) the
bandwidth controller.

* ``static`` — never migrates; the frozen-placement baseline.
* ``hotness_lru`` — TPP-style: promote the hottest slow pages into free
  fast-tier capacity, demote the coldest fast pages when occupancy crosses
  the high watermark (down to the low watermark).
* ``miku_coordinated`` — ``hotness_lru``'s candidates, gated by MIKU: while
  a slow tier's ladder is restricting demand traffic (or its migration
  budget is zero), jobs crossing that tier are *deferred*, and per-window
  enqueue volume scales with the ladder's migration budget.  Migration is
  best-effort by construction: it only spends bandwidth the controller says
  the tier can give away.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.controller import TierDecisions
from repro.tiering.engine import MigrationEngine, MigrationJob
from repro.tiering.pagemap import PageMap


@dataclasses.dataclass
class PolicyContext:
    """What a policy may consult when deciding one window's migrations."""

    window: int
    tier_names: Tuple[str, ...]
    engine: MigrationEngine
    #: The control plane's latest tier-addressed decision (None when the sim
    #: runs without a controller, or before the first decision window).
    decisions: Optional[TierDecisions] = None
    #: Per-slow-tier migration budgets from the MIKU ladders (tier name →
    #: allowed concurrent migration streams); None without a MIKU ensemble.
    budgets: Optional[Dict[str, int]] = None
    #: Out-parameter: jobs the policy wanted but chose to defer this window
    #: (telemetry — the miku_coordinated deferral counter).
    deferred: int = 0


class StaticPolicy:
    """Placement is frozen at construction — the no-migration baseline."""

    name = "static"

    def decide(self, pagemap: PageMap, ctx: PolicyContext) -> List[MigrationJob]:
        """Never migrate (the placement-vector baseline)."""
        del pagemap, ctx
        return []


class HotnessLRUPolicy:
    """TPP-style promotion + watermark demotion over decayed hotness.

    ``promote_per_window`` bounds promotion aggressiveness (the naive
    configuration races exactly as hard as this allows); ``min_hotness``
    filters never-touched pages; the watermark pair bounds fast-tier
    occupancy, demoting coldest-first back to each region's home slow tier.
    """

    name = "hotness_lru"

    def __init__(
        self,
        promote_per_window: int = 64,
        demote_per_window: int = 64,
        high_watermark: float = 0.95,
        low_watermark: float = 0.85,
        min_hotness: float = 1e-9,
    ) -> None:
        self.promote_per_window = promote_per_window
        self.demote_per_window = demote_per_window
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        self.min_hotness = min_hotness

    # -- candidate selection ----------------------------------------------
    def _promotions(
        self, pagemap: PageMap, engine: MigrationEngine
    ) -> List[MigrationJob]:
        free = (
            pagemap.fast_capacity_pages
            - pagemap.fast_pages_used()
            - engine.queued_promotions()
        )
        budget = min(free, self.promote_per_window)
        if budget <= 0:
            return []
        candidates: List[Tuple[float, str, int, int]] = []
        for region in pagemap.regions.values():
            slow = np.flatnonzero(region.tier != 0)
            if not slow.size:
                continue
            hot = region.hotness[slow]
            keep = hot > self.min_hotness
            for page, h in zip(slow[keep], hot[keep]):
                if not engine.is_queued(region.name, int(page)):
                    candidates.append(
                        (float(h), region.name, int(page),
                         int(region.tier[page]))
                    )
        candidates.sort(key=lambda c: (-c[0], c[1], c[2]))
        return [
            MigrationJob(region=name, page=page, src=src, dst=0)
            for _, name, page, src in candidates[:budget]
        ]

    def _demotions(
        self, pagemap: PageMap, engine: MigrationEngine
    ) -> List[MigrationJob]:
        # Project occupancy past the copies already in flight: queued
        # demotions will free their pages once paid for, so re-demoting for
        # the same gap every window would overshoot far below the low
        # watermark while the engine drains.
        used = pagemap.fast_pages_used() - engine.queued_demotions()
        cap = pagemap.fast_capacity_pages
        if used <= self.high_watermark * cap:
            return []
        target = max(0, used - int(self.low_watermark * cap))
        budget = min(target, self.demote_per_window)
        candidates: List[Tuple[float, str, int, int]] = []
        for region in pagemap.regions.values():
            fast = region.pages_on(0)
            for page in fast:
                if not engine.is_queued(region.name, int(page)):
                    candidates.append(
                        (float(region.hotness[page]), region.name,
                         int(page), region.home_slow)
                    )
        candidates.sort(key=lambda c: (c[0], c[1], c[2]))  # coldest first
        return [
            MigrationJob(region=name, page=page, src=0, dst=dst)
            for _, name, page, dst in candidates[:budget]
        ]

    def decide(self, pagemap: PageMap, ctx: PolicyContext) -> List[MigrationJob]:
        """TPP-style: promote hottest slow pages into free fast capacity,
        demote coldest fast pages past the watermark."""
        return (
            self._promotions(pagemap, ctx.engine)
            + self._demotions(pagemap, ctx.engine)
        )


class MikuCoordinatedPolicy:
    """``hotness_lru`` candidates, admitted only with MIKU's consent.

    Per window, for each candidate job: look up the ladder state of the slow
    tier the copy would cross.  If that tier's decision is currently
    RESTRICTED, or its migration budget is 0, the job is deferred (counted,
    re-considered next window — hot pages stay hot).  Otherwise at most
    ``jobs_per_budget_unit × budget`` jobs are enqueued on that tier this
    window, so migration aggressiveness follows the ladder's promotion state
    instead of racing demand traffic.
    """

    name = "miku_coordinated"

    def __init__(self, jobs_per_budget_unit: int = 8, **base_kwargs) -> None:
        self.base = HotnessLRUPolicy(**base_kwargs)
        self.jobs_per_budget_unit = jobs_per_budget_unit

    def decide(self, pagemap: PageMap, ctx: PolicyContext) -> List[MigrationJob]:
        """Run the base policy, then defer jobs beyond the MIKU ladders'
        per-tier migration budgets (throttled tiers issue nothing)."""
        jobs = self.base.decide(pagemap, ctx)
        if not jobs:
            return jobs
        admitted: List[MigrationJob] = []
        taken: Dict[int, int] = {}
        for job in jobs:
            code = job.traffic_tier
            tier = ctx.tier_names[code]
            budget = (
                ctx.budgets.get(tier) if ctx.budgets is not None else None
            )
            if budget is not None:
                # The ladder's migration budget is the gate: 0 (fine-grained
                # rate control engaged — even level-3 demand concurrency is
                # too much) defers everything; a restricted-but-stable
                # ladder admits a budget-scaled trickle.
                if budget <= 0 or taken.get(code, 0) >= (
                    budget * self.jobs_per_budget_unit
                ):
                    ctx.deferred += 1
                    continue
            elif ctx.decisions is not None and tier in ctx.decisions.tiers:
                # No per-ladder budgets (merged law / foreign controller):
                # fall back to the coarse restricted bit.
                if ctx.decisions.for_tier(tier).restricted:
                    ctx.deferred += 1
                    continue
            taken[code] = taken.get(code, 0) + 1
            admitted.append(job)
        return admitted


POLICIES: Dict[str, Callable[..., object]] = {
    StaticPolicy.name: StaticPolicy,
    HotnessLRUPolicy.name: HotnessLRUPolicy,
    MikuCoordinatedPolicy.name: MikuCoordinatedPolicy,
}


def make_policy(name: str, **kwargs):
    """Instantiate a registered tiering policy by name (ValueError lists
    the registry)."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown tiering policy {name!r}; registered policies: "
            f"{', '.join(sorted(POLICIES))}"
        ) from None
    return cls(**kwargs)

"""DES integration: TieringSpec (picklable config) → TieringHook (per-sim).

:class:`TieredMemorySim` accepts ``tiering=hook`` and drives it through
three duck-typed entry points, keeping :mod:`repro.core.des` import-free of
this package:

* ``migration_workloads(platform)`` — the per-slow-tier MIGRATE
  pseudo-workloads appended to the sim's workload list at construction
  (kernel migration daemons: a few cores issuing page-copy traffic).
* ``bind(sim)`` — resolve tier codes, build the PageMap/engine/policy,
  apply the *initial* PageMap-derived routing, and gate the migration
  workloads closed (no backlog yet).
* ``on_window(sim)`` — once per control window, after the ControlLoop
  fired: drain migration completions into page moves, feed demand
  completions to the hotness tracker, run the policy, re-resolve each
  tracked workload's live placement vector, and re-gate migration issue.

:class:`TieringSpec` is the picklable description scenario builders put on a
:class:`~repro.memsim.sweep.SimJob` (``tiering=``); the worker builds a
fresh hook per simulation, exactly like MIKU controllers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.controller import TierDecisions
from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.invariants import require
from repro.core.device_model import PlatformModel
from repro.core.littles_law import OpClass
from repro.tiering.engine import MigrationEngine
from repro.tiering.pagemap import HotSetPattern, PageMap
from repro.tiering.policies import PolicyContext, make_policy


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One tracked workload's page region (initial placement + access
    pattern).  ``workload`` names a demand workload in the same sim."""

    workload: str
    n_pages: int
    placement: Dict[str, float]
    pattern: HotSetPattern = HotSetPattern()


@dataclasses.dataclass(frozen=True)
class TieringSpec:
    """Everything a worker needs to build a fresh tiering hook (picklable)."""

    regions: Tuple[RegionSpec, ...]
    policy: str = "hotness_lru"
    policy_args: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: Fast-tier page budget shared by all regions.
    fast_capacity_pages: int = 1024
    page_bytes: int = 4096
    hotness_decay: float = 0.5
    #: The migration pseudo-workloads: cores per slow tier and per-core MLP
    #: (how hard the copy engine races when it has backlog).
    mig_cores: int = 4
    mig_mlp: int = 64
    #: False models a kernel migration daemon outside MIKU's reach (the
    #: *naive* configuration); True makes migration a MIKU-governed request
    #: class like any other slow-tier actor.
    mig_miku_managed: bool = True

    def build(self) -> "TieringHook":
        """Construct a fresh per-sim hook (the spec itself stays picklable)."""
        return TieringHook(self)


#: Migration pseudo-workload name prefix (one per slow tier).
MIG_PREFIX = "mig-"


class TieringHook:
    """Per-simulation tiering state machine (see module docstring)."""

    def __init__(self, spec: TieringSpec) -> None:
        self.spec = spec
        self.pagemap: Optional[PageMap] = None
        self.window_log: List[dict] = []
        self.deferred_jobs = 0
        self._windows = 0
        self._sim: Optional[TieredMemorySim] = None

    # -- pre-construction --------------------------------------------------
    def migration_workloads(
        self, platform: PlatformModel
    ) -> List[WorkloadSpec]:
        """The per-slow-tier migration pseudo-workloads (``mig-<tier>``)
        this spec contributes to the sim's workload list."""
        return [
            WorkloadSpec(
                name=f"{MIG_PREFIX}{tier}",
                op=OpClass.MIGRATE,
                tier=tier,
                n_cores=self.spec.mig_cores,
                mlp=self.spec.mig_mlp,
                miku_managed=self.spec.mig_miku_managed,
            )
            for tier in platform.tier_names[1:]
        ]

    # -- binding -----------------------------------------------------------
    def bind(self, sim: TieredMemorySim) -> None:
        """Attach to a constructed sim: resolve regions, initial placement
        vectors, and the migration workload indices."""
        spec = self.spec
        self._sim = sim
        names = sim.platform.tier_names
        self.pagemap = PageMap(
            names, spec.fast_capacity_pages, decay=spec.hotness_decay
        )
        wl_names = {w.name for w in sim.workloads}
        for region in spec.regions:
            if region.workload not in wl_names:
                raise ValueError(
                    f"tiering region tracks unknown workload "
                    f"{region.workload!r}; sim workloads: "
                    f"{', '.join(sorted(wl_names))}"
                )
            self.pagemap.add_region(
                region.workload, region.n_pages, spec.page_bytes,
                region.placement, region.pattern,
            )
        self.policy = make_policy(spec.policy, **spec.policy_args)
        # One page's copy = page_bytes of traffic on its slow link, issued
        # as MIGRATE macro-requests of (access_bytes x granularity) each.
        g = sim.granularity
        self.engine = MigrationEngine({
            code: math.ceil(
                spec.page_bytes
                / (sim.platform.tiers[code].access_bytes * g)
            )
            for code in range(1, len(names))
        })
        wi_by_name = {w.name: i for i, w in enumerate(sim.workloads)}
        self._region_wi = {
            r.workload: wi_by_name[r.workload] for r in spec.regions
        }
        self._mig_wi: Dict[int, int] = {
            code: wi_by_name[f"{MIG_PREFIX}{tier}"]
            for code, tier in enumerate(names) if code > 0
        }
        # Gate migration issue closed until there is backlog (effective MLP
        # 0 blocks the round-robin arbiter for those cores).
        self._mig_effmlp = {
            wi: sim._w_effmlp[wi] for wi in self._mig_wi.values()
        }
        for wi in self._mig_wi.values():
            sim._w_effmlp[wi] = 0
        self._stat_mark = list(sim._stat_completed)
        self._apply_placements(sim)

    # -- per-window pass ---------------------------------------------------
    def on_window(self, sim: TieredMemorySim) -> bool:
        """One per-window tiering pass: sample accesses into the PageMap,
        drain completed copies, run the policy, re-resolve placements and
        budgets.  Returns True when routing or budgets changed."""
        require(self.pagemap is not None, "tiering-bind",
                "on_window before bind(): the hook has no PageMap yet")
        self._windows += 1
        completed = sim._stat_completed
        deltas = [c - m for c, m in zip(completed, self._stat_mark)]
        self._stat_mark = list(completed)

        # 1. Completed MIGRATE traffic retires jobs and flips pages.
        promoted = demoted = 0
        mig_done: Dict[str, int] = {}
        for code, wi in self._mig_wi.items():
            if deltas[wi]:
                mig_done[sim.platform.tier_names[code]] = deltas[wi]
                p, d = self.engine.on_completions(code, deltas[wi],
                                                  self.pagemap)
                promoted += p
                demoted += d

        # 2. Demand completions are the sampled access stream feeding the
        #    hotness tracker (station accounting, not offered load).
        for name, wi in self._region_wi.items():
            self.pagemap.record_window(name, deltas[wi])

        # 3. Policy pass under the control plane's latest view.
        ctx = PolicyContext(
            window=self._windows,
            tier_names=sim.platform.tier_names,
            engine=self.engine,
            decisions=self._latest_decisions(sim),
            budgets=self._budgets(sim),
        )
        jobs = self.policy.decide(self.pagemap, ctx)
        enqueued = self.engine.enqueue(jobs)
        self.deferred_jobs += ctx.deferred

        # 4. Placement re-resolution + migration issue gating.  ``changed``
        # is the return contract: only a window that actually moved routing
        # or re-opened migration issue makes the DES re-pump its issue path.
        changed = self._apply_placements(sim)
        for code, wi in self._mig_wi.items():
            want = self._mig_effmlp[wi] if self.engine.pending_reqs(code) else 0
            if sim._w_effmlp[wi] != want:
                sim._w_effmlp[wi] = want
                changed = True

        self.window_log.append({
            "window": self._windows,
            "t_ns": sim.now,
            "promoted": promoted,
            "demoted": demoted,
            "enqueued": enqueued,
            "deferred": ctx.deferred,
            "backlog_pages": self.engine.backlog_pages(),
            "migrated_bytes": self.engine.migrated_bytes,
            "mig_reqs_completed": mig_done,
            "fast_fraction": {
                name: self.pagemap.fast_fraction(name)
                for name in self._region_wi
            },
        })
        return changed

    # -- control-plane views ----------------------------------------------
    @staticmethod
    def _latest_decisions(sim: TieredMemorySim) -> Optional[TierDecisions]:
        ds = sim.control.decisions
        if ds and isinstance(ds[-1], TierDecisions):
            return ds[-1]
        return None

    @staticmethod
    def _budgets(sim: TieredMemorySim) -> Optional[Dict[str, int]]:
        budgets = getattr(sim.controller, "migration_budgets", None)
        return budgets() if callable(budgets) else None

    # -- routing -----------------------------------------------------------
    def _apply_placements(self, sim: TieredMemorySim) -> bool:
        """Write each tracked workload's live PageMap-derived routing vector
        into the sim's issue tables (two-tier platforms stay on the
        single-draw ``ddr_fraction`` fast path).  Returns whether any
        routing entry actually changed (a static policy's steady state
        changes nothing — no re-pump needed)."""
        require(self.pagemap is not None, "tiering-bind",
                "_apply_placements before bind(): the hook has no PageMap")
        n = sim._n_tiers
        changed = False
        for name, wi in self._region_wi.items():
            fr = self.pagemap.regions[name].tier_fractions()
            if n == 2:
                frac = float(fr[0])
                if sim._w_frac[wi] != frac:
                    sim._w_frac[wi] = frac
                    sim._w_cum[wi] = None
                    sim._w_placed_slow[wi] = ()
                    sim._recompute_throttle(wi)
                    changed = True
            else:
                acc = 0.0
                cum = []
                for f in fr:
                    acc += float(f)
                    cum.append(acc)
                cum[-1] = float("inf")
                cum = tuple(cum)
                if sim._w_cum[wi] != cum:
                    sim._w_frac[wi] = None
                    sim._w_cum[wi] = cum
                    sim._w_placed_slow[wi] = tuple(
                        i for i in range(1, n) if fr[i] > 0.0
                    )
                    sim._recompute_throttle(wi)
                    changed = True
        return changed

    # -- result surface ----------------------------------------------------
    def summary(self) -> dict:
        """End-of-run summary (pages promoted/demoted, migrated bytes,
        deferrals, final fast fractions) for ``SimResult.tiering``."""
        require(self.pagemap is not None, "tiering-bind",
                "summary() before bind(): the hook has no PageMap")
        return {
            **self.engine.counters(),
            "policy": self.policy.name,
            "windows": self._windows,
            "deferred_jobs": self.deferred_jobs,
            "fast_pages_used": self.pagemap.fast_pages_used(),
            "occupancy": self.pagemap.occupancy(),
            "fast_fraction": {
                name: self.pagemap.fast_fraction(name)
                for name in self._region_wi
            },
        }

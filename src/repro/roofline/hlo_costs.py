"""Trip-count-aware cost extraction from partitioned HLO text.

``compiled.cost_analysis()`` counts every ``while`` body **once** — a
scan-over-layers model therefore under-reports FLOPs/bytes by ~the layer
count, and collective bytes parsed naively from the text have the same
problem.  This parser rebuilds the call graph:

  1. pass 1 — symbol table: every op's result (dtype, dims) per computation;
  2. pass 2 — per-computation own-costs:
       * flops: ``dot`` (2 x result x contracted dims via the lhs operand's
         shape) and ``convolution`` (2 x result x kernel-elements x
         in-features/group),
       * traffic bytes: result + operand buffer bytes of every top-level op
         (fusion internals excluded — fusions touch HBM only at their
         boundary, which is exactly the call-site accounting here),
       * per-collective wire bytes (ring factors: all-reduce 2x);
  3. pass 3 — accumulate over the call graph: ``while`` bodies/conditions
     multiply by ``known_trip_count`` (default 1 + warning note), ``call``
     sites by 1, fusion calls contribute call-site bytes only.

Shapes in post-SPMD HLO are already per-device, so totals are per-device;
multiply by chip count for cluster totals (the roofline terms divide that
right back out).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_WIRE_FACTOR = {
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP = re.compile(
    r"^\s+(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"([a-z0-9\-]+)\((.*)$"
)
_TUPLE_OP = re.compile(
    r"^\s+(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(.*?\))\s+([a-z0-9\-]+)\((.*)$"
)
_OPERAND = re.compile(r"%[\w.\-]+")
_TYPED_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|to_apply|branch_computations)=")


def _size(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _bytes(dtype: str, dims: str) -> int:
    return _size(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class _Op:
    name: str
    dtype: str
    dims: str
    kind: str
    rest: str  # remainder of the line (operands + attributes)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _WIRE_FACTOR}
    )
    notes: List[str] = dataclasses.field(default_factory=list)
    #: scaled traffic per HLO op kind (diagnostics for the perf loop)
    kind_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: lower-bound ("ideal fusion") traffic: dot/conv operands+results,
    #: slice windows, in-place updates — the irreducible HBM traffic a TPU
    #: compile cannot fuse away.  ``bytes`` is the upper bound including
    #: every top-level buffer the CPU-backend module materializes.
    bytes_min: float = 0.0

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_min += mult * other.bytes_min
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += mult * v
        for k, v in other.kind_bytes.items():
            self.kind_bytes[k] = self.kind_bytes.get(k, 0.0) + mult * v


def _parse_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        m = _COMP_START.match(line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def parse_hlo_costs(text: str) -> HloCost:
    comps = _parse_computations(text)

    # Pass 1: symbol table (per computation — names are globally unique in
    # practice, but keep per-comp to be safe, with a global fallback).
    shapes: Dict[str, Tuple[str, str]] = {}
    comp_ops: Dict[str, List[_Op]] = {}
    for cname, lines in comps.items():
        ops: List[_Op] = []
        for line in lines:
            m = _OP.match(line)
            if m:
                name, dtype, dims, kind, rest = m.groups()
                shapes[name] = (dtype, dims)
                ops.append(_Op(name, dtype, dims, kind, rest))
                continue
            mt = _TUPLE_OP.match(line)
            if mt:
                name, tup, kind, rest = mt.groups()
                total = 0
                for td, tdim in _TYPED_SHAPE.findall(tup):
                    total += _bytes(td, tdim)
                # store tuple as pseudo-shape: bytes encoded via u8[total]
                shapes[name] = ("u8", str(total))
                ops.append(_Op(name, "u8", str(total), kind, rest))
        comp_ops[cname] = ops
        # also register parameters' shapes from the header line
        # (header was consumed; parameters appear as ops `parameter(N)`).

    #: Ops that move no HBM traffic themselves: tuple plumbing, aliases, and
    #: control-flow shells (their bodies are accounted separately).  Without
    #: this, every get-tuple-element in a while body "reads" the whole carry
    #: tuple and inflates traffic by orders of magnitude.
    NO_TRAFFIC = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "after-all", "partition-id", "replica-id", "while", "conditional",
        "call", "custom-call", "opt-barrier", "get-dimension-size",
    }
    #: Layout/dtype plumbing that a TPU compile fuses into neighbours; the
    #: CPU backend leaves long unfused convert/broadcast/copy chains that
    #: would otherwise inflate traffic ~10-30x vs a real TPU module.  Their
    #: standalone appearances are skipped; their cost is captured where they
    #: feed a counted op's operands.
    LAYOUT_ONLY = {
        "convert", "broadcast", "copy", "transpose", "reshape", "iota",
        "copy-start", "copy-done", "concatenate", "pad",
    }
    #: Ops whose operands are genuine reads (matmuls read weights/KV;
    #: reduces stream inputs; fusions touch HBM at their boundary).
    READ_OPERANDS = {
        "dot", "convolution", "sort", "reduce", "reduce-window", "fusion",
        "select-and-scatter", "cholesky", "triangular-solve",
    }
    #: Slicing ops touch only the moved window, never the whole operand —
    #: a dynamic-slice of one layer out of a [46, ...] stacked-param buffer
    #: reads ~1/46th of it.  Traffic = 2 x moved bytes (read + write).
    SLICING = {"dynamic-slice", "gather", "slice"}
    SLICE_UPDATING = {"dynamic-update-slice", "scatter"}
    #: Fusion wrappers around a single layout op (CPU backend artifact).
    LAYOUT_FUSION = re.compile(
        r"calls=%wrapped_(convert|broadcast|copy|transpose|reshape|iota)"
    )

    def operand_bytes(rest: str) -> float:
        """Sum buffer sizes of operand names appearing before attributes."""
        # operands live before the first '),' or '), ' attr separator; take
        # the argument list up to the matching close paren (approximate: up
        # to the first '), ' or end).
        arglist = rest.split("), ")[0]
        total = 0.0
        for nm in _OPERAND.findall(arglist):
            if nm in shapes:
                dtype, dims = shapes[nm]
                total += _bytes(dtype, dims)
        return total

    trip_notes: List[str] = []

    # Pass 1b: window-access analysis of fusion bodies.  A fusion that
    # internally dynamic-slices parameter k reads only the *window*, not the
    # whole buffer (scan slicing stacked params is fused this way on the CPU
    # backend); one that dynamic-update-slices an aliased parameter writes
    # only the update window (the remat carry-stack save).  Record per-param
    # byte overrides + a result override for in-place updates, applied at
    # every call site.
    fusion_param_override: Dict[str, Dict[int, float]] = {}
    fusion_result_override: Dict[str, float] = {}
    _ALIAS_KINDS = {"convert", "bitcast", "copy", "reshape", "transpose",
                    "broadcast"}
    for cname, ops0 in comp_ops.items():
        param_idx: Dict[str, int] = {}
        alias: Dict[str, str] = {}  # op name -> transitive source name
        for op0 in ops0:
            if op0.kind == "parameter":
                num = op0.rest.split(")")[0]
                if num.isdigit():
                    param_idx[op0.name] = int(num)
            elif op0.kind in _ALIAS_KINDS:
                srcs = _OPERAND.findall(op0.rest.split("), ")[0])
                if srcs:
                    alias[op0.name] = alias.get(srcs[0], srcs[0])

        def _resolve(nm: str) -> str:
            return alias.get(nm, nm)

        overrides: Dict[int, float] = {}
        result_override = None
        for op0 in ops0:
            arglist0 = op0.rest.split("), ")[0]
            names0 = [_resolve(n) for n in _OPERAND.findall(arglist0)]
            if op0.kind in ("dynamic-slice", "slice") and names0:
                src = names0[0]
                if src in param_idx:
                    overrides[param_idx[src]] = float(
                        _bytes(op0.dtype, op0.dims)
                    )
            elif op0.kind == "dynamic-update-slice" and len(names0) >= 2:
                buf, upd0 = names0[0], names0[1]
                ub = (
                    float(_bytes(*shapes[upd0])) if upd0 in shapes else 0.0
                )
                if buf in param_idx:
                    overrides[param_idx[buf]] = ub
                result_override = (result_override or 0.0) + ub
            elif op0.kind == "scatter" and names0:
                buf, upd0 = names0[0], names0[-1]
                ub = (
                    float(_bytes(*shapes[upd0])) if upd0 in shapes else 0.0
                )
                if buf in param_idx:
                    overrides[param_idx[buf]] = ub
                result_override = (result_override or 0.0) + ub
        if overrides:
            fusion_param_override[cname] = overrides
        if result_override is not None:
            fusion_result_override[cname] = result_override

    # Pass 2: own costs + call edges per computation.
    own: Dict[str, HloCost] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    fusion_bodies: set = set()
    for cname, ops in comp_ops.items():
        c = HloCost()
        ed: List[Tuple[str, float]] = []
        for op in ops:
            kind = op.kind
            rbytes = _bytes(op.dtype, op.dims)
            # Traffic model (TPU-fusion-faithful estimate; see class notes):
            #   dot/conv/gather/scatter/reduce/fusion -> operands + result;
            #   collectives -> wire bytes (below);
            #   other compute ops -> result only;
            #   layout/dtype plumbing -> skipped.
            def acct(v: float, tag: str, irreducible: bool = False) -> None:
                c.bytes += v
                if irreducible:
                    c.bytes_min += v
                c.kind_bytes[tag] = c.kind_bytes.get(tag, 0.0) + v

            if kind in NO_TRAFFIC:
                pass
            elif kind in LAYOUT_ONLY:
                pass
            elif kind == "fusion" and LAYOUT_FUSION.search(op.rest):
                pass
            elif kind == "fusion" and (
                (fm := re.search(r"calls=(%[\w.\-]+)", op.rest)) is not None
                and (
                    fm.group(1) in fusion_param_override
                    or fm.group(1) in fusion_result_override
                )
            ):
                callee = fm.group(1)
                over = fusion_param_override.get(callee, {})
                arglist = op.rest.split("), ")[0]
                names = _OPERAND.findall(arglist)
                total = 0.0
                window_part = 0.0
                for i, nm in enumerate(names):
                    if i in over:
                        total += over[i]
                        window_part += over[i]
                    elif nm in shapes:
                        total += _bytes(*shapes[nm])
                ro = fusion_result_override.get(callee)
                total += rbytes if ro is None else ro
                window_part += 0.0 if ro is None else ro
                acct(total - window_part, "fusion-windowed")
                acct(window_part, "fusion-window-moved", irreducible=True)
            elif kind in SLICING:
                acct(2.0 * rbytes, kind, irreducible=True)
            elif kind in SLICE_UPDATING:
                # traffic = 2 x update-window bytes (read update, write into
                # the aliased buffer); the update is the 2nd operand for DUS
                # and the last for scatter.
                arglist = op.rest.split("), ")[0]
                names = _OPERAND.findall(arglist)
                upd = None
                if kind == "dynamic-update-slice" and len(names) >= 2:
                    upd = names[1]
                elif kind == "scatter" and names:
                    upd = names[-1]
                if upd is not None and upd in shapes:
                    d2, dd = shapes[upd]
                    acct(2.0 * _bytes(d2, dd), kind, irreducible=True)
            elif kind in READ_OPERANDS:
                acct(rbytes + operand_bytes(op.rest), kind,
                     irreducible=kind in ("dot", "convolution"))
            elif kind.replace("-start", "") in _WIRE_FACTOR:
                pass  # accounted as collective wire bytes below
            else:
                acct(rbytes, "elementwise")
            if kind == "dot":
                lhs_m = _OPERAND.search(op.rest)
                contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                     op.rest)
                k = 1
                if lhs_m and contract and lhs_m.group(0) in shapes:
                    _, ldims = shapes[lhs_m.group(0)]
                    lsizes = [int(d) for d in ldims.split(",") if d]
                    for ci in contract.group(1).split(","):
                        if ci and int(ci) < len(lsizes):
                            k *= lsizes[int(ci)]
                c.flops += 2.0 * _size(op.dims) * k
            elif kind == "convolution":
                kern = re.search(r"window=\{size=([0-9x]+)", op.rest)
                kelem = 1
                if kern:
                    for d in kern.group(1).split("x"):
                        kelem *= int(d)
                feat = re.search(r"feature_group_count=(\d+)", op.rest)
                lhs_m = _OPERAND.search(op.rest)
                in_feat = 1
                if lhs_m and lhs_m.group(0) in shapes:
                    _, ldims = shapes[lhs_m.group(0)]
                    lsizes = [int(d) for d in ldims.split(",") if d]
                    if lsizes:
                        in_feat = lsizes[-1]
                groups = int(feat.group(1)) if feat else 1
                c.flops += 2.0 * _size(op.dims) * kelem * max(in_feat // groups, 1)
            else:
                base = kind.replace("-start", "")
                if base in _WIRE_FACTOR:
                    c.collective_bytes[base] += rbytes * _WIRE_FACTOR[base]
            # call edges
            if kind == "while":
                body = re.search(r"body=(%[\w.\-]+)", op.rest)
                cond = re.search(r"condition=(%[\w.\-]+)", op.rest)
                trip_m = _TRIP.search(op.rest)
                trip = float(trip_m.group(1)) if trip_m else 1.0
                if not trip_m:
                    trip_notes.append(f"while in {cname}: no known_trip_count")
                if body:
                    ed.append((body.group(1), trip))
                if cond:
                    ed.append((cond.group(1), trip))
            elif kind in ("call", "conditional"):
                for callee in re.findall(r"(?:to_apply|branch_computations)="
                                         r"\{?(%[\w.\-]+)", op.rest):
                    ed.append((callee, 1.0))
                cc = re.search(r"to_apply=(%[\w.\-]+)", op.rest)
                if cc:
                    ed.append((cc.group(1), 1.0))
            elif kind == "fusion":
                fm = re.search(r"calls=(%[\w.\-]+)", op.rest)
                if fm:
                    fusion_bodies.add(fm.group(1))
        own[cname] = c
        edges[cname] = ed

    # Pass 3: accumulate over the call graph from ENTRY (the computation
    # whose name is referenced by no one / starts with %main, prefer ENTRY).
    entry = None
    for cname in comps:
        if "main" in cname:
            entry = cname
    if entry is None and comps:
        entry = next(iter(comps))

    memo: Dict[str, HloCost] = {}

    def total(cname: str, depth: int = 0) -> HloCost:
        if cname in memo:
            return memo[cname]
        c = HloCost()
        if cname not in own or depth > 50:
            return c
        c.add(own[cname])
        for callee, mult in edges.get(cname, []):
            if callee in fusion_bodies:
                continue
            c.add(total(callee, depth + 1), mult)
        memo[cname] = c
        return c

    result = total(entry) if entry else HloCost()
    result.notes = trip_notes[:10]
    return result

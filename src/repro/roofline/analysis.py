"""Three-term roofline analysis from dry-run artifacts (assignment §Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_* come from the trip-count-scaled parser (:mod:`repro.roofline.hlo_costs`)
over the partitioned module — per-device numbers, so the "/chips" cancels and
the terms are simply per-device cost / per-device capability.

MODEL_FLOPS bookkeeping follows the assignment: 6·N·D for training (N =
params, D = tokens; N_active for MoE) and 2·N_active·D for prefill/decode
(D = tokens processed: B·S for prefill, B for one decode step).
"""

from __future__ import annotations

import dataclasses

from repro.configs import ArchSpec, Shape
from repro.roofline.hlo_costs import HloCost


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # B/s per chip
    link_bw: float  # B/s per ICI link
    hbm_gib: float


V5E = HardwareModel(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, hbm_gib=16.0
)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_dev: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/redundancy waste."""
        total_hlo = self.hlo_flops_per_dev * self.n_devices
        return self.model_flops / total_hlo if total_hlo > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time over the bound: MODEL_FLOPS/(chips·peak) ÷
        max(term) — the score-carrying 'fraction of roofline' number."""
        ideal = self.model_flops / (self.n_devices * V5E.peak_flops)
        return ideal / self.bound_s if self.bound_s > 0 else 0.0


def model_flops(spec: ArchSpec, shape: Shape) -> float:
    """Assignment bookkeeping (6·N·D / 2·N_active·D)."""
    cfg = spec.config
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    if shape.kind == "decode":
        return 2.0 * n_active * shape.global_batch
    del n_total
    raise ValueError(shape.kind)


def roofline_from_cell(
    spec: ArchSpec,
    shape: Shape,
    mesh_name: str,
    n_devices: int,
    cost: HloCost,
    hw: HardwareModel = V5E,
) -> RooflineTerms:
    return RooflineTerms(
        arch=spec.arch_id,
        shape=shape.name,
        mesh=mesh_name,
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.bytes / hw.hbm_bw,
        collective_s=cost.total_collective / hw.link_bw,
        model_flops=model_flops(spec, shape),
        hlo_flops_per_dev=cost.flops,
        n_devices=n_devices,
    )

from repro.roofline.hlo_costs import HloCost, parse_hlo_costs
from repro.roofline.analysis import (
    RooflineTerms,
    V5E,
    HardwareModel,
    roofline_from_cell,
    model_flops,
)

__all__ = [
    "HloCost",
    "parse_hlo_costs",
    "RooflineTerms",
    "V5E",
    "HardwareModel",
    "roofline_from_cell",
    "model_flops",
]

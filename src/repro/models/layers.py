"""Shared layer primitives: norms, RoPE, MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays; every init function returns
``(params, axes)`` where ``axes`` mirrors the param tree with tuples of
*logical* axis names consumed by :mod:`repro.distributed.sharding`.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initializers (all take an explicit key; variance-scaled).
# ---------------------------------------------------------------------------


def _normal(key, shape, dtype, scale: float):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, in_dim: int, shape, dtype) -> jax.Array:
    return _normal(key, shape, dtype, in_dim**-0.5)


def embed_init(key, shape, dtype) -> jax.Array:
    return _normal(key, shape, dtype, 1.0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype) -> Tuple[jax.Array, Tuple[str, ...]]:
    return jnp.zeros((dim,), dtype=dtype), ("embed",)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # "zero-centered" scale (gemma-style 1+w); w init 0 => identity.
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array] = None,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    sin = jnp.sin(angles)[..., None, :]  # [..., S, 1, Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype, stacked: Optional[int] = None
             ) -> Tuple[Params, Axes]:
    kg, ku, kd = jax.random.split(key, 3)
    lead = (stacked,) if stacked else ()
    lead_ax = ("layers",) if stacked else ()
    params = {
        "w_gate": dense_init(kg, d_model, lead + (d_model, d_ff), dtype),
        "w_up": dense_init(ku, d_model, lead + (d_model, d_ff), dtype),
        "w_down": dense_init(kd, d_ff, lead + (d_ff, d_model), dtype),
    }
    axes = {
        "w_gate": lead_ax + ("embed", "ffn"),
        "w_up": lead_ax + ("embed", "ffn"),
        "w_down": lead_ax + ("ffn", "embed"),
    }
    return params, axes


def mlp_apply(params: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if activation == "silu":
        act = jax.nn.silu(gate)
    elif activation == "gelu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        raise ValueError(activation)
    return jnp.einsum("...f,fd->...d", act * up, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype) -> Tuple[jax.Array, Tuple]:
    return embed_init(key, (vocab, d_model), dtype), ("vocab", "embed")


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) embedding table: [..., D] -> [..., V]."""
    return jnp.einsum("...d,vd->...v", x, table)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)

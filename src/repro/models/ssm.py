"""Mamba2 — state-space duality (SSD) blocks (arXiv:2405.21060).

The chunked SSD algorithm: split the sequence into chunks of Q tokens; within
a chunk the recurrence collapses to an attention-like quadratic contraction,
across chunks a small [H, P, N] state is carried by a scan.  This is both
the jnp baseline (lowering-friendly: one lax.scan over chunks nested inside
the layer scan) and the oracle for the Pallas ``ssd_scan`` kernel.

Decode is the pure recurrence: O(1) state per token — which is exactly why
attention-KV tiering is inapplicable to this family (DESIGN.md §4) and why
the long_500k shape runs here.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Axes, Params, dense_init, rmsnorm


def ssm_dims(d_model: int, *, expand: int = 2, head_dim: int = 64,
             d_state: int = 128, n_groups: int = 1, d_conv: int = 4) -> Dict[str, int]:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state
    return dict(
        d_inner=d_inner,
        n_heads=n_heads,
        head_dim=head_dim,
        d_state=d_state,
        n_groups=n_groups,
        d_conv=d_conv,
        conv_dim=conv_dim,
        d_in_proj=2 * d_inner + 2 * n_groups * d_state + n_heads,
    )


def ssm_init(
    key, d_model: int, dims: Dict[str, int], dtype, *, stacked: Optional[int] = None
) -> Tuple[Params, Axes]:
    kin, kconv, kdt, kout = jax.random.split(key, 4)
    lead = (stacked,) if stacked else ()
    lead_ax = ("layers",) if stacked else ()
    h, di = dims["n_heads"], dims["d_inner"]
    params: Params = {
        "in_proj": dense_init(kin, d_model, lead + (d_model, dims["d_in_proj"]), dtype),
        "conv_w": dense_init(
            kconv, dims["d_conv"], lead + (dims["d_conv"], dims["conv_dim"]), dtype
        ),
        "conv_b": jnp.zeros(lead + (dims["conv_dim"],), dtype),
        "A_log": jnp.zeros(lead + (h,), jnp.float32)
        + jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones(lead + (h,), jnp.float32),
        "dt_bias": jnp.zeros(lead + (h,), jnp.float32)
        + jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "norm": jnp.zeros(lead + (di,), dtype),
        "out_proj": dense_init(kout, di, lead + (di, d_model), dtype),
    }
    axes: Axes = {
        "in_proj": lead_ax + ("embed", "ssm_proj"),
        "conv_w": lead_ax + ("conv", "ssm_conv_dim"),
        "conv_b": lead_ax + ("ssm_conv_dim",),
        "A_log": lead_ax + ("ssm_heads",),
        "D": lead_ax + ("ssm_heads",),
        "dt_bias": lead_ax + ("ssm_heads",),
        "norm": lead_ax + ("ssm_inner",),
        "out_proj": lead_ax + ("ssm_inner", "embed"),
    }
    return params, axes


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B, S, C]; w: [K, C] depthwise causal conv along S."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad,
        w[:, None, :],  # [K, 1, C]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _split_proj(params: Params, x: jax.Array, dims: Dict[str, int]):
    di, gn, h = dims["d_inner"], dims["n_groups"] * dims["d_state"], dims["n_heads"]
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]  # [B,S,H]
    return z, xbc, dt


def _prep_inputs(params: Params, xbc_conv: jax.Array, dt: jax.Array,
                 dims: Dict[str, int]):
    di, g, n = dims["d_inner"], dims["n_groups"], dims["d_state"]
    h, p = dims["n_heads"], dims["head_dim"]
    xs = xbc_conv[..., :di]
    bmat = xbc_conv[..., di : di + g * n]
    cmat = xbc_conv[..., di + g * n :]
    b_, s_ = xs.shape[0], xs.shape[1]
    xs = xs.reshape(b_, s_, h, p)
    bmat = bmat.reshape(b_, s_, g, n)
    cmat = cmat.reshape(b_, s_, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["A_log"])  # [H]
    return xs, bmat, cmat, dt, a


def ssd_chunked(
    xs: jax.Array,  # [B,S,H,P]
    bmat: jax.Array,  # [B,S,G,N]
    cmat: jax.Array,  # [B,S,G,N]
    dt: jax.Array,  # [B,S,H] (post-softplus, fp32)
    a: jax.Array,  # [H] (negative, fp32)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # [B,H,P,N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan: lax.scan over chunks carrying the [B,H,P,N] state,
    with the quadratic intra-chunk term computed *inside* the scan body so
    peak temporaries are per-chunk ([B,Q,Q,H]) — the same blocking the
    Pallas ``ssd_scan`` kernel tiles into VMEM.

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    b, s, h, p = xs.shape
    g, n = bmat.shape[2], bmat.shape[3]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk != 0:
        # Zero-pad to a chunk multiple: dt=0 makes padded steps exact
        # no-ops (decay exp(0)=1, zero state contribution).
        pad = chunk - s % chunk
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc, q = s // chunk, chunk
    rep = h // g  # heads per group
    mask = jnp.tril(jnp.ones((q, q), bool))

    # Chunked views, scanned over the chunk axis (placed leading).
    xs_c = jnp.moveaxis(xs.reshape(b, nc, q, h, p), 1, 0)
    b_c = jnp.moveaxis(bmat.reshape(b, nc, q, g, n), 1, 0)
    c_c = jnp.moveaxis(cmat.reshape(b, nc, q, g, n), 1, 0)
    dt_c = jnp.moveaxis(dt.reshape(b, nc, q, h), 1, 0)

    h0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def body(carry, inp):
        x_q, b_q, c_q, dt_q = inp  # [B,Q,H,P], [B,Q,G,N], [B,Q,G,N], [B,Q,H]
        da = dt_q * a  # [B,Q,H]
        cum = jnp.cumsum(da, axis=1)  # [B,Q,H]

        # Intra-chunk quadratic term.
        rel = cum[:, :, None, :] - cum[:, None, :, :]  # [B,Q,Q,H]
        decay = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bqgn,bkgn->bqkg", c_q, b_q)  # [B,Q,Q,G]
        scores = jnp.repeat(scores, rep, axis=-1)  # [B,Q,Q,H]
        w = (scores * decay).astype(x_q.dtype)
        dx = (dt_q[..., None] * x_q.astype(jnp.float32)).astype(x_q.dtype)
        y_q = jnp.einsum("bqkh,bkhp->bqhp", w, dx)

        # Inter-chunk contribution from the carried state.
        c_heads = jnp.repeat(c_q, rep, axis=2)  # [B,Q,H,N]
        y_q = y_q + jnp.einsum(
            "bqhn,bhpn->bqhp", jnp.exp(cum)[..., None] * c_heads, carry
        ).astype(x_q.dtype)

        # State update: new = decay_total * old + sum_q tail[q] dt[q] B[q] x[q]^T.
        tail = jnp.exp(cum[:, -1:, :] - cum)  # [B,Q,H]
        b_heads = jnp.repeat(b_q, rep, axis=2)  # [B,Q,H,N]
        weighted_x = (tail * dt_q)[..., None] * x_q.astype(jnp.float32)  # [B,Q,H,P]
        s_chunk = jnp.einsum("bqhp,bqhn->bhpn", weighted_x, b_heads)
        total_decay = jnp.exp(jnp.sum(da, axis=1))  # [B,H]
        new_carry = carry * total_decay[:, :, None, None] + s_chunk
        return new_carry, y_q

    final, y = jax.lax.scan(body, h0, (xs_c, b_c, c_c, dt_c))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p)
    return y[:, :s_orig], final


def ssm_forward(
    params: Params,
    x: jax.Array,  # [B,S,D]
    dims: Dict[str, int],
    *,
    chunk: int = 128,
) -> jax.Array:
    z, xbc, dt_raw = _split_proj(params, x, dims)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, bmat, cmat, dt, a = _prep_inputs(params, xbc, dt_raw, dims)
    y, _ = ssd_chunked(xs, bmat, cmat, dt, a, chunk=chunk)
    b, s = x.shape[0], x.shape[1]
    y = y.reshape(b, s, dims["d_inner"])
    y = y + (params["D"].repeat(dims["head_dim"]) * xs.reshape(b, s, -1).astype(
        jnp.float32)).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"])


# ---------------------------------------------------------------------------
# Decode path (recurrent single-step)
# ---------------------------------------------------------------------------


def init_ssm_state(batch: int, dims: Dict[str, int], dtype=jnp.float32
                   ) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros(
            (batch, dims["n_heads"], dims["head_dim"], dims["d_state"]), jnp.float32
        ),
        "conv": jnp.zeros((batch, dims["d_conv"] - 1, dims["conv_dim"]), dtype),
    }


SSM_STATE_AXES = {"h": ("batch", "ssm_heads", "ssm_head_dim", "ssm_state"),
                  "conv": ("batch", "conv", "ssm_conv_dim")}


def ssm_step(
    params: Params,
    x: jax.Array,  # [B,1,D]
    state: Dict[str, jax.Array],
    dims: Dict[str, int],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = x.shape[0]
    g, h = dims["n_groups"], dims["n_heads"]
    rep = h // g
    z, xbc, dt_raw = _split_proj(params, x, dims)  # [B,1,*]
    # Conv over the rolling window [conv_state | new].
    window = jnp.concatenate([state["conv"], xbc], axis=1)  # [B,K,conv]
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]  # [B,1,conv]
    new_conv = window[:, 1:, :]
    xs, bmat, cmat, dt, a = _prep_inputs(params, conv_out, dt_raw, dims)
    # Single-step recurrence.
    dt1 = dt[:, 0]  # [B,H]
    da = jnp.exp(dt1 * a)  # [B,H]
    b1 = jnp.repeat(bmat[:, 0], rep, axis=1)  # [B,H,N]
    c1 = jnp.repeat(cmat[:, 0], rep, axis=1)  # [B,H,N]
    x1 = xs[:, 0].astype(jnp.float32)  # [B,H,P]
    new_h = state["h"] * da[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", dt1[:, :, None] * x1, b1
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_h, c1)  # [B,H,P]
    y = y + params["D"][None, :, None] * x1
    y = y.reshape(b, 1, dims["d_inner"]).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, {"h": new_h, "conv": new_conv}

"""Grouped-query attention with sliding windows, soft-capping and KV caches.

One implementation serves every assigned attention arch:

  * GQA head grouping (n_q_heads % n_kv_heads == 0), optional QKV biases
    (qwen2.5) and per-head QK-norm (stablelm-2).
  * Per-layer *dynamic* attention windows: the window size is a traced
    scalar, so a scan over layers can alternate local/global (gemma2) or
    SWA/full (hymba, h2o-danube) without breaking layer-stacking.  A window
    >= S is full causal attention.
  * Logit soft-capping (gemma2).
  * Serving: ``attend_cached`` runs one-token decode against a [B, S_max]
    cache updated in place (dynamic_update_slice), masked by current length.

The flash-decode Pallas kernel (:mod:`repro.kernels`) replaces the cached
path's einsums on TPU; this module is the lowering-friendly jnp baseline and
the oracle's building block.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Axes, Params, apply_rope, dense_init, rmsnorm

NEG_INF = -2.3819763e38  # large negative for masking (bf16-safe)


def attention_init(
    key,
    d_model: int,
    n_q: int,
    n_kv: int,
    head_dim: int,
    dtype,
    *,
    stacked: Optional[int] = None,
    qkv_bias: bool = False,
    qk_norm: bool = False,
) -> Tuple[Params, Axes]:
    kq, kk, kv, ko = jax.random.split(key, 4)
    lead = (stacked,) if stacked else ()
    lead_ax = ("layers",) if stacked else ()
    params: Params = {
        "wq": dense_init(kq, d_model, lead + (d_model, n_q, head_dim), dtype),
        "wk": dense_init(kk, d_model, lead + (d_model, n_kv, head_dim), dtype),
        "wv": dense_init(kv, d_model, lead + (d_model, n_kv, head_dim), dtype),
        "wo": dense_init(ko, n_q * head_dim, lead + (n_q, head_dim, d_model), dtype),
    }
    axes: Axes = {
        "wq": lead_ax + ("embed", "q_heads", "head_dim"),
        "wk": lead_ax + ("embed", "kv_heads", "head_dim"),
        "wv": lead_ax + ("embed", "kv_heads", "head_dim"),
        "wo": lead_ax + ("q_heads", "head_dim", "embed"),
    }
    if qkv_bias:
        params["bq"] = jnp.zeros(lead + (n_q, head_dim), dtype)
        params["bk"] = jnp.zeros(lead + (n_kv, head_dim), dtype)
        params["bv"] = jnp.zeros(lead + (n_kv, head_dim), dtype)
        axes["bq"] = lead_ax + ("q_heads", "head_dim")
        axes["bk"] = lead_ax + ("kv_heads", "head_dim")
        axes["bv"] = lead_ax + ("kv_heads", "head_dim")
    if qk_norm:
        params["q_norm"] = jnp.zeros(lead + (head_dim,), dtype)
        params["k_norm"] = jnp.zeros(lead + (head_dim,), dtype)
        axes["q_norm"] = lead_ax + ("head_dim",)
        axes["k_norm"] = lead_ax + ("head_dim",)
    return params, axes


def project_qkv(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    rope_theta: Optional[float],
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, S, D] -> q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh] (RoPE applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,S,Hq,Dh] x k [B,T,Hkv,Dh] -> scores [B,Hq,S,T] with GQA groups."""
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    q = q.reshape(b, s, hkv, group, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k)
    return scores.reshape(b, hkv * group, s, k.shape[1])


def _grouped_values(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs [B,Hq,S,T] x v [B,T,Hkv,Dh] -> [B,S,Hq,Dh]."""
    b, hq, s, t = probs.shape
    hkv = v.shape[2]
    group = hq // hkv
    probs = probs.reshape(b, hkv, group, s, t)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, hq, v.shape[3])


#: Above this sequence length, attend_full processes queries in row blocks
#: of this size, bounding live score buffers to [B, H, Q_BLOCK, S] — the
#: jnp flash-attention analogue (and the structure the Pallas kernel tiles).
Q_BLOCK = 1024


def _attention_core(
    q: jax.Array,  # [B,Sq,Hq,Dh] (pre-scaled)
    k: jax.Array,  # [B,T,Hkv,Dh]
    v: jax.Array,  # [B,T,Hkv,Dh]
    qpos: jax.Array,  # [B,Sq]
    tpos: jax.Array,  # [B,T]
    *,
    window: jax.Array,
    softcap_value: Optional[float],
    causal: bool,
    dtype,
) -> jax.Array:
    scores = _grouped_scores(q, k)  # [B,Hq,Sq,T]
    if softcap_value is not None:
        scores = softcap_value * jnp.tanh(scores / softcap_value)
    sp = qpos[:, :, None]  # [B,Sq,1]
    tp = tpos[:, None, :]  # [B,1,T]
    if causal:
        mask = (tp <= sp) & (sp - tp < window)
    else:
        mask = jnp.abs(sp - tp) < window
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
    return _grouped_values(probs, v)  # [B,Sq,Hq,Dh]


def attend_full(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    rope_theta: Optional[float],
    window: jax.Array,
    softcap_value: Optional[float] = None,
    causal: bool = True,
    query_scale: Optional[float] = None,
    q_block: int = Q_BLOCK,
) -> jax.Array:
    """Full-sequence attention (training / prefill).  ``window`` is a traced
    scalar: key t attends to query s iff 0 <= s - t < window (causal) —
    window >= S means dense causal; non-causal encoders pass causal=False.

    For S > q_block, queries are processed in blocks via lax.map so the
    [B, H, S, S] score tensor never materializes (exact, not approximate)."""
    s = x.shape[1]
    dh = params["wq"].shape[-1]
    q, k, v = project_qkv(params, x, positions, rope_theta=rope_theta)
    scale = query_scale if query_scale is not None else dh**-0.5
    q = q * scale
    if s <= q_block or s % q_block != 0:
        out = _attention_core(
            q, k, v, positions, positions,
            window=window, softcap_value=softcap_value, causal=causal,
            dtype=x.dtype,
        )
    else:
        nb = s // q_block
        b, _, hq, _ = q.shape
        q_c = q.reshape(b, nb, q_block, hq, dh).swapaxes(0, 1)
        pos_c = positions.reshape(b, nb, q_block).swapaxes(0, 1)

        def one(args):
            qc, pc = args
            return _attention_core(
                qc, k, v, pc, positions,
                window=window, softcap_value=softcap_value, causal=causal,
                dtype=x.dtype,
            )

        # Per-block checkpoint: the map's backward otherwise saves every
        # block's probs simultaneously — the full S^2 buffer again.
        out = jax.lax.map(jax.checkpoint(one), (q_c, pos_c))
        out = out.swapaxes(0, 1).reshape(b, s, hq, dh)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def attend_cross(
    params: Params,
    x: jax.Array,
    memory_k: jax.Array,
    memory_v: jax.Array,
    *,
    q_block: int = 0,
) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    b, s, hq, dh = q.shape
    q = q * dh**-0.5
    q_block = q_block or Q_BLOCK

    def core(qc):
        scores = _grouped_scores(qc, memory_k)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(
            x.dtype
        )
        return _grouped_values(probs, memory_v)

    if s <= q_block or s % q_block != 0:
        out = core(q)
    else:
        nb = s // q_block
        q_c = q.reshape(b, nb, q_block, hq, dh).swapaxes(0, 1)
        out = jax.lax.map(jax.checkpoint(core), q_c)
        out = out.swapaxes(0, 1).reshape(b, s, hq, dh)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def project_memory_kv(params: Params, memory: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, max_len: int, n_kv: int, head_dim: int, dtype
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


KV_CACHE_AXES = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
                 "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def attend_cached(
    params: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    length: jax.Array,
    *,
    rope_theta: Optional[float],
    window: jax.Array,
    softcap_value: Optional[float] = None,
    query_scale: Optional[float] = None,
    update_cache: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  x: [B, 1, D]; cache k/v: [B, S_max, Hkv, Dh];
    ``length`` [B] or scalar = tokens already in cache (new token lands at
    index ``length``).  Returns ([B, 1, D], updated cache)."""
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.atleast_1d(length), (b,))[:, None]  # [B,1]
    q, k_new, v_new = project_qkv(params, x, positions, rope_theta=rope_theta)
    if update_cache:
        idx = jnp.broadcast_to(jnp.atleast_1d(length), (b,))

        def upd(buf, new):
            def one(buf_b, new_b, i):
                return jax.lax.dynamic_update_slice_in_dim(buf_b, new_b, i, axis=0)

            return jax.vmap(one)(buf, new, idx)

        cache = {"k": upd(cache["k"], k_new), "v": upd(cache["v"], v_new)}
        k, v = cache["k"], cache["v"]
    else:
        k, v = cache["k"], cache["v"]
    dh = q.shape[-1]
    scale = query_scale if query_scale is not None else dh**-0.5
    scores = _grouped_scores(q * scale, k)  # [B,Hq,1,S_max]
    if softcap_value is not None:
        scores = softcap_value * jnp.tanh(scores / softcap_value)
    t = jnp.arange(k.shape[1])[None, :]  # [1,S_max]
    cur = positions  # [B,1]
    valid = (t <= cur) & (cur - t < window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _grouped_values(probs, v)  # [B,1,Hq,Dh]
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache

"""Mixture-of-Experts FFN with sort-based token dispatch.

Covers dbrx (16 experts, top-4, fine-grained) and llama4-maverick (128
experts, top-1, plus a shared expert).  Dispatch is the MaxText-style
sort/gather/scatter pipeline — *not* one-hot dispatch einsums, whose
[tokens x experts x capacity] contractions would add O(T^2) FLOPs at 128
experts and drown the roofline's useful-compute ratio.

Expert weights are stacked [E, ...] and logically sharded over the
``experts`` axis (expert parallelism); the gather/scatter pair is what GSPMD
turns into the all-to-all (baseline) — the perf pass replaces it with an
explicit shard_map dispatch where profitable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.autosharding import constrain
from repro.models.layers import Axes, Params, dense_init


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    dtype,
    *,
    stacked: Optional[int] = None,
    shared_expert_ff: int = 0,
) -> Tuple[Params, Axes]:
    kr, kg, ku, kd, ksg, ksu, ksd = jax.random.split(key, 7)
    lead = (stacked,) if stacked else ()
    lead_ax = ("layers",) if stacked else ()
    params: Params = {
        "router": dense_init(kr, d_model, lead + (d_model, n_experts), dtype),
        "w_gate": dense_init(kg, d_model, lead + (n_experts, d_model, d_ff), dtype),
        "w_up": dense_init(ku, d_model, lead + (n_experts, d_model, d_ff), dtype),
        "w_down": dense_init(kd, d_ff, lead + (n_experts, d_ff, d_model), dtype),
    }
    axes: Axes = {
        "router": lead_ax + ("embed", "experts_r"),
        "w_gate": lead_ax + ("experts", "embed", "ffn"),
        "w_up": lead_ax + ("experts", "embed", "ffn"),
        "w_down": lead_ax + ("experts", "ffn", "embed"),
    }
    if shared_expert_ff > 0:
        params["shared"] = {
            "w_gate": dense_init(
                ksg, d_model, lead + (d_model, shared_expert_ff), dtype
            ),
            "w_up": dense_init(
                ksu, d_model, lead + (d_model, shared_expert_ff), dtype
            ),
            "w_down": dense_init(
                ksd, shared_expert_ff, lead + (shared_expert_ff, d_model), dtype
            ),
        }
        axes["shared"] = {
            "w_gate": lead_ax + ("embed", "ffn"),
            "w_up": lead_ax + ("embed", "ffn"),
            "w_down": lead_ax + ("ffn", "embed"),
        }
    return params, axes


def moe_apply(
    params: Params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], load-balance aux loss scalar).

    Under an active logical-sharding context with a >1 data axis, the
    dispatch runs shard_map-manual over the batch axes: the token sort,
    capacity ranking and scatter are *local per data shard* (capacity is
    per-shard), so there is no global argsort and — critically — no
    replicated [E, C, D] scatter buffer for GSPMD to all-reduce (tens of
    TB/step on dbrx otherwise).  Expert weights enter through replicated
    in_specs (one FSDP all-gather's worth) while their expert dimension
    stays auto-sharded over ``model`` (EP).
    """
    from repro.distributed.autosharding import _top

    ctx = _top()
    if ctx is not None:
        mesh, _rules = ctx
        data_axes = tuple(
            a for a in ("pod", "data")
            if a in mesh.shape and mesh.shape[a] > 1
        )
        n_shards = 1
        for a in data_axes:
            n_shards *= mesh.shape[a]
        # NOTE: the shard_map dispatch path triggers an XLA CPU crash
        # ("Invalid binary instruction opcode copy") under scan+remat in
        # jax 0.8.2; the pure-GSPMD path below achieves locality with
        # explicit sharding constraints instead.  Flip to re-enable on TPU.
        _SHARD_MAP_DISPATCH = False
        if _SHARD_MAP_DISPATCH and n_shards > 1 and x.shape[0] % n_shards == 0:
            return _moe_apply_sharded(
                params, x, mesh, data_axes,
                top_k=top_k, capacity_factor=capacity_factor,
                activation=activation,
            )
    return _moe_apply_local(
        params, x, top_k=top_k, capacity_factor=capacity_factor,
        activation=activation,
    )


def _moe_apply_sharded(params, x, mesh, data_axes, *, top_k,
                       capacity_factor, activation):
    from jax.sharding import PartitionSpec as P

    dn = data_axes if len(data_axes) > 1 else data_axes[0]

    def body(x_l, router, w_gate, w_up, w_down, shared):
        sub = {"router": router, "w_gate": w_gate, "w_up": w_up,
               "w_down": w_down}
        if shared is not None:
            sub["shared"] = shared
        out_l, aux_l = _moe_apply_local(
            sub, x_l, top_k=top_k, capacity_factor=capacity_factor,
            activation=activation, use_constraints=False,
        )
        return out_l, jax.lax.pmean(aux_l, data_axes)

    shared = params.get("shared")
    in_specs = (
        P(dn), P(), P(), P(), P(),
        (jax.tree.map(lambda _: P(), shared) if shared is not None else None),
    )
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(dn), P()),
        axis_names=set(data_axes),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"], shared)
    return out, aux


def _moe_apply_local(
    params: Params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    use_constraints: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Shard-major dispatch: tokens are viewed as [NS, T_local, D] with the
    leading dim on the batch mesh axes.  Every sort/rank/scatter is batched
    over that axis (vmap), so under GSPMD each device executes its own
    *local* dispatch — no global argsort, no cross-shard scatter for the
    partitioner to replicate-and-all-reduce.  Capacity is per shard
    (standard per-device capacity semantics).  NS=1 without a mesh context
    (tests, single device) — then this is the plain global algorithm."""
    from repro.distributed.autosharding import _top

    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s

    ns = 1
    ctx = _top()
    if use_constraints and ctx is not None:
        mesh, _ = ctx
        cand = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                cand *= mesh.shape[a]
        if cand > 1 and b % cand == 0:
            ns = cand
    tl = t // ns

    x3 = x.reshape(ns, tl, d)
    if use_constraints:
        x3 = constrain(x3, ("data_shards", "moe_tok", "embed_act"))

    router_logits = jnp.einsum(
        "ntd,de->nte", x3, params["router"]
    ).astype(jnp.float32)
    gates = jax.nn.softmax(router_logits, axis=-1)  # [NS, TL, E]
    top_vals, top_idx = jax.lax.top_k(gates, top_k)  # [NS, TL, k]
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e.
    me = jnp.mean(gates, axis=(0, 1))  # [E]
    assign_mean = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / (t * top_k)
    )
    aux_loss = e * jnp.sum(me * assign_mean)

    capacity = int(max(top_k, capacity_factor * tl * top_k / e))
    capacity = min(capacity, tl)

    flat_e = top_idx.reshape(ns, tl * top_k)  # [NS, TL*k]
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    # Rank of each request within its expert's arrival order (per shard).
    group_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_e)  # [NS, E]
    rank = jnp.arange(tl * top_k)[None, :] - jnp.take_along_axis(
        group_start, sorted_e, axis=1
    )
    keep = rank < capacity
    slot = sorted_e * capacity + rank
    token_of = sort_idx // top_k
    gate_of = jnp.take_along_axis(
        top_vals.reshape(ns, tl * top_k), sort_idx, axis=1
    )

    # Dispatch: per-shard scatter into [E*C, D] (out-of-capacity dropped).
    safe_slot = jnp.where(keep, slot, e * capacity)

    def scatter_one(slot_l, src_l):
        buf = jnp.zeros((e * capacity, d), x.dtype)
        return buf.at[slot_l].set(src_l, mode="drop")

    src = jnp.take_along_axis(x3, token_of[..., None], axis=1)  # [NS,TL*k,D]
    xe = jax.vmap(scatter_one)(safe_slot, src)  # [NS, E*C, D]
    xe = xe.reshape(ns, e, capacity, d)
    if use_constraints:
        xe = constrain(xe, ("data_shards", "experts", "moe_cap_l",
                            "embed_act"))

    # Expert FFNs: E over model (EP), NS over data.  Gather the FSDP weight
    # shards first — otherwise GSPMD partial-sums the contraction and
    # all-reduces [NS, E, C, F] activations.
    if use_constraints:
        wg = constrain(params["w_gate"], ("experts", "gathered", "gathered"))
        wu = constrain(params["w_up"], ("experts", "gathered", "gathered"))
        wd = constrain(params["w_down"], ("experts", "gathered", "gathered"))
    else:
        wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    g = jnp.einsum("necd,edf->necf", xe, wg)
    u = jnp.einsum("necd,edf->necf", xe, wu)
    act = jax.nn.silu(g) if activation == "silu" else jax.nn.gelu(
        g, approximate=True)
    y = jnp.einsum("necf,efd->necd", act * u, wd)

    # Combine: per-shard gather + weighted scatter-add back to tokens.
    y_flat = y.reshape(ns, e * capacity, d)
    gather_slot = jnp.where(keep, slot, 0)
    contrib = jnp.take_along_axis(y_flat, gather_slot[..., None], axis=1)
    contrib = contrib * (keep.astype(x.dtype) * gate_of.astype(x.dtype))[
        ..., None
    ]

    def combine_one(tok_l, con_l):
        return jnp.zeros((tl, d), x.dtype).at[tok_l].add(con_l)

    out = jax.vmap(combine_one)(token_of, contrib)  # [NS, TL, D]
    out = out.reshape(b, s, d)
    if use_constraints:
        out = constrain(out, ("batch", "seq", "embed_act"))

    if "shared" in params:
        sh = params["shared"]
        xf = x.reshape(t, d)
        g2 = jnp.einsum("td,df->tf", xf, sh["w_gate"])
        u2 = jnp.einsum("td,df->tf", xf, sh["w_up"])
        a2 = jax.nn.silu(g2) if activation == "silu" else jax.nn.gelu(
            g2, approximate=True)
        out = out + jnp.einsum("tf,fd->td", a2 * u2, sh["w_down"]).reshape(
            b, s, d)

    return out, aux_loss

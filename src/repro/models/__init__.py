"""Model zoo: every assigned architecture family in pure JAX.

Layer families:
  * dense GQA transformers (stablelm, qwen2.5, h2o-danube, gemma2, internvl2 LM)
  * MoE transformers (dbrx 16e top-4, llama4-maverick 128e top-1 + shared)
  * SSM (mamba2, SSD chunked scan)
  * hybrid attention+SSM (hymba, parallel heads)
  * encoder-decoder (whisper, conv frontend stubbed)

All models are scan-over-layers (stacked params) for small HLO / fast
multi-pod compiles, expose ``forward`` (train), ``prefill`` and
``decode_step`` (serving, explicit KV/SSM state), and carry logical-axis
annotations for the distributed sharding rules.
"""

from repro.models.transformer import TransformerLM, DecodeState

__all__ = ["TransformerLM", "DecodeState"]

"""The unified scan-over-layers transformer covering every assigned family.

``ModelConfig`` declares the family (dense / moe / ssm / hybrid, optionally
encoder-decoder); :class:`TransformerLM` builds stacked-layer params, a
training ``forward`` (last-token or loss-ready hidden states), ``prefill``
and a one-token ``decode_step`` with explicit :class:`DecodeState`.

Layer stacking + ``lax.scan`` keeps the HLO program size O(1) in depth: a
46-layer gemma2 or 64-layer mamba2 compiles in roughly the time of one
layer — essential for 512-device dry-run compiles.  Heterogeneous layer
patterns (gemma2 local/global alternation, hymba's three full-attention
layers) are expressed as *per-layer scanned scalars* (attention window
sizes), keeping the scanned computation uniform.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.autosharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    Axes,
    Params,
    embed_init,
    embed_lookup,
    layernorm,
    rmsnorm,
    softcap,
    unembed,
)

FULL_WINDOW = 1 << 30  # "window" larger than any sequence = dense attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    block: str = "dense"  # dense | moe | ssm | hybrid
    # attention flavour
    rope_theta: Optional[float] = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    sliding_window: Optional[int] = None  # default window for SWA layers
    #: per-layer window pattern: "full" | "swa" | "gemma2" (alternate
    #: local/global) | "hymba" (full at first/middle/last, SWA elsewhere)
    window_pattern: str = "full"
    # norms / activations / embeddings
    norm: str = "rms"  # rms | layernorm
    activation: str = "silu"  # silu | gelu
    tied_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) scaling
    use_post_norms: bool = False  # gemma2 post-attn/post-ffn norms
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert_ff: int = 0
    capacity_factor: float = 1.25
    #: 1 = every layer MoE (dbrx); 2 = alternating dense/MoE pairs (llama4
    #: maverick: 24 dense + 24 MoE layers — this is what reconciles the
    #: 400B-total / 17B-active name with 128 experts).  Pair-scanned.
    moe_every: int = 1
    d_ff_dense: int = 0  # dense sub-layer FFN width when moe_every == 2
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of 10 ms frames after conv stub
    # frontend stub: number of precomputed embedding positions prepended
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_seq: int = 0  # e.g. 256 vision patch embeddings
    dtype: Any = jnp.bfloat16

    @property
    def uses_attention(self) -> bool:
        return self.block in ("dense", "moe", "hybrid")

    @property
    def uses_ssm(self) -> bool:
        return self.block in ("ssm", "hybrid")

    @property
    def ssm_dims(self) -> Dict[str, int]:
        return ssm_lib.ssm_dims(
            self.d_model,
            expand=self.ssm_expand,
            head_dim=self.ssm_head_dim,
            d_state=self.ssm_state,
            n_groups=self.ssm_groups,
        )

    @property
    def paired(self) -> bool:
        return self.block == "moe" and self.moe_every == 2

    @property
    def n_scan(self) -> int:
        """Scanned steps (pairs count as one step)."""
        return self.n_layers // 2 if self.paired else self.n_layers

    def window_sizes(self) -> jnp.ndarray:
        """Per-layer attention windows (scanned).  Shape [n_scan] or
        [n_scan, 2] for paired stacks."""
        w = self.sliding_window or FULL_WINDOW
        if self.window_pattern == "full":
            out = [FULL_WINDOW] * self.n_layers
        elif self.window_pattern == "swa":
            out = [w] * self.n_layers
        elif self.window_pattern == "gemma2":
            # local (SWA) on even layers, global on odd (gemma2 ordering).
            out = [w if i % 2 == 0 else FULL_WINDOW for i in range(self.n_layers)]
        elif self.window_pattern == "hymba":
            full_at = {0, self.n_layers // 2, self.n_layers - 1}
            out = [FULL_WINDOW if i in full_at else w for i in range(self.n_layers)]
        else:
            raise ValueError(self.window_pattern)
        arr = jnp.asarray(out, dtype=jnp.int32)
        return arr.reshape(self.n_scan, 2) if self.paired else arr

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        n = self.vocab * d  # embed
        if not self.tied_embeddings:
            n += self.vocab * d
        attn_per = d * self.head_dim * (self.n_q_heads * 2 + self.n_kv_heads * 2)
        per_layer = 0
        if self.uses_attention:
            per_layer += attn_per
        if self.block == "moe":
            n_moe_layers = L // 2 if self.paired else L
            n_dense_layers = L - n_moe_layers
            n += n_moe_layers * (
                attn_per
                + d * self.n_experts
                + 3 * d * f * self.n_experts
                + (3 * d * self.shared_expert_ff if self.shared_expert_ff else 0)
            )
            dense_ff = self.d_ff_dense or 2 * f
            n += n_dense_layers * (attn_per + 3 * d * dense_ff)
            per_layer = 0  # fully accounted above
            L = 0
        elif self.block in ("dense", "hybrid") and f > 0:
            per_layer += 3 * d * f
        if self.uses_ssm:
            dims = self.ssm_dims
            per_layer += d * dims["d_in_proj"] + dims["d_inner"] * d
            per_layer += dims["d_conv"] * dims["conv_dim"]
        n += L * per_layer
        if self.n_encoder_layers:
            enc_per = attn_per + 3 * d * f
            n += self.n_encoder_layers * enc_per
            n += self.n_layers * attn_per  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.block != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_moe_layers = self.n_layers // 2 if self.paired else self.n_layers
        total = self.param_count()
        moe_all = n_moe_layers * 3 * d * f * self.n_experts
        moe_active = n_moe_layers * 3 * d * f * self.top_k
        return total - moe_all + moe_active


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    """Per-request decoding state (stacked over layers for scanning)."""

    kv: Optional[Dict[str, jax.Array]]  # k/v: [L, B, S_max, Hkv, Dh]
    ssm: Optional[Dict[str, jax.Array]]  # h: [L,B,H,P,N]; conv: [L,B,K-1,C]
    cross_kv: Optional[Dict[str, jax.Array]]  # whisper: [L,B,T_enc,Hkv,Dh]
    length: jax.Array  # [] int32: tokens already decoded


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


class TransformerLM:
    """Unified scan-over-layers LM for every assigned family.

    ``remat``: activation-checkpointing policy applied to the scanned layer
    body under differentiation — "none" | "full" (save only carries) |
    "dots" (save matmul outputs; XLA's checkpoint_dots policy).
    """

    def __init__(self, cfg: ModelConfig, *, remat: str = "none"):
        self.cfg = cfg
        self.remat = remat

    def _maybe_remat(self, body):
        if self.remat == "none":
            return body
        if self.remat == "full":
            return jax.checkpoint(body, prevent_cse=False)
        if self.remat == "dots":
            return jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots,
                prevent_cse=False,
            )
        raise ValueError(self.remat)

    # ------------------------------------------------------------------ init
    def _sublayer_init(self, key, stacked: int, *, ffn: Optional[str],
                       d_ff: int, cross: bool = False,
                       with_attn: Optional[bool] = None,
                       with_ssm: Optional[bool] = None) -> Tuple[Params, Axes]:
        """One layer kind: attention/ssm mixing + the chosen FFN."""
        cfg = self.cfg
        keys = jax.random.split(key, 6)
        params: Params = {}
        axes: Axes = {}
        norm_ax = ("layers", "embed")
        zeros = lambda: jnp.zeros((stacked, cfg.d_model), cfg.dtype)  # noqa: E731
        use_attn = cfg.uses_attention if with_attn is None else with_attn
        use_ssm = cfg.uses_ssm if with_ssm is None else with_ssm
        if use_attn:
            params["attn"], axes["attn"] = attn.attention_init(
                keys[0], cfg.d_model, cfg.n_q_heads, cfg.n_kv_heads, cfg.head_dim,
                cfg.dtype, stacked=stacked, qkv_bias=cfg.qkv_bias,
                qk_norm=cfg.qk_norm,
            )
            params["pre_attn_norm"] = zeros()
            axes["pre_attn_norm"] = norm_ax
            if cfg.use_post_norms:
                params["post_attn_norm"] = zeros()
                axes["post_attn_norm"] = norm_ax
        if cross:
            params["cross"], axes["cross"] = attn.attention_init(
                keys[1], cfg.d_model, cfg.n_q_heads, cfg.n_kv_heads, cfg.head_dim,
                cfg.dtype, stacked=stacked,
            )
            params["pre_cross_norm"] = zeros()
            axes["pre_cross_norm"] = norm_ax
        if use_ssm:
            params["ssm"], axes["ssm"] = ssm_lib.ssm_init(
                keys[2], cfg.d_model, cfg.ssm_dims, cfg.dtype, stacked=stacked
            )
            if not use_attn:
                params["pre_ssm_norm"] = zeros()
                axes["pre_ssm_norm"] = norm_ax
        if ffn == "moe":
            params["moe"], axes["moe"] = moe_lib.moe_init(
                keys[3], cfg.d_model, d_ff, cfg.n_experts, cfg.dtype,
                stacked=stacked, shared_expert_ff=cfg.shared_expert_ff,
            )
            params["pre_mlp_norm"] = zeros()
            axes["pre_mlp_norm"] = norm_ax
        elif ffn == "mlp":
            from repro.models.layers import mlp_init

            params["mlp"], axes["mlp"] = mlp_init(
                keys[4], cfg.d_model, d_ff, cfg.dtype, stacked=stacked
            )
            params["pre_mlp_norm"] = zeros()
            axes["pre_mlp_norm"] = norm_ax
            if cfg.use_post_norms:
                params["post_mlp_norm"] = zeros()
                axes["post_mlp_norm"] = norm_ax
        return params, axes

    def _layer_init(self, key, cross: bool = False) -> Tuple[Params, Axes]:
        cfg = self.cfg
        if cfg.paired:
            kd, km = jax.random.split(key)
            dense_ff = cfg.d_ff_dense or 2 * cfg.d_ff
            pd, ad = self._sublayer_init(kd, cfg.n_scan, ffn="mlp",
                                         d_ff=dense_ff, cross=cross)
            pm, am = self._sublayer_init(km, cfg.n_scan, ffn="moe",
                                         d_ff=cfg.d_ff, cross=False)
            return {"dense": pd, "moe": pm}, {"dense": ad, "moe": am}
        ffn = {"dense": "mlp", "hybrid": "mlp", "moe": "moe", "ssm": None}[cfg.block]
        if cfg.block in ("dense", "hybrid") and cfg.d_ff == 0:
            ffn = None
        return self._sublayer_init(key, cfg.n_scan, ffn=ffn, d_ff=cfg.d_ff,
                                   cross=cross)

    def init(self, key) -> Tuple[Params, Axes]:
        cfg = self.cfg
        k_embed, k_layers, k_enc, k_head = jax.random.split(key, 4)
        params: Params = {}
        axes: Axes = {}
        params["embed"] = embed_init(k_embed, (cfg.vocab, cfg.d_model), cfg.dtype)
        axes["embed"] = ("vocab", "embed")
        params["layers"], axes["layers"] = self._layer_init(
            k_layers, cross=cfg.n_encoder_layers > 0
        )
        if cfg.n_encoder_layers:
            params["enc_layers"], axes["enc_layers"] = self._sublayer_init(
                k_enc, cfg.n_encoder_layers, ffn="mlp", d_ff=cfg.d_ff,
                with_attn=True, with_ssm=False,
            )
            params["enc_final_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
            axes["enc_final_norm"] = ("embed",)
        params["final_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        axes["final_norm"] = ("embed",)
        if not cfg.tied_embeddings:
            params["lm_head"] = embed_init(k_head, (cfg.vocab, cfg.d_model), cfg.dtype)
            axes["lm_head"] = ("vocab", "embed")
        return params, axes

    def param_axes(self) -> Axes:
        _, axes = self.init_shapes()
        return axes

    def param_specs(self) -> Params:
        specs, _ = self.init_shapes()
        return specs

    def init_shapes(self) -> Tuple[Params, Axes]:
        """(ShapeDtypeStruct tree, axes tree) without allocating anything."""
        specs = jax.eval_shape(lambda k: self.init(k)[0], jax.random.PRNGKey(0))
        return specs, _axes_of(self)

    # ----------------------------------------------------------------- norms
    def _norm(self, x, scale):
        if self.cfg.norm == "rms":
            return rmsnorm(x, scale)
        return layernorm(x, scale)

    # ------------------------------------------------------- full-seq blocks
    def _ffn_apply(self, layer: Params, x: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if "moe" in layer:
            h = self._norm(x, layer["pre_mlp_norm"])
            m, aux = moe_lib.moe_apply(
                layer["moe"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, activation=cfg.activation,
            )
            x = x + m
        elif "mlp" in layer:
            from repro.models.layers import mlp_apply

            h = self._norm(x, layer["pre_mlp_norm"])
            m = mlp_apply(layer["mlp"], h, activation=cfg.activation)
            if cfg.use_post_norms:
                m = self._norm(m, layer["post_mlp_norm"])
            x = x + m
        return x, aux

    def _ssm_forward_branch(self, layer: Params, h: jax.Array
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full-sequence SSM branch; returns (out, final ssm state pieces)."""
        cfg = self.cfg
        dims = cfg.ssm_dims
        b, s = h.shape[0], h.shape[1]
        z, xbc, dt_raw = ssm_lib._split_proj(layer["ssm"], h, dims)
        xbc_c = jax.nn.silu(
            ssm_lib._causal_depthwise_conv(
                xbc, layer["ssm"]["conv_w"], layer["ssm"]["conv_b"]
            )
        )
        xs_, bm, cm, dt, a_ = ssm_lib._prep_inputs(layer["ssm"], xbc_c, dt_raw, dims)
        y, hfinal = ssm_lib.ssd_chunked(xs_, bm, cm, dt, a_, chunk=cfg.ssm_chunk)
        y = y.reshape(b, s, dims["d_inner"])
        y = y + (layer["ssm"]["D"].repeat(dims["head_dim"])
                 * xs_.reshape(b, s, -1).astype(jnp.float32)).astype(h.dtype)
        y = rmsnorm(y * jax.nn.silu(z), layer["ssm"]["norm"])
        out = jnp.einsum("bsi,id->bsd", y, layer["ssm"]["out_proj"])
        state = {"h": hfinal, "conv": xbc[:, -(dims["d_conv"] - 1):, :]}
        return out, state

    def _sub_block(self, layer: Params, x: jax.Array, positions: jax.Array,
                   window: jax.Array, memory_kv=None
                   ) -> Tuple[jax.Array, jax.Array]:
        """One (sub-)layer, full-sequence.  Returns (x, aux)."""
        cfg = self.cfg
        x = constrain(x, ("batch", "seq", "embed_act"))
        if "attn" not in layer:  # pure SSM block
            h = self._norm(x, layer["pre_ssm_norm"])
            out, _ = self._ssm_forward_branch(layer, h)
            x = x + out
            return self._ffn_apply(layer, x)
        h = self._norm(x, layer["pre_attn_norm"])
        a = attn.attend_full(
            layer["attn"], h, positions, rope_theta=cfg.rope_theta,
            window=window, softcap_value=cfg.attn_softcap, causal=True,
            query_scale=cfg.query_scale,
        )
        if "ssm" in layer:  # hybrid: parallel heads, mean-fused
            s_out, _ = self._ssm_forward_branch(layer, h)
            a = 0.5 * (a + s_out)
        if cfg.use_post_norms:
            a = self._norm(a, layer["post_attn_norm"])
        x = x + a
        if memory_kv is not None and "cross" in layer:
            h = self._norm(x, layer["pre_cross_norm"])
            x = x + attn.attend_cross(layer["cross"], h, memory_kv["k"],
                                      memory_kv["v"])
        return self._ffn_apply(layer, x)

    def _block_body(self, layer: Params, x: jax.Array, positions: jax.Array,
                    window: jax.Array, memory_kv=None
                    ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.paired:
            x, aux0 = self._sub_block(layer["dense"], x, positions, window[0],
                                      memory_kv)
            x, aux1 = self._sub_block(layer["moe"], x, positions, window[1], None)
            return x, aux0 + aux1
        return self._sub_block(layer, x, positions, window, memory_kv)

    def _run_stack(self, layers: Params, x: jax.Array, positions: jax.Array,
                   windows: jax.Array, memory_kv=None
                   ) -> Tuple[jax.Array, jax.Array]:
        aux0 = jnp.zeros((), jnp.float32)

        if memory_kv is None:
            def body(carry, inp):
                x1, acc = carry
                layer, window = inp
                x2, aux = self._block_body(layer, x1, positions, window)
                return (x2, acc + aux), None

            (x, aux), _ = jax.lax.scan(
                self._maybe_remat(body), (x, aux0), (layers, windows)
            )
        else:
            def body(carry, inp):
                x1, acc = carry
                layer, window, mem_k, mem_v = inp
                x2, aux = self._block_body(
                    layer, x1, positions, window,
                    memory_kv={"k": mem_k, "v": mem_v},
                )
                return (x2, acc + aux), None

            (x, aux), _ = jax.lax.scan(
                self._maybe_remat(body), (x, aux0),
                (layers, windows, memory_kv["k"], memory_kv["v"]),
            )
        return x, aux

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params: Params, tokens: jax.Array,
                      frontend_embeds: Optional[jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        if frontend_embeds is not None and cfg.frontend == "vision":
            # VLM early fusion: precomputed patch embeddings (stubbed
            # InternViT output) replace the first frontend_seq positions.
            x = jnp.concatenate(
                [frontend_embeds.astype(x.dtype), x[:, frontend_embeds.shape[1]:]],
                axis=1,
            )
        return constrain(x, ("batch", "seq", "embed_act"))

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper-style encoder over precomputed (stubbed conv) frames."""
        cfg = self.cfg
        b, t = frames.shape[0], frames.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        windows = jnp.full((cfg.n_encoder_layers,), FULL_WINDOW, jnp.int32)
        x = frames.astype(cfg.dtype)

        def body(carry, inp):
            layer, window = inp
            h = self._norm(carry, layer["pre_attn_norm"])
            a = attn.attend_full(
                layer["attn"], h, positions, rope_theta=None, window=window,
                softcap_value=None, causal=False,
            )
            x2 = carry + a
            x2, _ = self._ffn_apply(layer, x2)
            return x2, None

        x, _ = jax.lax.scan(body, x, (params["enc_layers"], windows))
        return self._norm(x, params["enc_final_norm"])

    def _cross_memory(self, params: Params, frontend_embeds: jax.Array):
        enc = self.encode(params, frontend_embeds)
        layers = params["layers"]["dense"] if self.cfg.paired else params["layers"]
        ks, vs = jax.vmap(lambda c: attn.project_memory_kv(c, enc))(layers["cross"])
        return {"k": ks, "v": vs}

    # ------------------------------------------------------- train / prefill
    def forward(
        self,
        params: Params,
        tokens: jax.Array,  # [B, S]
        *,
        frontend_embeds: Optional[jax.Array] = None,
        last_only: bool = False,
    ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward.  Returns (hidden [B,S,D] or last-logits
        [B,1,V], moe aux loss).  The training loss computes chunked logits
        itself — [B,S,V] is never materialized here."""
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._embed_inputs(params, tokens, frontend_embeds)
        memory_kv = None
        if cfg.n_encoder_layers:
            assert frontend_embeds is not None, "enc-dec needs frontend frames"
            memory_kv = self._cross_memory(params, frontend_embeds)
        x, aux = self._run_stack(params["layers"], x, positions,
                                 cfg.window_sizes(), memory_kv=memory_kv)
        x = self._norm(x, params["final_norm"])
        if last_only:
            return self._logits(params, x[:, -1:, :]), aux
        return x, aux

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        table = params["embed"] if cfg.tied_embeddings else params["lm_head"]
        logits = unembed(x, table)
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        return logits

    def logits(self, params: Params, hidden: jax.Array) -> jax.Array:
        return self._logits(params, hidden)

    # ---------------------------------------------------------------- serving
    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        kv = ssm_state = cross_kv = None
        if cfg.uses_attention:
            shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            kv = {"k": jnp.zeros(shape, cfg.dtype),
                  "v": jnp.zeros(shape, cfg.dtype)}
        if cfg.uses_ssm:
            dims = cfg.ssm_dims
            ssm_state = {
                "h": jnp.zeros((cfg.n_layers, batch, dims["n_heads"],
                                dims["head_dim"], dims["d_state"]), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, dims["d_conv"] - 1,
                                   dims["conv_dim"]), cfg.dtype),
            }
        if cfg.n_encoder_layers:
            shape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads,
                     cfg.head_dim)
            cross_kv = {"k": jnp.zeros(shape, cfg.dtype),
                        "v": jnp.zeros(shape, cfg.dtype)}
        return DecodeState(kv=kv, ssm=ssm_state, cross_kv=cross_kv,
                           length=jnp.zeros((batch,), jnp.int32))

    def decode_state_axes(self) -> DecodeState:
        cfg = self.cfg
        kv_ax = {"k": ("layers", "batch", "kv_seq", "cache_heads", "cache_dim"),
                 "v": ("layers", "batch", "kv_seq", "cache_heads", "cache_dim")}
        ssm_ax = {
            "h": ("layers", "batch", "ssm_heads", "ssm_head_dim", "ssm_state"),
            "conv": ("layers", "batch", "conv", "ssm_conv_dim"),
        }
        return DecodeState(
            kv=kv_ax if cfg.uses_attention else None,
            ssm=ssm_ax if cfg.uses_ssm else None,
            cross_kv=kv_ax if cfg.n_encoder_layers else None,
            length=("batch",),
        )

    def _pair_view(self, tree):
        """[L, ...] -> [L/2, 2, ...] for pair-scanned stacks."""
        if tree is None:
            return None
        ns = self.cfg.n_scan
        return jax.tree.map(lambda x: x.reshape((ns, 2) + x.shape[1:]), tree)

    def _pair_unview(self, tree):
        if tree is None:
            return None
        return jax.tree.map(
            lambda x: x.reshape((x.shape[0] * 2,) + x.shape[2:]), tree
        )

    def _sub_decode(self, layer: Params, x: jax.Array, kv, ssm_state, cross,
                    window, length):
        """One (sub-)layer, one-token decode.  Returns (x, new_kv, new_ssm)."""
        cfg = self.cfg
        x = constrain(x, ("batch", "seq", "embed_act"))
        new_kv = new_ssm = None
        if "attn" not in layer:
            h = self._norm(x, layer["pre_ssm_norm"])
            y, new_ssm = ssm_lib.ssm_step(layer["ssm"], h, ssm_state, cfg.ssm_dims)
            x = x + y
            x, _ = self._ffn_apply(layer, x)
            return x, new_kv, new_ssm
        h = self._norm(x, layer["pre_attn_norm"])
        a, new_kv = attn.attend_cached(
            layer["attn"], h, kv, length, rope_theta=cfg.rope_theta,
            window=window, softcap_value=cfg.attn_softcap,
            query_scale=cfg.query_scale,
        )
        if "ssm" in layer:
            s2, new_ssm = ssm_lib.ssm_step(layer["ssm"], h, ssm_state, cfg.ssm_dims)
            a = 0.5 * (a + s2)
        if cfg.use_post_norms:
            a = self._norm(a, layer["post_attn_norm"])
        x = x + a
        if cross is not None and "cross" in layer:
            h = self._norm(x, layer["pre_cross_norm"])
            x = x + attn.attend_cross(layer["cross"], h, cross["k"], cross["v"])
        x, _ = self._ffn_apply(layer, x)
        return x, new_kv, new_ssm

    def decode_step(
        self,
        params: Params,
        state: DecodeState,
        token: jax.Array,  # [B] int32
    ) -> Tuple[jax.Array, DecodeState]:
        """One decode step: (logits [B, V], new state)."""
        cfg = self.cfg
        x = embed_lookup(params["embed"], token[:, None])  # [B,1,D]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        windows = cfg.window_sizes()
        length = state.length

        inp: Dict[str, Any] = {"layer": params["layers"], "window": windows}
        if state.kv is not None:
            inp["kv"] = self._pair_view(state.kv) if cfg.paired else state.kv
        if state.ssm is not None:
            inp["ssm"] = state.ssm
        if state.cross_kv is not None:
            inp["cross"] = (self._pair_view(state.cross_kv) if cfg.paired
                            else state.cross_kv)

        def body(carry, inp1):
            x1 = carry
            layer, window = inp1["layer"], inp1["window"]
            outs: Dict[str, Any] = {}
            if cfg.paired:
                kv = inp1["kv"]
                cross = inp1.get("cross")
                x1, k0, _ = self._sub_decode(
                    layer["dense"], x1,
                    jax.tree.map(lambda t: t[0], kv),
                    None, None if cross is None else
                    jax.tree.map(lambda t: t[0], cross),
                    window[0], length,
                )
                x1, k1, _ = self._sub_decode(
                    layer["moe"], x1, jax.tree.map(lambda t: t[1], kv),
                    None, None, window[1], length,
                )
                outs["kv"] = jax.tree.map(lambda a, b: jnp.stack([a, b]), k0, k1)
            else:
                x1, new_kv, new_ssm = self._sub_decode(
                    layer, x1, inp1.get("kv"), inp1.get("ssm"),
                    inp1.get("cross"), window, length,
                )
                if new_kv is not None:
                    outs["kv"] = new_kv
                if new_ssm is not None:
                    outs["ssm"] = new_ssm
            return x1, outs

        x, outs = jax.lax.scan(body, x, inp)
        x = self._norm(x, params["final_norm"])
        logits = self._logits(params, x)[:, 0, :]
        new_kv = outs.get("kv")
        if new_kv is not None and cfg.paired:
            new_kv = self._pair_unview(new_kv)
        new_state = DecodeState(
            kv=new_kv if new_kv is not None else state.kv,
            ssm=outs.get("ssm", state.ssm),
            cross_kv=state.cross_kv,
            length=length + 1,
        )
        return logits, new_state

    def _sub_prefill(self, layer: Params, x: jax.Array, positions, window,
                     kv, cross):
        """One (sub-)layer full-prompt prefill writing the KV prefix.
        Returns (x, new_kv, new_ssm)."""
        cfg = self.cfg
        x = constrain(x, ("batch", "seq", "embed_act"))
        b, s = x.shape[0], x.shape[1]
        new_kv = new_ssm = None
        if "attn" not in layer:
            h = self._norm(x, layer["pre_ssm_norm"])
            out, new_ssm = self._ssm_forward_branch(layer, h)
            x = x + out
            x, _ = self._ffn_apply(layer, x)
            return x, new_kv, new_ssm
        h = self._norm(x, layer["pre_attn_norm"])
        q, k, v = attn.project_qkv(layer["attn"], h, positions,
                                   rope_theta=cfg.rope_theta)
        kbuf = jax.lax.dynamic_update_slice_in_dim(
            kv["k"], k.astype(kv["k"].dtype), 0, axis=1)
        vbuf = jax.lax.dynamic_update_slice_in_dim(
            kv["v"], v.astype(kv["v"].dtype), 0, axis=1)
        new_kv = {"k": kbuf, "v": vbuf}
        a = attn.attend_full(
            layer["attn"], h, positions, rope_theta=cfg.rope_theta,
            window=window, softcap_value=cfg.attn_softcap,
            query_scale=cfg.query_scale,
        )
        if "ssm" in layer:
            s_out, new_ssm = self._ssm_forward_branch(layer, h)
            a = 0.5 * (a + s_out)
        if cfg.use_post_norms:
            a = self._norm(a, layer["post_attn_norm"])
        x = x + a
        if cross is not None and "cross" in layer:
            h = self._norm(x, layer["pre_cross_norm"])
            x = x + attn.attend_cross(layer["cross"], h, cross["k"], cross["v"])
        x, _ = self._ffn_apply(layer, x)
        return x, new_kv, new_ssm

    def prefill(
        self,
        params: Params,
        tokens: jax.Array,
        state: DecodeState,
        *,
        frontend_embeds: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, DecodeState]:
        """Prefill the caches with a prompt; returns (last logits [B,V], state)."""
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._embed_inputs(params, tokens, frontend_embeds)
        windows = cfg.window_sizes()
        memory_kv = None
        if cfg.n_encoder_layers:
            assert frontend_embeds is not None
            memory_kv = self._cross_memory(params, frontend_embeds)

        inp: Dict[str, Any] = {"layer": params["layers"], "window": windows}
        if state.kv is not None:
            inp["kv"] = self._pair_view(state.kv) if cfg.paired else state.kv
        if state.ssm is not None:
            inp["ssm"] = state.ssm
        if memory_kv is not None:
            inp["cross"] = memory_kv

        def body(carry, inp1):
            x1 = carry
            layer, window = inp1["layer"], inp1["window"]
            outs: Dict[str, Any] = {}
            if cfg.paired:
                kv = inp1["kv"]
                x1, k0, _ = self._sub_prefill(
                    layer["dense"], x1, positions, window[0],
                    jax.tree.map(lambda t: t[0], kv), inp1.get("cross"),
                )
                x1, k1, _ = self._sub_prefill(
                    layer["moe"], x1, positions, window[1],
                    jax.tree.map(lambda t: t[1], kv), None,
                )
                outs["kv"] = jax.tree.map(lambda p, q2: jnp.stack([p, q2]), k0, k1)
            else:
                x1, new_kv, new_ssm = self._sub_prefill(
                    layer, x1, positions, window, inp1.get("kv"),
                    inp1.get("cross"),
                )
                if new_kv is not None:
                    outs["kv"] = new_kv
                if new_ssm is not None:
                    outs["ssm"] = new_ssm
            return x1, outs

        x, outs = jax.lax.scan(body, x, inp)
        x = self._norm(x, params["final_norm"])
        logits = self._logits(params, x[:, -1:, :])[:, 0, :]
        new_kv = outs.get("kv")
        if new_kv is not None and cfg.paired:
            new_kv = self._pair_unview(new_kv)
        new_state = DecodeState(
            kv=new_kv if new_kv is not None else state.kv,
            ssm=outs.get("ssm", state.ssm),
            cross_kv=memory_kv if memory_kv is not None else state.cross_kv,
            length=jnp.full((b,), s, jnp.int32),
        )
        return logits, new_state


def _axes_of(model: "TransformerLM") -> Axes:
    """Build the axes tree without touching device memory: run init under
    eval_shape and capture the (shape-independent) axes side through a
    holder."""
    holder = {}

    def capture(k):
        p, a = model.init(k)
        holder["axes"] = a
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return holder["axes"]

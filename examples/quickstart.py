"""Quickstart: the paper in 60 lines.

1. Characterize the tiered-memory testbed (bw-test co-run -> unfair queuing).
2. Turn on MIKU -> fast tier recovers, slow tier stays near its ceiling.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.des import run_bw_test, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku


def main() -> None:
    platform = platform_a()  # Intel EMR + 2x CXL (paper Table 1)
    op = OpClass.LOAD

    ddr_alone = run_bw_test(platform, op=op, tier="ddr", n_threads=16)
    cxl_alone = run_bw_test(platform, op=op, tier="cxl", n_threads=16)
    opt_ddr = ddr_alone.bandwidth("bw-ddr-load")
    opt_cxl = cxl_alone.bandwidth("bw-cxl-load")
    print(f"optimal:  DDR {opt_ddr:6.1f} GB/s   CXL {opt_cxl:5.1f} GB/s")

    racing = run_corun(platform, op=op, n_threads=16, sim_ns=300_000)
    print(
        f"racing:   DDR {racing.bandwidth('ddr'):6.1f} GB/s "
        f"({100 * racing.bandwidth('ddr') / opt_ddr:.0f}% of optimal — "
        f"the paper's unfair-queuing collapse)"
    )

    miku = run_corun(
        platform, op=op, n_threads=16, sim_ns=300_000,
        controller=default_miku(platform),
    )
    print(
        f"MIKU:     DDR {miku.bandwidth('ddr'):6.1f} GB/s "
        f"({100 * miku.bandwidth('ddr') / opt_ddr:.0f}% of optimal)   "
        f"CXL {miku.bandwidth('cxl'):5.1f} GB/s "
        f"({100 * miku.bandwidth('cxl') / opt_cxl:.0f}% of its ceiling)"
    )


if __name__ == "__main__":
    main()

"""Quickstart: the paper in 80 lines.

1. Characterize the tiered-memory testbed (bw-test co-run -> unfair queuing).
2. Turn on MIKU -> fast tier recovers, slow tier stays near its ceiling.
3. The declarative scenario API: run a registered paper figure and an
   N-tier scenario (three tiers — DDR + CXL + CXL-over-switch) that the
   two-tier surface could not express.

Run:  PYTHONPATH=src python examples/quickstart.py

Next stop: ``examples/tiering_demo.py`` — the tiering layer (page-granular
hotness tracking + a migration engine whose copies are real modeled
``MIGRATE`` traffic, coordinated with MIKU), and the
``migrate_interference`` / ``tiering_policies`` scenarios that exercise it
from ``benchmarks/run.py``.  Then ``examples/fabric_demo.py`` — routed
switch-fabric topologies (``repro.fabric``): spine-port congestion
collapse and the per-edge MIKU ensemble that relieves it.
"""

from repro.core.des import run_bw_test, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku
from repro.scenarios import run_scenario


def scenarios() -> None:
    # Any registered scenario is one call: overrides are axis=value pairs
    # (the same surface as `benchmarks/run.py --scenario ... --set ...`).
    table = run_scenario(
        "fig3_bandwidth",
        {"platform": "A", "op": "load", "threads": (16,)},
    )
    print("\nfig3_bandwidth (registry scenario):")
    print(table.to_csv(), end="")

    # Three tiers co-running — DDR + local CXL + CXL behind a switch —
    # with MIKU protecting the fast tier; no engine or controller changes.
    table = run_scenario(
        "corun3_switch",
        {"op": "load", "miku": (True,), "sim_ns": 300_000.0},
    )
    (row,) = table.rows
    print("\ncorun3_switch (three tiers, MIKU on):")
    print(
        f"DDR {row['ddr_corun_gbps']:6.1f} GB/s "
        f"(loss {row['ddr_loss_pct']:.0f}%)   "
        f"CXL {row['cxl_corun_gbps']:5.1f} GB/s   "
        f"CXL-over-switch {row['cxl_sw_corun_gbps']:5.1f} GB/s "
        f"(residency {row['t_cxl_sw_corun_ns']:.0f} ns)"
    )


def main() -> None:
    platform = platform_a()  # Intel EMR + 2x CXL (paper Table 1)
    op = OpClass.LOAD

    ddr_alone = run_bw_test(platform, op=op, tier="ddr", n_threads=16)
    cxl_alone = run_bw_test(platform, op=op, tier="cxl", n_threads=16)
    opt_ddr = ddr_alone.bandwidth("bw-ddr-load")
    opt_cxl = cxl_alone.bandwidth("bw-cxl-load")
    print(f"optimal:  DDR {opt_ddr:6.1f} GB/s   CXL {opt_cxl:5.1f} GB/s")

    racing = run_corun(platform, op=op, n_threads=16, sim_ns=300_000)
    print(
        f"racing:   DDR {racing.bandwidth('ddr'):6.1f} GB/s "
        f"({100 * racing.bandwidth('ddr') / opt_ddr:.0f}% of optimal — "
        f"the paper's unfair-queuing collapse)"
    )

    miku = run_corun(
        platform, op=op, n_threads=16, sim_ns=300_000,
        controller=default_miku(platform),
    )
    print(
        f"MIKU:     DDR {miku.bandwidth('ddr'):6.1f} GB/s "
        f"({100 * miku.bandwidth('ddr') / opt_ddr:.0f}% of optimal)   "
        f"CXL {miku.bandwidth('cxl'):5.1f} GB/s "
        f"({100 * miku.bandwidth('cxl') / opt_cxl:.0f}% of its ceiling)"
    )

    scenarios()

    print(
        "\ndocs: docs/scenarios.md (generated scenario catalog) · "
        "docs/telemetry.md (--trace schema) · "
        "docs/decision-laws.md (control-plane + batched-lane contracts) · "
        "examples/README.md (demo index)"
    )


if __name__ == "__main__":
    main()

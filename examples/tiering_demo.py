"""Tiering demo: hotness tracking, page migration, and MIKU coordination.

Three acts on the paper's Platform A:

1. A workload whose hot set lives on the CXL tier, with a *static*
   placement: it is stuck at slow-tier bandwidth.
2. The same workload under the ``hotness_lru`` policy: the tiering engine
   promotes the hot set page by page — every copy paid for as real
   ``MIGRATE`` traffic through the simulated CXL link — and the live
   PageMap re-routes accesses as pages land on DDR.
3. The migrate-interference co-run (the new ``migrate_interference``
   scenario): naive migration races demand traffic and costs the DDR
   workload real bandwidth; the ``miku_coordinated`` policy defers copies
   past throttled windows and recovers it.

Run:  PYTHONPATH=src python examples/tiering_demo.py
"""

from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.scenarios import run_scenario
from repro.tiering import HotSetPattern, RegionSpec, TieringSpec


def spec(policy: str) -> TieringSpec:
    return TieringSpec(
        regions=(RegionSpec(
            workload="app",
            n_pages=512,
            placement={"cxl": 1.0},  # everything starts slow
            pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.9),
        ),),
        policy=policy,
        fast_capacity_pages=256,
    )


def main() -> None:
    platform = platform_a()
    app = WorkloadSpec(name="app", op=OpClass.LOAD, tier="cxl", n_cores=16)

    for policy in ("static", "hotness_lru"):
        sim = TieredMemorySim(platform, [app], seed=0,
                              tiering=spec(policy).build())
        res = sim.run(300_000.0)
        t = res.tiering
        print(
            f"{policy:12s}  app {res.bandwidth('app'):6.1f} GB/s   "
            f"fast-frac {t['fast_fraction']['app']:.2f}   "
            f"promoted {t['pages_promoted']:4d} pages "
            f"({t['migrated_bytes'] / 1e6:.1f} MB of copy traffic at "
            f"{res.bandwidth('mig-cxl'):.1f} GB/s)"
        )

    print("\nmigrate_interference (DDR demand vs migration traffic):")
    table = run_scenario("migrate_interference", {"sim_ns": 300_000.0})
    for row in table.rows:
        print(
            f"  {row['variant']:12s} DDR {row['ddr_gbps']:6.1f} GB/s "
            f"({row['ddr_pct_of_demand_only']:5.1f}% of demand-only)   "
            f"promoted {row['pages_promoted']:4d}   "
            f"deferred {row['deferred_jobs']:4d}"
        )


if __name__ == "__main__":
    main()

"""Routed switch fabric: spine congestion collapse vs per-edge MIKU.

Two hosts reach a shared CXL pool through per-host uplinks and one spine
downlink (``repro.fabric.spine_leaf_platform``).  Racing, the saturated
spine port backpressures into the uplinks; spine-stalled requests sit on
shared ToR entries and collapse host0's *DDR* bandwidth — the paper's
unfair-queuing pathology, one switch hop removed.  The per-edge MIKU
ensemble (one ladder per control edge: slow tiers + fabric links) lands
the throttle on the congested spine edge and recovers DDR, without
touching the healthy CXL device edge.

Run:  PYTHONPATH=src python examples/fabric_demo.py
"""

from repro.core.littles_law import OpClass
from repro.fabric import spine_leaf_platform
from repro.memsim.sweep import SimJob, run_job
from repro.memsim.workloads import bw_test

OP, N, SIM_NS = OpClass.LOAD, 16, 300_000.0


def corun_job(platform, law):
    """host0: DDR + CXL via uplink0; host1: CXL via uplink1."""
    return SimJob(
        platform=platform,
        workloads=[
            bw_test("ddr", OP, N, name="ddr", miku_managed=False,
                    host="host0"),
            bw_test("cxl", OP, N, name="cxl0", host="host0"),
            bw_test("cxl", OP, N, name="cxl1", host="host1"),
        ],
        sim_ns=SIM_NS,
        miku=law == "peredge",
        miku_law="peredge" if law == "peredge" else "pertier",
    )


def main() -> None:
    pm = spine_leaf_platform()
    alone = run_job(SimJob(
        platform=pm,
        workloads=[bw_test("ddr", OP, N, name="ddr", miku_managed=False,
                           host="host0")],
        sim_ns=120_000.0,
    ))
    ddr_alone = alone.bandwidth("ddr")
    print(f"platform {pm.name}: 2 hosts -> uplinks -> shared spine -> cxl")
    print(f"DDR alone: {ddr_alone:.1f} GB/s\n")
    print("law      DDR GB/s  (% alone)  cxl0  cxl1   spine stalls  "
          "spine-restricted windows")
    for law in ("racing", "peredge"):
        res = run_job(corun_job(pm, law))
        spine = res.fabric["spine-cxl"]
        restricted = sum(
            1 for d in res.decisions if d.for_tier("spine-cxl").restricted
        ) if res.decisions else 0
        print(
            f"{law:8s} {res.bandwidth('ddr'):8.1f}  "
            f"({100.0 * res.bandwidth('ddr') / ddr_alone:5.1f}%)  "
            f"{res.bandwidth('cxl0'):5.1f} {res.bandwidth('cxl1'):5.1f}"
            f"   {spine['stall_events']:12d}  {restricted:8d}"
        )
    print(
        "\nracing: spine backpressure holds ToR entries and collapses DDR;"
        "\nperedge: the spine edge's own ladder restricts the congested hop"
        "\nand DDR recovers.  Scenario form: benchmarks/run.py --scenario"
        "\nfabric_spine_congestion (see docs/fabric.md)."
    )


if __name__ == "__main__":
    main()

"""Tiered serving demo: co-located HBM-resident and host-tier-resident LLM
instances — DataRacing vs MIKU (the paper's §6 LLM case study on TPU tiers).

Run:  PYTHONPATH=src python examples/serve_tiered_kv.py
"""

from repro.launch.serve import build_cluster


def main() -> None:
    for mode in ("racing", "miku"):
        cl = build_cluster("llama31-8b", smoke=True, n_requests=24, mode=mode)
        res = cl.run()
        line = "  ".join(
            f"{k}={v['tokens_per_s']:.0f}tok/s" for k, v in res.items()
        )
        print(f"{mode:7s}: {line}")


if __name__ == "__main__":
    main()

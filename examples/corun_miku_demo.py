"""Watch MIKU stabilize: per-window controller decisions and the estimated
slow-tier service time during a DDR/CXL co-run (paper Fig. 9/10 dynamics).

Run:  PYTHONPATH=src python examples/corun_miku_demo.py
"""

from repro.core.des import run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku


def main() -> None:
    platform = platform_a()
    controller = default_miku(platform)
    result = run_corun(
        platform, op=OpClass.STORE, n_threads=16, sim_ns=250_000,
        controller=controller,
    )
    print("window  t_slow(ns)  threshold  cores  rate   phase")
    for i, (d, e) in enumerate(
        zip(controller.decisions, controller.estimator.history)
    ):
        cores = d.max_concurrency if d.max_concurrency is not None else "-"
        print(
            f"{i:4d} {e.t_slow_raw:11.0f} {e.threshold:10.0f} "
            f"{cores!s:>6} {d.rate_factor:5.2f}  {d.phase.value}"
        )
    print(
        f"\nfinal bandwidth: DDR {result.bandwidth('ddr'):.1f} GB/s, "
        f"CXL {result.bandwidth('cxl'):.1f} GB/s"
    )


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~25M-param qwen2.5-family model trained for
a few hundred steps on synthetic packed data, with async checkpointing and
resume.  (Reduce --steps for a quick look.)

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.launch.train import Trainer
from repro.configs import get_arch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # Widen the smoke config to ~25M params (fp32 for CPU stability).
    cfg = dataclasses.replace(
        get_arch("qwen2.5-3b").smoke, n_layers=4, d_model=256, d_ff=1024,
        vocab=8192, n_q_heads=8, n_kv_heads=4, dtype=jnp.float32,
    )
    trainer = Trainer(
        "qwen2.5-3b", smoke=True, global_batch=8, seq_len=256,
        microbatches=2, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        total_steps=args.steps, config_override=cfg,
    )
    state = trainer.train(args.steps, resume=True, log_every=10)
    print("final step:", int(state.opt.step))


if __name__ == "__main__":
    main()

"""Docs suite gates: docstring coverage, catalog completeness, freshness.

Three guarantees:

* every exported symbol on the public surface (``repro.scenarios``,
  ``repro.tiering``, ``repro.memsim``, ``repro.memsim.batched``,
  ``repro.fabric``, the control-plane classes) carries a docstring —
  public methods included;
* the generated scenario catalog contains every registered scenario, and
  the committed ``docs/scenarios.md`` is byte-identical to a fresh
  generation (the same check CI runs — the registry cannot drift from its
  docs);
* the ``--trace`` schema documented in ``docs/telemetry.md`` matches what
  a live run actually emits.
"""

from __future__ import annotations

import importlib
import inspect
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_PUBLIC_MODULES = (
    "repro.scenarios",
    "repro.tiering",
    "repro.memsim",
    "repro.memsim.batched",
    "repro.fabric",
    "repro.workload",
)


def _public_symbols():
    for modname in _PUBLIC_MODULES:
        mod = importlib.import_module(modname)
        assert inspect.getdoc(mod), f"{modname} has no module docstring"
        for name in mod.__all__:
            yield f"{modname}.{name}", getattr(mod, name)
    from repro.core.controller import (
        Decision,
        MikuController,
        SlowTierMiku,
        TierDecisions,
        VectorMikuLadder,
    )
    from repro.core.littles_law import (
        LittlesLawEstimator,
        TierCounters,
        TierWindow,
    )

    for cls in (MikuController, SlowTierMiku, VectorMikuLadder,
                TierDecisions, Decision, LittlesLawEstimator, TierCounters,
                TierWindow):
        yield cls.__name__, cls


def test_public_surface_is_documented():
    undocumented = []
    for label, obj in _public_symbols():
        if not inspect.getdoc(obj):
            undocumented.append(label)
        if inspect.isclass(obj):
            for mname, m in inspect.getmembers(obj, inspect.isfunction):
                if mname.startswith("_"):
                    continue
                if not inspect.getdoc(m):
                    undocumented.append(f"{label}.{mname}")
    assert not undocumented, f"missing docstrings: {undocumented}"


def test_catalog_contains_every_registered_scenario():
    from repro.scenarios import all_scenarios
    from repro.scenarios.catalog import catalog_md

    md = catalog_md()
    for sc in all_scenarios():
        assert f"## `{sc.name}`" in md, f"{sc.name} missing from catalog"
        for axis in sc.axes:
            assert f"`{axis.name}`" in md
        for metric in sc.metrics:
            assert f"`{metric.name}`" in md


def test_docs_scenarios_md_is_fresh():
    from repro.scenarios.catalog import catalog_md

    path = REPO / "docs" / "scenarios.md"
    assert path.exists(), "docs/scenarios.md missing — regenerate with " \
        "benchmarks/run.py --list --format md"
    on_disk = path.read_text()
    assert on_disk == catalog_md(), (
        "docs/scenarios.md is stale; regenerate with:\n"
        "  PYTHONPATH=src python benchmarks/run.py --list --format md "
        "> docs/scenarios.md"
    )


def test_readme_references_current_surface():
    readme = (REPO / "README.md").read_text()
    for needle in ("docs/scenarios.md", "docs/telemetry.md",
                   "docs/decision-laws.md", "--lane batched", "--trace",
                   "examples/README.md"):
        assert needle in readme, f"README.md lost its {needle!r} reference"
    # The pre-scenario-API entry points must stay gone from the quickstart
    # docs (fig modules live on only as registry shims).
    assert "python benchmarks/fig" not in readme


def test_examples_index_covers_all_demos():
    idx = (REPO / "examples" / "README.md").read_text()
    for demo in sorted(p.name for p in (REPO / "examples").glob("*.py")):
        assert demo in idx, f"examples/README.md does not index {demo}"


@pytest.mark.parametrize("doc,needles", [
    ("telemetry.md", ("mytrace.trace.json", "max_concurrency",
                      "t_slow_raw", "class_counts", "tiering",
                      "queue_depth", "arrival-conservation")),
    ("decision-laws.md", ("TierDecisions", "VectorMikuLadder",
                          "REPRO_BATCH_BACKEND", "fallback")),
    ("workloads.md", ("ArrivalSpec", "poisson", "zipf", "bursty",
                      "flash_crowd", "trace", "queue_limit", "slo_knee",
                      "REPRO_REGEN")),
])
def test_doc_files_exist_with_key_content(doc, needles):
    text = (REPO / "docs" / doc).read_text()
    for needle in needles:
        assert needle in text, f"docs/{doc} lost {needle!r}"


def test_telemetry_doc_matches_live_window_records():
    """The documented window-record schema must match a real trace."""
    from repro.core.device_model import platform_a
    from repro.memsim.sweep import SimJob, run_job
    from repro.memsim.workloads import bw_test
    from repro.core.littles_law import OpClass

    job = SimJob(
        platform=platform_a(),
        workloads=[
            bw_test("ddr", OpClass.LOAD, 8, name="ddr", miku_managed=False),
            bw_test("cxl", OpClass.LOAD, 8, name="cxl"),
        ],
        sim_ns=60_000.0,
        miku=True,
        record_windows=True,
    )
    res = run_job(job)
    assert res.window_records
    doc = (REPO / "docs" / "telemetry.md").read_text()
    rec = res.window_records[0]
    assert set(rec) == {"window", "t_ns", "tiers", "decision"}
    for tier, counters in rec["tiers"].items():
        assert set(counters) == {"inserts", "occupancy_time",
                                 "class_counts"}
    for tier, decision in rec["decision"].items():
        for key in decision:
            assert key in doc, f"undocumented decision field {key!r}"
    for key in ("window", "t_ns", "tiers", "decision"):
        assert f"`{key}`" in doc


def test_telemetry_doc_matches_live_arrival_block():
    """The documented open-loop `arrival` block must match a real run."""
    from repro.core.device_model import platform_a
    from repro.memsim.sweep import SimJob, run_job
    from repro.memsim.workloads import serve_test
    from repro.workload import ArrivalSpec

    wl = serve_test(2, arrival=ArrivalSpec("poisson", rate=0.01, seed=1))
    job = SimJob(platform=platform_a(), workloads=[wl], sim_ns=60_000.0,
                 record_windows=True)
    res = run_job(job)
    recs = [r for r in res.window_records if "arrival" in r]
    assert recs
    doc = (REPO / "docs" / "telemetry.md").read_text()
    for blk in recs[0]["arrival"].values():
        assert set(blk) == {"generated", "issued", "shed", "queue_depth"}
        for key in blk:
            assert f"`{key}`" in doc, f"undocumented arrival field {key!r}"

"""Checkpoint roundtrip, retention, async, elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def state_tree(scale=1.0):
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) * scale,
                   "b": jnp.ones((4,), jnp.bfloat16) * scale},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    st = state_tree()
    save_checkpoint(str(tmp_path), 7, st)
    restored, extra = restore_checkpoint(str(tmp_path), 7, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state_tree(scale=float(s)))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(str(tmp_path)))
    assert len([k for k in kept if k.startswith("step_")]) == 2


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save under one sharding, restore under another (elastic resume)."""
    n = jax.device_count()
    mesh_a = jax.make_mesh((n,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec

    st = state_tree()
    sharded = jax.device_put(
        st, jax.tree.map(lambda _: NamedSharding(mesh_a, PartitionSpec()), st)
    )
    save_checkpoint(str(tmp_path), 1, sharded)
    mesh_b = jax.make_mesh((1, n), ("data", "model"))
    sh_b = jax.tree.map(
        lambda _: NamedSharding(mesh_b, PartitionSpec()), st
    )
    restored, _ = restore_checkpoint(str(tmp_path), 1, st, shardings=sh_b)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manifest_extra_roundtrip(tmp_path):
    st = state_tree()
    save_checkpoint(str(tmp_path), 3, st, extra={"loader": {"step": 42}})
    _, extra = restore_checkpoint(str(tmp_path), 3, st)
    assert extra["loader"]["step"] == 42


def test_shape_mismatch_rejected(tmp_path):
    st = state_tree()
    save_checkpoint(str(tmp_path), 1, st)
    bad = {"params": {"w": jnp.zeros((2, 4)), "b": jnp.zeros((4,), jnp.bfloat16)},
           "step": jnp.asarray(0, jnp.int32)}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)

"""Fabric-layer contract tests (repro.fabric: routed switch topologies,
per-hop backpressure, per-edge MIKU).

Five contracts:

1. **Topology validation** — malformed graphs (zero-capacity ports,
   unreachable devices, cycles, duplicate/dangling names) fail loudly at
   construction, with messages naming the offending node/link.
2. **Degenerate bit-identity** — an all-transparent (direct) topology and
   the ``peredge`` law on it reproduce the flat-station DES *exactly*:
   identical stats, event ordering, decisions, and telemetry as the plain
   platform under ``pertier``.  The fabric layer is a strict superset.
3. **Backpressure physics** — a port-bearing link enforces its entry limit
   (peak occupancy == limit, stall events > 0 while the port binds) and the
   limit stops binding once the queue out-sizes demand.
4. **Golden per-edge traces** — the canonical spine co-run under the
   per-edge ensemble reproduces the recorded decision + fabric telemetry
   trace (``tests/data/fabric_trace_goldens.json``), both replayed law-only
   through :class:`~repro.core.substrate.ReplaySubstrate` and re-simulated
   end to end.
5. **Error-message regressions** — unknown fabric hosts/devices and unknown
   transfer-queue links name their namespace and every known name.
"""

import json
import os

import pytest

from repro.core.controller import TierDecisions
from repro.core.des import TieredMemorySim, validate_workloads
from repro.core.device_model import UnknownTierError, platform_a
from repro.core.littles_law import OpClass, TierCounters, TierWindow
from repro.core.substrate import ControlLoop, ReplaySubstrate
from repro.fabric import (
    FabricTopology,
    Link,
    TopologyError,
    direct,
    direct_platform,
    edge_names,
    peredge_miku,
    single_switch,
    single_switch_platform,
    spine_leaf,
    spine_leaf_platform,
)
from repro.memsim.calibration import default_miku
from repro.memsim.sweep import SimJob, run_job
from repro.memsim.workloads import bw_test

DATA = os.path.join(os.path.dirname(__file__), "data")


# -- topology validation ------------------------------------------------------


def test_direct_topology_is_all_transparent():
    topo = direct(("ddr", "cxl"))
    assert topo.hosts == ("host0",)
    assert topo.devices == ("ddr", "cxl")
    assert not topo.has_hops and topo.station_links == ()
    for t in ("ddr", "cxl"):
        assert topo.route("host0", t).hops == ()


def test_single_switch_routes_through_one_port():
    topo = single_switch(("ddr", "cxl"), routed=("cxl",),
                         port_slots=8, service_ns=36.0, queue_entries=1024)
    assert topo.route("host0", "ddr").hops == ()
    (hop,) = topo.route("host0", "cxl").hops
    assert hop.name == "sw0-cxl" and hop.port_slots == 8
    assert [l.name for l in topo.station_links] == ["sw0-cxl"]


def test_spine_leaf_shares_the_spine_port():
    topo = spine_leaf(("ddr", "cxl"), routed=("cxl",), n_hosts=2)
    assert topo.hosts == ("host0", "host1")
    for h, up in (("host0", "uplink0"), ("host1", "uplink1")):
        names = [l.name for l in topo.route(h, "cxl").hops]
        assert names == [up, "spine-cxl"]  # shared spine downlink
        assert topo.route(h, "ddr").hops == ()


def test_zero_capacity_port_rejected():
    with pytest.raises(TopologyError, match="declares a zero-capacity port"):
        FabricTopology(
            hosts=("host0",), devices=("cxl",),
            links=(Link("bad", "host0", "cxl", port_slots=4,
                        service_ns=0.0, queue_entries=0),),
        )


def test_unreachable_device_rejected():
    with pytest.raises(TopologyError, match="is unreachable from host"):
        FabricTopology(
            hosts=("host0",), devices=("ddr", "cxl"), switches=("sw0",),
            links=(Link("host0-ddr", "host0", "ddr"),
                   Link("sw0-cxl", "sw0", "cxl")),  # nothing feeds sw0
        )


def test_cycle_rejected():
    with pytest.raises(TopologyError, match="has a cycle through link"):
        FabricTopology(
            hosts=("host0",), devices=("cxl",), switches=("sw0", "sw1"),
            links=(Link("in", "host0", "sw0"),
                   Link("a", "sw0", "sw1"),
                   Link("b", "sw1", "sw0"),
                   Link("out", "sw0", "cxl")),
        )


def test_duplicate_and_dangling_names_rejected():
    with pytest.raises(TopologyError):
        FabricTopology(hosts=("host0", "host0"), devices=("cxl",),
                       links=(Link("l", "host0", "cxl"),))
    with pytest.raises(TopologyError):
        FabricTopology(hosts=("host0",), devices=("cxl",),
                       links=(Link("l", "host0", "nowhere"),))


def test_unknown_fabric_host_and_device_messages():
    topo = spine_leaf(("ddr", "cxl"), routed=("cxl",))
    with pytest.raises(UnknownTierError, match="fabric host") as ei:
        topo.route("host9", "cxl")
    assert "topology hosts" in str(ei.value)
    assert "host0" in str(ei.value) and "host1" in str(ei.value)
    with pytest.raises(UnknownTierError, match="fabric device") as ei:
        topo.route("host0", "pmem")
    assert "topology devices" in str(ei.value)


def test_validate_workloads_checks_hosts():
    pm = spine_leaf_platform()
    validate_workloads(pm, [bw_test("cxl", OpClass.LOAD, 2, host="host1")])
    with pytest.raises(UnknownTierError, match="topology hosts"):
        validate_workloads(pm, [bw_test("cxl", OpClass.LOAD, 2,
                                        host="host7")])
    with pytest.raises(ValueError, match="no fabric topology"):
        validate_workloads(platform_a(),
                           [bw_test("cxl", OpClass.LOAD, 2, host="host0")])


def test_transfer_queue_unknown_link_message():
    from repro.core.offload import TransferQueue

    q = TransferQueue()
    with pytest.raises(UnknownTierError, match="transfer link") as ei:
        q.slow_inflight("warp_drive")
    msg = str(ei.value)
    assert "this queue's links" in msg and "fast" in msg and "slow" in msg


# -- degenerate bit-identity --------------------------------------------------


def _run_pair(op, n_threads, seed, sim_ns=120_000.0):
    """The same co-run on the plain platform (pertier) and on its direct
    fabric twin (peredge, host-pinned): every observable must match."""
    plain, fab = platform_a(), direct_platform()
    out = []
    for pm, law, host in ((plain, "pertier", None), (fab, "peredge", "host0")):
        wls = [bw_test("ddr", op, n_threads, name="ddr",
                       miku_managed=False, host=host),
               bw_test("cxl", op, n_threads, name="cxl", host=host)]
        ctl = (peredge_miku(pm, 4) if law == "peredge"
               else default_miku(pm, 4))
        sim = TieredMemorySim(pm, wls, seed=seed, granularity=4,
                              controller=ctl, window_ns=10_000.0,
                              record_windows=True,
                              control_scope="edge" if law == "peredge"
                              else "tier")
        out.append(sim.run(sim_ns))
    return out


def _assert_bit_identical(plain, fab):
    assert fab.fabric is None  # no port-bearing links -> no hop stations
    for name in plain.stats:
        p, f = plain.stats[name], fab.stats[name]
        assert (p.completed, p.bytes, p.latency_sum, p.latency_count) == \
            (f.completed, f.bytes, f.latency_sum, f.latency_count), name
    assert plain.tor_peak == fab.tor_peak
    assert plain.tor_occupancy_integral == fab.tor_occupancy_integral
    assert plain.tor_inserts == fab.tor_inserts
    assert plain.per_tier_occupancy_integral == \
        fab.per_tier_occupancy_integral
    assert len(plain.decisions) == len(fab.decisions)
    for dp, df in zip(plain.decisions, fab.decisions):
        assert dp.tiers == df.tiers == ("cxl",)  # edge set degenerates
        assert (dp.for_tier("cxl").max_concurrency,
                dp.for_tier("cxl").rate_factor,
                dp.for_tier("cxl").phase) == \
            (df.for_tier("cxl").max_concurrency,
             df.for_tier("cxl").rate_factor,
             df.for_tier("cxl").phase)


def test_direct_fabric_is_bit_identical_to_flat_stations():
    """An all-transparent topology compiles to zero hop stations: the DES
    must produce the *identical* event chain — stats, ToR telemetry,
    decision sequence — as the fabric-less platform it wraps."""
    plain, fab = _run_pair(OpClass.LOAD, 8, seed=0)
    _assert_bit_identical(plain, fab)
    # window records match too (decision telemetry, window for window)
    assert len(plain.window_records) == len(fab.window_records)
    for rp, rf in zip(plain.window_records, fab.window_records):
        assert rp == rf


@pytest.mark.parametrize("op,n,seed", [
    (OpClass.STORE, 4, 1),
    (OpClass.NT_STORE, 16, 2),
    (OpClass.LOAD, 2, 3),
])
def test_direct_fabric_bit_identity_across_seeds(op, n, seed):
    plain, fab = _run_pair(op, n, seed, sim_ns=60_000.0)
    _assert_bit_identical(plain, fab)


def test_peredge_degenerates_to_pertier_on_linkless_platform():
    """On a platform whose fabric has no port-bearing links, the per-edge
    ensemble *is* the per-tier ensemble: same edges, same calibration,
    same decisions on identical windows."""
    pm = direct_platform()
    assert edge_names(pm) == ("cxl",)
    per_edge, per_tier = peredge_miku(pm, 4), default_miku(platform_a(), 4)
    fast, slow = TierCounters(), TierCounters()
    for _ in range(50):
        fast.record(OpClass.LOAD, 100.0)
        slow.record(OpClass.LOAD, 5000.0)
    win = TierWindow((fast, slow), ("ddr", "cxl"))
    de = per_edge.window(win)
    dt = per_tier.window(win)
    assert isinstance(de, TierDecisions) and de.tiers == dt.tiers == ("cxl",)
    assert (de.for_tier("cxl").max_concurrency,
            de.for_tier("cxl").rate_factor) == \
        (dt.for_tier("cxl").max_concurrency, dt.for_tier("cxl").rate_factor)


def test_hypothesis_one_hop_routes_match_flat_chain():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(op=st.sampled_from(list(OpClass)),
           n=st.integers(1, 12), seed=st.integers(0, 2**16))
    @settings(max_examples=5, deadline=None)
    def prop(op, n, seed):
        plain, fab = _run_pair(op, n, seed, sim_ns=40_000.0)
        _assert_bit_identical(plain, fab)

    prop()


# -- backpressure physics -----------------------------------------------------


def _port_job(port_queue, n_threads=8):
    pm = single_switch_platform(port_slots=8, port_service_ns=36.0,
                                port_queue=port_queue)
    wl = bw_test("cxl", OpClass.LOAD, n_threads, name="cxl", host="host0")
    return SimJob(platform=pm, workloads=[wl], sim_ns=120_000.0, seed=0)


def test_port_entry_limit_binds_and_releases():
    tight = run_job(_port_job(64))
    port = tight.fabric["sw0-cxl"]
    assert port["entry_limit"] == 64 // 4  # macro-request granularity
    assert port["peak_occupancy"] == port["entry_limit"]  # limit binds
    assert port["stall_events"] > 0  # head-of-line backpressure fired
    roomy = run_job(_port_job(2048))
    port = roomy.fabric["sw0-cxl"]
    assert port["peak_occupancy"] < port["entry_limit"]  # ToR binds instead
    assert port["stall_events"] == 0
    # the port was the bottleneck: relieving it raises delivered bandwidth
    assert roomy.bandwidth("cxl") >= tight.bandwidth("cxl")


def test_fabric_summary_only_on_port_bearing_routes():
    res = run_job(SimJob(platform=direct_platform(),
                         workloads=[bw_test("cxl", OpClass.LOAD, 2,
                                            name="cxl", host="host0")],
                         sim_ns=40_000.0))
    assert res.fabric is None


# -- batched-lane fallback (explicit, never silent) ---------------------------


def test_batched_lane_falls_back_on_fabric_jobs():
    from repro.memsim.batched.lane import can_batch, partition_jobs
    from repro.memsim.sweep import run_sweep

    fab_job = _port_job(1024)
    assert can_batch(fab_job) == "fabric_topology"
    # peredge law alone (even on a hopless platform) routes scalar too
    edge_job = SimJob(platform=direct_platform(),
                      workloads=[bw_test("cxl", OpClass.LOAD, 2, name="cxl")],
                      sim_ns=40_000.0, miku=True, miku_law="peredge")
    assert can_batch(edge_job) == "fabric_topology"
    plans, fallbacks = partition_jobs([fab_job, edge_job])
    assert plans == [None, None]
    assert [r for _, r in fallbacks] == ["fabric_topology"] * 2
    # ...and the lane still returns correct results via the scalar path
    batched = run_sweep([fab_job], lane="batched")[0]
    scalar = run_sweep([fab_job], lane="scalar")[0]
    assert batched.fabric == scalar.fabric
    assert batched.bandwidth("cxl") == scalar.bandwidth("cxl")


# -- golden per-edge decision + telemetry trace -------------------------------


def _load_fabric_golden():
    with open(os.path.join(DATA, "fabric_trace_goldens.json")) as f:
        return json.load(f)


def _counters(d):
    return TierCounters(
        inserts=d["inserts"],
        occupancy_time=d["occupancy_time"],
        class_counts={OpClass(k): v for k, v in d["class_counts"].items()},
    )


def _assert_edge_decisions_match(decisions, golden_windows, names):
    assert len(decisions) == len(golden_windows)
    for i, (d, w) in enumerate(zip(decisions, golden_windows)):
        assert isinstance(d, TierDecisions) and d.tiers == names, i
        for e in names:
            de, ge = d.for_tier(e), w["decision"][e]
            assert de.max_concurrency == ge["max_concurrency"], (i, e)
            assert de.rate_factor == ge["rate_factor"], (i, e)
            assert de.phase.value == ge["phase"], (i, e)


def test_replayed_fabric_trace_reproduces_golden_decisions():
    blob = _load_fabric_golden()
    cnames = tuple(blob["counter_names"])
    edges = tuple(blob["edge_names"])
    deltas = [
        TierWindow(tuple(_counters(w["tiers"][n]) for n in cnames), cnames)
        for w in blob["windows"]
    ]
    sub = ReplaySubstrate(deltas)
    loop = ControlLoop(sub, peredge_miku(spine_leaf_platform(), 4),
                       window_ns=1.0)
    while not sub.exhausted:
        loop.fire()
    _assert_edge_decisions_match(loop.decisions, blob["windows"], edges)


def test_live_spine_corun_reproduces_golden_trace():
    """End to end: the canonical spine co-run re-simulated under the
    per-edge ensemble emits the recorded decision sequence, window
    telemetry (fabric blocks included), and fabric summary."""
    blob = _load_fabric_golden()
    pm = spine_leaf_platform()
    assert pm.name == blob["platform"]
    op, n = OpClass(blob["op"]), blob["n_threads"]
    wls = [bw_test("ddr", op, n, name="ddr", miku_managed=False,
                   host="host0"),
           bw_test("cxl", op, n, name="cxl0", host="host0"),
           bw_test("cxl", op, n, name="cxl1", host="host1")]
    sim = TieredMemorySim(pm, wls, seed=0, granularity=4,
                          controller=peredge_miku(pm, 4),
                          window_ns=blob["window_ns"], record_windows=True,
                          control_scope="edge")
    res = sim.run(blob["sim_ns"])
    _assert_edge_decisions_match(res.decisions, blob["windows"],
                                 tuple(blob["edge_names"]))
    assert res.fabric == blob["fabric"]
    assert res.window_records == blob["windows"]
    for name, bw in blob["bandwidths"].items():
        assert res.bandwidth(name) == pytest.approx(bw, rel=1e-12)


def test_golden_spine_trace_shows_congestion_and_relief():
    """The pinned trace itself demonstrates the physics: the shared spine
    port saturates (peak == limit, stalls), the per-edge ladder restricts
    the congested *link* edges — tightest on the spine — while the CXL
    *device* edge (healthy once the fabric is throttled) stays open."""
    blob = _load_fabric_golden()
    spine = blob["fabric"]["spine-cxl"]
    assert spine["peak_occupancy"] == spine["entry_limit"]
    assert spine["stall_events"] > 0

    def restricted(e):
        return sum(1 for w in blob["windows"]
                   if w["decision"][e]["phase"] == "restricted")

    def mean_cap(e, top=16.0):
        caps = [w["decision"][e]["max_concurrency"] for w in blob["windows"]]
        return sum(top if c is None else c for c in caps) / len(caps)

    n = len(blob["windows"])
    assert restricted("spine-cxl") == n  # the congested edge, every window
    assert restricted("cxl") == 0  # the device edge is not the problem
    assert mean_cap("spine-cxl") < mean_cap("uplink0")  # tightest at spine
    assert mean_cap("spine-cxl") < mean_cap("cxl")
    # per-window fabric telemetry is present and well-formed
    for w in blob["windows"]:
        assert set(w["fabric"]) == {"uplink0", "uplink1", "spine-cxl"}
        for entry in w["fabric"].values():
            assert set(entry) == {"queued", "in_service", "occupancy",
                                  "stalled", "stall_events"}


# -- scenario acceptance ------------------------------------------------------


def test_fabric_spine_congestion_scenario_acceptance():
    """CLI-runnable demonstrator: racing collapses DDR via ToR
    monopolization by spine-stalled requests; the per-edge ladder on the
    spine edge recovers it."""
    from repro.scenarios import run_scenario

    table = run_scenario("fabric_spine_congestion", {})
    rows = {r["law"]: r for r in table.rows}
    racing, peredge = rows["racing"], rows["peredge"]
    assert racing["ddr_pct_of_alone"] < 10.0  # congestion collapse
    assert peredge["ddr_pct_of_alone"] > 60.0  # per-edge MIKU relief
    assert peredge["spine_restricted_windows"] > 0
    assert racing["spine_stall_events"] > peredge["spine_stall_events"]

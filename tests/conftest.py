import os
import tempfile

# Tests and benches must see the real (1-device) CPU backend — only the
# dry-run forces 512 host devices, and only in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Persistent compilation cache: the suite recompiles identical smoke-config
# HLO across many tests (three Trainers in the checkpoint test alone); the
# disk cache dedupes within a run and makes repeat runs much faster.  Must
# be set before jax initializes.
os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(tempfile.gettempdir(), "repro-jax-cache"),
)
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.5")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

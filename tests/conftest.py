import os

# Tests and benches must see the real (1-device) CPU backend — only the
# dry-run forces 512 host devices, and only in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

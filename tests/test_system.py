"""End-to-end behaviour tests for the paper's system.

1. The characterization pipeline reproduces the paper's §4 claims.
2. MIKU (§5) restores near-peak fast-tier throughput, work-conserving.
3. The training substrate trains (loss falls), checkpoints, and resumes
   bit-exactly.
4. The serving substrate completes batched requests under tier control.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import run_bw_test, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku


def test_paper_headline_numbers():
    """One test, four §4/§5 claims (CI-fast versions of the benchmarks)."""
    p = platform_a()
    op = OpClass.LOAD
    opt_ddr = run_bw_test(p, op=op, tier="ddr", n_threads=16,
                          sim_ns=80_000).bandwidth("bw-ddr-load")
    opt_cxl = run_bw_test(p, op=op, tier="cxl", n_threads=16,
                          sim_ns=80_000).bandwidth("bw-cxl-load")
    racing = run_corun(p, op=op, n_threads=16, sim_ns=200_000)
    miku = run_corun(p, op=op, n_threads=16, sim_ns=300_000,
                     controller=default_miku(p))
    # claim 1: heavy co-run collapses the fast tier (paper: up to 81-89%)
    assert racing.bandwidth("ddr") < 0.35 * opt_ddr
    # claim 2: the slow tier is barely impacted
    assert racing.bandwidth("cxl") > 0.9 * opt_cxl
    # claim 3: MIKU recovers the fast tier to near-peak
    assert miku.bandwidth("ddr") > 0.9 * opt_ddr
    # claim 4: while keeping the slow tier at high utilization (loads: the
    # paper's level-1 = 8 cores keeps CXL near its ceiling)
    assert miku.bandwidth("cxl") > 0.8 * opt_cxl


def test_train_checkpoint_resume_bit_exact(tmp_path):
    """Two paths to step 4 — straight vs checkpoint+resume — must agree."""
    from repro.launch.train import Trainer

    kw = dict(smoke=True, global_batch=2, seq_len=32, ckpt_every=1)
    t1 = Trainer("qwen2.5-3b", ckpt_dir=str(tmp_path / "a"), **kw)
    s1 = t1.train(2, log_every=100)

    t2 = Trainer("qwen2.5-3b", ckpt_dir=str(tmp_path / "b"), **kw)
    t2.train(1, log_every=100)
    t3 = Trainer("qwen2.5-3b", ckpt_dir=str(tmp_path / "b"), **kw)
    s3 = t3.train(2, resume=True, log_every=100)

    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s3.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-6, rtol=1e-6,
        )


def test_train_loss_decreases():
    from repro.launch.train import Trainer

    # total_steps sizes the warmup to the run: the default (1000-step)
    # schedule leaves lr ~0 over 6 steps, making the loss trend pure noise.
    t = Trainer("h2o-danube-1.8b", smoke=True, global_batch=4, seq_len=64,
                total_steps=6)
    state = t.init_or_resume(False)
    losses = []
    with t.mesh:
        for _ in range(6):
            tokens, labels = next(t.loader)
            state, m = t.step_fn(state, jnp.asarray(tokens),
                                 jnp.asarray(labels))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_serving_end_to_end_tokens_match_greedy_reference():
    """The engine's continuous-batched greedy decode must equal a simple
    sequential greedy loop on the same model."""
    from repro.configs import get_arch
    from repro.models.transformer import TransformerLM
    from repro.serving.engine import (EngineConfig, Request, ServingEngine,
                                      TieredServingCluster)

    cfg = dataclasses.replace(get_arch("qwen2.5-3b").smoke,
                              dtype=jnp.float32)
    model = TransformerLM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prompt = [5, 6, 7]
    n_new = 6

    # reference: sequential prefill + decode loop, batch 1
    state = model.init_decode_state(1, 64)
    logits, state = model.prefill(params,
                                  jnp.asarray([prompt], jnp.int32), state)
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, state = model.decode_step(
            params, state, jnp.asarray([ref[-1]], jnp.int32))
        ref.append(int(jnp.argmax(logits[0])))

    eng = ServingEngine(
        EngineConfig(name="e", model=cfg, max_slots=2, max_len=64),
        params,
    )
    for i in range(3):
        eng.submit(Request(rid=i, prompt=list(prompt),
                           max_new_tokens=n_new))
    TieredServingCluster([eng]).run(500)
    assert len(eng.done) == 3
    for r in eng.done:
        assert r.output == ref, (r.output, ref)

"""MIKU controller state-machine tests (paper §5.2 throttling ladder)."""

from repro.core.controller import (
    MikuConfig,
    MikuController,
    Phase,
    StragglerGovernor,
)
from repro.core.littles_law import EstimatorConfig, OpClass, TierCounters


def mk_controller(**cfg_kw):
    est = EstimatorConfig(t_fast=100.0, slow_read_threshold=500.0, ewma=1.0)
    return MikuController(MikuConfig(**cfg_kw), est)


def win(n_fast, t_fast, n_slow, t_slow, op=OpClass.LOAD):
    f, s = TierCounters(), TierCounters()
    for _ in range(n_fast):
        f.record(op, t_fast)
    for _ in range(n_slow):
        s.record(op, t_slow)
    return f, s


def test_detection_demotes_to_most_restrictive():
    ctl = mk_controller()
    d = ctl.window(*win(50, 100.0, 50, 5000.0))
    assert d.phase is Phase.RESTRICTED
    assert d.max_concurrency == 1  # paper: jump to level-3


def test_promotion_ladder_respects_class_cap():
    ctl = mk_controller(promote_patience=1)
    ctl.window(*win(50, 100.0, 50, 5000.0))  # detect
    caps_seen = []
    for _ in range(12):
        d = ctl.window(*win(50, 100.0, 50, 120.0, op=OpClass.STORE))
        caps_seen.append(d.max_concurrency)
    # store class cap = 4: never promoted beyond it while fast tier active
    assert max(c for c in caps_seen if c is not None) <= 4


def test_ntstore_capped_at_one():
    ctl = mk_controller(promote_patience=1)
    ctl.window(*win(50, 100.0, 50, 9000.0, op=OpClass.NT_STORE))
    for _ in range(10):
        d = ctl.window(*win(50, 100.0, 50, 300.0, op=OpClass.NT_STORE))
        assert d.max_concurrency == 1


def test_work_conserving_release_on_fast_idle():
    ctl = mk_controller()
    ctl.window(*win(50, 100.0, 50, 5000.0))  # detect
    d = ctl.window(*win(0, 0.0, 50, 5000.0))  # fast tier went idle
    assert d.phase is Phase.UNRESTRICTED


def test_rate_backoff_at_floor_level():
    ctl = mk_controller(drain_factor=0.0)  # disable drain grace
    ctl.window(*win(50, 100.0, 50, 5000.0))
    d = ctl.window(*win(50, 100.0, 50, 6000.0))  # still growing
    assert d.max_concurrency == 1 and d.rate_factor < 1.0


def test_drain_grace_holds_position():
    ctl = mk_controller()
    ctl.window(*win(50, 100.0, 50, 5000.0))
    d = ctl.window(*win(50, 100.0, 50, 2000.0))  # draining (2000 < .9*5000)
    assert d.rate_factor == 1.0 and d.max_concurrency == 1


def test_straggler_governor_demotes_and_recovers():
    gov = StragglerGovernor(n_hosts=4, patience=1)
    for _ in range(3):
        out = gov.window([1.0, 1.0, 1.0, 5.0])
    assert not out[3].healthy and out[3].rate_factor < 1.0
    assert all(h.healthy for h in out[:3])
    for _ in range(6):
        out = gov.window([1.0, 1.0, 1.0, 1.0])
    assert out[3].rate_factor == 1.0

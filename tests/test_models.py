"""Per-arch smoke tests + decode/forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models.transformer import TransformerLM

KEY = jax.random.PRNGKey(0)


# The biggest smoke configs dominate tier-1 wall-clock (5-12 s each, almost
# all jit compile); they run in the non-gating slow lane instead.
_HEAVY_ARCHES = {
    "hymba-1.5b",
    "whisper-large-v3",
    "llama4-maverick-400b-a17b",
    "gemma2-27b",
    "h2o-danube-1.8b",
}


@pytest.mark.parametrize(
    "arch_id",
    [
        pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHES
        else a
        for a in ARCH_IDS
    ],
)
def test_arch_smoke_forward_and_decode(arch_id):
    """Assignment: reduced same-family config, one forward + one decode
    step on CPU, output shapes + no NaNs."""
    spec = get_arch(arch_id)
    cfg = spec.smoke
    model = TransformerLM(cfg)
    params, axes = model.init(KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    fe = None
    if cfg.frontend == "vision":
        fe = jax.random.normal(KEY, (B, cfg.frontend_seq, cfg.d_model))
    elif cfg.frontend == "audio":
        fe = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    hidden, aux = jax.jit(
        lambda p, t, f: model.forward(p, t, frontend_embeds=f)
    )(params, tokens, fe)
    logits = model.logits(params, hidden)
    assert logits.shape == (B, S, cfg.vocab)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    state = model.init_decode_state(B, 64)
    if cfg.n_encoder_layers:
        _, state = jax.jit(
            lambda p, t, st, f: model.prefill(p, t, st, frontend_embeds=f)
        )(params, tokens, state, fe)
    lg, state = jax.jit(model.decode_step)(params, state, tokens[:, 0])
    assert lg.shape == (B, cfg.vocab)
    assert not jnp.isnan(lg.astype(jnp.float32)).any()
    assert int(state.length[0]) >= 1


@pytest.mark.parametrize(
    "arch_id",
    [
        "qwen2.5-3b",
        "mamba2-2.7b",
        pytest.param("h2o-danube-1.8b", marks=pytest.mark.slow),
        pytest.param("gemma2-27b", marks=pytest.mark.slow),
        pytest.param("hymba-1.5b", marks=pytest.mark.slow),
        pytest.param("dbrx-132b", marks=pytest.mark.slow),
    ],
)
def test_prefill_matches_forward(arch_id):
    """Teacher-forcing equivalence: prefill's last-token logits == the full
    forward's last-position logits (fp32 smoke configs)."""
    cfg = dataclasses.replace(get_arch(arch_id).smoke, dtype=jnp.float32)
    model = TransformerLM(cfg)
    params, _ = model.init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 1, cfg.vocab)
    hidden, _ = model.forward(params, tokens)
    full_logits = model.logits(params, hidden)[:, -1, :]
    state = model.init_decode_state(B, 32)
    pre_logits, state = model.prefill(params, tokens, state)
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize(
    "arch_id",
    [
        "qwen2.5-3b",
        "mamba2-2.7b",
        pytest.param("hymba-1.5b", marks=pytest.mark.slow),
    ],
)
def test_decode_step_matches_forward(arch_id):
    """prefill(t) + decode(token_t) == forward(t+1 tokens) last logits."""
    cfg = dataclasses.replace(get_arch(arch_id).smoke, dtype=jnp.float32)
    model = TransformerLM(cfg)
    params, _ = model.init(KEY)
    B, S = 1, 9
    tokens = jax.random.randint(KEY, (B, S), 1, cfg.vocab)
    state = model.init_decode_state(B, 32)
    _, state = model.prefill(params, tokens[:, :-1], state)
    dec_logits, _ = model.decode_step(params, state, tokens[:, -1])
    hidden, _ = model.forward(params, tokens)
    ref = model.logits(params, hidden)[:, -1, :]
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(ref), atol=3e-3, rtol=3e-3
    )


def test_param_counts_match_published_sizes():
    expected = {
        "hymba-1.5b": (1.4e9, 1.8e9),
        "stablelm-12b": (11.5e9, 12.6e9),
        "qwen2.5-3b": (2.8e9, 3.4e9),
        "h2o-danube-1.8b": (1.6e9, 2.0e9),
        "gemma2-27b": (26e9, 28.5e9),
        "dbrx-132b": (125e9, 136e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "mamba2-2.7b": (2.5e9, 2.9e9),
        "llama31-8b": (7.5e9, 8.5e9),
    }
    for arch_id, (lo, hi) in expected.items():
        n = get_arch(arch_id).config.param_count()
        assert lo < n < hi, f"{arch_id}: {n/1e9:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    dbrx = get_arch("dbrx-132b").config
    assert 33e9 < dbrx.active_param_count() < 40e9
    l4 = get_arch("llama4-maverick-400b-a17b").config
    assert 15e9 < l4.active_param_count() < 19e9


def test_sliding_window_masks_old_tokens():
    """A token beyond the window must not influence attention output."""
    cfg = dataclasses.replace(get_arch("h2o-danube-1.8b").smoke,
                              dtype=jnp.float32, sliding_window=4)
    model = TransformerLM(cfg)
    params, _ = model.init(KEY)
    B, S = 1, 12
    t1 = jax.random.randint(KEY, (B, S), 1, cfg.vocab)
    t2 = t1.at[0, 0].set((int(t1[0, 0]) % (cfg.vocab - 1)) + 1)  # differs at pos 0
    h1, _ = model.forward(params, t1)
    h2, _ = model.forward(params, t2)
    # position 11 only sees positions >= 8 (window 4): identical output
    np.testing.assert_allclose(
        np.asarray(h1[:, -1]), np.asarray(h2[:, -1]), atol=1e-5
    )

"""Scenario-API tests.

Three contracts:

1. **Legacy equivalence** — each registered grid scenario expands to the
   same SimJob matrix (and produces the same result rows on a small grid)
   as the seed's imperative ``memsim/runner.py`` construction, replicated
   inline here as the frozen reference.
2. **N-tier** — the new platforms/scenarios the two-tier API could not
   express work, and adding tiers never perturbs two-tier results
   (bit-identity).
3. **Plumbing** — axis-grid expansion, ``--set`` parsing, unknown-tier
   validation, CSV/JSON emission.
"""

import json
import os

import pytest

from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import (
    PLATFORMS,
    UnknownTierError,
    platform_a,
    platform_a_numa,
    platform_a_switch,
)
from repro.core.littles_law import DEMAND_CLASSES, OpClass
from repro.memsim.sweep import SimJob, run_sweep
from repro.memsim.workloads import alternating_bw_pair, bw_test, lat_test
from repro.scenarios import (
    expand_cells,
    get,
    names,
    parse_set_args,
    plan,
    resolve_axes,
    run_scenario,
)

DATA = os.path.join(os.path.dirname(__file__), "data")
P = platform_a()


def _legacy_job(platform, workloads, sim_ns, *, miku=False, seed=0,
                granularity=4, window_ns=10_000.0):
    """The seed runner's ``_job`` helper, frozen as the reference."""
    return SimJob(platform=platform, workloads=workloads, sim_ns=sim_ns,
                  seed=seed, granularity=granularity, window_ns=window_ns,
                  miku=miku)


# -- registry coverage --------------------------------------------------------


def test_registry_covers_all_eleven_figures_and_ntier():
    have = set(names())
    for expected in (
        "fig2_tiering", "fig3_bandwidth", "fig4_latency", "fig5_corun",
        "fig6_tor_correlation", "fig7_llc", "fig8_sync", "fig9_service",
        "fig10_miku", "fig11_llm", "fig13_spark", "fig14_kv",
        "corun3_switch", "numa_remote",
    ):
        assert expected in have


def test_module_list_derived_from_registry():
    import benchmarks.run as harness

    mods = harness._module_names()
    # every scenario-declared module appears, in declaration order, and the
    # only non-registry module is the explicit extras list
    assert mods[0] == "fig2_tiering"
    assert mods[-1] == "roofline_table"
    assert "fig5_corun" in mods
    assert mods.count("fig5_corun") == 1  # fig5+fig6 share one module
    from repro.scenarios import all_scenarios

    declared = {sc.module for sc in all_scenarios() if sc.module}
    assert declared == set(mods) - set(harness._EXTRA_MODULES)


# -- legacy SimJob-matrix equivalence ----------------------------------------


def test_fig3_plan_matches_legacy_matrix():
    planned = plan("fig3_bandwidth", {"platform": "A"})
    got = [j for _, _, jobs in planned for j in jobs]
    legacy = [
        _legacy_job(P, [bw_test(tier, op, n)], 120_000.0)
        for op in DEMAND_CLASSES
        for n in (1, 16)
        for tier in ("ddr", "cxl")
    ]
    assert got == legacy


def test_fig4_plan_matches_legacy_matrix():
    import dataclasses

    planned = plan("fig4_latency", {"platform": "A"})
    got = [j for _, _, jobs in planned for j in jobs]
    # fig4 now also collects the mergeable latency histogram (p95_ns
    # row); everything the seed runner pinned is otherwise unchanged.
    legacy = [
        dataclasses.replace(
            _legacy_job(P, [lat_test(tier, OpClass.LOAD, n)], 400_000.0,
                        granularity=1),
            latency_hist=True)
        for tier in ("ddr", "cxl")
        for n in (1, 2, 4, 8, 16)
    ]
    assert got == legacy


def test_fig5_plan_matches_legacy_matrix():
    planned = plan("fig5_corun", {"platform": "A"})
    got = [j for _, _, jobs in planned for j in jobs]
    legacy = []
    for op in DEMAND_CLASSES:
        a = bw_test("ddr", op, 16, name="ddr", miku_managed=False)
        c = bw_test("cxl", op, 16, name="cxl")
        legacy.append(_legacy_job(P, [a], 120_000.0))
        legacy.append(_legacy_job(P, [c], 120_000.0))
        legacy.append(_legacy_job(P, [a, c], 300_000.0))
    assert got == legacy


def test_fig10_plan_matches_legacy_matrix():
    planned = plan("fig10_miku", {"platform": "A", "op": (OpClass.STORE,)})
    got = [j for _, _, jobs in planned for j in jobs]
    op, n, period = OpClass.STORE, 16, 100_000.0
    alt = alternating_bw_pair(op, n, period)
    legacy = [
        _legacy_job(P, [bw_test("ddr", op, n, name="a")], 120_000.0),
        _legacy_job(P, [bw_test("cxl", op, n, name="a")], 120_000.0),
        _legacy_job(P, alt, 600_000.0, window_ns=5_000.0),
        _legacy_job(P, alt, 600_000.0, window_ns=5_000.0, miku=True),
        _legacy_job(P, alt, 600_000.0, window_ns=5_000.0, miku=True),
    ]
    assert got == legacy


def test_fig3_rows_match_legacy_small_grid():
    """Same rows (not just jobs) as the seed's imperative loop, 1:1."""
    over = {"platform": "A-1to1", "op": (OpClass.LOAD,), "threads": (16,)}
    got = run_scenario("fig3_bandwidth", over).rows

    p = PLATFORMS["A-1to1"]
    cells = [(OpClass.LOAD, 16, tier) for tier in ("ddr", "cxl")]
    jobs = [_legacy_job(p, [bw_test(tier, op, n)], 120_000.0)
            for op, n, tier in cells]
    legacy = []
    for (op, n, tier), job, res in zip(cells, jobs, run_sweep(jobs)):
        legacy.append({
            "op": op.value,
            "tier": tier,
            "threads": n,
            "bandwidth_gbps": res.bandwidth(job.workloads[0].name),
            "peak_model_gbps": p.device_for(tier).peak_bandwidth_gbps(op),
        })
    assert [{k: r[k] for k in legacy[0]} for r in got] == legacy
    assert all(r["platform"] == "A-1to1" for r in got)


def test_scenario_rows_reproduce_seed_goldens_quick():
    """The acceptance pin: registry-driven figures == the seed goldens."""
    with open(os.path.join(DATA, "seed_fig_goldens.json")) as f:
        gold = json.load(f)
    rows = run_scenario(
        "fig3_bandwidth",
        {"platform": "A", "op": (OpClass.LOAD,), "threads": (16,)},
    ).rows
    by_tier = {r["tier"]: r for r in rows}
    for g in gold["fig3"]:
        if g["op"] != "load":
            continue
        assert by_tier[g["tier"]]["bandwidth_gbps"] == pytest.approx(
            g["bandwidth_gbps"], rel=0.01)

    (corun,) = run_scenario(
        "fig5_corun", {"platform": "A", "op": (OpClass.LOAD,)}
    ).rows
    g5 = gold["fig5"]["load"]
    assert corun["ddr_corun_gbps"] == pytest.approx(g5["ddr_gbps"], rel=0.01)
    assert corun["cxl_corun_gbps"] == pytest.approx(g5["cxl_gbps"], rel=0.01)


@pytest.mark.slow
def test_scenario_goldens_full_matrix():
    with open(os.path.join(DATA, "seed_fig_goldens.json")) as f:
        gold = json.load(f)
    rows = run_scenario("fig3_bandwidth",
                        {"platform": "A", "threads": (16,)}).rows
    by_key = {(r["op"], r["tier"]): r for r in rows}
    for g in gold["fig3"]:
        assert by_key[(g["op"], g["tier"])]["bandwidth_gbps"] == \
            pytest.approx(g["bandwidth_gbps"], rel=0.01)
    rows5 = run_scenario("fig5_corun", {"platform": "A"}).rows
    for r in rows5:
        g = gold["fig5"][r["op"]]
        assert r["ddr_corun_gbps"] == pytest.approx(g["ddr_gbps"], rel=0.01)
        assert r["cxl_corun_gbps"] == pytest.approx(g["cxl_gbps"], rel=0.01)


# -- N-tier: the scenarios the two-tier API could not express ----------------


def test_three_tier_platform_preserves_two_tier_results_bit_identical():
    """Adding a tier nobody touches must not move a single number."""
    wls = [
        WorkloadSpec(name="ddr", op=OpClass.LOAD, tier="ddr", n_cores=16,
                     miku_managed=False),
        WorkloadSpec(name="cxl", op=OpClass.LOAD, tier="cxl", n_cores=16),
    ]
    base = TieredMemorySim(platform_a(), [w for w in wls], seed=0)
    r2 = base.run(150_000.0)
    p3 = platform_a_switch()
    r3 = TieredMemorySim(p3, [w for w in wls], seed=0).run(150_000.0)
    assert r3.bandwidth("ddr") == r2.bandwidth("ddr")
    assert r3.bandwidth("cxl") == r2.bandwidth("cxl")
    assert r3.tor_inserts == r2.tor_inserts
    assert r3.tor_peak == r2.tor_peak
    assert r3.tier_counters["cxl_sw"].inserts == 0


def test_placement_vector_matches_ddr_fraction_bit_identical():
    """{"ddr": f, "cxl": 1-f} must replay ddr_fraction=f exactly (same RNG
    draw count, same routing decisions)."""
    f = 0.3

    def mk(**kw):
        return WorkloadSpec(name="w", op=OpClass.LOAD, tier="ddr",
                            n_cores=8, miku_managed=False, **kw)

    ra = TieredMemorySim(P, [mk(ddr_fraction=f)], seed=7).run(100_000.0)
    rb = TieredMemorySim(P, [mk(placement={"ddr": f, "cxl": 1 - f})],
                         seed=7).run(100_000.0)
    assert ra.bandwidth("w") == rb.bandwidth("w")
    assert ra.tor_inserts == rb.tor_inserts
    assert ra.tier_counters["ddr"].inserts == rb.tier_counters["ddr"].inserts


def test_corun3_switch_scenario_nontrivial():
    t = run_scenario(
        "corun3_switch",
        {"op": (OpClass.LOAD,), "miku": (False,), "sim_ns": 150_000.0},
    )
    (row,) = t.rows
    assert row["platform"] == "A-switch"
    for tier in ("ddr", "cxl", "cxl_sw"):
        assert row[f"{tier}_corun_gbps"] > 0
    # the third tier behaves like CXL-plus-a-switch: comparable bandwidth,
    # strictly higher residency than local CXL
    assert row["t_cxl_sw_corun_ns"] > row["t_cxl_corun_ns"]
    # and the paper's collapse now comes from *two* slow tiers
    assert row["ddr_loss_pct"] > 50.0


def test_numa_remote_scenario_nontrivial():
    t = run_scenario(
        "numa_remote",
        {"remote_fraction": (0.0, 0.5), "sim_ns": 120_000.0},
    )
    rows = {r["remote_fraction"]: r for r in t.rows}
    assert rows[0.0]["remote_inserts"] == 0
    assert rows[0.5]["remote_inserts"] > 0
    # NUMA striping adds DIMM parallelism: more deliverable bandwidth
    assert (rows[0.5]["striped_alone_gbps"]
            > 1.3 * rows[0.0]["striped_alone_gbps"])


def test_miku_controls_three_tier_corun():
    """The control plane generalizes: MIKU recovers the fast tier with two
    slow tiers co-running (no controller changes)."""
    racing = run_scenario(
        "corun3_switch",
        {"op": (OpClass.STORE,), "miku": (False,), "sim_ns": 200_000.0},
    ).rows[0]
    miku = run_scenario(
        "corun3_switch",
        {"op": (OpClass.STORE,), "miku": (True,), "sim_ns": 200_000.0},
    ).rows[0]
    assert miku["ddr_corun_gbps"] > 2 * racing["ddr_corun_gbps"]
    assert miku["ddr_loss_pct"] < 20.0


# -- validation ---------------------------------------------------------------


def test_device_for_unknown_tier_raises_with_tier_list():
    with pytest.raises(UnknownTierError, match="ddr, cxl"):
        P.device_for("hbm3")
    # known names still resolve on an extended platform
    p3 = platform_a_numa()
    assert p3.device_for("ddr_remote").tier == "ddr_remote"
    with pytest.raises(UnknownTierError, match="ddr_remote"):
        p3.device_for("cxl_sw")


def test_simjob_construction_rejects_unknown_tier():
    wl = WorkloadSpec(name="w", op=OpClass.LOAD, tier="optane", n_cores=1)
    with pytest.raises(UnknownTierError, match="optane"):
        SimJob(platform=P, workloads=[wl], sim_ns=1000.0)


def test_sim_construction_rejects_unknown_phase_and_placement_tiers():
    phased = WorkloadSpec(name="w", op=OpClass.LOAD, tier="ddr", n_cores=1,
                          phases=[(10.0, "ddr"), (10.0, "cxl_sw")])
    with pytest.raises(UnknownTierError, match="cxl_sw"):
        TieredMemorySim(P, [phased])
    placed = WorkloadSpec(name="w", op=OpClass.LOAD, tier="ddr", n_cores=1,
                          placement={"ddr": 0.5, "pmem": 0.5})
    with pytest.raises(UnknownTierError, match="pmem"):
        TieredMemorySim(P, [placed])


def test_malformed_placement_rejected():
    bad_sum = WorkloadSpec(name="w", op=OpClass.LOAD, tier="ddr", n_cores=1,
                           placement={"ddr": 0.5, "cxl": 0.2})
    with pytest.raises(ValueError, match="sum"):
        TieredMemorySim(P, [bad_sum])
    both = WorkloadSpec(name="w", op=OpClass.LOAD, tier="ddr", n_cores=1,
                        placement={"ddr": 1.0}, ddr_fraction=0.5)
    with pytest.raises(ValueError, match="mutually exclusive"):
        TieredMemorySim(P, [both])


# -- planner plumbing ---------------------------------------------------------


def test_axis_grid_expansion_order_and_scalars():
    sc = get("fig3_bandwidth")
    values = resolve_axes(sc, {"platform": "A", "op": (OpClass.LOAD,)})
    cells = expand_cells(sc, values)
    # row-major product in axis declaration order: platform, op, threads, tier
    assert len(cells) == 1 * 1 * 2 * 2
    assert [(c["threads"], c["tier"]) for c in cells] == [
        (1, "ddr"), (1, "cxl"), (16, "ddr"), (16, "cxl")
    ]
    assert all(c["op"] is OpClass.LOAD for c in cells)


def test_set_override_parsing():
    sc = get("fig3_bandwidth")
    over = parse_set_args(sc, ["threads=4,8", "op=store", "platform=B"])
    assert over["threads"] == (4, 8)
    assert over["op"] == (OpClass.STORE,)
    assert over["platform"] == ("B",)
    sc10 = get("fig10_miku")
    over10 = parse_set_args(sc10, ["period_ns=5e4", "cycles=2"])
    assert over10["period_ns"] == 5e4
    assert over10["cycles"] == 2
    sc3t = get("corun3_switch")
    assert parse_set_args(sc3t, ["miku=true"])["miku"] == (True,)
    with pytest.raises(KeyError, match="no axis"):
        parse_set_args(sc, ["bogus=1"])
    with pytest.raises(ValueError, match="axis=value"):
        parse_set_args(sc, ["threads"])


def test_unknown_scenario_and_platform_errors():
    with pytest.raises(KeyError, match="registered scenarios"):
        get("fig99_nope")
    with pytest.raises(KeyError, match="known platforms"):
        run_scenario("fig3_bandwidth", {"platform": "Z9"})


def test_result_table_csv_json_emission():
    t = run_scenario(
        "fig3_bandwidth",
        {"platform": "A-1to1", "op": (OpClass.LOAD,), "threads": (1,),
         "tier": ("ddr",)},
    )
    csv_text = t.to_csv()
    header, line = csv_text.strip().split("\n")
    assert header.split(",")[:4] == ["platform", "op", "tier", "threads"]
    assert line.startswith("A-1to1,load,ddr,1,")
    blob = json.loads(t.to_json())
    assert blob["scenario"] == "fig3_bandwidth"
    assert blob["rows"][0]["op"] == "load"
    assert blob["params"]["op"] == ["load"]

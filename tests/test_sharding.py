"""Sharding rule resolution: divisibility fallbacks, multi-axis batch."""

import jax
import pytest
from jax.sharding import PartitionSpec

from repro.distributed.sharding import (
    LONG_CONTEXT_RULES,
    TRAIN_RULES,
    partition_spec_for,
    rules_for_shape,
)


@pytest.fixture(scope="module")
def mesh():
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


@pytest.fixture(scope="module")
def mesh_4x2():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return jax.make_mesh((4, 2), ("data", "model"))


def test_ffn_shards_over_model():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = partition_spec_for(("embed", "ffn"), (128, 256), mesh, TRAIN_RULES)
    # size-1 axes are never assigned
    assert spec == PartitionSpec()


def test_divisibility_fallback_heads_to_head_dim():
    """hymba: 25 q heads don't divide a 16-way model axis; head_dim (64)
    does — TP survives via the fallback chain."""
    import numpy as np
    devs = np.array(jax.devices() * 16)[:16].reshape(1, 16)
    from jax.sharding import Mesh
    mesh = Mesh(devs, ("data", "model"))
    spec = partition_spec_for(
        ("embed", "q_heads", "head_dim"), (1600, 25, 64), mesh, TRAIN_RULES
    )
    assert spec == PartitionSpec(None, None, "model")


def test_batch_uses_pod_and_data_axes():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 8)[:8].reshape(2, 2, 2)
    mesh = Mesh(devs, ("pod", "data", "model"))
    spec = partition_spec_for(("batch", "seq"), (8, 128), mesh, TRAIN_RULES)
    assert spec == PartitionSpec(("pod", "data"))


def test_long_context_rules_shard_kv_seq_not_batch():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 4)[:4].reshape(4, 1)
    mesh = Mesh(devs, ("data", "model"))
    rules = rules_for_shape("decode", global_batch=1)
    assert rules is LONG_CONTEXT_RULES
    spec = partition_spec_for(
        ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        (4, 1, 1024, 2, 64), mesh, rules,
    )
    # batch stays unsharded; kv_seq takes the data axis (possibly jointly
    # with model — the context-parallel spread over every chip)
    assigned = spec[2] if len(spec) > 2 else None
    assert assigned is not None
    names = (assigned,) if isinstance(assigned, str) else assigned
    assert "data" in names
    assert len(spec) < 2 or spec[1] is None


def test_no_mesh_axis_reused_within_tensor():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    mesh = Mesh(devs, ("data", "model"))
    spec = partition_spec_for(
        ("experts", "embed", "ffn"), (4, 64, 128), mesh, TRAIN_RULES
    )
    # experts takes model; embed takes data; ffn wants model (taken) -> None
    assert spec == PartitionSpec("model", "data")

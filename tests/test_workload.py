"""The workload-layer hardening pass: arrival generators + SLO scenarios.

Four groups:

1. **Generator properties** — exact-seed determinism, empirical rate
   within tolerance, Zipf skew monotone in ``s`` (exact, not
   statistical: the same uniform draws bisect a pointwise-larger
   cumulative table), bursty duty-cycle conservation (every arrival
   inside the on-phase by construction), and bit-faithful trace replay.
   Each runs as a hypothesis property when hypothesis is installed; the
   container image does not ship it, so the same properties are also
   exercised over a fixed spread of kinds and seeds.
2. **Open-loop DES integration** — request conservation
   (``generated == issued + shed + backlog``), queue-limit shedding,
   the ``"arrival"`` batched-lane fallback with cross-lane equality,
   zero-completion NaN percentiles, and the sanitizer's
   ``arrival-conservation`` check via fault injection.
3. **SLO scenario acceptance** — the ``slo_knee`` knee ordering the
   ISSUE pins (CXL-heavy placement blows the p99 budget at a fraction
   of the DDR rate; MIKU moves the knee above racing) and the
   ``flash_crowd`` transient contrast (racing lets the backlog run
   away, MIKU drains it).
4. **Pinned golden** — one ``slo_knee`` cell's decision/telemetry trace
   (``tests/data/slo_knee_trace_goldens.json``; ``REPRO_REGEN=1`` to
   re-record), replayed law-only through a ReplaySubstrate AND
   re-simulated end to end.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os

import pytest

from repro.analysis import InvariantViolation
from repro.core.des import TieredMemorySim, WorkloadStats
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass, TierCounters, TierWindow
from repro.core.substrate import ControlLoop, ReplaySubstrate
from repro.memsim.batched import partition_jobs
from repro.memsim.calibration import default_miku
from repro.memsim.sweep import SimJob, run_job, run_sweep
from repro.memsim.workloads import bw_test, serve_test
from repro.obs.histogram import LatencyHistogram
from repro.scenarios import get
from repro.workload import ArrivalSpec, arrival_times

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "slo_knee_trace_goldens.json")
P = platform_a()

_RANDOM_KINDS = ("poisson", "zipf", "bursty", "diurnal", "flash_crowd")
_HORIZON = 2_000_000.0
_RATE = 0.01


def _spec(kind: str, seed: int = 0, **over) -> ArrivalSpec:
    base = dict(rate=_RATE, seed=seed)
    if kind == "flash_crowd":
        base.update(t_step_ns=_HORIZON / 2, surge=3.0, surge_ns=0.0)
    base.update(over)
    return ArrivalSpec(kind, **base)


# -- 1a. spec validation ------------------------------------------------------


@pytest.mark.parametrize("bad", [
    dict(kind="weibull", rate=1.0),
    dict(kind="poisson"),  # rate defaults to 0.0
    dict(kind="poisson", rate=-1.0),
    dict(kind="zipf", rate=1.0, s=0.0),
    dict(kind="zipf", rate=1.0, n_keys=0),
    dict(kind="bursty", rate=1.0, duty=0.0),
    dict(kind="bursty", rate=1.0, duty=1.5),
    dict(kind="bursty", rate=1.0, period_ns=0.0),
    dict(kind="diurnal", rate=1.0, amplitude=1.0),
    dict(kind="flash_crowd", rate=1.0, surge=0.0),
    dict(kind="trace"),  # path missing
    dict(kind="poisson", rate=1.0, queue_limit=0),
])
def test_arrival_spec_validation(bad):
    with pytest.raises(ValueError):
        ArrivalSpec(**bad)


def test_des_rejects_non_arrival_spec():
    wl = dataclasses.replace(serve_test(2), arrival="poisson")
    with pytest.raises(ValueError, match="arrival="):
        SimJob(platform=P, workloads=[wl], sim_ns=10_000.0)


# -- 1b. determinism + rate properties ----------------------------------------


def _check_determinism(kind: str, seed: int, stream_seed: int) -> None:
    spec = _spec(kind, seed)
    a = arrival_times(spec, stream_seed=stream_seed, limit=256)
    b = arrival_times(spec, stream_seed=stream_seed, limit=256)
    assert a == b  # exact, not approximate
    assert all(t0 <= t1 for (t0, _), (t1, _) in zip(a, a[1:]))
    # A different stream or spec seed is a genuinely different stream.
    c = arrival_times(spec, stream_seed=stream_seed + 1, limit=256)
    d = arrival_times(dataclasses.replace(spec, seed=seed + 1),
                      stream_seed=stream_seed, limit=256)
    assert a != c and a != d


def _check_rate(kind: str, seed: int) -> None:
    spec = _spec(kind, seed)
    n = len(arrival_times(spec, stream_seed=seed * 31 + 7,
                          horizon_ns=_HORIZON))
    if kind == "flash_crowd":
        # rate until the midpoint step, rate * surge after it.
        expected = spec.rate * _HORIZON / 2 + \
            spec.rate * spec.surge * _HORIZON / 2
    else:
        expected = spec.rate * _HORIZON
    assert n == pytest.approx(expected, rel=0.10), (kind, n, expected)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    @pytest.mark.parametrize("kind", _RANDOM_KINDS)
    @pytest.mark.parametrize("seed,stream_seed",
                             [(0, 0), (1, 17), (42, 3), (7, 1000003)])
    def test_generator_determinism(kind, seed, stream_seed):
        _check_determinism(kind, seed, stream_seed)

    @pytest.mark.parametrize("kind", _RANDOM_KINDS)
    @pytest.mark.parametrize("seed", [0, 5, 23])
    def test_generator_empirical_rate(kind, seed):
        _check_rate(kind, seed)
else:
    @given(kind=st.sampled_from(_RANDOM_KINDS), seed=st.integers(0, 2 ** 16),
           stream_seed=st.integers(0, 2 ** 32))
    @settings(max_examples=25, deadline=None)
    def test_generator_determinism(kind, seed, stream_seed):
        _check_determinism(kind, seed, stream_seed)

    @given(kind=st.sampled_from(_RANDOM_KINDS), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def test_generator_empirical_rate(kind, seed):
        _check_rate(kind, seed)


def test_zipf_skew_monotone_in_s():
    """Sharper skew concentrates mass on the hottest rank — *exactly*:
    each arrival consumes one expovariate and one uniform regardless of
    ``s``, and ``bisect`` over the cumulative table is pointwise monotone,
    so with the draws held fixed the rank-0 count never decreases in s."""
    for seed in (0, 9, 77):
        counts = []
        for s in (0.5, 0.8, 1.1, 1.5, 2.0):
            spec = _spec("zipf", seed, s=s, n_keys=256)
            keys = [k for _, k in arrival_times(spec, stream_seed=seed,
                                                limit=2048)]
            counts.append(sum(1 for k in keys if k == 0.0))
        assert counts == sorted(counts), counts
        assert counts[-1] > counts[0]  # strictly sharper over the range


def test_zipf_keys_are_quantiles():
    spec = _spec("zipf", 3, n_keys=64)
    keys = [k for _, k in arrival_times(spec, stream_seed=1, limit=512)]
    assert all(0.0 <= k < 1.0 for k in keys)
    assert all(abs(k * 64 - round(k * 64)) < 1e-9 for k in keys)


def test_bursty_duty_cycle_conservation():
    """Every arrival lands inside the on-phase (offset < duty * period) —
    exact by construction — and the time-average rate stays ``rate``."""
    for duty in (0.1, 0.5, 0.9):
        spec = _spec("bursty", 2, duty=duty, period_ns=10_000.0)
        times = [t for t, _ in arrival_times(spec, stream_seed=5,
                                             horizon_ns=_HORIZON)]
        assert times, duty
        for t in times:
            assert t % spec.period_ns < duty * spec.period_ns + 1e-6
        assert len(times) == pytest.approx(spec.rate * _HORIZON, rel=0.10)


def test_flash_crowd_step_is_piecewise():
    spec = _spec("flash_crowd", 4, t_step_ns=500_000.0, surge=5.0,
                 surge_ns=500_000.0)
    times = [t for t, _ in arrival_times(spec, stream_seed=2,
                                         horizon_ns=1_500_000.0)]
    pre = sum(1 for t in times if t < 500_000.0)
    mid = sum(1 for t in times if 500_000.0 <= t < 1_000_000.0)
    post = sum(1 for t in times if t >= 1_000_000.0)
    assert pre == pytest.approx(spec.rate * 500_000.0, rel=0.15)
    assert mid == pytest.approx(spec.rate * 5.0 * 500_000.0, rel=0.15)
    assert post == pytest.approx(spec.rate * 500_000.0, rel=0.15)


def test_diurnal_oscillates_about_mean():
    spec = _spec("diurnal", 6, period_ns=200_000.0, amplitude=0.9)
    times = [t for t, _ in arrival_times(spec, stream_seed=8,
                                         horizon_ns=_HORIZON)]
    assert len(times) == pytest.approx(spec.rate * _HORIZON, rel=0.10)
    # First half-period runs above the mean rate, second half below.
    crest = sum(1 for t in times if t % 200_000.0 < 100_000.0)
    trough = len(times) - crest
    assert crest > 1.3 * trough


# -- 1c. trace replay ---------------------------------------------------------


def test_trace_replay_is_bit_faithful(tmp_path):
    path = tmp_path / "arrivals.txt"
    rows = [(10.0, -1.0), (10.0, 0.25), (35.5, -1.0), (80.0, 0.5)]
    path.write_text(
        "# header comment\n\n10.0\n10.0,0.25\n35.5\n80.0,0.5\n")
    spec = ArrivalSpec("trace", path=str(path))
    got = arrival_times(spec, horizon_ns=1e9)
    assert got == rows  # bit-faithful, stream_seed-independent
    assert arrival_times(spec, stream_seed=99, horizon_ns=1e9) == rows


def test_trace_replay_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.txt"
    bad.write_text("10.0\nnot-a-number\n")
    with pytest.raises(ValueError, match="t_ns"):
        arrival_times(ArrivalSpec("trace", path=str(bad)), limit=10)
    dec = tmp_path / "dec.txt"
    dec.write_text("10.0\n5.0\n")
    with pytest.raises(ValueError, match="non-decreasing"):
        arrival_times(ArrivalSpec("trace", path=str(dec)), limit=10)


def test_trace_driven_sim(tmp_path):
    path = tmp_path / "t.txt"
    path.write_text("".join(f"{t * 100.0}\n" for t in range(200)))
    wl = serve_test(2, arrival=ArrivalSpec("trace", path=str(path)))
    res = run_job(SimJob(platform=P, workloads=[wl], sim_ns=60_000.0))
    a = res.arrival["serve"]
    assert a["generated"] == 200
    assert a["generated"] == a["issued"] + a["shed"] + a["backlog"]


# -- 2. open-loop DES integration ---------------------------------------------


def _open_job(**over) -> SimJob:
    params = dict(
        platform=P,
        workloads=[
            serve_test(4, arrival=ArrivalSpec("poisson", rate=0.01, seed=7),
                       ddr_fraction=0.5),
            bw_test("cxl", OpClass.LOAD, 8, name="hog"),
        ],
        sim_ns=100_000.0,
        seed=3,
    )
    params.update(over)
    return SimJob(**params)


def test_open_loop_conservation_and_latency_includes_wait():
    res = run_job(_open_job(latency_hist=True))
    a = res.arrival["serve"]
    st = res.stats["serve"]
    assert a["generated"] == a["issued"] + a["shed"] + a["backlog"]
    assert a["shed"] == 0  # unbounded queue
    assert 0 < st.completed <= a["issued"]
    # Latency is measured from *generation* (backlog wait included), so
    # the mean must be at least the unloaded pipeline latency.
    assert st.mean_latency_ns() > 0.0


def test_queue_limit_sheds_and_bounds_backlog():
    wl = serve_test(2, arrival=ArrivalSpec("poisson", rate=0.05, seed=4,
                                           queue_limit=16))
    res = run_job(SimJob(platform=P, workloads=[wl], sim_ns=100_000.0))
    a = res.arrival["serve"]
    assert a["shed"] > 0
    assert a["backlog"] <= 16
    assert a["generated"] == a["issued"] + a["shed"] + a["backlog"]


def test_closed_loop_jobs_have_no_arrival_block():
    res = run_job(SimJob(platform=P,
                         workloads=[bw_test("cxl", OpClass.LOAD, 4)],
                         sim_ns=50_000.0))
    assert res.arrival is None


def test_window_records_carry_arrival_deltas():
    res = run_job(_open_job(record_windows=True))
    recs = [r for r in res.window_records if "arrival" in r]
    assert recs, "open-loop run recorded no arrival blocks"
    a = res.arrival["serve"]
    gen = sum(r["arrival"]["serve"]["generated"] for r in recs)
    issued = sum(r["arrival"]["serve"]["issued"] for r in recs)
    shed = sum(r["arrival"]["serve"]["shed"] for r in recs)
    # Per-window deltas fold back to the run totals (the final partial
    # window past the last boundary is the only slack).
    assert gen <= a["generated"] and issued <= a["issued"]
    assert shed <= a["shed"]
    last = recs[-1]["arrival"]["serve"]
    assert last["queue_depth"] >= 0
    # The hog is closed-loop: it never appears in arrival blocks.
    assert all("hog" not in r["arrival"] for r in recs)


def test_open_loop_runs_are_deterministic():
    """Same job, same seeds → bit-identical everything: the arrival
    generators draw from dedicated streams (never wall-clock, never the
    process-global RNG), so open-loop runs replay exactly."""
    r1, r2 = run_job(_open_job()), run_job(_open_job())
    assert r1.arrival == r2.arrival
    for name in ("serve", "hog"):
        assert r1.stats[name].bytes == r2.stats[name].bytes
        assert r1.stats[name].latency_sum == r2.stats[name].latency_sum


# -- 2b. cross-lane equivalence -----------------------------------------------


def test_batched_lane_falls_back_with_arrival_reason():
    jobs = [_open_job(), SimJob(platform=P,
                                workloads=[bw_test("cxl", OpClass.LOAD, 8)],
                                sim_ns=50_000.0)]
    plans, fallbacks = partition_jobs(jobs)
    assert dict(fallbacks) == {0: "arrival"}  # closed-loop job batches
    batched = run_sweep(jobs, lane="batched")
    scalar = run_sweep(jobs, lane="scalar")
    # The fallback is a scalar rerun: bit-identical, conservation intact.
    assert batched[0].arrival == scalar[0].arrival
    for name in ("serve", "hog"):
        assert batched[0].stats[name].bytes == scalar[0].stats[name].bytes
    assert batched[1].arrival is None


# -- 2c. zero-completion NaN regression ---------------------------------------


def test_empty_percentiles_are_nan_not_zero():
    assert math.isnan(WorkloadStats().percentile_ns(0.99))
    assert math.isnan(LatencyHistogram().percentile(0.99))
    # NaN never satisfies a budget comparison — the property the SLO
    # scenarios rely on to mark zero-completion cells as blown.
    assert not (WorkloadStats().percentile_ns(0.99) <= 1e12)
    assert not (LatencyHistogram().percentile(0.99) <= 1e12)


def test_zero_completion_window_hist_is_nan_safe():
    # A rate so low nothing arrives within the horizon: stats exist, the
    # histogram is empty, and every percentile is NaN (not 0.0).
    wl = serve_test(1, arrival=ArrivalSpec("poisson", rate=1e-9, seed=1))
    res = run_job(SimJob(platform=P, workloads=[wl], sim_ns=20_000.0,
                         latency_hist=True))
    st = res.stats["serve"]
    assert st.completed == 0
    assert math.isnan(st.percentile_ns(0.5))
    assert math.isnan(st.latency_hist.percentile(0.99))


# -- 2d. sanitizer ------------------------------------------------------------


def test_sanitized_open_loop_run_is_clean():
    res = run_job(_open_job(sanitize="record"))
    assert res.sanitizer["violations"] == []


def test_injected_arrival_miscount_trips_conservation():
    sim = TieredMemorySim(
        P, _open_job().workloads, seed=3, sanitize=True,
    )
    sim._san.add_mutation(1, lambda s: s._arr_gen.__setitem__(
        0, s._arr_gen[0] + 3))
    with pytest.raises(InvariantViolation) as ei:
        sim.run(100_000.0)
    assert ei.value.check == "arrival-conservation"
    assert ei.value.context["workload"] == "serve"


# -- 3. SLO scenario acceptance -----------------------------------------------


def _slo_cell(placement, policy, rate):
    return {
        "platform": "A", "op": OpClass.LOAD, "placement": placement,
        "policy": policy, "rate": rate, "budget_ns": 10_000.0,
        "sim_ns": 300_000.0,
    }


def _slo_row(placement, policy, rate):
    sc = get("slo_knee")
    cell = _slo_cell(placement, policy, rate)
    jobs = sc.build(P, cell)
    results = [run_job(j) for j in jobs]
    (row,) = sc.reduce(P, cell, jobs, results)
    return row


@pytest.fixture(scope="module")
def knee_rows():
    rows = {}
    for placement, policy in (("cxl_heavy", "racing"), ("cxl_heavy", "miku"),
                              ("ddr", "racing")):
        for rate in (0.005, 0.020):
            rows[(placement, policy, rate)] = _slo_row(
                placement, policy, rate)
    return rows


def test_slo_knee_orders_placements_and_policies(knee_rows):
    """The ISSUE's acceptance pins: under racing, CXL-heavy placement
    blows the p99 budget at a fraction of the rate DDR sustains; MIKU
    moves the CXL-heavy knee above the racing knee."""
    blown = {k: r["budget_blown"] for k, r in knee_rows.items()}
    # racing, cxl_heavy: knee at 0.005 (the lowest swept blown rate).
    assert blown[("cxl_heavy", "racing", 0.005)] == 1
    # racing, ddr: survives 0.005, blows at 0.020 — the knee is higher.
    assert blown[("ddr", "racing", 0.005)] == 0
    assert blown[("ddr", "racing", 0.020)] == 1
    # miku, cxl_heavy: survives the rate racing died at — the knee moved.
    assert blown[("cxl_heavy", "miku", 0.005)] == 0
    assert blown[("cxl_heavy", "miku", 0.020)] == 1


def test_slo_knee_rows_conserve_and_report_tails(knee_rows):
    for row in knee_rows.values():
        assert row["generated"] == \
            row["issued"] + row["shed"] + row["backlog"]
        p50, p95, p99 = row["p50_ns"], row["p95_ns"], row["p99_ns"]
        assert p50 <= p95 * 1.0001 and p95 <= p99 * 1.07  # hist tolerance
    # Overload shows up as unbounded backlog growth, not silence.
    assert knee_rows[("cxl_heavy", "racing", 0.020)]["backlog"] > 0
    assert knee_rows[("cxl_heavy", "miku", 0.005)]["backlog"] == 0


def test_flash_crowd_transient_contrast():
    sc = get("flash_crowd")
    rows = {}
    for policy in ("racing", "miku"):
        cell = {
            "platform": "A", "op": OpClass.LOAD, "placement": "split",
            "policy": policy, "rate": 0.004, "surge": 6.0,
            "t_step_ns": 100_000.0, "surge_ns": 60_000.0,
            "sim_ns": 300_000.0,
        }
        jobs = sc.build(P, cell)
        results = [run_job(j) for j in jobs]
        (rows[policy],) = sc.reduce(P, cell, jobs, results)
    racing, miku = rows["racing"], rows["miku"]
    # The control plane's transient response: racing lets the crowd's
    # backlog run away and never drains it; MIKU caps the excursion and
    # drains the queue before the horizon.
    assert miku["peak_queue_depth"] < racing["peak_queue_depth"]
    assert miku["backlog"] == 0
    assert racing["backlog"] > 0
    assert miku["surge_p99_ns"] < racing["surge_p99_ns"] * 0.75
    assert miku["recovery_windows"] <= racing["recovery_windows"]


# -- 4. pinned golden: one slo_knee cell --------------------------------------

_GOLDEN_CELL = ("cxl_heavy", "miku", 0.005)


def _golden_job() -> SimJob:
    sc = get("slo_knee")
    (job,) = sc.build(P, _slo_cell(*_GOLDEN_CELL))
    return dataclasses.replace(job, record_windows=True)


def _strip(rec: dict) -> dict:
    return {k: v for k, v in rec.items() if k != "latency_hist"}


@pytest.fixture(scope="module")
def golden_blob():
    if os.environ.get("REPRO_REGEN"):
        res = run_job(_golden_job())
        blob = {
            "scenario": "slo_knee",
            "placement": _GOLDEN_CELL[0],
            "policy": _GOLDEN_CELL[1],
            "rate": _GOLDEN_CELL[2],
            "window_ns": 10_000.0,
            "sim_ns": 300_000.0,
            "tier_names": ["ddr", "cxl"],
            "windows": [_strip(r) for r in res.window_records],
        }
        with open(GOLDEN, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
            f.write("\n")
    with open(GOLDEN) as f:
        return json.load(f)


def _counters(d) -> TierCounters:
    return TierCounters(
        inserts=d["inserts"],
        occupancy_time=d["occupancy_time"],
        class_counts={OpClass(k): v for k, v in d["class_counts"].items()},
    )


def test_golden_replayed_law_only(golden_blob):
    """The recorded counter windows, replayed through a ReplaySubstrate
    (no DES), drive the MIKU law to the identical decision sequence."""
    names = tuple(golden_blob["tier_names"])
    deltas = [
        TierWindow(tuple(_counters(w["tiers"][t]) for t in names), names)
        for w in golden_blob["windows"]
    ]
    sub = ReplaySubstrate(deltas)
    loop = ControlLoop(sub, default_miku(P), window_ns=1.0)
    while not sub.exhausted:
        loop.fire()
    assert len(loop.decisions) == len(golden_blob["windows"])
    for d, w in zip(loop.decisions, golden_blob["windows"]):
        g = w["decision"]["cxl"]
        dt = d.for_tier("cxl")
        assert dt.max_concurrency == g["max_concurrency"]
        assert dt.rate_factor == g["rate_factor"]
        assert dt.phase.value == g["phase"]


def test_golden_resimulates_bit_identically(golden_blob):
    """End to end: re-running the cell reproduces every recorded window —
    tier counters, decisions, AND the per-window arrival deltas."""
    res = run_job(_golden_job())
    got = [_strip(r) for r in res.window_records]
    want = golden_blob["windows"]
    assert json.loads(json.dumps(got)) == want, (
        "slo_knee golden trace drifted from tests/data/"
        "slo_knee_trace_goldens.json; if intentional, re-record with "
        "REPRO_REGEN=1 pytest tests/test_workload.py"
    )

"""repro.tiering — PageMap, MigrationEngine, policies, DES hook, scenarios.

Five contracts:

1. **PageMap units** — placement validation, circular hot-set weights,
   decayed hotness, access-weighted tier fractions.
2. **MigrationEngine units** — FIFO completion credit, dedup, page flips
   only when the copy traffic has actually completed.
3. **Policy laws** — hotness_lru promotes hottest-first within fast
   capacity and demotes coldest-first over the watermark;
   miku_coordinated defers against the ladders' migration budgets.
4. **DES integration** — migration traffic is real ``OpClass.MIGRATE``
   station traffic (visible in TierWindow class counts), placement
   re-resolves from the live PageMap, and a sim without a hook carries no
   migration workloads (the fast path stays pinned by tests/test_substrate).
5. **Scenario acceptance + golden traces** — ``migrate_interference``
   reproduces the recorded decision/telemetry sequences
   (tests/data/migrate_trace_goldens.json) and the headline result: naive
   migration degrades DDR, MIKU coordination recovers it.
"""

import json
import os
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.controller import (
    Decision,
    Phase,
    TierDecisions,
)
from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import platform_a, platform_a_switch
from repro.core.littles_law import DEMAND_CLASSES, OpClass
from repro.memsim.calibration import default_miku
from repro.tiering import (
    HotSetPattern,
    MigrationEngine,
    MigrationJob,
    PageMap,
    PolicyContext,
    RegionSpec,
    TieringSpec,
    make_policy,
)

DATA = os.path.join(os.path.dirname(__file__), "data")
P = platform_a()
P3 = platform_a_switch()


# -- PageMap ------------------------------------------------------------------


def _pagemap(n_pages=64, fast_cap=16, placement=None, pattern=None):
    pm = PageMap(("ddr", "cxl"), fast_capacity_pages=fast_cap)
    pm.add_region("app", n_pages, 4096,
                  placement or {"cxl": 1.0}, pattern or HotSetPattern())
    return pm


def test_pagemap_rejects_bad_placement_and_duplicates():
    pm = PageMap(("ddr", "cxl"), 16)
    with pytest.raises(ValueError, match="unknown tier"):
        pm.add_region("a", 8, 4096, {"nope": 1.0})
    with pytest.raises(ValueError, match="sum to"):
        pm.add_region("a", 8, 4096, {"cxl": 0.5})
    pm.add_region("a", 8, 4096, {"cxl": 1.0})
    with pytest.raises(ValueError, match="duplicate region"):
        pm.add_region("a", 8, 4096, {"cxl": 1.0})


def test_hot_set_pattern_validation():
    with pytest.raises(ValueError, match="hot_fraction"):
        HotSetPattern(hot_fraction=0.0)
    with pytest.raises(ValueError, match="hot_weight"):
        HotSetPattern(hot_weight=1.5)


def test_access_weights_sum_to_one_and_drift_is_circular():
    pat = HotSetPattern(hot_fraction=0.25, hot_weight=0.8, drift_pages=60.0)
    pm = _pagemap(n_pages=64, pattern=pat)
    region = pm.regions["app"]
    w = region.access_weights()
    assert w.sum() == pytest.approx(1.0)
    hot = np.flatnonzero(w > w.min())
    assert len(hot) == 16 and set(hot) == set(range(16))
    region.record_window(100.0, decay=0.5)  # drifts by 60
    w2 = region.access_weights()
    hot2 = set(np.flatnonzero(w2 > w2.min()))
    assert hot2 == {(60 + i) % 64 for i in range(16)}  # wrapped


def test_hotness_decays_and_tracks_throughput():
    pm = _pagemap(pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.8))
    region = pm.regions["app"]
    pm.record_window("app", 1000.0)
    h1 = region.hotness.sum()
    assert h1 == pytest.approx(1000.0)
    pm.record_window("app", 0.0)  # idle window: pure decay
    assert region.hotness.sum() == pytest.approx(500.0)


def test_tier_fractions_follow_moves():
    pm = _pagemap(n_pages=10, placement={"ddr": 0.5, "cxl": 0.5},
                  pattern=HotSetPattern(hot_fraction=1.0))  # uniform
    assert pm.fast_fraction("app") == pytest.approx(0.5)
    pm.move("app", 9, 0)
    assert pm.fast_fraction("app") == pytest.approx(0.6)
    assert pm.fast_pages_used() == 6
    assert pm.occupancy() == {"ddr": 6, "cxl": 4}


# -- MigrationEngine ----------------------------------------------------------


def test_engine_flips_pages_only_when_copy_traffic_completes():
    pm = _pagemap(n_pages=8)
    eng = MigrationEngine({1: 4})  # 4 MIGRATE reqs per page on tier 1
    jobs = [MigrationJob("app", p, src=1, dst=0) for p in (0, 1)]
    assert eng.enqueue(jobs) == 2
    assert eng.enqueue(jobs) == 0  # dedup: already queued
    assert eng.pending_reqs(1) == 8
    assert eng.on_completions(1, 3, pm) == (0, 0)  # not yet paid
    assert pm.regions["app"].tier[0] == 1
    assert eng.on_completions(1, 1, pm) == (1, 0)  # page 0 flips, FIFO
    assert pm.regions["app"].tier[0] == 0 and pm.regions["app"].tier[1] == 1
    assert eng.on_completions(1, 10, pm) == (1, 0)  # page 1 + surplus credit
    assert eng.pending_reqs(1) == 0 and eng.backlog_pages() == 0
    assert eng.migrated_bytes == 2 * 4096
    # demotions count separately
    eng.enqueue([MigrationJob("app", 0, src=0, dst=1)])
    assert eng.on_completions(1, 4, pm) == (0, 1)
    assert eng.pages_promoted == 2 and eng.pages_demoted == 1


def test_engine_rejects_unknown_traffic_tier():
    eng = MigrationEngine({1: 4})
    with pytest.raises(KeyError, match="slow tier code 2"):
        eng.enqueue([MigrationJob("app", 0, src=2, dst=0)])


def test_migration_job_traffic_tier_is_the_slow_side():
    assert MigrationJob("a", 0, src=2, dst=0).traffic_tier == 2  # promotion
    assert MigrationJob("a", 0, src=0, dst=1).traffic_tier == 1  # demotion
    assert MigrationJob("a", 0, src=2, dst=0).is_promotion


# -- policies -----------------------------------------------------------------


def _ctx(engine, names=("ddr", "cxl"), decisions=None, budgets=None):
    return PolicyContext(window=1, tier_names=names, engine=engine,
                         decisions=decisions, budgets=budgets)


def test_unknown_policy_is_a_loud_error():
    with pytest.raises(ValueError, match="registered policies"):
        make_policy("nope")


def test_static_policy_never_migrates():
    pm = _pagemap()
    pm.record_window("app", 1000.0)
    assert make_policy("static").decide(pm, _ctx(MigrationEngine({1: 4}))) == []


def test_hotness_lru_promotes_hottest_within_capacity():
    pm = _pagemap(n_pages=64, fast_cap=4,
                  pattern=HotSetPattern(hot_fraction=0.125, hot_weight=0.9))
    pm.record_window("app", 1000.0)
    eng = MigrationEngine({1: 4})
    jobs = make_policy("hotness_lru", promote_per_window=32).decide(
        pm, _ctx(eng))
    assert len(jobs) == 4  # fast capacity bounds promotion
    assert all(j.is_promotion for j in jobs)
    hot_pages = set(np.argsort(pm.regions["app"].hotness)[-8:])
    assert {j.page for j in jobs} <= hot_pages


def test_hotness_lru_demotes_coldest_over_watermark():
    pm = _pagemap(n_pages=32, fast_cap=8,
                  placement={"ddr": 0.5, "cxl": 0.5},
                  pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.9))
    # 16 fast pages against an 8-page budget: well over the high watermark.
    pm.record_window("app", 1000.0)
    eng = MigrationEngine({1: 4})
    policy = make_policy("hotness_lru", promote_per_window=0,
                         high_watermark=0.9, low_watermark=0.75)
    jobs = policy.decide(pm, _ctx(eng))
    demotions = [j for j in jobs if not j.is_promotion]
    assert demotions and all(j.dst == 1 for j in demotions)
    region = pm.regions["app"]
    coldest = region.hotness[region.pages_on(0)].min()
    assert any(region.hotness[j.page] == coldest for j in demotions)


def test_demotion_projects_in_flight_copies_no_overshoot():
    """Regression: while demotion copies are pending, the watermark logic
    must not re-demote for the same occupancy gap every window (it used to
    enqueue the gap repeatedly and land far below the low watermark)."""
    pm = PageMap(("ddr", "cxl"), fast_capacity_pages=100)
    pm.add_region("app", 200, 4096, {"ddr": 0.5, "cxl": 0.5},
                  HotSetPattern(hot_fraction=1.0))
    pm.record_window("app", 1000.0)
    eng = MigrationEngine({1: 10})  # copies span several windows
    policy = make_policy("hotness_lru", promote_per_window=0,
                         high_watermark=0.95, low_watermark=0.85)
    total = 0
    for _ in range(4):
        jobs = policy.decide(pm, _ctx(eng))
        eng.enqueue(jobs)
        total += len(jobs)
    assert total == 15  # one batch for the 100->85 gap, not 4x
    eng.on_completions(1, 10_000, pm)
    assert pm.fast_pages_used() == 85  # lands on the low watermark


def test_pagemap_rounding_never_truncates_counts():
    """Regression: per-tier int(round()) counts could sum past n_pages and
    silently truncate the last run; cumulative boundaries always assign
    exactly n_pages."""
    pm = PageMap(("ddr", "cxl"), 16)
    r = pm.add_region("a", 15, 4096, {"ddr": 0.5, "cxl": 0.5})
    assert r.resident_pages(0) + r.resident_pages(1) == 15
    assert abs(r.resident_pages(0) - 7.5) <= 0.5
    pm3 = PageMap(("ddr", "cxl", "cxl_sw"), 16)
    r3 = pm3.add_region("a", 2, 4096,
                        {"ddr": 0.5, "cxl": 0.25, "cxl_sw": 0.25})
    assert sum(r3.resident_pages(c) for c in range(3)) == 2
    assert r3.resident_pages(0) == 1  # half the region really stays fast


def test_miku_coordinated_defers_on_zero_budget_and_restriction():
    pm = _pagemap(n_pages=64, fast_cap=32)
    pm.record_window("app", 1000.0)
    eng = MigrationEngine({1: 16})
    policy = make_policy("miku_coordinated", promote_per_window=8)

    ctx = _ctx(eng, budgets={"cxl": 0})
    assert policy.decide(pm, ctx) == [] and ctx.deferred == 8

    ctx = _ctx(eng, budgets={"cxl": 2})  # 2 * jobs_per_budget_unit allowed
    assert len(policy.decide(pm, ctx)) == 8 and ctx.deferred == 0

    restricted = TierDecisions(
        tiers=("cxl",),
        decisions=(Decision(max_concurrency=1, rate_factor=0.5,
                            phase=Phase.RESTRICTED),),
    )
    ctx = _ctx(eng, decisions=restricted)  # no budgets: coarse fallback
    assert policy.decide(pm, ctx) == [] and ctx.deferred == 8


def test_miku_ladder_migration_budget_follows_state():
    ctl = default_miku(P)
    unit = ctl.units[0]
    cap = unit.config.class_caps[OpClass.MIGRATE]
    assert unit.migration_budget() == cap  # unrestricted: the class cap
    unit._demote_fully()
    assert unit.migration_budget() == min(cap, unit.config.levels[0])
    unit._rate = 0.5  # fine-grained rate control: stand down
    assert unit.migration_budget() == 0
    assert ctl.migration_budgets() == {unit.tier: 0}


# -- DES integration ----------------------------------------------------------


def _spec(policy="hotness_lru", **kw):
    defaults = dict(
        regions=(RegionSpec(
            workload="app", n_pages=256, placement={"cxl": 1.0},
            pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.9),
        ),),
        policy=policy,
        fast_capacity_pages=128,
    )
    defaults.update(kw)
    return TieringSpec(**defaults)


def _app(n_cores=8):
    return WorkloadSpec(name="app", op=OpClass.LOAD, tier="cxl",
                        n_cores=n_cores)


def test_no_hook_means_no_migration_workloads_and_no_summary():
    sim = TieredMemorySim(P, [_app()], seed=0)
    assert [w.name for w in sim.workloads] == ["app"]
    assert sim.run(30_000.0).tiering is None


def test_hook_tracks_unknown_workload_loudly():
    spec = _spec(regions=(RegionSpec(workload="ghost", n_pages=8,
                                     placement={"cxl": 1.0}),))
    with pytest.raises(ValueError, match="unknown workload"):
        TieredMemorySim(P, [_app()], seed=0, tiering=spec.build())


def test_migrate_traffic_is_real_station_traffic_and_placement_follows():
    sim = TieredMemorySim(P, [_app()], seed=0, tiering=_spec().build())
    assert [w.name for w in sim.workloads] == ["app", "mig-cxl"]
    res = sim.run(200_000.0)
    t = res.tiering
    assert t["pages_promoted"] > 0
    assert res.bandwidth("mig-cxl") > 0  # copies cost modeled bandwidth
    # MIGRATE retires are classed per tier in the uncore-style counters.
    assert res.tier_counters["cxl"].class_counts[OpClass.MIGRATE] > 0
    assert res.tier_counters["ddr"].class_counts[OpClass.MIGRATE] == 0
    # the app's live routing follows the PageMap: most accesses now fast
    assert t["fast_fraction"]["app"] > 0.8
    assert t["fast_pages_used"] <= 128  # capacity respected
    # ... and it beats the frozen placement
    static = TieredMemorySim(P, [_app()], seed=0,
                             tiering=_spec("static").build())
    res_static = static.run(200_000.0)
    assert res_static.tiering["pages_promoted"] == 0
    assert res.bandwidth("app") > 1.5 * res_static.bandwidth("app")


def test_hook_on_three_tier_platform_routes_with_cum_vectors():
    spec = _spec(regions=(RegionSpec(
        workload="app", n_pages=256,
        placement={"cxl": 0.5, "cxl_sw": 0.5},
        pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.9),
    ),))
    sim = TieredMemorySim(P3, [_app()], seed=0, tiering=spec.build())
    assert [w.name for w in sim.workloads] == ["app", "mig-cxl", "mig-cxl_sw"]
    res = sim.run(150_000.0)
    assert res.tiering["pages_promoted"] > 0
    assert res.tiering["fast_fraction"]["app"] > 0.5


def test_window_records_carry_migration_counters_without_controller():
    sim = TieredMemorySim(P, [_app()], seed=0, tiering=_spec().build(),
                          record_windows=True)
    res = sim.run(60_000.0)
    assert res.window_records, "hook-only telemetry must still be recorded"
    for rec in res.window_records:
        tiering = rec["tiering"]
        assert {"promoted", "demoted", "enqueued", "deferred",
                "backlog_pages", "migrated_bytes"} <= set(tiering)


# -- scenario acceptance + golden traces --------------------------------------


@pytest.fixture(scope="module")
def migrate_run():
    from repro.scenarios import run_scenario

    with open(os.path.join(DATA, "migrate_trace_goldens.json")) as f:
        golden = json.load(f)
    table = run_scenario("migrate_interference", golden["overrides"],
                         trace=True)
    return golden, table


def test_migrate_interference_headline(migrate_run):
    """Naive migration degrades DDR under load; MIKU coordination recovers
    it to within a few percent of the demand-only co-run."""
    _, table = migrate_run
    rows = {r["variant"]: r for r in table.rows}
    assert rows["naive"]["ddr_pct_of_demand_only"] < 90.0
    assert rows["miku"]["ddr_pct_of_demand_only"] > 97.0
    assert rows["miku"]["pages_promoted"] > 0  # coordination still migrates
    assert rows["miku"]["deferred_jobs"] > 0  # ... and actually deferred
    assert rows["naive"]["mig_gbps"] > rows["miku"]["mig_gbps"]


def test_migrate_interference_matches_golden_traces(migrate_run):
    golden, table = migrate_run
    jobs = table.traces[0]["jobs"]
    for variant, blob in golden["variants"].items():
        windows = jobs[blob["job"]]["windows"]
        assert len(windows) == len(blob["windows"])
        for got, want in zip(windows, blob["windows"]):
            gd = got.get("decision", {}).get("cxl")
            wd = want["decision"]
            if wd is None:
                assert gd is None, got["window"]
            else:
                assert gd["max_concurrency"] == wd["max_concurrency"]
                assert gd["rate_factor"] == wd["rate_factor"]
                assert gd["phase"] == wd["phase"]
            for k, v in want["tiering"].items():
                assert got["tiering"][k] == v, (variant, got["window"], k)
    for variant, want in golden["rows"].items():
        row = next(r for r in table.rows if r["variant"] == variant)
        assert row["ddr_pct_of_demand_only"] == pytest.approx(
            want["ddr_pct_of_demand_only"])
        assert row["pages_promoted"] == want["pages_promoted"]
        assert row["pages_demoted"] == want["pages_demoted"]
        assert row["deferred_jobs"] == want["deferred_jobs"]


def test_migrate_trace_windows_expose_migrate_class(migrate_run):
    """Acceptance: per-window migration counters present in the trace JSON,
    and MIGRATE visible in the per-tier class counts MIKU consumes."""
    _, table = migrate_run
    windows = table.traces[0]["jobs"][2]["windows"]
    assert any(w["tiers"]["cxl"]["class_counts"]["migrate"] > 0
               for w in windows)
    assert all("tiering" in w for w in windows)


def test_tiering_policies_scenario_hotness_beats_static():
    from repro.scenarios import run_scenario

    table = run_scenario("tiering_policies", {"platform": ("A",)})
    rows = {r["policy"]: r for r in table.rows}
    assert rows["hotness_lru"]["app_gbps"] > 1.3 * rows["static"]["app_gbps"]
    assert rows["hotness_lru"]["app_fast_fraction"] > 0.5
    assert rows["static"]["pages_promoted"] == 0
    assert rows["hotness_lru"]["migrated_gb"] > 0


# -- serving engine: PageMap-driven KV offload split ---------------------------


def test_kv_tier_bytes_follows_pagemap():
    from repro.serving.engine import ServingEngine

    pm = PageMap(("hbm", "host"), fast_capacity_pages=8)
    pm.add_region("eng", 10, 4096, {"hbm": 0.5, "host": 0.5},
                  HotSetPattern(hot_fraction=1.0))  # uniform access
    stub = SimpleNamespace(kv_pagemap=pm,
                           cfg=SimpleNamespace(name="eng", placement="host"),
                           n_active=4)
    fast, slow = ServingEngine.kv_tier_bytes(stub, 1000)
    assert fast == 500 and slow == 500
    pm.move("eng", 9, 0)  # promote one KV page
    fast, slow = ServingEngine.kv_tier_bytes(stub, 1000)
    assert fast == 600 and slow == 400
    # without a pagemap the static placement decides, bit-for-bit
    stub_static = SimpleNamespace(
        kv_pagemap=None, cfg=SimpleNamespace(name="eng", placement="host"),
        n_active=4)
    assert ServingEngine.kv_tier_bytes(stub_static, 1000) == (0, 1000)
    stub_static.cfg.placement = "device"
    assert ServingEngine.kv_tier_bytes(stub_static, 1000) == (1000, 0)


# -- MIGRATE class plumbing ----------------------------------------------------


def test_migrate_class_excluded_from_demand_grids():
    assert OpClass.MIGRATE not in DEMAND_CLASSES
    from repro.scenarios import get

    for name in ("fig3_bandwidth", "fig5_corun"):
        assert OpClass.MIGRATE not in get(name).axis("op").default

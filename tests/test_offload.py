"""Host offloader (real transfers) + transfer-queue timing model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.offload import HostOffloader, TransferQueue
from repro.core.tiers import TieredLayout


def test_offload_roundtrip_real_arrays():
    off = HostOffloader()
    tree = {"a": jnp.arange(64, dtype=jnp.float32),
            "b": jnp.ones((8, 8), jnp.bfloat16)}
    h = off.to_host(tree)
    d = off.to_device(h)
    off.block(d)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(d)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    if off.supported:
        kinds = {s.memory_kind for s in
                 [x.sharding for x in jax.tree.leaves(h)]}
        assert kinds == {"pinned_host"}


def test_transfer_queue_stream_duration_is_bandwidth_bound():
    q = TransferQueue()
    total = 16 << 20  # 16 MiB
    done = q.submit_slow_stream(total, 64)
    expected = total / q.slow.bandwidth_gbps
    assert done == pytest.approx(expected, rel=0.05)


def test_cap_bounds_backlog_without_slowing_stream():
    from repro.core.controller import Decision, Phase

    q1 = TransferQueue()
    d1 = q1.submit_slow_stream(16 << 20, 64)
    backlog_uncapped = q1.slow_backlog()

    q2 = TransferQueue()
    q2._decision = Decision(max_concurrency=4, rate_factor=1.0,
                            phase=Phase.RESTRICTED)
    d2 = q2.submit_slow_stream(16 << 20, 64)
    assert q2.slow_backlog() == 0
    assert backlog_uncapped > 32
    # work conservation: the capped stream is not slower
    assert d2 == pytest.approx(d1, rel=0.01)


def test_unknown_slow_tier_is_a_loud_error():
    """Regression: decision_for/slow_inflight/slow_backlog/submit used to
    fall back silently (or KeyError) on unknown link names; they now raise
    UnknownTierError naming the queue's links, like the DES does."""
    from repro.core.device_model import UnknownTierError

    q = TransferQueue()
    with pytest.raises(UnknownTierError, match="slow"):
        q.decision_for("warp_drive")
    with pytest.raises(UnknownTierError):
        q.slow_inflight("warp_drive")
    with pytest.raises(UnknownTierError):
        q.slow_backlog("warp_drive")
    with pytest.raises(UnknownTierError):
        q.submit_slow_stream(1 << 20, 4, tier="warp_drive")
    # the valid names still work, including the tier=None backlog sum
    q.submit_slow_stream(1 << 20, 4)
    assert q.slow_backlog() >= 0
    assert q.decision_for("slow") is q.decision


def test_fast_penalty_rises_with_backlog():
    q = TransferQueue()
    assert q.fast_penalty() == 1.0
    q.submit_slow_stream(16 << 20, 64)
    assert q.fast_penalty() > 1.2


def test_tiered_layout_pages():
    lay = TieredLayout(total_tokens=10_000, hot_tokens=2_000,
                       page_tokens=1024)
    assert lay.cold_tokens == 8_000
    assert lay.n_cold_pages == 8
    assert lay.page_slice(0) == slice(0, 1024)
    assert lay.page_slice(7).stop == 8000

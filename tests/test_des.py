"""DES behaviour tests — the paper's characterization claims (§4)."""

import pytest

from repro.core.des import run_bw_test, run_corun, run_lat_test
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku

P = platform_a()


def test_standalone_bandwidth_hits_device_peak():
    for op in OpClass:
        r = run_bw_test(P, op=op, tier="ddr", n_threads=16, sim_ns=80_000)
        peak = P.ddr.peak_bandwidth_gbps(op)
        assert r.bandwidth(f"bw-ddr-{op.value}") > 0.95 * peak


def test_cxl_peak_is_single_dimm_class():
    """Paper §4.1: CXL peak ~ one DDR DIMM despite 4-8x capacity."""
    r = run_bw_test(P, op=OpClass.LOAD, tier="cxl", n_threads=16,
                    sim_ns=80_000)
    bw = r.bandwidth("bw-cxl-load")
    assert bw < 0.25 * P.ddr.peak_bandwidth_gbps(OpClass.LOAD)
    assert bw > 0.9 * P.cxl.peak_bandwidth_gbps(OpClass.LOAD)


def test_unloaded_latency_matches_model():
    r = run_lat_test(P, op=OpClass.LOAD, tier="ddr")
    lat = r.stats["lat-ddr-load"].mean_latency_ns()
    assert lat == pytest.approx(P.ddr.unloaded_latency_ns(OpClass.LOAD),
                                rel=0.05)
    r = run_lat_test(P, op=OpClass.LOAD, tier="cxl")
    lat = r.stats["lat-cxl-load"].mean_latency_ns()
    assert lat == pytest.approx(P.cxl.unloaded_latency_ns(OpClass.LOAD),
                                rel=0.05)


def test_corun_collapse_in_paper_band():
    """Paper Fig. 5: DDR loses 74-89% under co-run; CXL barely impacted."""
    for op in (OpClass.LOAD, OpClass.NT_STORE):
        alone = run_bw_test(P, op=op, tier="ddr", n_threads=16,
                            sim_ns=80_000).bandwidth(f"bw-ddr-{op.value}")
        both = run_corun(P, op=op, n_threads=16, sim_ns=150_000)
        loss = 1 - both.bandwidth("ddr") / alone
        assert 0.6 < loss < 0.95, f"{op}: loss {loss}"
        cxl_alone = run_bw_test(P, op=op, tier="cxl", n_threads=16,
                                sim_ns=80_000).bandwidth(f"bw-cxl-{op.value}")
        assert both.bandwidth("cxl") > 0.9 * cxl_alone


def test_cxl_tor_latency_blows_up_under_load():
    """Paper §4.2: loaded CXL service time ~8-10x its unloaded latency."""
    r = run_bw_test(P, op=OpClass.LOAD, tier="cxl", n_threads=16,
                    sim_ns=80_000)
    loaded = r.tier_counters["cxl"].mean_service_time
    unloaded = P.cxl.unloaded_latency_ns(OpClass.LOAD)
    assert loaded > 5 * unloaded


def test_miku_recovers_fast_tier():
    """Paper Fig. 10: MIKU brings DDR near optimal, keeps CXL high."""
    op = OpClass.STORE
    alone = run_bw_test(P, op=op, tier="ddr", n_threads=16,
                        sim_ns=80_000).bandwidth(f"bw-ddr-{op.value}")
    cxl_alone = run_bw_test(P, op=op, tier="cxl", n_threads=16,
                            sim_ns=80_000).bandwidth(f"bw-cxl-{op.value}")
    miku = run_corun(P, op=op, n_threads=16, sim_ns=300_000,
                     controller=default_miku(P))
    assert miku.bandwidth("ddr") > 0.9 * alone
    assert miku.bandwidth("cxl") > 0.7 * cxl_alone


def test_conservation_completed_bytes_consistent():
    r = run_bw_test(P, op=OpClass.LOAD, tier="ddr", n_threads=4,
                    sim_ns=50_000)
    st = r.stats["bw-ddr-load"]
    assert st.bytes == st.completed * 256  # granularity 4 x 64B

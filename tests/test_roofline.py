"""HLO cost parser: trip-count scaling correctness on known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_costs import parse_hlo_costs


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    txt = _compiled_text(lambda x, y: x @ y, a, b)
    c = parse_hlo_costs(txt)
    assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_trip_count_scaling():
    """A scan of N matmuls must cost ~N x one matmul (cost_analysis counts
    the body once; the parser must not)."""
    n, d = 8, 64
    ws = jnp.zeros((n, d, d), jnp.float32)
    x = jnp.zeros((16, d), jnp.float32)

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c.sum()

    c1 = parse_hlo_costs(_compiled_text(f, ws, x))
    one_matmul = 2 * 16 * d * d
    assert c1.flops == pytest.approx(n * one_matmul, rel=0.15)


def test_nested_scan_scaling():
    n_out, n_in, d = 4, 3, 32
    ws = jnp.zeros((n_out, n_in, d, d), jnp.float32)
    x = jnp.zeros((8, d), jnp.float32)

    def f(ws, x):
        def outer(c, w_stack):
            def inner(c2, w):
                return jnp.tanh(c2 @ w), None
            c3, _ = jax.lax.scan(inner, c, w_stack)
            return c3, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c.sum()

    c1 = parse_hlo_costs(_compiled_text(f, ws, x))
    assert c1.flops == pytest.approx(n_out * n_in * 2 * 8 * d * d, rel=0.2)


def test_collective_detection_on_sharded_program():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")

"""Batched sweep lane: ladder equivalence, exact fast path, fluid tolerance.

Three layers of cross-lane guarantees, strongest first:

1. The vectorized MIKU ladder is *decision-identical* to per-cell
   ``SlowTierMiku`` ensembles on arbitrary counter traces (same state
   machine, different arithmetic substrate).
2. Single-workload cells (bw-test / lat-test shapes) are *bit-identical*
   on completed counts, bytes and bandwidth, and ≤1e-9 relative on
   occupancy/latency integrals (float-summation order is the only
   difference).
3. Co-run cells are fluid approximations: bandwidths within pinned
   tolerances on the two equivalence scenarios (fig5-style co-run grid and
   ``corun3_pertier``), with the fast-tier error much tighter than the
   throttled-slow-tier error.  Tolerances were measured on the scalar
   baselines and pinned with ~2x margin (see docs/decision-laws.md).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.controller import VectorMikuLadder
from repro.core.device_model import PLATFORMS, platform_a
from repro.core.littles_law import OpClass, TierCounters
from repro.memsim.batched import can_batch, partition_jobs
from repro.memsim.batched.exact import exact_regime
from repro.memsim.batched.lane import run_sweep_batched
from repro.memsim.calibration import default_miku
from repro.memsim.sweep import SimJob, run_sweep
from repro.memsim.workloads import bw_test, lat_test

_OPS = tuple(OpClass)


def _counters(rng, scale=1.0) -> TierCounters:
    tc = TierCounters()
    tc.inserts = int(rng.integers(0, 400) * scale)
    tc.occupancy_time = tc.inserts * float(rng.uniform(100.0, 3000.0))
    if tc.inserts:
        split = rng.multinomial(tc.inserts, [0.5, 0.3, 0.15, 0.05])
        tc.class_counts = {op: int(n) for op, n in zip(_OPS, split)}
    return tc


def _cls_array(tc: TierCounters) -> np.ndarray:
    return np.asarray([tc.class_counts.get(op, 0) for op in _OPS], float)


# ---------------------------------------------------------------------------
# 1. Vectorized ladder == scalar ladder, decision for decision.
# ---------------------------------------------------------------------------


def test_vector_ladder_matches_scalar_ensembles():
    rng = np.random.default_rng(7)
    platform = PLATFORMS["A-switch"]
    n_cells, n_units, n_windows = 5, 2, 60
    scalar_units = []
    for _ in range(n_cells):
        ctl = default_miku(platform, 4)
        ctl._ensure_units(n_units, ["cxl", "cxl_sw"])
        scalar_units.append(ctl.units[:n_units])
    vec = VectorMikuLadder.from_units(scalar_units)

    for w in range(n_windows):
        # Mix regimes: calm, backlogged, idle-fast, and starved windows.
        fast = [_counters(rng, scale=rng.choice([0.0, 0.2, 1.0]))
                for _ in range(n_cells)]
        slows = [[_counters(rng, scale=rng.choice([0.0, 1.0, 3.0]))
                  for _ in range(n_units)] for _ in range(n_cells)]
        out = vec.window(
            np.asarray([f.inserts for f in fast], float),
            np.asarray([f.occupancy_time for f in fast]),
            np.stack([_cls_array(f) for f in fast]),
            np.asarray([[s.inserts for s in row] for row in slows], float),
            np.asarray([[s.occupancy_time for s in row] for row in slows]),
            np.stack([np.stack([_cls_array(s) for s in row])
                      for row in slows]),
        )
        for ci in range(n_cells):
            for ui in range(n_units):
                d = scalar_units[ci][ui].window(fast[ci], slows[ci][ui])
                cap = np.inf if d.max_concurrency is None \
                    else d.max_concurrency
                assert out["restricted"][ci, ui] == d.restricted, (w, ci, ui)
                assert out["cap"][ci, ui] == cap, (w, ci, ui)
                assert out["rate"][ci, ui] == pytest.approx(d.rate_factor)
                est = d.estimate
                assert out["valid"][ci, ui] == est.valid
                assert out["backlogged"][ci, ui] == est.backlogged
                assert out["t_slow_raw"][ci, ui] == pytest.approx(
                    est.t_slow_raw, abs=1e-9)
                assert out["threshold"][ci, ui] == pytest.approx(
                    est.threshold)


# ---------------------------------------------------------------------------
# 2. Exact fast path: bit-identical single-workload cells.
# ---------------------------------------------------------------------------


def _exact_jobs():
    p = platform_a()
    jobs = []
    for op in _OPS[:3]:
        for tier in ("ddr", "cxl"):
            jobs.append(SimJob(platform=p, workloads=[bw_test(tier, op, 16)],
                               sim_ns=120_000.0))
    jobs.append(SimJob(platform=p, workloads=[bw_test("ddr", OpClass.LOAD, 1)],
                       sim_ns=120_000.0))
    jobs.append(SimJob(platform=p,
                       workloads=[lat_test("ddr", OpClass.LOAD, 1)],
                       sim_ns=200_000.0, granularity=1))
    jobs.append(SimJob(platform=p,
                       workloads=[lat_test("cxl", OpClass.LOAD, 8)],
                       sim_ns=200_000.0, granularity=1))
    return jobs


def test_exact_path_bit_identical_to_scalar():
    jobs = _exact_jobs()
    plans, fallbacks = partition_jobs(jobs)
    assert not fallbacks
    regimes = [exact_regime(p) for p in plans]
    assert all(r in ("noqueue", "saturated") for r in regimes), regimes
    scalar = run_sweep(jobs)
    batched = run_sweep_batched(jobs)
    for job, s, b in zip(jobs, scalar, batched):
        name = job.workloads[0].name
        ss, bs = s.stats[name], b.stats[name]
        assert bs.completed == ss.completed
        assert bs.bytes == ss.bytes  # bit-identical bandwidth
        assert b.bandwidth(name) == s.bandwidth(name)
        assert bs.timeline == ss.timeline
        assert b.tor_inserts == s.tor_inserts
        assert b.tor_peak == s.tor_peak
        assert b.tor_occupancy_integral == pytest.approx(
            s.tor_occupancy_integral, rel=1e-9)
        assert bs.latency_sum == pytest.approx(ss.latency_sum, rel=1e-9)
        tier = job.workloads[0].tier
        assert b.tier_counters[tier].inserts == s.tier_counters[tier].inserts
        assert b.tier_counters[tier].occupancy_time == pytest.approx(
            s.tier_counters[tier].occupancy_time, rel=1e-9)


def test_middle_regime_falls_to_fluid_and_stays_close():
    # 1 thread on CXL: outstanding (40) sits between the device's 28 slots
    # and the saturated-cohort bound — no closed form, fluid instead.
    p = platform_a()
    job = SimJob(platform=p, workloads=[bw_test("cxl", OpClass.LOAD, 1)],
                 sim_ns=120_000.0)
    (plan,), _ = partition_jobs([job])
    assert exact_regime(plan) is None
    (s,), (b,) = run_sweep([job]), run_sweep_batched([job])
    name = job.workloads[0].name
    assert b.bandwidth(name) == pytest.approx(s.bandwidth(name), rel=0.02)


# ---------------------------------------------------------------------------
# 3. Fluid tolerance on co-run cells (the unfair-queuing collapse + MIKU).
# ---------------------------------------------------------------------------


def _corun_job(platform, op, miku, sim_ns=300_000.0, threads=16):
    wls = [bw_test("ddr", op, threads, name="ddr", miku_managed=False),
           bw_test("cxl", op, threads, name="cxl")]
    return SimJob(platform=platform, workloads=wls, sim_ns=sim_ns, miku=miku)


def test_corun_racing_equivalence():
    p = platform_a()
    jobs = [_corun_job(p, op, miku=False) for op in _OPS[:3]]
    scalar = run_sweep(jobs)
    batched = run_sweep_batched(jobs)
    for s, b in zip(scalar, batched):
        # Racing collapse: measured ≤2.2% across the full grid; pinned 5%.
        assert b.bandwidth("ddr") == pytest.approx(s.bandwidth("ddr"),
                                                   rel=0.05)
        assert b.bandwidth("cxl") == pytest.approx(s.bandwidth("cxl"),
                                                   rel=0.05)
        # The collapse mechanism itself: loaded slow-tier ToR residency.
        assert (b.tier_counters["cxl"].mean_service_time
                == pytest.approx(s.tier_counters["cxl"].mean_service_time,
                                 rel=0.1))


def test_corun_miku_equivalence():
    p = platform_a()
    jobs = [_corun_job(p, OpClass.LOAD, miku=True),
            _corun_job(p, OpClass.STORE, miku=True)]
    scalar = run_sweep(jobs)
    batched = run_sweep_batched(jobs)
    for s, b in zip(scalar, batched):
        # Fast-tier recovery: measured ≤0.7%; pinned 5%.  Throttled slow
        # tier: measured ≤4.2%; pinned 10%.
        assert b.bandwidth("ddr") == pytest.approx(s.bandwidth("ddr"),
                                                   rel=0.05)
        assert b.bandwidth("cxl") == pytest.approx(s.bandwidth("cxl"),
                                                   rel=0.10)
        rs = sum(1 for d in s.decisions if d.restricted)
        rb = sum(1 for d in b.decisions if d.restricted)
        assert len(b.decisions) == len(s.decisions)
        assert abs(rs - rb) <= 3


@pytest.mark.slow
def test_corun_sweep_grid_equivalence_full():
    from repro.scenarios import plan

    jobs = [j for _, _, js in plan("corun_sweep") for j in js]
    scalar = run_sweep(jobs)
    batched = run_sweep_batched(jobs)
    errs = []
    for s, b in zip(scalar, batched):
        for w in ("ddr", "cxl"):
            errs.append(abs(b.bandwidth(w) - s.bandwidth(w))
                        / max(s.bandwidth(w), 1e-9))
    # Full 96-cell grid: measured worst ~8%, mean ~0.7%; pinned 15% / 3%.
    assert max(errs) < 0.15
    assert sum(errs) / len(errs) < 0.03


def test_corun3_pertier_equivalence_one_cell():
    from repro.scenarios import run_scenario

    overrides = {"law": ("pertier",), "sim_ns": 300_000.0}
    ts = run_scenario("corun3_pertier", overrides)
    tb = run_scenario("corun3_pertier", overrides, lane="batched")
    assert tb.meta["lane"] == "batched"
    assert tb.meta["scalar_fallback_jobs"] == 0
    (rs,), (rb,) = ts.rows, tb.rows
    # The per-tier signature must survive the lane change: the switch tier
    # is capped harder than local CXL, and DDR recovers.
    assert rb["cxl_sw_mean_cap"] < rb["cxl_mean_cap"]
    assert rb["ddr_pct_of_opt"] == pytest.approx(rs["ddr_pct_of_opt"], abs=8)
    for col in ("cxl_mean_cap", "cxl_sw_mean_cap"):
        assert rb[col] == pytest.approx(rs[col], rel=0.25)
    for col in ("cxl_corun_gbps", "cxl_sw_corun_gbps"):
        assert rb[col] == pytest.approx(rs[col], rel=0.12)


@pytest.mark.slow
def test_corun3_pertier_equivalence_full_grid():
    from repro.scenarios import run_scenario

    ts = run_scenario("corun3_pertier", {})
    tb = run_scenario("corun3_pertier", {}, lane="batched")
    for rs, rb in zip(ts.rows, tb.rows):
        assert rb["law"] == rs["law"]
        assert rb["ddr_pct_of_opt"] == pytest.approx(rs["ddr_pct_of_opt"],
                                                     abs=8)
    by_law = {r["law"]: r for r in tb.rows}
    # Merged broadcasts one cap; per-tier throttles the switch tier harder.
    assert by_law["merged"]["cxl_mean_cap"] == pytest.approx(
        by_law["merged"]["cxl_sw_mean_cap"])
    assert by_law["pertier"]["cxl_sw_mean_cap"] \
        < by_law["pertier"]["cxl_mean_cap"]


# ---------------------------------------------------------------------------
# Edge cases: fallback routing, single-cell grids, mixed MIKU grids.
# ---------------------------------------------------------------------------


def test_lane_is_total_over_tiering_and_telemetry():
    # The lane no longer screens out tiering or record_windows jobs: every
    # SimJob passes the static screen and runs batched.
    p = platform_a()
    traced = SimJob(platform=p, workloads=[bw_test("cxl", OpClass.LOAD, 4)],
                    sim_ns=60_000.0, record_windows=True, miku=True)
    assert can_batch(traced) is None
    from repro.tiering import HotSetPattern, RegionSpec, TieringSpec

    spec = TieringSpec(
        regions=(RegionSpec(workload="cxl", n_pages=128,
                            placement={"cxl": 1.0},
                            pattern=HotSetPattern()),),
        policy="static",
    )
    tiering = SimJob(platform=p,
                     workloads=[bw_test("cxl", OpClass.LOAD, 4, name="cxl")],
                     sim_ns=60_000.0, tiering=spec)
    assert can_batch(tiering) is None
    clean = SimJob(platform=p, workloads=[bw_test("cxl", OpClass.LOAD, 4)],
                   sim_ns=60_000.0)
    assert can_batch(clean) is None

    jobs = [clean, traced, tiering]
    plans, fallbacks = partition_jobs(jobs)
    assert not fallbacks
    assert all(pl is not None for pl in plans)
    batched = run_sweep_batched(jobs, partition=(plans, fallbacks))
    assert not fallbacks  # no dynamic stacking failures either
    scalar = run_sweep(jobs)
    for i in (1, 2):
        name = jobs[i].workloads[0].name
        assert batched[i].bandwidth(name) == pytest.approx(
            scalar[i].bandwidth(name), rel=0.05)
    assert batched[1].window_records  # vectorized telemetry
    assert batched[2].tiering is not None  # vectorized tiering summary


def test_dynamic_stacking_failure_is_recorded_and_runs_scalar():
    # A tiering policy outside the vectorized registry plans fine (the
    # scalar hook can run it) but can't stack — the group must fall back
    # AND the partition's fallback list must say so.
    from repro.tiering import HotSetPattern, RegionSpec, TieringSpec
    from repro.tiering.policies import POLICIES

    class FrozenPolicy:  # deliberately outside the vectorizable hierarchy
        name = "frozen_test_policy"

        def decide(self, pagemap, ctx):
            del pagemap, ctx
            return []

    POLICIES[FrozenPolicy.name] = FrozenPolicy
    try:
        p = platform_a()
        spec = TieringSpec(
            regions=(RegionSpec(workload="cxl", n_pages=128,
                                placement={"cxl": 1.0},
                                pattern=HotSetPattern()),),
            policy=FrozenPolicy.name,
        )
        job = SimJob(
            platform=p,
            workloads=[bw_test("cxl", OpClass.LOAD, 4, name="cxl")],
            sim_ns=60_000.0, tiering=spec,
        )
        plans, fallbacks = partition_jobs([job])
        assert not fallbacks  # the plan itself is fine
        (b,) = run_sweep_batched([job], partition=(plans, fallbacks))
        assert [i for i, _ in fallbacks] == [0]
        assert "frozen_test_policy" in fallbacks[0][1]
        (s,) = run_sweep([job])
        # The fallback reran the scalar DES — identical, not approximate.
        assert b.bandwidth("cxl") == s.bandwidth("cxl")
        assert b.tiering == s.tiering
    finally:
        POLICIES.pop(FrozenPolicy.name, None)


def test_zero_fallbacks_surface_in_result_table_meta():
    from repro.scenarios import run_scenario

    # migrate_interference builds tiering jobs: the now-total batched lane
    # runs all of them stacked and reports a clean split.
    table = run_scenario(
        "migrate_interference", {"sim_ns": 60_000.0}, lane="batched"
    )
    assert table.meta["lane"] == "batched"
    assert table.meta["scalar_fallback_jobs"] == 0
    assert table.meta["batched_jobs"] == 3
    assert table.meta["fallback_reasons"] == []
    assert table.meta["fallback_reason_counts"] == {}


def test_single_cell_grid_batched():
    from repro.scenarios import run_scenario

    overrides = {"platform": ("A",), "op": (OpClass.LOAD,), "threads": (16,),
                 "miku": (True,), "mlp": (160,), "sim_ns": 150_000.0}
    table = run_scenario("corun_sweep", overrides, lane="batched")
    assert len(table.rows) == 1
    assert table.meta["batched_jobs"] == 1
    assert table.rows[0]["restricted_windows"] > 0


def test_mixed_miku_grid_batched():
    from repro.scenarios import run_scenario

    overrides = {"platform": ("A",), "op": (OpClass.LOAD,), "threads": (16,),
                 "miku": (False, True), "mlp": (160,), "sim_ns": 150_000.0}
    table = run_scenario("corun_sweep", overrides, lane="batched")
    off, on = table.rows
    assert off["restricted_windows"] == 0
    assert on["restricted_windows"] > 0
    assert on["ddr_gbps"] > 2.0 * off["ddr_gbps"]  # MIKU recovers DDR


def test_multistage_scenario_notes_scalar_lane(monkeypatch):
    from repro.scenarios import run_scenario

    table = run_scenario(
        "fig2_tiering", {"op": OpClass.LOAD}, lane="batched"
    )
    assert table.meta["lane"] == "scalar"
    assert "multi-stage" in table.meta["note"]
    # REPRO_SWEEP_LANE must not leak into run_cell bodies' internal
    # run_sweep calls: the rows must be the scalar lane's, bit for bit.
    monkeypatch.setenv("REPRO_SWEEP_LANE", "batched")
    enved = run_scenario("fig2_tiering", {"op": OpClass.LOAD})
    assert enved.meta["note"].startswith("multi-stage")
    assert enved.rows == table.rows


def test_tiny_tor_disqualifies_noqueue_regime():
    """tor_capacity < outstanding < slots: admissions stagger even though
    servers are idle — not the no-queue closed form (it would double-count;
    the cell must take the fluid path and stay close to the scalar DES)."""
    import dataclasses as dc

    p = dc.replace(platform_a(), tor_entries=64)  # 16 macro entries
    job = SimJob(platform=p,
                 workloads=[bw_test("ddr", OpClass.LOAD, 1, mlp=128)],
                 sim_ns=120_000.0)
    (plan,), _ = partition_jobs([job])
    assert exact_regime(plan) is None
    (s,), (b,) = run_sweep([job]), run_sweep_batched([job])
    name = job.workloads[0].name
    assert b.stats[name].completed == pytest.approx(
        s.stats[name].completed, rel=0.02)


def test_mixed_workload_counts_in_one_fluid_group():
    """A 1-workload middle-regime cell and a 2-workload co-run cell share
    one fluid window group: padded workload slots must stay inert (no NaN
    from the unused-station +inf fair shares)."""
    p = platform_a()
    single = SimJob(platform=p, workloads=[bw_test("cxl", OpClass.LOAD, 1)],
                    sim_ns=100_000.0)
    corun = _corun_job(p, OpClass.LOAD, miku=True, sim_ns=100_000.0)
    batched = run_sweep_batched([single, corun])
    scalar = run_sweep([single, corun])
    name = single.workloads[0].name
    assert batched[0].bandwidth(name) == pytest.approx(
        scalar[0].bandwidth(name), rel=0.03)
    assert batched[1].bandwidth("ddr") == pytest.approx(
        scalar[1].bandwidth("ddr"), rel=0.05)


def test_env_lane_is_reported_in_meta(monkeypatch):
    from repro.scenarios import run_scenario

    monkeypatch.setenv("REPRO_SWEEP_LANE", "batched")
    overrides = {"platform": ("A",), "op": (OpClass.LOAD,), "threads": (8,),
                 "miku": (False,), "mlp": (160,), "sim_ns": 60_000.0}
    table = run_scenario("corun_sweep", overrides)
    assert table.meta["lane"] == "batched"
    assert table.meta["batched_jobs"] == 1


# ---------------------------------------------------------------------------
# Solver backends.
# ---------------------------------------------------------------------------


def test_fused_window_solver_matches_numpy_loop(monkeypatch):
    """REPRO_BATCH_BACKEND=pallas routes the whole per-window relaxation
    through kernel.fused_window_solve (one jit dispatch per window); the
    results must match the numpy loop, and the loud scalar-loop fallback
    must NOT fire (warnings are errors here)."""
    pytest.importorskip("jax")
    import warnings

    p = platform_a()
    jobs = [_corun_job(p, op, miku=m, sim_ns=150_000.0)
            for op in _OPS[:2] for m in (False, True)]
    base = run_sweep_batched(jobs)
    monkeypatch.setenv("REPRO_BATCH_BACKEND", "pallas")
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        fused = run_sweep_batched(jobs)
    for s, b in zip(base, fused):
        for w in ("ddr", "cxl"):
            assert b.bandwidth(w) == pytest.approx(s.bandwidth(w), rel=1e-4)
        rs = sum(1 for d in s.decisions if d.restricted)
        rb = sum(1 for d in b.decisions if d.restricted)
        assert rs == rb


def test_pallas_backend_matches_numpy():
    jax = pytest.importorskip("jax")
    del jax
    from repro.memsim.batched import kernel

    rng = np.random.default_rng(3)
    C, W, S = 6, 3, 4
    A = rng.uniform(1, 16, (C, W))
    cap = rng.uniform(0.05, 3.0, (C, W))
    y_sta = rng.uniform(0.05, 2.0, (C, W))
    o_eff = rng.uniform(20, 640, (C, W))
    R_tor = rng.uniform(150, 2500, (C, W))
    tor = rng.uniform(64, 512, C)
    irq = np.full(C, 64.0)
    lam_np = kernel.global_lambda(A, cap, y_sta, o_eff, R_tor, tor, irq,
                                  force_backend="numpy")
    lam_pl = kernel.global_lambda(A, cap, y_sta, o_eff, R_tor, tor, irq,
                                  force_backend="pallas")
    finite = np.isfinite(lam_np)
    assert (np.isfinite(lam_pl) == finite).all()
    # f32 kernel vs f64 numpy: parity to f32 tolerance.
    assert lam_pl[finite] == pytest.approx(lam_np[finite], rel=2e-3)

"""Mamba2 SSD: chunked scan vs naive recurrence; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import ssd_scan_ref
from repro.models.ssm import (
    init_ssm_state,
    ssd_chunked,
    ssm_dims,
    ssm_forward,
    ssm_init,
    ssm_step,
)

KEY = jax.random.PRNGKey(0)


def test_ssd_chunked_matches_naive_recurrence():
    b, s, h, p, n = 2, 64, 4, 16, 8
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    bm = jax.random.normal(ks[1], (b, s, 1, n)) * 0.3
    cm = jax.random.normal(ks[2], (b, s, 1, n)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y, final = ssd_chunked(xs, bm, cm, dt, a, chunk=16)
    yref = jnp.moveaxis(
        ssd_scan_ref(
            jnp.moveaxis(xs, 2, 1), jnp.moveaxis(dt, 2, 1),
            jnp.stack([bm[:, :, 0], cm[:, :, 0]], 2), a,
        ), 1, 2,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4,
                               rtol=1e-3)


def test_ssd_chunk_size_invariance():
    b, s, h, p, n = 1, 64, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    bm = jax.random.normal(ks[1], (b, s, 1, n)) * 0.3
    cm = jax.random.normal(ks[2], (b, s, 1, n)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y8, f8 = ssd_chunked(xs, bm, cm, dt, a, chunk=8)
    y32, f32_ = ssd_chunked(xs, bm, cm, dt, a, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f8), np.asarray(f32_), atol=1e-4)


def test_ssm_decode_matches_forward():
    """Step-by-step recurrence equals the chunked full forward."""
    d = 64
    dims = ssm_dims(d, expand=2, head_dim=16, d_state=8, n_groups=1)
    params, _ = ssm_init(KEY, d, dims, jnp.float32)
    b, s = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(7), (b, s, d),
                          jnp.float32) * 0.3
    full = ssm_forward(params, x, dims, chunk=4)
    state = init_ssm_state(b, dims, jnp.float32)
    outs = []
    for t in range(s):
        y, state = ssm_step(params, x[:, t : t + 1], state, dims)
        outs.append(y)
    step_out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_out), np.asarray(full), atol=2e-3, rtol=2e-3
    )

"""MVA solver: cross-validation vs DES + monotonicity properties."""

import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.des import run_bw_test
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.core.mva import analyze

P = platform_a()


@pytest.mark.parametrize("op", list(OpClass))
def test_mva_matches_des_at_saturation(op):
    des = run_bw_test(P, op=op, tier="ddr", n_threads=16, sim_ns=80_000)
    mva = analyze(P, op, fast_threads=16, slow_threads=0)
    des_bw = des.bandwidth(f"bw-ddr-{op.value}")
    assert float(mva.bandwidth_fast_gbps) == pytest.approx(des_bw, rel=0.10)


def test_mva_slow_tier_residency_matches_des():
    des = run_bw_test(P, op=OpClass.LOAD, tier="cxl", n_threads=16,
                      sim_ns=100_000)
    mva = analyze(P, OpClass.LOAD, fast_threads=0, slow_threads=16)
    des_res = des.tier_counters["cxl"].mean_service_time
    assert float(mva.residency_slow) == pytest.approx(des_res, rel=0.15)


@given(n=st.integers(1, 32))
@settings(max_examples=20, deadline=None)
def test_mva_bandwidth_monotone_in_threads(n):
    a = analyze(P, OpClass.LOAD, fast_threads=n, slow_threads=0)
    b = analyze(P, OpClass.LOAD, fast_threads=n + 1, slow_threads=0)
    assert float(b.bandwidth_fast_gbps) >= float(a.bandwidth_fast_gbps) - 1e-3


@given(n=st.integers(1, 32))
@settings(max_examples=20, deadline=None)
def test_mva_residency_monotone_in_threads(n):
    a = analyze(P, OpClass.LOAD, fast_threads=0, slow_threads=n)
    b = analyze(P, OpClass.LOAD, fast_threads=0, slow_threads=n + 1)
    assert float(b.residency_slow) >= float(a.residency_slow) - 1e-3

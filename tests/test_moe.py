"""Sort-based MoE dispatch correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_apply, moe_init

KEY = jax.random.PRNGKey(0)


def dense_reference(params, x, top_k):
    """Dense per-token loop: the obviously-correct MoE semantics (no
    capacity drops: capacity_factor large)."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    xf = np.asarray(x.reshape(-1, d), np.float32)
    router = np.asarray(params["router"], np.float32)
    gates = jax.nn.softmax(jnp.asarray(xf @ router), axis=-1)
    gates = np.asarray(gates)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-gates[t])[:top_k]
        w = gates[t][top]
        w = w / w.sum()
        for wi, ei in zip(w, top):
            g = np.asarray(params["w_gate"][ei], np.float32)
            u = np.asarray(params["w_up"][ei], np.float32)
            dn = np.asarray(params["w_down"][ei], np.float32)
            h = (xf[t] @ g)
            h = h / (1 + np.exp(-h)) * (xf[t] @ u)  # silu(g)*u
            out[t] += wi * (h @ dn)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("top_k,e", [(1, 4), (2, 4), (4, 8)])
def test_moe_matches_dense_loop(top_k, e):
    d, f = 16, 32
    params, _ = moe_init(KEY, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d), jnp.float32)
    out, aux = moe_apply(params, x, top_k=top_k, capacity_factor=64.0)
    ref = dense_reference(params, x, top_k)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_not_crash():
    d, f, e = 16, 32, 4
    params, _ = moe_init(KEY, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, d), jnp.float32)
    out, _ = moe_apply(params, x, top_k=2, capacity_factor=0.25)
    assert out.shape == x.shape
    assert not jnp.isnan(out).any()


def test_moe_shared_expert_adds_dense_path():
    d, f, e = 16, 32, 4
    p1, _ = moe_init(KEY, d, f, e, jnp.float32, shared_expert_ff=32)
    assert "shared" in p1
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, d), jnp.float32)
    out, _ = moe_apply(p1, x, top_k=1, capacity_factor=8.0)
    assert out.shape == x.shape

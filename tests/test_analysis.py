"""repro.analysis: lint-rule units, twin parity, and sanitizer fault
injection.

The fault-injection tests are the core contract: each one corrupts a live
simulation's state through :meth:`DesSanitizer.add_mutation` and asserts
the *intended* check — and only it — fires (check order is part of the
sanitizer's API: the first check that can see a corruption names it).
"""

import ast
import subprocess
import sys
import time
from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.analysis import (
    DesSanitizer,
    InvariantViolation,
    QueueSanitizer,
    run_lint,
    sanitize_enabled,
)
from repro.analysis.lint import (
    compare_twin_surfaces,
    rule_counter_mutation,
    rule_deprecated_surface,
    rule_nondeterminism,
    rule_scenario_pickle_ast,
    twin_pairs,
)
from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.core.offload import TransferQueue
from repro.fabric import spine_leaf_platform
from repro.memsim.batched.lane import can_batch
from repro.memsim.sweep import SimJob, run_job
from repro.memsim.workloads import bw_test
from repro.scenarios import UnknownScenarioError, all_scenarios, get, \
    run_scenario
from repro.tiering import HotSetPattern, RegionSpec, TieringSpec

P = platform_a()
REPO = Path(__file__).resolve().parents[1]


# -- lint rule units ----------------------------------------------------------


def _findings(rule, src, rel):
    return rule(ast.parse(src), rel)


def test_counter_mutation_rule_fires_outside_substrate():
    src = "def f(tc):\n    tc.inserts += 1\n"
    found = _findings(rule_counter_mutation, src, "tiering/foo.py")
    assert [f.rule for f in found] == ["counter-mutation"]
    assert found[0].line == 2


def test_counter_mutation_rule_covers_assignment_and_subscript():
    src = (
        "def f(tc):\n"
        "    tc.occupancy_time = 0.0\n"
        "    tc.class_counts[op] = 3\n"
    )
    found = _findings(rule_counter_mutation, src, "core/des.py")
    assert len(found) == 2


def test_counter_mutation_rule_allows_substrate_and_materializers():
    src = "def f(tc):\n    tc.inserts += 1\n"
    assert _findings(rule_counter_mutation, src, "core/substrate.py") == []
    allowed = "def _materialize_counters(tc):\n    tc.inserts += 1\n"
    assert _findings(rule_counter_mutation, allowed, "core/des.py") == []


def test_nondeterminism_rule_fires_on_unseeded_sources():
    src = (
        "def f():\n"
        "    x = random.random()\n"
        "    t = time.time()\n"
        "    y = np.random.rand(3)\n"
        "    rng = np.random.default_rng()\n"
    )
    found = _findings(rule_nondeterminism, src, "core/foo.py")
    assert len(found) == 4
    assert all(f.rule == "nondeterminism" for f in found)


def test_nondeterminism_rule_allows_seeded_rng_and_non_sim_paths():
    ok = "def f(seed):\n    return np.random.default_rng(seed)\n"
    assert _findings(rule_nondeterminism, ok, "core/foo.py") == []
    bad = "def f():\n    return random.random()\n"
    # models/ is not a sim hot path: kernels may use jax PRNG conventions.
    assert _findings(rule_nondeterminism, bad, "models/foo.py") == []


def test_deprecated_surface_rule():
    src = "d = ctl.window(fast, slow)\n"
    found = _findings(rule_deprecated_surface, src, "memsim/foo.py")
    assert [f.rule for f in found] == ["deprecated-surface"]
    # The shim implementation module is the one allowed caller.
    assert _findings(rule_deprecated_surface, src, "core/controller.py") == []
    merged = "c = TierSetWindowedCounters(names, merged=True)\n"
    assert len(_findings(
        rule_deprecated_surface, merged, "memsim/foo.py")) == 1
    assert _findings(
        rule_deprecated_surface, merged, "core/substrate.py") == []


def test_scenario_pickle_ast_rule():
    src = "Scenario(name='x', build=lambda c: c)\n"
    found = _findings(rule_scenario_pickle_ast, src, "scenarios/foo.py")
    assert [f.rule for f in found] == ["scenario-pickle"]
    # Outside scenarios/ the rule does not apply.
    assert _findings(rule_scenario_pickle_ast, src, "core/foo.py") == []


def test_twin_parity_catches_injected_one_sided_knob():
    label, fields, consumed, extra, path, line = twin_pairs()[0]
    assert compare_twin_surfaces(
        label, fields, consumed, extra_allowed=extra, path=path, line=line
    ) == []
    # A knob added to the scalar config but never consumed by the vector
    # twin must fail analysis.
    found = compare_twin_surfaces(
        label, fields | {"new_knob"}, consumed,
        extra_allowed=extra, path=path, line=line,
    )
    assert len(found) == 1 and "new_knob" in found[0].message
    # ...and so must a vector-side read with no scalar field behind it.
    found = compare_twin_surfaces(
        label, fields, consumed | {"phantom"},
        extra_allowed=extra, path=path, line=line,
    )
    assert len(found) == 1 and "phantom" in found[0].message


def test_repo_lint_is_green():
    assert run_lint() == []


# -- sanitizer fault injection ------------------------------------------------


def _run_mutated(mutation, window=1, platform=None, tiering=None):
    sim = TieredMemorySim(
        platform or P, [bw_test("cxl", OpClass.LOAD, 8)], seed=0,
        sanitize=True, tiering=tiering,
    )
    sim._san.add_mutation(window, mutation)
    with pytest.raises(InvariantViolation) as ei:
        sim.run(60_000.0)
    return ei.value


def test_injected_retire_miscount_trips_conservation():
    def corrupt(s):
        s._stat_completed[0] += 3
    assert _run_mutated(corrupt).check == "conservation"


def test_injected_double_free_trips_free_list():
    def corrupt(s):
        s._r_free.extend([123456, 123456])
    err = _run_mutated(corrupt)
    assert err.check == "free-list"
    assert err.window == 1


def test_injected_negative_tokens_trip_token_bucket():
    def corrupt(s):
        s._tokens[0] = -1.0
    assert _run_mutated(corrupt).check == "token-bucket"


def test_injected_past_event_trips_event_order():
    def corrupt(s):
        s._push(s.now - 5_000.0, 3, 0)  # an _EV_TOKEN scheduled in the past
    assert _run_mutated(corrupt).check == "event-order"


def test_injected_counter_rollback_trips_counter_monotone():
    def corrupt(s):
        s._tc_ins[1] = 0
    # Window 2: the mark from window 1's pass is already set.
    assert _run_mutated(corrupt, window=2).check == "counter-monotone"


def test_injected_port_overflow_trips_entry_limit():
    def corrupt(s):
        st = s._link0
        s._hop_occ[st] = s._hop_limit[st] + 1
    err = _run_mutated(corrupt, platform=spine_leaf_platform())
    assert err.check == "entry-limit"
    assert err.station is not None


def test_injected_backpressure_cycle_trips_stall_cycle():
    def corrupt(s):
        u, v = s._link0, s._link0 + 1
        for st in (u, v):
            s._st_q[st].clear()
            s._st_busy[st] = 1
            s._hop_occ[st] = 1
            s._hop_stall[st].clear()
        # u's lone busy server waits on v and vice versa: a frozen cycle
        # no completion event can ever drain.
        s._hop_stall[u].append((1, v))
        s._hop_stall[v].append((2, u))
    err = _run_mutated(corrupt, platform=spine_leaf_platform())
    assert err.check == "stall-cycle"
    assert err.context["cycle"]


def test_injected_negative_credit_trips_migrate_debt():
    spec = TieringSpec(
        regions=(RegionSpec(
            workload="app", n_pages=256, placement={"cxl": 1.0},
            pattern=HotSetPattern(hot_fraction=0.25, hot_weight=0.9),
        ),),
        fast_capacity_pages=128,
    )
    sim = TieredMemorySim(
        P, [WorkloadSpec(name="app", op=OpClass.LOAD, tier="cxl", n_cores=8)],
        seed=0, sanitize=True, tiering=spec.build(),
    )
    sim._san.add_mutation(
        2, lambda s: s._tiering.engine._credit.__setitem__(1, -1)
    )
    with pytest.raises(InvariantViolation) as ei:
        sim.run(60_000.0)
    assert ei.value.check == "migrate-debt"


def test_phase_flip_without_schedule_is_structured():
    sim = TieredMemorySim(P, [bw_test("cxl", OpClass.LOAD, 8)], seed=0)
    with pytest.raises(InvariantViolation) as ei:
        sim._phase_flip(0)
    assert ei.value.check == "phase-schedule"


def test_record_mode_accumulates_and_completes():
    sim = TieredMemorySim(
        P, [bw_test("cxl", OpClass.LOAD, 8)], seed=0, sanitize="record"
    )
    sim._san.add_mutation(1, lambda s: s._tokens.__setitem__(0, -1.0))
    res = sim.run(60_000.0)
    assert res.sanitizer["mode"] == "record"
    checks = {v["check"] for v in res.sanitizer["violations"]}
    assert "token-bucket" in checks
    assert res.sanitizer["windows_checked"] >= 1


def test_counter_delta_hook_flags_negative_window_delta():
    san = DesSanitizer(2, mode="record")
    bad = SimpleNamespace(inserts=-1, occupancy_time=0.0)
    ok = SimpleNamespace(inserts=3, occupancy_time=1.0)
    san.check_counter_deltas(("ddr", "cxl"), (ok, bad))
    assert [v.check for v in san.violations] == ["counter-delta"]
    assert san.violations[0].station == "cxl"


def test_sanitizer_mode_validation():
    with pytest.raises(ValueError, match="unknown sanitizer mode"):
        DesSanitizer(2, mode="explode")


def test_sanitize_enabled_env_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert sanitize_enabled() is None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert sanitize_enabled() is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled() == "raise"
    monkeypatch.setenv("REPRO_SANITIZE", "record")
    assert sanitize_enabled() == "record"


# -- clean runs stay clean ----------------------------------------------------


def test_sanitized_run_is_bit_identical_and_clean():
    wl = [bw_test("ddr", OpClass.LOAD, 8), bw_test("cxl", OpClass.LOAD, 8)]
    plain = TieredMemorySim(P, wl, seed=0).run(200_000.0)
    sim = TieredMemorySim(P, wl, seed=0, sanitize=True)
    checked = sim.run(200_000.0)
    assert checked.sanitizer["violations"] == []
    assert checked.sanitizer["windows_checked"] >= 10
    assert checked.tor_inserts == plain.tor_inserts
    assert checked.tor_occupancy_integral == plain.tor_occupancy_integral
    for name, st in plain.stats.items():
        assert checked.stats[name] == st
    assert plain.sanitizer is None


def test_simjob_sanitize_plumbs_to_result():
    job = SimJob(P, [bw_test("cxl", OpClass.LOAD, 8)], sim_ns=60_000.0,
                 sanitize=True)
    res = run_job(job)
    assert res.sanitizer is not None
    assert res.sanitizer["violations"] == []
    assert sum(res.sanitizer["retired"]) > 0


def test_can_batch_screens_sanitized_jobs(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    job = SimJob(P, [bw_test("cxl", OpClass.LOAD, 8)], sim_ns=60_000.0)
    assert can_batch(job) is None
    assert can_batch(
        SimJob(P, [bw_test("cxl", OpClass.LOAD, 8)], sim_ns=60_000.0,
               sanitize=True)
    ) == "sanitize"
    # sanitize=None defers to the env; an explicit False opts back in.
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert can_batch(job) == "sanitize"
    assert can_batch(
        SimJob(P, [bw_test("cxl", OpClass.LOAD, 8)], sim_ns=60_000.0,
               sanitize=False)
    ) is None


@pytest.mark.slow
def test_sanitizer_overhead_is_bounded():
    wl = [bw_test("ddr", OpClass.LOAD, 16), bw_test("cxl", OpClass.LOAD, 16)]
    horizon = 500_000.0
    t0 = time.perf_counter()
    TieredMemorySim(P, wl, seed=0).run(horizon)
    plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    TieredMemorySim(P, wl, seed=0, sanitize=True).run(horizon)
    checked = time.perf_counter() - t0
    # Measured ~1.12x; 1.5x leaves headroom for noisy CI machines.
    assert checked < plain * 1.5 + 0.05


# -- transfer queue -----------------------------------------------------------


def test_transfer_queue_sanitized_clean_run():
    q = TransferQueue(sanitize=True)
    q.submit_slow_stream(1 << 20, 4)
    q.advance(10_000_000.0)
    assert q._san.summary()["violations"] == []
    assert q._san.summary()["submitted"] == {"slow": 4}


def test_transfer_queue_lost_transfer_trips_link_conservation():
    q = TransferQueue(sanitize=True)
    q.submit_slow_stream(1 << 20, 4)
    q._inflight.pop()  # a transfer vanishes without completing
    with pytest.raises(InvariantViolation) as ei:
        q.advance(10_000_000.0)
    assert ei.value.check == "link-conservation"
    assert ei.value.station == "slow"


def test_queue_sanitizer_counter_delta_hook():
    san = QueueSanitizer(mode="record")
    bad = SimpleNamespace(inserts=0, occupancy_time=-2.0)
    san.check_counter_deltas(("fast", "slow"), (bad,))
    assert [v.check for v in san.violations] == ["counter-delta"]


# -- scenario registry / harness surface --------------------------------------


def test_unknown_scenario_suggests_near_misses():
    with pytest.raises(UnknownScenarioError) as ei:
        get("fabric_spine_congstion")
    err = ei.value
    assert isinstance(err, KeyError)
    assert "fabric_spine_congestion" in err.suggestions
    assert "did you mean" in str(err)
    # Gibberish still lists the registry, without bogus suggestions.
    with pytest.raises(UnknownScenarioError) as ei:
        get("zzzzzz")
    assert ei.value.suggestions == []
    assert "registered scenarios:" in str(ei.value)


def test_run_py_unknown_scenario_exits_2():
    proc = subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "run.py"),
         "--scenario", "fabric_spine_congstion"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert proc.returncode == 2
    assert "unknown scenario" in proc.stderr
    assert "fabric_spine_congestion" in proc.stderr


# -- every scenario stays clean one-cell under the sanitizer ------------------

_HEAVY = {"fig2_tiering", "fig10_miku", "fig11_llm"}


@pytest.mark.parametrize(
    "name",
    [pytest.param(sc.name,
                  marks=[pytest.mark.slow] if sc.name in _HEAVY else [])
     for sc in all_scenarios()],
)
def test_scenario_one_cell_sanitized(name, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sc = get(name)
    overrides = {a.name: a.default[0] for a in sc.axes if a.is_grid}
    if any(a.name == "sim_ns" for a in sc.axes):
        overrides["sim_ns"] = 60_000.0
    table = run_scenario(sc, overrides)
    assert table.rows  # a clean sanitized run produced its result table

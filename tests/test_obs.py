"""Observability-layer tests (repro.obs: tracing, histograms, metrics).

Six contracts:

1. **Histogram algebra** — the log16 bucket layout partitions the positive
   reals; merge over any window/cell split is *exact* (bucket-for-bucket
   equal to bucketing the concatenated samples); percentiles land within
   the documented 1/16 bucket relative error of the order statistics.
2. **Linear-interpolated percentiles** — ``linear_percentile`` (and
   ``WorkloadStats.percentile_ns`` on top of it) matches hand-computed
   order-statistic interpolation on pinned inputs.
3. **Tracing-off bit-identity** — enabling tracing + histograms +
   profiling changes *nothing* about the simulation outcome: bandwidth,
   latency sums, completion counts and ToR inserts are equal bit for bit
   (the sampler draws no random numbers).
4. **Span-chain physics** — every traced request's spans contiguously
   partition ``[t_tor, t_retire]`` (monotone, non-overlapping,
   non-negative), so queue + service + stall + flight exactly equals the
   ToR residency; fabric requests show the hop-port stations.
5. **Golden Perfetto export** — the canonical spine co-run's sampled trace
   reproduces the pinned Chrome trace-event JSON
   (``tests/data/spine_perfetto_golden.json``; set ``REPRO_REGEN=1`` to
   re-record after an intentional change).
6. **Lane parity** — the batched exact lane's histogram equals the scalar
   DES's exactly; the fluid lane's analytic synthesis lands within the
   documented tolerance; traced jobs fall back to the scalar DES.
"""

import dataclasses
import json
import math
import os

import pytest

from repro.core.des import WorkloadStats, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass, linear_percentile
from repro.memsim.sweep import SimJob, run_job, run_sweep
from repro.memsim.workloads import bw_test
from repro.obs import (
    LatencyHistogram,
    MetricsRegistry,
    PhaseProfiler,
    RequestTracer,
    TraceConfig,
    TransferTracer,
    default_registry,
    to_chrome,
)
from repro.obs.histogram import bucket_bounds, bucket_index, merge_all

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "spine_perfetto_golden.json")

#: Max relative error of a log16 bucket (docs/observability.md): 1/16
#: between bucket edges, plus interpolation slack inside the bucket.
BUCKET_TOL = 1.0 / 16.0 + 0.01


# -- 1. histogram algebra -----------------------------------------------------


def _samples(n: int = 400) -> list:
    # Deterministic, spread over ~4 decades (LCG — no random module).
    xs, state = [], 12345
    for _ in range(n):
        state = (state * 1103515245 + 12345) % (1 << 31)
        xs.append(50.0 + (state % 1_000_000) / 37.0)
    return xs


def test_bucket_layout_partitions():
    for v in (1e-3, 0.5, 1.0, 17.3, 291.0, 1e6, 3.7e9):
        idx = bucket_index(v)
        lo, hi = bucket_bounds(idx)
        assert lo <= v < hi, (v, lo, hi)
        # Adjacent buckets tile: this bucket's hi is the next one's lo.
        assert bucket_bounds(idx + 1)[0] == hi
        # Relative bucket width is 1/(16+s) <= 1/16 (6.25% max error).
        assert (hi - lo) / lo <= 1.0 / 16.0 + 1e-12


def test_histogram_percentiles_within_bucket_error():
    xs = _samples()
    h = LatencyHistogram.from_samples(xs)
    assert h.n == len(xs)
    s = sorted(xs)
    for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
        exact = linear_percentile(s, q)
        assert h.percentile(q) == pytest.approx(exact, rel=BUCKET_TOL)
    assert h.mean() == pytest.approx(sum(xs) / len(xs), rel=BUCKET_TOL)
    # min/max are tracked exactly, and percentiles clamp to them.
    assert h.percentile(0.0) == min(xs)
    assert h.percentile(1.0) == max(xs)


def test_histogram_merge_is_exact():
    xs = _samples(600)
    whole = LatencyHistogram.from_samples(xs)
    parts = [
        LatencyHistogram.from_samples(xs[i::4]) for i in range(4)
    ]
    merged = merge_all(parts)
    # Exact merge: same bucket counts, n, zero count, min and max — not
    # "approximately equal", *equal* (the acceptance contract).
    assert merged == whole
    # Pairwise merge agrees too, in any order.
    alt = parts[3].merge(parts[1]).merge(parts[0]).merge(parts[2])
    assert alt == whole
    assert merge_all([]) == LatencyHistogram()


def test_histogram_from_samples_numpy_parity():
    # The >=512-sample numpy fast path must bucket identically to the
    # scalar loop.
    xs = _samples(700)
    fast = LatencyHistogram.from_samples(xs)
    slow = LatencyHistogram()
    for v in xs:
        slow.record(v)
    assert fast == slow


def test_histogram_weighted_and_zero():
    h = LatencyHistogram()
    h.record_weighted(100.0, 3.0)
    h.record_weighted(100.0, 0.0)  # ignored
    h.record_weighted(-5.0, 2.0)  # zero bucket
    g = LatencyHistogram()
    for _ in range(3):
        g.record(100.0)
    g.record(-5.0)
    g.record(-5.0)
    assert h.n == 5 and h.zero == 2
    assert h.counts == g.counts
    # Rank 0 lands in the zero bucket: reports min(0, vmin).
    assert h.percentile(0.0) == -5.0


def test_histogram_jsonable_roundtrip():
    h = LatencyHistogram.from_samples(_samples(300))
    h.record_weighted(0.0, 2.0)
    blob = json.loads(json.dumps(h.to_jsonable()))
    assert blob["scheme"] == "log16"
    back = LatencyHistogram.from_jsonable(blob)
    assert back == h
    for q in (0.5, 0.99):
        assert back.percentile(q) == h.percentile(q)


# -- 2. linear-interpolated percentiles ---------------------------------------


def test_linear_percentile_pins():
    xs = [10.0, 20.0, 30.0, 40.0]
    assert linear_percentile(xs, 0.5) == 25.0
    assert linear_percentile(xs, 0.25) == 17.5
    assert linear_percentile(xs, 0.0) == 10.0
    assert linear_percentile(xs, 1.0) == 40.0
    assert linear_percentile([7.0], 0.9) == 7.0
    assert linear_percentile([], 0.5) == 0.0
    # Out-of-range q clamps.
    assert linear_percentile(xs, -1.0) == 10.0
    assert linear_percentile(xs, 2.0) == 40.0


def test_workload_stats_percentile_interpolates():
    st = WorkloadStats()
    st.latency_samples = [40.0, 10.0, 30.0, 20.0]  # unsorted on purpose
    assert st.percentile_ns(0.5) == 25.0
    assert st.percentile_ns(0.75) == 32.5
    # Zero completions → NaN, not a fake 0 ns latency: a NaN p99 can never
    # satisfy an SLO budget comparison (see WorkloadStats.percentile_ns).
    assert math.isnan(WorkloadStats().percentile_ns(0.5))


# -- 3. tracing-off bit-identity ----------------------------------------------


def _corun_job(**over) -> SimJob:
    p = platform_a()
    wls = [
        bw_test("ddr", OpClass.LOAD, 16, name="ddr", miku_managed=False),
        bw_test("cxl", OpClass.LOAD, 16, name="cxl"),
    ]
    return SimJob(platform=p, workloads=wls, sim_ns=150_000.0, miku=True,
                  **over)


@pytest.fixture(scope="module")
def corun_pair():
    plain = run_job(_corun_job())
    instr = run_job(
        dataclasses.replace(
            _corun_job(), trace=16, latency_hist=True, profile=True,
            record_windows=True,
        )
    )
    return plain, instr


def test_observability_is_bit_identical(corun_pair):
    plain, instr = corun_pair
    for w in ("ddr", "cxl"):
        assert instr.stats[w].bytes == plain.stats[w].bytes
        assert instr.stats[w].completed == plain.stats[w].completed
        assert instr.stats[w].latency_sum == plain.stats[w].latency_sum
        assert instr.stats[w].latency_samples == plain.stats[w].latency_samples
    assert instr.tor_inserts == plain.tor_inserts
    assert instr.tor_peak == plain.tor_peak
    assert [repr(d) for d in instr.decisions] == \
        [repr(d) for d in plain.decisions]
    # The plain run carries no observability payloads at all.
    assert plain.trace is None and plain.profile is None
    assert plain.stats["ddr"].latency_hist is None
    assert instr.trace is not None and instr.profile is not None


def test_histogram_tracks_reservoir(corun_pair):
    _, instr = corun_pair
    for w in ("ddr", "cxl"):
        st = instr.stats[w]
        h = st.latency_hist
        assert h is not None and h.n == st.latency_count
        for q in (0.5, 0.99):
            assert h.percentile(q) == pytest.approx(
                st.percentile_ns(q), rel=BUCKET_TOL
            )
    # Per-tier histograms cover every completion.
    tier_n = sum(h.n for h in instr.tier_latency_hist.values())
    assert tier_n == sum(s.latency_count for s in instr.stats.values())


def test_window_histograms_merge_to_full(corun_pair):
    _, instr = corun_pair
    per_window = {}
    for rec in instr.window_records:
        for w, blob in rec.get("latency_hist", {}).items():
            per_window.setdefault(w, []).append(
                LatencyHistogram.from_jsonable(blob)
            )
    for w in ("ddr", "cxl"):
        merged = merge_all(per_window[w])
        # Exact cross-window merge: equal to the full-run histogram
        # bucket for bucket (windows slice the same sample stream).
        assert merged == instr.stats[w].latency_hist


def test_phase_profile_shape(corun_pair):
    _, instr = corun_pair
    phases = instr.profile["phases"]
    assert {"setup", "event_loop", "window_pass"} <= set(phases)
    assert phases["event_loop"]["seconds"] > 0
    assert phases["window_pass"]["calls"] == len(
        [r for r in instr.window_records]
    )


# -- 4. span-chain physics ----------------------------------------------------


def _check_span_conservation(rec, tol=1e-6):
    assert rec["t_issue"] <= rec["t_tor"] <= rec["t_retire"]
    spans = rec["spans"]
    assert spans, rec
    t = rec["t_issue"] if spans[0]["kind"] == "irq" else rec["t_tor"]
    for sp in spans:
        # Contiguous partition: each span starts where the last ended.
        assert sp["t0"] == pytest.approx(t, abs=tol), (sp, t)
        assert sp["t1"] >= sp["t0"]
        t = sp["t1"]
    assert t == pytest.approx(rec["t_retire"], abs=tol)
    # Conservation: queue + service + stall + flight == ToR residency.
    tor = sum(sp["t1"] - sp["t0"] for sp in spans if sp["kind"] != "irq")
    assert tor == pytest.approx(rec["t_retire"] - rec["t_tor"], abs=tol)


def test_trace_spans_conserve(corun_pair):
    _, instr = corun_pair
    payload = instr.trace
    assert 0 < payload["n_traced"] <= payload["limit"]
    assert payload["sample_every"] == 16
    kinds = set()
    for rec in payload["requests"]:
        _check_span_conservation(rec)
        kinds.update(sp["kind"] for sp in rec["spans"])
    assert {"service", "flight"} <= kinds


@pytest.fixture(scope="module")
def spine_trace():
    from repro.scenarios import get

    sc = get("fabric_spine_congestion")
    cell = {
        "op": OpClass.LOAD, "law": "peredge", "n_threads": 16,
        "spine_slots": 8, "spine_service_ns": 36.0, "sim_ns": 120_000.0,
    }
    corun = sc.build(None, cell)[2]
    job = dataclasses.replace(
        corun, trace=TraceConfig(sample_every=997, limit=64)
    )
    return run_job(job).trace


def test_fabric_spans_show_hop_ports(spine_trace):
    stations = set()
    for rec in spine_trace["requests"]:
        _check_span_conservation(rec)
        stations.update(
            sp["station"] for sp in rec["spans"]
            if sp["kind"] in ("queue", "service", "stall")
        )
    # Hop-port stations (uplinks + the shared spine downlink) appear in
    # the span chains, not just the terminal device.
    assert any("uplink" in s or "spine" in s for s in stations), stations


def test_trace_is_deterministic(spine_trace):
    from repro.scenarios import get

    sc = get("fabric_spine_congestion")
    cell = {
        "op": OpClass.LOAD, "law": "peredge", "n_threads": 16,
        "spine_slots": 8, "spine_service_ns": 36.0, "sim_ns": 120_000.0,
    }
    corun = sc.build(None, cell)[2]
    again = run_job(dataclasses.replace(
        corun, trace=TraceConfig(sample_every=997, limit=64)
    )).trace
    assert again == spine_trace


# -- 5. golden Perfetto export ------------------------------------------------


def test_perfetto_golden(spine_trace):
    doc = to_chrome(spine_trace["requests"])
    if os.environ.get("REPRO_REGEN"):
        with open(GOLDEN, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert doc == golden, (
        "spine Perfetto trace drifted from tests/data/"
        "spine_perfetto_golden.json; if intentional, re-record with "
        "REPRO_REGEN=1 pytest tests/test_obs.py::test_perfetto_golden"
    )


def test_chrome_export_schema(spine_trace):
    doc = to_chrome(spine_trace["requests"])
    assert doc["displayTimeUnit"] == "ns"
    evs = doc["traceEvents"]
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
    procs = {e["args"]["name"] for e in evs if e["name"] == "process_name"}
    assert procs == {r["workload"] for r in spine_trace["requests"]}


# -- 6. lane parity -----------------------------------------------------------


def test_exact_lane_histogram_equals_scalar():
    p = platform_a()
    job = SimJob(
        platform=p,
        workloads=[bw_test("cxl", OpClass.LOAD, 16, name="bw")],
        sim_ns=100_000.0, latency_hist=True,
    )
    (batched,) = run_sweep([job], lane="batched")
    (scalar,) = run_sweep([job], lane="scalar")
    # The exact lane buckets the full (bit-identical) latency vector, so
    # its histogram equals the scalar DES's exactly.
    assert batched.stats["bw"].latency_hist == scalar.stats["bw"].latency_hist
    assert batched.tier_latency_hist["cxl"] == scalar.tier_latency_hist["cxl"]
    assert batched.tier_latency_hist["ddr"].n == 0


def test_fluid_lane_histogram_tolerance():
    job = dataclasses.replace(_corun_job(), latency_hist=True)
    (batched,) = run_sweep([job], lane="batched")
    (scalar,) = run_sweep([job], lane="scalar")
    for w in ("ddr", "cxl"):
        hb, hs = batched.stats[w].latency_hist, scalar.stats[w].latency_hist
        assert hb is not None
        # Analytic synthesis from station waits: means track closely,
        # counts within the fluid lane's flow approximation.
        assert hb.mean() == pytest.approx(hs.mean(), rel=0.10)
        assert hb.n == pytest.approx(hs.n, rel=0.05)


def test_traced_jobs_fall_back_to_scalar():
    from repro.memsim.batched.lane import can_batch

    assert can_batch(dataclasses.replace(_corun_job(), trace=16)) == "trace"
    assert can_batch(dataclasses.replace(_corun_job(), latency_hist=True)) \
        is None


# -- transfer-queue tracing & metrics -----------------------------------------


def test_transfer_queue_trace_records():
    from repro.core.offload import TransferQueue

    q = TransferQueue(trace=1)
    q.submit_slow_stream(8 << 20, 8, OpClass.LOAD)
    q.advance(5e6)
    recs = q.trace_records
    assert len(recs) == 8
    for rec in recs:
        _check_span_conservation(rec)
        assert rec["workload"] == "offload:slow"
    # Sampling: every 4th chunk only.
    q4 = TransferQueue(trace=4)
    q4.submit_slow_stream(8 << 20, 8, OpClass.LOAD)
    assert len(q4.trace_records) == 2
    # to_chrome renders transfer records alongside DES ones.
    doc = to_chrome(recs)
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


def test_transfer_tracer_respects_limit():
    tr = TransferTracer(sample_every=1, limit=3)
    for i in range(10):
        tr.on_chunk("slow", float(i), float(i + 2), 1.0)
    assert len(tr.records) == 3 and tr.count == 10


def test_metrics_registry():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2.0)
    reg.gauge("g").set(7.5)
    reg.histogram("h").record(100.0)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.0
    assert snap["gauges"]["g"] == 7.5
    assert snap["histograms"]["h"]["n"] == 1
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert default_registry() is default_registry()


def test_des_registers_metrics():
    reg = default_registry()
    before = reg.snapshot()["counters"].get("des.runs", 0.0)
    run_corun(platform_a(), op=OpClass.LOAD, n_threads=4, sim_ns=20_000)
    after = reg.snapshot()["counters"]
    assert after["des.runs"] == before + 1.0
    assert after["des.requests"] > 0


def test_phase_profiler():
    prof = PhaseProfiler()
    with prof.phase("work"):
        math.sqrt(2.0)
    with prof.phase("work"):
        pass
    snap = prof.snapshot()
    assert snap["phases"]["work"]["calls"] == 2
    assert snap["phases"]["work"]["seconds"] >= 0.0


def test_tracer_config_validation():
    with pytest.raises(ValueError):
        TraceConfig(sample_every=0)
    with pytest.raises(ValueError):
        TraceConfig(limit=0)
    with pytest.raises(ValueError):
        TransferTracer(sample_every=0)
    tracer = RequestTracer(TraceConfig(limit=1), ["w"], ["st"], ["t"])
    tracer.admit(1, 0, 0, 0.0, 1.0)
    tracer.admit(2, 0, 0, 0.0, 1.0)  # over the limit: dropped
    tracer.retire(1, 5.0)
    assert len(tracer.done) == 1 and tracer.dropped == 1


# -- planner + CLI integration ------------------------------------------------


def test_planner_perfetto_collects_traces():
    from repro.scenarios import run_scenario

    table = run_scenario(
        "fig4_latency",
        {"platform": "A", "tier": ("cxl",), "threads": (4,)},
        perfetto=True,
    )
    assert table.request_traces is not None
    payload = table.request_traces[0]["jobs"][0]["trace"]
    assert payload["n_traced"] > 0
    # request_traces never leak into the JSON document.
    assert "request_traces" not in table.to_json()


def test_planner_perfetto_rejects_run_cell():
    from repro.scenarios import run_scenario

    with pytest.raises(ValueError, match="run_cell"):
        run_scenario("fig2_tiering", perfetto=True)


def test_fig4_reports_p95():
    from repro.scenarios import run_scenario

    table = run_scenario(
        "fig4_latency", {"platform": "A", "tier": ("ddr",), "threads": (2,)}
    )
    (row,) = table.rows
    assert row["p50_ns"] <= row["p95_ns"] * (1 + BUCKET_TOL)
    assert row["p95_ns"] <= row["p99_ns"] * (1 + BUCKET_TOL)
    assert row["p95_ns"] > 0

"""Property test: the batched lane is *total* and order-preserving.

For an arbitrary mixed job list — exact fast-path cells, fluid co-run
cells, and jobs that dynamically fall back to the scalar DES (a tiering
policy outside the vectorizable registry) — ``run_sweep_batched`` must:

* return one result per job, in job order;
* reproduce the scalar DES bit-for-bit on exact-regime cells;
* reproduce the scalar DES bit-for-bit on fallback cells (they *are*
  scalar reruns), and record the fallback with its reason;
* stay within the pinned fluid tolerance on co-run cells.

Runs as a hypothesis property when hypothesis is installed; the container
image does not ship it, so the same property is also exercised over a
fixed spread of kind-sequences and rng seeds.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.batched import partition_jobs
from repro.memsim.batched.exact import exact_regime
from repro.memsim.batched.lane import run_sweep_batched
from repro.memsim.sweep import SimJob, run_sweep
from repro.memsim.workloads import bw_test
from repro.tiering import HotSetPattern, RegionSpec, TieringSpec
from repro.tiering.policies import POLICIES

_OPS = tuple(OpClass)
_SIM_NS = 100_000.0
_KINDS = ("exact", "fluid", "fallback")


class _FrozenPolicy:  # deliberately outside the vectorizable hierarchy
    name = "frozen_property_policy"

    def decide(self, pagemap, ctx):
        del pagemap, ctx
        return []


@pytest.fixture
def frozen_policy():
    POLICIES[_FrozenPolicy.name] = _FrozenPolicy
    try:
        yield _FrozenPolicy.name
    finally:
        POLICIES.pop(_FrozenPolicy.name, None)


def _mk_job(kind: str, i: int, rng, platform, frozen: str) -> SimJob:
    name = f"x{i}"
    if kind == "exact":
        op = _OPS[int(rng.integers(0, 3))]
        tier = ("ddr", "cxl")[int(rng.integers(0, 2))]
        return SimJob(platform=platform,
                      workloads=[bw_test(tier, op, 16, name=name)],
                      sim_ns=_SIM_NS)
    if kind == "fluid":
        op = _OPS[int(rng.integers(0, 3))]
        wls = [bw_test("ddr", op, int(rng.integers(8, 17)), name=name,
                       miku_managed=False),
               bw_test("cxl", op, int(rng.integers(8, 17)), name=name + "s")]
        return SimJob(platform=platform, workloads=wls, sim_ns=_SIM_NS,
                      miku=bool(rng.integers(0, 2)))
    spec = TieringSpec(
        regions=(RegionSpec(workload=name, n_pages=128,
                            placement={"cxl": 1.0},
                            pattern=HotSetPattern()),),
        policy=frozen,
    )
    return SimJob(platform=platform,
                  workloads=[bw_test("cxl", OpClass.LOAD, 4, name=name)],
                  sim_ns=_SIM_NS, tiering=spec)


def _check_mixed_list(kinds, seed: int, frozen: str) -> None:
    platform = platform_a()
    rng = np.random.default_rng(seed)
    jobs = [_mk_job(k, i, rng, platform, frozen)
            for i, k in enumerate(kinds)]
    plans, fallbacks = partition_jobs(jobs)
    assert not fallbacks  # every job passes the static screen
    batched = run_sweep_batched(jobs, partition=(plans, fallbacks))
    scalar = run_sweep(jobs)
    assert len(batched) == len(jobs)

    fell_back = dict(fallbacks)  # filled dynamically during the run
    assert sorted(fell_back) == [i for i, k in enumerate(kinds)
                                 if k == "fallback"]
    for i, (job, kind, s, b) in enumerate(zip(jobs, kinds, scalar, batched)):
        name = job.workloads[0].name
        assert name in b.stats, (i, kind)  # results stay in job order
        if kind == "exact":
            assert exact_regime(plans[i]) in ("noqueue", "saturated")
            assert b.stats[name].bytes == s.stats[name].bytes
            assert b.bandwidth(name) == s.bandwidth(name)
        elif kind == "fallback":
            assert _FrozenPolicy.name in fell_back[i]
            assert b.bandwidth(name) == s.bandwidth(name)  # scalar rerun
            assert b.tiering == s.tiering
        else:
            assert b.bandwidth(name) == pytest.approx(
                s.bandwidth(name), rel=0.12), (i, kind)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    _CASES = [
        (("exact",), 11),
        (("fluid",), 12),
        (("fallback",), 13),
        (("exact", "fluid", "fallback"), 14),
        (("fallback", "exact", "exact", "fluid"), 15),
        (("fluid", "fallback", "fluid", "exact", "fallback"), 16),
        (("exact", "exact", "fluid", "fluid", "fallback", "exact"), 17),
    ]

    @pytest.mark.parametrize("kinds,seed", _CASES)
    def test_mixed_job_lists_property(kinds, seed, frozen_policy):
        _check_mixed_list(list(kinds), seed, frozen_policy)
else:
    @given(kinds=st.lists(st.sampled_from(_KINDS), min_size=1, max_size=6),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=12, deadline=None)
    def test_mixed_job_lists_property(kinds, seed, frozen_policy):
        _check_mixed_list(kinds, seed, frozen_policy)
